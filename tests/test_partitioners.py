"""Partitioner correctness: balance invariants, refinement semantics,
quality ordering (CUTTANA >= FENNEL), ablations."""
import numpy as np
import pytest

from repro.core import PARTITIONERS, get_partitioner, refine_any
from repro.core.cuttana import partition as cuttana_partition
from repro.core.hdrf import partition_ginger, partition_hdrf
from repro.core.refinement import Refiner, build_subpartition_graph
from repro.graph import (
    CSRGraph,
    edge_cut,
    ldbc_like_graph,
    powerlaw_cluster_graph,
    quality_report,
    rmat_graph,
    road_graph,
)
from repro.graph.metrics import partition_edge_counts, partition_vertex_counts


@pytest.fixture(scope="module")
def small_social():
    return rmat_graph(2000, avg_degree=12, seed=1)


@pytest.fixture(scope="module")
def small_web():
    return powerlaw_cluster_graph(2000, avg_degree=10, seed=2)


ALL_VERTEX_PARTITIONERS = sorted(PARTITIONERS)


@pytest.mark.parametrize("name", ALL_VERTEX_PARTITIONERS)
def test_partition_is_total_and_in_range(small_social, name):
    k = 4
    part = get_partitioner(name)(small_social, k, seed=0)
    assert part.shape == (small_social.num_vertices,)
    assert part.min() >= 0 and part.max() < k


@pytest.mark.parametrize("name", ["fennel", "ldg", "cuttana", "heistream"])
@pytest.mark.parametrize("balance_mode", ["vertex", "edge"])
def test_balance_condition_holds(small_social, name, balance_mode):
    k, eps = 4, 0.05
    part = get_partitioner(name)(
        small_social, k, epsilon=eps, balance_mode=balance_mode, seed=0
    )
    if balance_mode == "vertex":
        counts = partition_vertex_counts(part, k)
        cap = (1 + eps) * small_social.num_vertices / k
    else:
        counts = partition_edge_counts(small_social, part, k)
        cap = (1 + eps) * small_social.indices.shape[0] / k
    assert counts.max() <= cap + 1e-6, f"{name} violates {balance_mode} balance"


def test_cuttana_beats_fennel_edge_cut(small_social, small_web):
    """Paper Table II: CUTTANA <= FENNEL on edge-cut. We test under random
    stream order (the representative case; the paper's §IV-A concedes that
    an order-ideal stream can favour non-buffered placement, its US-Roads
    observation)."""
    k = 8
    for g in (small_social, small_web):
        fennel_part = get_partitioner("fennel")(
            g, k, balance_mode="edge", order="random", seed=0
        )
        cut_f = edge_cut(g, fennel_part)
        cut_c = edge_cut(
            g, cuttana_partition(g, k, balance_mode="edge", order="random", seed=0)
        )
        assert cut_c <= cut_f + 1e-9, f"CUTTANA ({cut_c}) worse than FENNEL ({cut_f})"


def test_ablation_ordering(small_web):
    """Table III: full <= w/o refine <= w/o both (fennel) in edge-cut,
    with small tolerance since these are heuristics."""
    k = 8
    full = edge_cut(small_web, cuttana_partition(small_web, k, seed=0))
    no_refine = edge_cut(
        small_web, cuttana_partition(small_web, k, use_refinement=False, seed=0)
    )
    neither = edge_cut(
        small_web,
        cuttana_partition(
            small_web, k, use_refinement=False, use_buffer=False, seed=0
        ),
    )
    assert full <= no_refine + 1e-9
    # buffering should not catastrophically hurt vs plain streaming
    assert no_refine <= neither * 1.2 + 1e-9


def test_refinement_monotone_and_maximal():
    """Refinement strictly decreases coarse cut and reaches maximality."""
    rng = np.random.default_rng(0)
    kp, k = 32, 4
    w = rng.random((kp, kp))
    w = np.triu(w, 1)
    w = w + w.T
    w[w < 0.5] = 0.0
    sub_part = rng.integers(0, k, size=kp)
    size = np.ones(kp)
    r = Refiner(w, sub_part, size, k, epsilon=0.5)
    cut_before = r.current_cut()
    stats = r.refine(thresh=0.0)
    cut_after = r.current_cut()
    assert cut_after <= cut_before
    assert abs((cut_before - cut_after) - stats.cut_improvement) < 1e-6
    r.check_invariants()
    # maximality: no single feasible move improves the cut
    assert r.best_move(0.0) is None
    for i in range(kp):
        src = int(r.sub_part[i])
        for dst in range(k):
            if dst == src:
                continue
            if r.part_load[dst] + r.size[i] > r.cap + 1e-9:
                continue
            dec = r.m[i, dst] - r.m[i, src]
            assert dec <= 1e-9, f"missed trade <{i},{dst}> dec={dec}"


def test_refinement_respects_balance():
    rng = np.random.default_rng(3)
    kp, k = 64, 4
    w = rng.random((kp, kp)) * (rng.random((kp, kp)) < 0.3)
    w = np.triu(w, 1)
    w = w + w.T
    sub_part = rng.integers(0, k, size=kp)
    size = rng.random(kp) + 0.5
    eps = 0.3
    total = float(size.sum())
    r = Refiner(w, sub_part, size, k, epsilon=eps, total_mass=total)
    # note: random initial assignment may violate balance; refinement must
    # never move INTO a partition beyond cap
    cap = (1 + eps) * total / k
    before = np.bincount(r.sub_part, weights=size, minlength=k)
    r.refine()
    after = np.bincount(r.sub_part, weights=size, minlength=k)
    for p in range(k):
        if after[p] > cap + 1e-9:
            assert after[p] <= before[p] + 1e-9, "grew an over-capacity partition"


def test_refine_any_improves_random_partition(small_web):
    k = 8
    rng = np.random.default_rng(0)
    part = rng.integers(0, k, size=small_web.num_vertices).astype(np.int32)
    cut0 = edge_cut(small_web, part)
    refined = refine_any(small_web, part, k, epsilon=0.1, balance_mode="edge")
    cut1 = edge_cut(small_web, refined)
    assert cut1 < cut0


def test_hdrf_replication_and_balance(small_social):
    k = 8
    ep = partition_hdrf(small_social, k, seed=0)
    assert ep.edge_part.shape == (small_social.num_edges,)
    assert ep.replication_factor >= 1.0
    assert ep.edge_imbalance() < 1.5
    gp = partition_ginger(small_social, k, seed=0)
    assert gp.replication_factor >= 1.0


def test_order_robustness_of_cuttana(small_web):
    """Buffering should make CUTTANA robust to stream order (paper §IV-A)."""
    k = 8
    cuts = [
        edge_cut(small_web, cuttana_partition(small_web, k, order=o, seed=0))
        for o in ("natural", "random")
    ]
    assert max(cuts) < 3.0 * min(cuts) + 1e-9


def test_road_graph_quality_sanity():
    g = road_graph(4000, seed=0)
    part = cuttana_partition(g, 4, balance_mode="edge", seed=0)
    rep = quality_report(g, part, 4)
    # a lattice should partition with low cut
    assert rep["edge_cut"] < 0.25
    assert rep["edge_imbalance"] < 1.3


def test_ldbc_like_generator_and_cuttana():
    g = ldbc_like_graph(3000, avg_degree=12, seed=0)
    part = cuttana_partition(g, 4, seed=0)
    rep = quality_report(g, part, 4)
    assert rep["edge_cut"] < 1.0 and rep["comm_volume"] <= 1.0


def test_empty_and_tiny_graphs():
    g = CSRGraph.from_edges(np.array([[0, 1], [1, 2]]), num_vertices=5)
    for name in ("fennel", "cuttana", "ldg"):
        part = get_partitioner(name)(g, 2, epsilon=0.5, seed=0)
        assert part.shape == (5,)


def test_batched_variant_quality(small_social):
    """Kernel-backed chunk-parallel variant stays within 10% of sequential
    CUTTANA's edge-cut (the bulk-synchronous relaxation's cost bound)."""
    from repro.core.cuttana_batched import partition_batched

    k = 8
    seq = edge_cut(small_social, cuttana_partition(small_social, k, seed=0))
    bat = edge_cut(small_social, partition_batched(small_social, k, seed=0))
    assert bat <= seq * 1.10 + 0.02
