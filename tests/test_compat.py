"""Guards for the repro.compat version-shim surface: the running jax must be
inside the declared support range, and the shims must actually provide a
working ambient-mesh context and shard_map on it."""
import re
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def test_jax_version_tuple():
    assert isinstance(compat.JAX_VERSION, tuple)
    assert len(compat.JAX_VERSION) == 3
    assert all(isinstance(p, int) for p in compat.JAX_VERSION)
    assert compat.JAX_VERSION == compat._parse_version(jax.__version__)


def test_running_jax_inside_declared_range():
    assert compat.JAX_VERSION >= compat.MIN_JAX_VERSION, (
        f"jax {jax.__version__} is older than the supported minimum "
        f"{'.'.join(map(str, compat.MIN_JAX_VERSION))}"
    )


def test_jax_at_least():
    assert compat.jax_at_least(0, 4)
    assert compat.jax_at_least(*compat.MIN_JAX_VERSION)
    assert not compat.jax_at_least(99, 0)


def test_pyproject_declares_the_same_floor():
    """pyproject's jax pin and compat.MIN_JAX_VERSION must not drift apart."""
    text = Path(__file__).resolve().parent.parent.joinpath("pyproject.toml").read_text()
    m = re.search(r'"jax>=(\d+)\.(\d+)\.(\d+)', text)
    assert m, "pyproject.toml must declare a jax>=X.Y.Z lower bound"
    assert tuple(int(g) for g in m.groups()) == compat.MIN_JAX_VERSION
    assert re.search(r'"jaxlib>=', text), "jaxlib range must be declared too"


def test_use_mesh_enables_ambient_sharding():
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    with compat.use_mesh(mesh):
        x = jnp.ones((4, 4))
        y = jax.lax.with_sharding_constraint(x, P("data", None))
    np.testing.assert_array_equal(np.asarray(y), np.ones((4, 4)))


def test_compat_shard_map_runs():
    mesh = Mesh(np.array(jax.devices()[:1]), ("w",))
    f = compat.shard_map(
        lambda x: x * 2.0,
        mesh=mesh,
        in_specs=(P("w"),),
        out_specs=P("w"),
        check_vma=False,
    )
    np.testing.assert_array_equal(np.asarray(f(jnp.ones(4))), np.full(4, 2.0))


def test_parse_version_handles_dev_suffixes():
    assert compat._parse_version("0.4.37") == (0, 4, 37)
    assert compat._parse_version("0.5.0.dev20250101") == (0, 5, 0)
    assert compat._parse_version("0.6") == (0, 6, 0)
    assert compat._parse_version("0.4.37rc1") == (0, 4, 37)
