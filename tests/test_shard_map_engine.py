"""Validate the real shard_map engine path on 8 forced host devices.

Runs in a subprocess so the XLA device-count flag never leaks into the main
test process (smoke tests elsewhere must see exactly 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.analytics import GraphEngine, localize, pagerank_program, cc_program
    from repro.analytics.programs import reference_pagerank, reference_cc
    from repro.core import get_partitioner
    from repro.graph import rmat_graph

    k = 8
    g = rmat_graph(1200, avg_degree=8, seed=5)
    part = get_partitioner("cuttana")(g, k, balance_mode="edge", seed=0)
    lg = localize(g, part, k)
    mesh = Mesh(np.array(jax.devices()[:k]), ("w",))

    eng = GraphEngine(lg, pagerank_program())
    got = eng.run_sharded(mesh, iters=10)
    want = reference_pagerank(g, iters=10)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=1e-9)

    # simulated and sharded paths must agree bit-for-bit-ish
    sim = eng.run_simulated(iters=10)
    np.testing.assert_allclose(got, sim, rtol=1e-6, atol=1e-12)

    eng2 = GraphEngine(lg, cc_program())
    got2 = eng2.run_sharded(mesh, iters=25)
    want2 = reference_cc(g, iters=25)
    np.testing.assert_allclose(got2, want2)

    # the compiled HLO must contain a real all-to-all collective
    txt = eng.lower_sharded(mesh, iters=3).compile().as_text()
    assert "all-to-all" in txt, "halo exchange did not lower to all-to-all"
    print(json.dumps({"ok": True, "devices": len(jax.devices())}))
    """
)


@pytest.mark.slow
def test_shard_map_engine_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["devices"] == 8


MOE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.compat import use_mesh
    from repro.configs import get_reduced_config
    from repro.models import Axes, Model

    # capacity large enough that no token drops: capacity-drop patterns are
    # per-source-shard and legitimately differ across mesh shapes; with no
    # drops the EP all-to-all path must match the single-device math exactly.
    cfg = dataclasses.replace(
        get_reduced_config("jamba-v0.1-52b"), capacity_factor=8.0
    )

    def run(mesh_shape):
        devs = np.array(jax.devices()[: mesh_shape[0] * mesh_shape[1]])
        mesh = Mesh(devs.reshape(mesh_shape), ("data", "model"))
        model = Model(cfg, Axes(dp=("data",), tp="model"), mesh)
        with use_mesh(mesh):
            params = model.init(jax.random.key(0))
            rng = np.random.default_rng(0)
            tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
            logits, aux = model.forward(params, {"tokens": tokens})
        return np.asarray(logits, np.float32)

    a = run((1, 1))
    b = run((2, 4))   # expert-parallel over a real 4-way model axis
    # 2e-2 is this repo's bf16 rtol (see test_kernels): TP splits every
    # projection's contraction across the model axis, so partial-sum rounding
    # legitimately differs from the 1-device mesh by a few bf16 ulps. The
    # atol is one bf16 ulp at the logit dynamic range (near-zero logits see
    # the full accumulated rounding of the large terms that cancelled).
    atol = float(np.spacing(np.abs(a).max(), dtype=np.float32) * 2**16)  # ~1 bf16 ulp
    np.testing.assert_allclose(a, b, rtol=2e-2, atol=max(atol, 2e-2))
    print(json.dumps({"ok": True, "maxdiff": float(np.abs(a - b).max())}))
    """
)


@pytest.mark.slow
def test_moe_expert_parallel_parity_subprocess():
    """MoE outputs must agree between a 1-device mesh and a real 2x4 mesh
    (expert-parallel all-to-all path) within bf16 tolerance."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", MOE_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["ok"]
