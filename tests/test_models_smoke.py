"""Per-architecture smoke tests: reduced config, one forward + loss/grad step
on CPU (1-device mesh), asserting output shapes and no NaNs. Decode smoke for
causal archs. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.compat import use_mesh
from repro.configs import ALIASES, get_config, get_reduced_config, cells_for
from repro.models import Axes, Model

ARCH_IDS = list(ALIASES)


def tiny_mesh():
    return Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model")
    )


def make_inputs(cfg, batch=2, seq=16, seed=0):
    rng = np.random.default_rng(seed)
    inputs = {}
    if cfg.frontend == "frames":
        inputs["frames"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_model)), jnp.float32
        )
    else:
        inputs["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32
        )
    if cfg.n_img_tokens:
        inputs["image_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_img_tokens, cfg.d_model)) * 0.02,
            jnp.float32,
        )
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    return inputs, labels


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_counts(arch):
    cfg = get_config(arch)
    # sanity: layer count matches the assignment table
    expected_layers = {
        "deepseek-v2-236b": 60, "arctic-480b": 35, "deepseek-coder-33b": 62,
        "minitron-8b": 32, "gemma3-12b": 48, "qwen3-8b": 36,
        "hubert-xlarge": 48, "llama-3.2-vision-90b": 100,
        "falcon-mamba-7b": 64, "jamba-v0.1-52b": 32,
    }[arch]
    assert cfg.num_layers == expected_layers
    n = cfg.param_count()
    assert n > 5e8, f"{arch}: param count {n} implausibly small"
    assert cfg.active_param_count() <= n


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_reduced_config(arch)
    mesh = tiny_mesh()
    model = Model(cfg, Axes(dp=("data",), tp="model"), mesh)
    params = model.init(jax.random.key(0))
    inputs, labels = make_inputs(cfg)

    def loss_fn(p):
        logits, aux = model.forward(p, inputs)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    with use_mesh(mesh):
        logits, aux = model.forward(params, inputs)
        b, s = (2, 16)
        assert logits.shape == (b, s, cfg.vocab_size)
        assert not np.isnan(np.asarray(logits, np.float32)).any()
        loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)


@pytest.mark.parametrize(
    "arch", [a for a in ARCH_IDS if not get_config(a).is_encoder_only]
)
def test_smoke_decode(arch):
    cfg = get_reduced_config(arch)
    mesh = tiny_mesh()
    model = Model(cfg, Axes(dp=("data",), tp="model"), mesh)
    params = model.init(jax.random.key(0))
    batch, cache_len = 2, 32
    cache = model.init_cache(batch, cache_len)
    if cfg.n_img_tokens:
        rng = np.random.default_rng(0)
        img = jnp.asarray(
            rng.standard_normal((batch, cfg.n_img_tokens, cfg.d_model)) * 0.02,
            jnp.float32,
        )
        # prefill image K/V into the cross-attn caches
        cache = _prefill_image_cache(model, params, cache, img)
    tok = jnp.zeros((batch, 1), jnp.int32)
    with use_mesh(mesh):
        logits, cache2 = model.decode_step(params, cache, tok, jnp.int32(0))
        logits2, _ = model.decode_step(params, cache2, tok, jnp.int32(1))
    assert logits.shape == (batch, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert not np.isnan(np.asarray(logits2, np.float32)).any()


def _prefill_image_cache(model, params, cache, img):
    cfg = model.cfg
    dh = cfg.head_dim
    hkv = cfg.n_kv_heads

    def fill(spec_list, param_list, cache_list):
        out = []
        for spec, p, c in zip(spec_list, param_list, cache_list):
            if spec.mixer == "cross_attn":
                k = (img @ p["attn"]["wk"]).reshape(img.shape[0], -1, hkv, dh)
                v = (img @ p["attn"]["wv"]).reshape(img.shape[0], -1, hkv, dh)
                c = dict(c, k_img=k.astype(c["k_img"].dtype),
                         v_img=v.astype(c["v_img"].dtype))
            out.append(c)
        return tuple(out)

    new_prefix = fill(cfg.prefix, params["prefix"], cache["prefix"])
    # blocks: vmap the fill across the stacked leading axis
    def fill_blocks(bp, bc):
        return fill(cfg.block, bp, bc)

    new_blocks = jax.vmap(fill_blocks)(params["blocks"], cache["blocks"])
    return {"prefix": new_prefix, "blocks": new_blocks}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cells_skip_rules(arch):
    cells = cells_for(arch)
    cfg = get_config(arch)
    if arch == "hubert-xlarge":
        assert "skip" in cells["decode_32k"] and "skip" in cells["long_500k"]
    if arch in ("falcon-mamba-7b", "jamba-v0.1-52b", "gemma3-12b"):
        assert cells["long_500k"] == "run"
    if arch in ("deepseek-coder-33b", "qwen3-8b", "minitron-8b",
                "deepseek-v2-236b", "arctic-480b", "llama-3.2-vision-90b"):
        assert "skip" in cells["long_500k"]
    assert cells["train_4k"] == "run"
