"""StreamEngine parity vs the seed per-vertex loops (repro.core.legacy) and
unit tests for the array-backed PriorityBuffer.

The engine's exact mode must be *bit-identical* to the sequential loops:
same scores, same tie-break RNG draws, same buffer eviction order. These
tests pin that contract for every stream order and balance mode.
"""
import numpy as np
import pytest

from repro.core import PARTITIONERS, legacy
from repro.core.buffer import PriorityBuffer
from repro.core.cuttana import partition as cuttana_partition
from repro.core.cuttana_batched import partition_batched
from repro.core.fennel import partition as fennel_partition
from repro.core.heistream_like import partition as heistream_partition
from repro.core.ldg import partition as ldg_partition
from repro.core.restream import partition_restream
from repro.graph import powerlaw_cluster_graph, rmat_graph

ORDERS = ("natural", "random", "bfs", "dfs")


@pytest.fixture(scope="module")
def graphs():
    return [
        rmat_graph(1200, avg_degree=10, seed=3),
        powerlaw_cluster_graph(900, avg_degree=8, seed=4),
    ]


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("balance_mode", ["vertex", "edge"])
def test_engine_fennel_parity(graphs, order, balance_mode):
    for g in graphs:
        want = legacy.fennel_partition(
            g, 4, balance_mode=balance_mode, order=order, seed=7
        )
        got = fennel_partition(g, 4, balance_mode=balance_mode, order=order, seed=7)
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("balance_mode", ["vertex", "edge"])
def test_engine_ldg_parity(graphs, order, balance_mode):
    for g in graphs:
        want = legacy.ldg_partition(
            g, 4, balance_mode=balance_mode, order=order, seed=7
        )
        got = ldg_partition(g, 4, balance_mode=balance_mode, order=order, seed=7)
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("order", ORDERS)
def test_engine_cuttana_buffered_parity(graphs, order):
    # small d_max / max_qsize exercise the D_max bypass, overflow evictions
    # and complete-eviction cascades
    kw = dict(d_max=32, max_qsize=128, theta=0.7, seed=1)
    for g in graphs:
        want = legacy.cuttana_partition(g, 4, order=order, **kw)
        got = cuttana_partition(g, 4, order=order, **kw)
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("use_refinement", [False, True])
def test_engine_cuttana_unbuffered_parity(graphs, use_refinement):
    for g in graphs:
        want = legacy.cuttana_partition(
            g, 4, use_buffer=False, use_refinement=use_refinement,
            order="random", seed=1,
        )
        got = cuttana_partition(
            g, 4, use_buffer=False, use_refinement=use_refinement,
            order="random", seed=1,
        )
        np.testing.assert_array_equal(got, want)


def test_engine_cuttana_batched_parity(graphs):
    # chunk smaller than the graph + tiny sample_cap to exercise the stale
    # histograms and the degree-capped sampling path
    kw = dict(chunk=128, sample_cap=16, order="random", seed=1)
    for g in graphs:
        want = legacy.cuttana_batched_partition(g, 4, **kw)
        got = partition_batched(g, 4, **kw)
        np.testing.assert_array_equal(got, want)


def test_engine_heistream_parity(graphs):
    for g in graphs:
        want = legacy.heistream_partition(g, 4, batch_size=256, seed=1)
        got = heistream_partition(g, 4, batch_size=256, seed=1)
        np.testing.assert_array_equal(got, want)


def test_engine_restream_parity(graphs):
    for g in graphs:
        want = legacy.restream_partition(
            g, 4, passes=3, base="fennel", order="random", seed=0
        )
        got = partition_restream(
            g, 4, passes=3, base="fennel", order="random", seed=0
        )
        np.testing.assert_array_equal(got, want)


def test_engine_kernel_interpret_matches_host_path(graphs):
    """The Pallas kernel (interpret mode) and the CPU bincount companion
    must produce the same histograms, hence the same partitions."""
    g = graphs[0]
    host = fennel_partition(g, 4, order="random", seed=2, use_pallas=False)
    kern = fennel_partition(g, 4, order="random", seed=2, interpret=True)
    np.testing.assert_array_equal(host, kern)


def test_engine_kernel_hub_cap_parity(graphs, monkeypatch):
    """Exact mode bounds the dense kernel width; over-width hub rows get
    exact host histograms. Force the cap low so the branch runs."""
    import repro.core.engine as engine_mod

    g = graphs[0]
    assert int(g.degrees.max()) > 8
    monkeypatch.setattr(engine_mod, "_EXACT_KERNEL_WIDTH", 8)
    got = fennel_partition(g, 4, order="random", seed=3, interpret=True)
    want = legacy.fennel_partition(g, 4, order="random", seed=3)
    np.testing.assert_array_equal(got, want)


def test_engine_kernel_sampled_scatter_parity(graphs):
    """Stale mode + sampling through the kernel path (interpret) must match
    the seed batched loop run through the same kernel."""
    g = graphs[0]
    kw = dict(chunk=128, sample_cap=16, order="random", seed=1, interpret=True)
    got = partition_batched(g, 4, **kw)
    want = legacy.cuttana_batched_partition(g, 4, **kw)
    np.testing.assert_array_equal(got, want)


class _ProtocolOnlyScorer:
    """FennelScorer stripped of the affine fast path: exercises
    ImmediatePolicy._run_generic, the path custom Scorer implementations
    take."""

    def __init__(self, inner):
        self._inner = inner

    def begin(self, state):
        self._inner.begin(state)

    def scores(self, state, hist):
        return self._inner.scores(state, hist)

    def on_assign(self, state, p, deg):
        self._inner.on_assign(state, p, deg)

    def on_unassign(self, state, p, deg):
        self._inner.on_unassign(state, p, deg)


def test_engine_generic_scorer_path_parity(graphs):
    from repro.core.base import FennelParams, PartitionState, finalize
    from repro.core.engine import FennelScorer, ImmediatePolicy, StreamEngine

    g = graphs[0]
    scorer = _ProtocolOnlyScorer(FennelScorer(g, 4, FennelParams(), "vertex"))
    assert not hasattr(scorer, "affine")
    state = PartitionState.create(g, 4, 0.05, "vertex", seed=7)
    StreamEngine(g, state, scorer, ImmediatePolicy(), order="random", seed=7).run()
    want = legacy.fennel_partition(g, 4, order="random", seed=7)
    np.testing.assert_array_equal(finalize(state), want)


def test_engine_generic_scorer_reassign_parity(graphs):
    """_run_generic's reassign branch vs the affine one: identical moves."""
    from repro.core.base import FennelParams, PartitionState
    from repro.core.engine import FennelScorer, ImmediatePolicy, StreamEngine

    g = graphs[0]
    base = legacy.fennel_partition(g, 4, balance_mode="edge", order="random", seed=0)
    parts = []
    for wrap in (False, True):
        scorer = FennelScorer(g, 4, FennelParams(hybrid=True), "edge")
        if wrap:
            scorer = _ProtocolOnlyScorer(scorer)
        state = PartitionState.create(g, 4, 0.05, "edge", seed=1)
        state.part_of[:] = base
        state.v_counts[:] = np.bincount(base, minlength=4)
        state.e_counts[:] = np.bincount(
            base, weights=g.degrees.astype(np.float64), minlength=4
        )
        StreamEngine(
            g, state, scorer, ImmediatePolicy(reassign=True),
            order="random", seed=1,
        ).run()
        parts.append(state.part_of.copy())
    np.testing.assert_array_equal(parts[0], parts[1])


def test_legacy_variants_registered():
    for name in ("fennel", "ldg", "cuttana", "cuttana-batched", "heistream"):
        assert name in PARTITIONERS
        assert f"{name}-legacy" in PARTITIONERS


# ------------------------------------------------------------ array buffer
def test_buffer_evicts_in_score_order():
    buf = PriorityBuffer(capacity=100, d_max=100, theta=1.0)
    degs = [10, 50, 30, 50, 5]
    for v, d in enumerate(degs):
        buf.push(v, np.arange(d), 0)
    # score == deg/d_max; ties (the two deg-50 entries) break to smaller id
    order = [buf.pop_best()[0] for _ in range(len(degs))]
    assert order == [1, 3, 2, 0, 4]
    assert len(buf) == 0


def test_buffer_notify_reorders_and_invalidates_stale_entries():
    buf = PriorityBuffer(capacity=100, d_max=100, theta=1.0)
    buf.push(0, np.arange(10), 0)  # score 0.1
    buf.push(1, np.arange(20), 0)  # score 0.2
    # bump vertex 0 twice: score 0.1 + 2/10 = 0.3 > 0.2
    assert buf.notify_assigned(0) is False
    assert buf.notify_assigned(0) is False
    v, nbrs = buf.pop_best()
    assert v == 0 and nbrs.shape[0] == 10
    # the two stale heap entries for vertex 0 must not resurface
    v, _ = buf.pop_best()
    assert v == 1
    with pytest.raises(IndexError):
        buf.pop_best()


def test_buffer_complete_eviction_and_notify_many():
    g = rmat_graph(300, avg_degree=6, seed=0)
    buf = PriorityBuffer(capacity=100, d_max=1000, theta=1.0, graph=g)
    v = int(np.argmax(g.degrees))
    nbrs = g.neighbors(v)
    deg = nbrs.shape[0]
    buf.push(v, None, deg - 1)  # one unassigned neighbour left
    assert buf.notify_assigned(v) is True  # now complete
    returned = buf.remove(v)
    np.testing.assert_array_equal(returned, nbrs)
    # vectorised path: batch-notify a placed vertex's neighbourhood
    others = [int(u) for u in nbrs[:3]]
    for u in others:
        buf.push(u, None, int(g.degree(u)) - 1)
    complete = buf.notify_many(nbrs)
    assert complete == others  # all complete, reported in nbrs order
    for u in others:
        buf.remove(u)
    assert len(buf) == 0


def test_buffer_notify_many_matches_scalar_notify():
    g = rmat_graph(400, avg_degree=8, seed=1)
    a = PriorityBuffer(capacity=1000, d_max=50, theta=1.0, graph=g)
    b = PriorityBuffer(capacity=1000, d_max=50, theta=1.0, graph=g)
    rng = np.random.default_rng(0)
    verts = rng.choice(g.num_vertices, size=200, replace=False)
    for v in verts:
        a.push(int(v), None, 0)
        b.push(int(v), None, 0)
    placed = rng.choice(g.num_vertices, size=50, replace=False)
    for u in placed:
        nbrs = g.neighbors(int(u))
        got = b.notify_many(nbrs)
        want = []
        for w in nbrs:
            wi = int(w)
            if a.contains(wi) and a.notify_assigned(wi):
                want.append(wi)
                a.remove(wi)
        assert got == want
        for wi in got:
            b.remove(wi)
    pa, pb = [], []
    while len(a):
        pa.append(a.pop_best()[0])
    while len(b):
        pb.append(b.pop_best()[0])
    assert pa == pb


def test_buffer_notify_many_duplicate_neighbours():
    """dedupe=False graphs can repeat a neighbour in one row: increments are
    counted per occurrence, completes reported once."""
    buf = PriorityBuffer(capacity=10, d_max=100, theta=1.0)
    buf.push(5, np.arange(2), 1)
    buf.push(7, np.arange(4), 0)
    assert buf.notify_many(np.array([5, 5])) == [5]
    buf.remove(5)  # a single remove must suffice
    assert buf.notify_many(np.array([7, 7])) == []
    assert buf.score(7) == 4 / 100 + 1.0 * 2 / 4  # both occurrences counted


def test_buffer_reuse_after_remove():
    """Re-pushing a removed vertex must not be confused by stale entries."""
    buf = PriorityBuffer(capacity=10, d_max=10, theta=1.0)
    buf.push(0, np.arange(5), 0)
    buf.notify_assigned(0)  # stale entry for version 0 remains in the heap
    buf.remove(0)
    buf.push(0, np.arange(5), 4)  # re-push with a much higher score
    buf.push(1, np.arange(2), 0)
    v, _ = buf.pop_best()
    assert v == 0
    assert buf.score(0) == 5 / 10 + 4 / 5
