"""Beyond-paper extensions: swap refinement (paper §VI future work),
restreaming, MoE expert placement, HLO analysis, spec sanitization."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.placement import (
    evaluate_placement,
    place_experts,
    synthetic_routing_trace,
)
from repro.core.refinement import Refiner, best_swap, refine_with_swaps
from repro.core.restream import partition_restream
from repro.core import get_partitioner
from repro.graph import edge_cut, rmat_graph
from repro.graph.metrics import partition_edge_counts


def _random_coarse(kp=40, k=4, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.random((kp, kp)) * (rng.random((kp, kp)) < 0.4)
    w = np.triu(w, 1)
    w = w + w.T
    return w, rng.integers(0, k, kp), np.ones(kp), k


def test_swaps_extend_maximality():
    """After refine+swaps, neither a single trade nor a pairwise swap can
    improve the cut; swaps strictly help under tight balance."""
    w, sub, size, k = _random_coarse(seed=3)
    r1 = Refiner(w, sub, size, k, epsilon=0.02)
    r1.refine()
    cut_single = r1.current_cut()
    r2 = Refiner(w, sub, size, k, epsilon=0.02)
    res = refine_with_swaps(r2)
    assert r2.current_cut() <= cut_single + 1e-9
    assert r2.best_move(0.0) is None
    assert best_swap(r2) is None
    r2.check_invariants()
    assert res["improvement"] >= 0


def test_swap_gain_accounting():
    w, sub, size, k = _random_coarse(seed=7)
    r = Refiner(w, sub, size, k, epsilon=0.05)
    r.refine()
    sw = best_swap(r)
    if sw is None:
        pytest.skip("no blocked swap in this instance")
    i, j, gain = sw
    before = r.current_cut()
    a, b = int(r.sub_part[i]), int(r.sub_part[j])
    r.apply_move(i, b)
    r.apply_move(j, a)
    after = r.current_cut()
    assert abs((before - after) - gain) < 1e-6


def test_restream_improves_quality():
    g = rmat_graph(3000, avg_degree=10, seed=2)
    k = 8
    single = edge_cut(
        g, get_partitioner("fennel")(g, k, balance_mode="edge",
                                     order="random", seed=0)
    )
    multi = partition_restream(
        g, k, passes=3, base="fennel", order="random", seed=0
    )
    assert multi.min() >= 0 and multi.max() < k
    assert edge_cut(g, multi) < single
    # balance survives restreaming + refinement
    cap = (1 + 0.05) * g.indices.shape[0] / k
    assert partition_edge_counts(g, multi, k).max() <= cap + g.degrees.max()


def test_expert_placement_reduces_fanout():
    trace = synthetic_routing_trace(5000, 64, 4, skew=0.75, seed=1)
    baseline = np.arange(64) % 8
    placed = place_experts(trace, 64, 8, seed=1)
    m0 = evaluate_placement(trace, baseline)
    m1 = evaluate_placement(trace, placed)
    assert m1["mean_fanout"] < m0["mean_fanout"]
    counts = np.bincount(placed, minlength=8)
    assert (counts == 8).all()  # exact capacity for EP kernels


def test_hlo_analysis_trip_counts():
    import jax
    import jax.numpy as jnp

    from repro.launch.hlo_analysis import analyze

    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out

    x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    res = analyze(jax.jit(f).lower(x, w).compile().as_text())
    assert res["dot_flops_per_shard"] == 2 * 64 * 32 * 32 * 5
    assert res["max_trip_count"] == 5


def test_spec_sanitization():
    import jax
    import numpy as np_
    from jax.sharding import Mesh

    from repro.launch.specs import sanitize_spec

    mesh = Mesh(np_.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # 504 does not divide the (1-sized here, but logic checks modulo) axes
    spec = sanitize_spec((504, 10), P("data", "model"), mesh)
    assert spec == P("data", "model")  # 1-device axes always divide

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = sanitize_spec((504, 1280), P("model", "data"), FakeMesh())
    assert spec[0] is None  # 504 % 16 != 0 -> replicated
    assert spec[1] == "data"
