"""Property-based tests (hypothesis) for the system's core invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.buffer import PriorityBuffer
from repro.core.cuttana import partition as cuttana_partition
from repro.core.refinement import Refiner, build_subpartition_graph
from repro.graph import CSRGraph, edge_cut, communication_volume
from repro.graph.metrics import (
    check_balance,
    partition_edge_counts,
    partition_vertex_counts,
)


# --------------------------------------------------------------- strategies
@st.composite
def random_graph(draw, max_n=120, max_m=500):
    n = draw(st.integers(min_value=4, max_value=max_n))
    m = draw(st.integers(min_value=1, max_value=max_m))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(m, 2))
    return CSRGraph.from_edges(edges, num_vertices=n)


@st.composite
def coarse_instance(draw):
    kp = draw(st.integers(min_value=4, max_value=40))
    k = draw(st.integers(min_value=2, max_value=min(kp, 6)))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    w = rng.random((kp, kp)) * (rng.random((kp, kp)) < 0.4)
    w = np.triu(w, 1)
    w = w + w.T
    sub_part = rng.integers(0, k, size=kp)
    size = rng.random(kp) + 0.25
    return w, sub_part, size, k


# ------------------------------------------------------------------- tests
@settings(max_examples=25, deadline=None)
@given(random_graph(), st.integers(min_value=2, max_value=6))
def test_cuttana_always_total_and_balanced(graph, k):
    part = cuttana_partition(graph, k, epsilon=0.3, balance_mode="edge", seed=0)
    assert part.shape == (graph.num_vertices,)
    assert part.min() >= 0 and part.max() < k
    ec = partition_edge_counts(graph, part, k)
    # slack: integer granularity on tiny graphs (one vertex may overshoot by
    # its degree); the capacity logic still must not blow past cap + max_deg
    cap = (1 + 0.3) * graph.indices.shape[0] / k
    max_deg = int(graph.degrees.max()) if graph.num_vertices else 0
    assert ec.max() <= cap + max_deg + 1e-9


@settings(max_examples=25, deadline=None)
@given(random_graph())
def test_metrics_bounds(graph):
    k = 4
    rng = np.random.default_rng(0)
    part = rng.integers(0, k, size=graph.num_vertices).astype(np.int32)
    lam_ec = edge_cut(graph, part)
    lam_cv = communication_volume(graph, part, k)
    assert 0.0 <= lam_ec <= 1.0
    assert 0.0 <= lam_cv <= 1.0


@settings(max_examples=20, deadline=None)
@given(coarse_instance())
def test_refinement_invariants(instance):
    w, sub_part, size, k = instance
    r = Refiner(w, sub_part, size, k, epsilon=0.4)
    cut0 = r.current_cut()
    stats = r.refine()
    # monotone improvement, internally-consistent bookkeeping, maximality
    assert r.current_cut() <= cut0 + 1e-9
    assert abs((cut0 - r.current_cut()) - stats.cut_improvement) < 1e-6
    r.check_invariants()
    assert r.best_move(0.0) is None


@settings(max_examples=20, deadline=None)
@given(coarse_instance())
def test_refinement_never_grows_overloaded_partition(instance):
    w, sub_part, size, k = instance
    eps = 0.25
    total = float(size.sum())
    cap = (1 + eps) * total / k
    before = np.bincount(sub_part, weights=size, minlength=k)
    r = Refiner(w, sub_part, size, k, epsilon=eps, total_mass=total)
    r.refine()
    after = np.bincount(r.sub_part, weights=size, minlength=k)
    for p in range(k):
        if after[p] > cap + 1e-9:  # was already over cap at input
            assert after[p] <= before[p] + 1e-9


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(min_value=0, max_value=50),  # degree
            st.integers(min_value=0, max_value=50),  # assigned count
        ),
        min_size=1,
        max_size=40,
    )
)
def test_buffer_pops_in_score_order(entries):
    buf = PriorityBuffer(capacity=1000, d_max=100, theta=1.0)
    for i, (deg, assigned) in enumerate(entries):
        deg = max(deg, assigned, 1)
        buf.push(i, np.arange(deg), min(assigned, deg))
    scores = []
    while len(buf):
        v, _ = buf.pop_best()
        scores.append(deg_score(buf, entries, v))
    assert scores == sorted(scores, reverse=True)


def deg_score(buf, entries, v):
    deg, assigned = entries[v]
    deg = max(deg, assigned, 1)
    return deg / buf.d_max + buf.theta * min(assigned, deg) / deg


@settings(max_examples=15, deadline=None)
@given(random_graph(max_n=60, max_m=200), st.integers(min_value=2, max_value=4))
def test_refinement_reaches_vertex_level_coarse_maximality(graph, k):
    """After refine(thresh=0), no whole-sub-partition move may improve cut -
    checked against a brute-force recount on the original graph."""
    res = cuttana_partition(
        graph, k, epsilon=0.5, balance_mode="vertex",
        subparts_per_partition=4, seed=0, return_detail=True,
    )
    kp = k * 4
    w = build_subpartition_graph(graph, res.sub_of, kp)
    part_of_sub = res.sub_part
    cut_now = edge_cut(graph, res.part) * graph.num_edges
    cap = (1 + 0.5) * graph.num_vertices / k
    loads = np.bincount(
        part_of_sub, weights=np.bincount(res.sub_of, minlength=kp), minlength=k
    )
    sizes = np.bincount(res.sub_of, minlength=kp)
    for i in range(kp):
        src = int(part_of_sub[i])
        for dst in range(k):
            if dst == src or loads[dst] + sizes[i] > cap + 1e-9:
                continue
            trial = part_of_sub.copy()
            trial[i] = dst
            new_cut = edge_cut(graph, trial[res.sub_of]) * graph.num_edges
            assert new_cut >= cut_now - 1e-6, (
                f"refinement missed improving move <{i},{dst}>"
            )
