"""The typed PartitionSpec -> PartitionResult surface (repro.api).

Pins the acceptance criteria of the api redesign: JSON round-trips for every
registered algorithm, bit-identical parity between spec runs and the bare
callables, lazy+cached quality metrics, telemetry plumbing, the deprecated
``get_partitioner`` shim, and the headless CLI.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.api import (
    REGISTRY,
    PartitionSpec,
    get_info,
    list_algorithms,
    partition,
)
from repro.api.registry import build_spec_kwargs
from repro.core import (
    EDGE_PARTITIONERS,
    PARTITIONERS,
    get_edge_partitioner,
    get_partitioner,
)
from repro.graph import rmat_graph

K = 4
SRC = str(Path(__file__).resolve().parent.parent / "src")


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(800, avg_degree=8, seed=3)


def _parity_cases():
    for name in sorted(REGISTRY):
        info = REGISTRY[name]
        for mode in info.balance_modes or ("edge",):
            yield name, mode


# ------------------------------------------------------------------ registry
def test_registry_covers_legacy_dicts():
    assert set(PARTITIONERS) == set(list_algorithms("edge-cut"))
    assert set(EDGE_PARTITIONERS) == set(list_algorithms("vertex-cut"))
    for name, fn in PARTITIONERS.items():
        assert get_partitioner(name) is fn
        assert REGISTRY[name].resolve() is fn
    for name, fn in EDGE_PARTITIONERS.items():
        assert get_edge_partitioner(name) is fn


def test_unknown_name_lists_registry_and_nearest_match():
    with pytest.raises(ValueError, match=r"fennel"):
        get_partitioner("fenel")
    with pytest.raises(ValueError, match=r"registered"):
        get_partitioner("definitely-not-an-algo")
    with pytest.raises(ValueError, match=r"hdrf"):
        get_edge_partitioner("hdrff")
    # kind mismatch is its own clear error, not a KeyError
    with pytest.raises(ValueError, match=r"vertex-cut"):
        get_partitioner("hdrf")


# ---------------------------------------------------------------------- spec
def test_spec_json_round_trip_all_algorithms():
    for name in sorted(REGISTRY):
        info = REGISTRY[name]
        mode = (info.balance_modes or ("edge",))[0]
        spec = PartitionSpec(algo=name, k=3, balance_mode=mode, seed=7)
        assert PartitionSpec.from_json(spec.to_json()) == spec
        if info.params_cls is not None:
            # flip one field away from its default and round-trip again
            field = dataclasses.fields(info.params_cls)[0]
            default = getattr(info.params_cls(), field.name)
            bumped = {
                field.name: (not default) if isinstance(default, bool)
                else (default or 1) * 2
            }
            spec2 = PartitionSpec(algo=name, k=3, balance_mode=mode,
                                  params=bumped)
            assert PartitionSpec.from_json(spec2.to_json()) == spec2
            assert spec2 != spec


def test_spec_normalizes_params_dict():
    spec = PartitionSpec(algo="cuttana", k=4, params={"d_max": 50})
    assert spec.params.d_max == 50
    assert spec.params.use_buffer is True  # other fields keep defaults
    typed = PartitionSpec(algo="cuttana", k=4,
                          params=type(spec.params)(d_max=50))
    assert typed == spec


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="fennel"):
        PartitionSpec(algo="fenel", k=4)
    with pytest.raises(ValueError, match="positive integer"):
        PartitionSpec(algo="fennel", k=0)
    with pytest.raises(ValueError, match="balance"):
        PartitionSpec(algo="fennel", k=4, balance_mode="degrees")
    with pytest.raises(ValueError, match="order"):
        PartitionSpec(algo="fennel", k=4, order="sorted")
    with pytest.raises(ValueError, match="valid fields"):
        PartitionSpec(algo="cuttana", k=4, params={"dmax": 10})
    # values are type-checked field-by-field at construction, not mid-stream
    with pytest.raises(ValueError, match="d_max"):
        PartitionSpec(algo="cuttana", k=4, params={"d_max": "big"})
    with pytest.raises(ValueError, match="use_buffer"):
        PartitionSpec(algo="cuttana", k=4, params={"use_buffer": 3})
    with pytest.raises(ValueError, match="max_qsize"):
        PartitionSpec(algo="cuttana", k=4, params={"max_qsize": 1.5})
    with pytest.raises(ValueError, match="base"):
        PartitionSpec(algo="cuttana-restream", k=4, params={"base": 7})
    with pytest.raises(ValueError, match="no per-algorithm params"):
        PartitionSpec(algo="random", k=4, params={"x": 1})
    with pytest.raises(ValueError, match="unknown PartitionSpec fields"):
        PartitionSpec.from_dict({"algo": "fennel", "k": 4, "kk": 8})
    # top-level scalars are type-checked too (hand-edited JSON specs)
    with pytest.raises(ValueError, match="seed"):
        PartitionSpec(algo="fennel", k=4, seed="7")
    with pytest.raises(ValueError, match="epsilon"):
        PartitionSpec(algo="fennel", k=4, epsilon="0.05")
    # a knob the algorithm ignores cannot be set away from its default
    with pytest.raises(ValueError, match="does not use 'order'"):
        PartitionSpec(algo="hdrf", k=4, order="bfs")
    with pytest.raises(ValueError, match="does not use 'epsilon'"):
        PartitionSpec(algo="random", k=4, epsilon=0.5)
    with pytest.raises(ValueError, match="does not use 'balance_mode'"):
        PartitionSpec(algo="chunked", k=4, balance_mode="vertex")


def test_spec_round_trip_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    algos = list_algorithms()

    @settings(max_examples=40, deadline=None)
    @given(
        algo=st.sampled_from(algos),
        k=st.integers(min_value=1, max_value=64),
        epsilon=st.floats(min_value=0.0, max_value=2.0, allow_nan=False),
        order=st.sampled_from(("natural", "random", "bfs", "dfs")),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        mode_idx=st.integers(min_value=0, max_value=1),
    )
    def round_trips(algo, k, epsilon, order, seed, mode_idx):
        info = get_info(algo)
        modes = info.balance_modes or ("edge",)
        spec = PartitionSpec(
            algo=algo, k=k, seed=seed,
            epsilon=epsilon if "epsilon" in info.common else 0.05,
            order=order if "order" in info.common else "natural",
            balance_mode=modes[mode_idx % len(modes)],
        )
        assert PartitionSpec.from_json(spec.to_json()) == spec

    round_trips()


# -------------------------------------------------------------------- parity
@pytest.mark.parametrize("name,mode", _parity_cases())
def test_spec_run_matches_bare_callable(graph, name, mode):
    """Acceptance: every registry algorithm is runnable via PartitionSpec and
    the assignment is bit-identical to the legacy callable under the same
    seed/order."""
    info = REGISTRY[name]
    kwargs = dict(algo=name, k=K, balance_mode=mode, seed=0)
    if "order" in info.common:
        kwargs["order"] = "random"
    spec = PartitionSpec(**kwargs)
    result = partition(graph, spec)
    bare_kwargs = {key: getattr(spec, key) for key in info.common}
    bare = info.resolve()(graph, K, **bare_kwargs)
    expected = bare.edge_part if info.kind == "vertex-cut" else np.asarray(bare)
    assert np.array_equal(result.assignment, expected)
    assert result.spec == spec
    assert result.timings["total_s"] >= 0.0
    if info.kind == "vertex-cut":
        assert result.edge_partition is not None
        assert result.vertex_assignment().shape == (graph.num_vertices,)
    else:
        assert result.assignment.shape == (graph.num_vertices,)


def test_spec_run_respects_params_block(graph):
    full = partition(graph, PartitionSpec(algo="cuttana", k=K, seed=0))
    ablated = partition(graph, PartitionSpec(
        algo="cuttana", k=K, seed=0,
        params={"use_refinement": False, "use_buffer": False},
    ))
    bare = PARTITIONERS["cuttana"](
        graph, K, use_refinement=False, use_buffer=False,
        balance_mode="edge", epsilon=0.05, order="natural", seed=0,
    )
    assert np.array_equal(ablated.assignment, bare)
    assert ablated.telemetry["refine_moves"] == 0
    assert full.telemetry["refine_moves"] >= 0


# ----------------------------------------------------------- result surface
def test_quality_is_lazy_and_cached(graph, monkeypatch):
    import repro.graph.metrics as metrics

    calls = {"n": 0}
    real = metrics.quality_report

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(metrics, "quality_report", counting)
    result = partition(graph, PartitionSpec(algo="ldg", k=K))
    assert calls["n"] == 0  # nothing computed until asked
    q1 = result.quality()
    q2 = result.quality()
    assert calls["n"] == 1
    assert q1 is q2
    assert 0.0 <= q1["edge_cut"] <= 1.0


def test_telemetry_and_timings(graph):
    result = partition(graph, PartitionSpec(algo="cuttana", k=K))
    assert "buffer_evictions" in result.telemetry
    assert "buffer_peak" in result.telemetry
    assert "refine_moves" in result.telemetry
    assert "phase1_seconds" in result.timings
    assert "phase2_seconds" in result.timings
    batched = partition(graph, PartitionSpec(algo="cuttana-batched", k=K))
    assert batched.telemetry["kernel_calls"] > 0
    assert "stream_seconds" in batched.timings
    restream = partition(graph, PartitionSpec(
        algo="cuttana-restream", k=K, params={"passes": 2}))
    # base pass kernel/host scoring is attributed, and its wall time is
    # separated from the re-pass stream time
    assert restream.telemetry["kernel_calls"] > 0
    assert "base_seconds" in restream.timings
    assert "stream_seconds" in restream.timings
    # the buffered base run's counters survive, namespaced
    assert "buffer_evictions" in restream.telemetry["base_telemetry"]


def test_cuttana_compat_flag_and_telemetry_agree(graph):
    from repro.core.cuttana import CuttanaResult, partition as cuttana

    telemetry = {}
    detail = cuttana(graph, K, seed=0, return_detail=True, telemetry=telemetry)
    assert isinstance(detail, CuttanaResult)
    assert telemetry["refine_moves"] == detail.refine_moves
    assert telemetry["refine_improvement"] == detail.refine_improvement
    result = partition(graph, PartitionSpec(algo="cuttana", k=K, seed=0))
    assert np.array_equal(result.assignment, detail.part)
    assert result.telemetry["refine_moves"] == detail.refine_moves


def test_partition_shortcuts(graph):
    by_name = partition(graph, "fennel", k=K, balance_mode="vertex", seed=1)
    by_dict = partition(graph, {"algo": "fennel", "k": K,
                                "balance_mode": "vertex", "seed": 1})
    assert np.array_equal(by_name.assignment, by_dict.assignment)
    assert by_name.spec == by_dict.spec


def test_downstream_adapters(graph):
    result = partition(graph, PartitionSpec(algo="fennel", k=2))
    cost = result.analytics(program="pagerank", iters=5, mode="model")
    assert cost["total_s"] > 0
    sim = result.analytics(program="pagerank", iters=2, mode="simulated")
    assert sim["values"].shape == (graph.num_vertices,)
    assert sim["halo_messages_per_iter"] >= 0
    db = result.db(hops=2, num_queries=32)
    assert db["qps"] > 0 and db["p99_latency_ms"] > 0
    # a precomputed query mix is reused verbatim
    from repro.db import ldbc_query_mix

    seeds = ldbc_query_mix(graph, 32, seed=0)
    assert result.db(hops=2, seeds=seeds) == result.db(hops=2, num_queries=32)
    # results hold ndarrays but still support ==/in without raising
    assert result != partition(graph, PartitionSpec(algo="fennel", k=2))
    assert result in [result]
    with pytest.raises(ValueError, match="mode"):
        result.analytics(mode="imaginary")
    with pytest.raises(ValueError, match="hops"):
        result.db(hops=3)


def test_vertex_cut_result_quality_and_db(graph):
    result = partition(graph, PartitionSpec(algo="hdrf", k=K, seed=0))
    q = result.quality()
    assert q["kind"] == "vertex-cut"
    assert q["replication_factor"] >= 1.0
    # db routes through replica masters for vertex-cut results
    db = result.db(hops=1, num_queries=16)
    assert db["qps"] > 0
    with pytest.raises(ValueError, match="vertex"):
        result.analytics(mode="simulated")
    assert result.analytics(mode="model")["total_s"] > 0


def test_degenerate_graphs_via_spec():
    """k=1 and edgeless graphs stay total through the spec surface (the
    edge-mode LDG case used to hit a ZeroDivisionError in the affine fast
    path where the legacy loop's nan sank into the least-loaded fallback)."""
    from repro.graph.csr import CSRGraph

    g = rmat_graph(300, avg_degree=6, seed=0)
    one = partition(g, PartitionSpec(algo="cuttana", k=1))
    assert one.assignment.max() == 0
    assert one.quality()["edge_cut"] == 0.0
    empty = CSRGraph.from_edges(np.zeros((0, 2), dtype=int), num_vertices=40)
    for algo in ("fennel", "ldg", "cuttana", "heistream", "random", "chunked"):
        info = REGISTRY[algo]
        for mode in info.balance_modes or ("edge",):
            kwargs = dict(algo=algo, k=3, balance_mode=mode)
            if "epsilon" in info.common:
                kwargs["epsilon"] = 0.5
            result = partition(empty, PartitionSpec(**kwargs))
            assert result.assignment.shape == (40,), (algo, mode)
            legacy = REGISTRY.get(f"{algo}-legacy")
            if legacy is not None:
                ref = legacy.resolve()(empty, 3, epsilon=0.5, balance_mode=mode)
                assert np.array_equal(result.assignment, ref), (algo, mode)


def test_report_is_json_serializable(graph):
    result = partition(graph, PartitionSpec(algo="cuttana", k=K))
    report = result.to_report()
    text = json.dumps(report)
    back = json.loads(text)
    assert back["spec"]["algo"] == "cuttana"
    assert back["quality"]["kind"] == "edge-cut"
    assert back["graph"]["num_vertices"] == graph.num_vertices


def test_build_spec_kwargs_reproduce_defaults():
    """The kwargs a default spec builds must equal the callable's own
    defaults - that is what makes spec runs bit-identical to bare calls."""
    import inspect

    for name in sorted(REGISTRY):
        info = REGISTRY[name]
        spec = PartitionSpec(algo=name, k=2,
                             balance_mode=(info.balance_modes or ("edge",))[0])
        kwargs = build_spec_kwargs(info, spec)
        sig = inspect.signature(info.resolve())
        for key, value in kwargs.items():
            assert key in sig.parameters or any(
                p.kind is inspect.Parameter.VAR_KEYWORD
                for p in sig.parameters.values()
            ), f"{name}: unexpected kwarg {key}"
            if key in sig.parameters and key in info.common:
                continue  # common fields may legitimately differ per spec
            if key in sig.parameters and key != "params":
                default = sig.parameters[key].default
                if default is not inspect.Parameter.empty:
                    assert default == value, (
                        f"{name}: params default drifted for {key}: "
                        f"registry={value!r} callable={default!r}"
                    )


# ------------------------------------------------------------------------ CLI
def _cli_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def test_cli_partition_smoke(tmp_path):
    spec_path = tmp_path / "spec.json"
    out_path = tmp_path / "report.json"
    spec = PartitionSpec(algo="fennel", k=3, balance_mode="edge",
                         order="random", seed=0)
    spec_path.write_text(spec.to_json())
    proc = subprocess.run(
        [sys.executable, "-m", "repro.api.cli", "partition",
         "--spec", str(spec_path), "--rmat", "600", "--avg-degree", "8",
         "--out", str(out_path),
         "--assignment-out", str(tmp_path / "assignment")],
        env=_cli_env(), capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    report = json.loads(out_path.read_text())
    assert report["spec"] == spec.to_dict()
    assert report["graph"]["num_vertices"] == 600
    assert 0.0 <= report["quality"]["edge_cut"] <= 1.0
    assert report["timings"]["total_s"] > 0
    # the recorded path is the one np.save actually wrote
    saved = np.load(report["assignment_path"])
    assert saved.shape == (600,)


def test_cli_list_smoke():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.api.cli", "list"],
        env=_cli_env(), capture_output=True, text=True, timeout=120,
    )
    assert proc.returncode == 0, proc.stderr
    for name in ("cuttana", "fennel", "hdrf"):
        assert name in proc.stdout
