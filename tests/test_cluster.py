"""Streaming-clustering coarsening prepass (repro.core.cluster).

Invariants under test:

* :func:`streaming_cluster` is a total assignment that respects the volume
  and member-count caps for every multi-member cluster, keeps hubs as
  singletons, and actually coarsens community graphs;
* projection (``coarse_part[cluster_of]``) plus greedy repair yields a
  total, in-range, *balanced* partition for both balance modes and both
  registered bases;
* determinism: same spec -> same assignment, bit for bit;
* the spec/registry layer round-trips ``cluster+<algo>`` and validates the
  prepass knobs.
"""
import numpy as np
import pytest

from repro.api import PartitionSpec, partition
from repro.core.cluster import (
    build_coarse_graph,
    partition_cluster,
    streaming_cluster,
)
from repro.graph import CSRGraph
from repro.graph.generators import powerlaw_cluster_graph, rmat_graph
from repro.graph.metrics import (
    check_balance,
    partition_edge_counts,
    partition_vertex_counts,
)
from repro.graph.stream import stream_order


@pytest.fixture(scope="module")
def web_graph():
    # preferential-attachment + id-locality: actual community structure,
    # the regime the prepass is built for
    return powerlaw_cluster_graph(3000, avg_degree=12, seed=4)


# ----------------------------------------------------------------- clustering
def test_streaming_cluster_invariants(web_graph):
    g = web_graph
    ids = stream_order(g, "random", seed=1)
    k = 8
    volume_cap = max(0.1 * g.indices.shape[0] / k, 1.0)
    count_cap = max(int(0.1 * g.num_vertices / k), 1)
    cluster_of, nc, vols = streaming_cluster(
        g, ids, volume_cap, count_cap, hub_degree=200
    )
    # total assignment into [0, nc)
    assert cluster_of.shape == (g.num_vertices,)
    assert cluster_of.min() >= 0 and cluster_of.max() < nc
    assert vols.shape == (nc,)
    degrees = np.asarray(g.degrees, dtype=np.int64)
    sizes = np.bincount(cluster_of, minlength=nc)
    # volumes bookkeeping is exactly the member-degree sums
    np.testing.assert_array_equal(
        vols, np.bincount(cluster_of, weights=degrees.astype(float), minlength=nc)
    )
    # caps hold for every multi-member cluster (singletons may exceed the
    # volume cap: a hub or isolated vertex is unsplittable)
    multi = sizes > 1
    assert (sizes[multi] <= count_cap).all()
    assert (vols[multi] <= volume_cap + 1e-9).all()
    # hubs stay singletons
    hubs = np.flatnonzero(degrees >= 200)
    if hubs.size:
        assert (sizes[cluster_of[hubs]] == 1).all()
    # on a community graph the pass must genuinely coarsen
    assert nc < g.num_vertices / 2


def test_streaming_cluster_volume_cap_binds():
    # a star: the centre is a hub singleton, leaves share clusters only up
    # to the caps
    edges = np.stack(
        [np.zeros(50, dtype=np.int64), np.arange(1, 51, dtype=np.int64)], axis=1
    )
    g = CSRGraph.from_edges(edges, num_vertices=51)
    ids = np.arange(51, dtype=np.int64)
    cluster_of, nc, vols = streaming_cluster(
        g, ids, volume_cap=5.0, count_cap=5, hub_degree=10
    )
    sizes = np.bincount(cluster_of, minlength=nc)
    assert sizes[cluster_of[0]] == 1  # centre (deg 50 >= hub_degree)
    multi = sizes > 1
    assert (vols[multi] <= 5.0).all()
    assert (sizes[multi] <= 5).all()


def test_build_coarse_graph_preserves_cross_edges(web_graph):
    g = web_graph
    ids = stream_order(g, "natural", seed=0)
    cluster_of, nc, _ = streaming_cluster(g, ids, 500.0, 40, hub_degree=200)
    coarse = build_coarse_graph(g, cluster_of, nc)
    assert coarse.num_vertices == nc
    # multiplicity preserved: coarse edge endpoints count original
    # cross-cluster edges exactly (each undirected edge once)
    cs = cluster_of[
        np.repeat(np.arange(g.num_vertices), np.asarray(g.degrees, dtype=np.int64))
    ]
    cd = cluster_of[g.indices]
    cross = int((cs != cd).sum()) // 2
    assert coarse.indices.shape[0] // 2 == cross


# ------------------------------------------------------------ full partitioner
@pytest.mark.parametrize("base", ["cuttana", "fennel"])
@pytest.mark.parametrize("balance", ["edge", "vertex"])
def test_partition_cluster_total_and_balanced(web_graph, base, balance):
    g = web_graph
    k = 6
    tele = {}
    part = partition_cluster(
        g, k, epsilon=0.05, balance_mode=balance, base=base,
        order="random", seed=0, telemetry=tele,
    )
    assert part.shape == (g.num_vertices,)
    assert part.dtype == np.int32
    assert part.min() >= 0 and part.max() < k
    if balance == "edge":
        sizes = partition_edge_counts(g, part, k)
        total = g.indices.shape[0]
    else:
        sizes = partition_vertex_counts(part, k)
        total = g.num_vertices
    assert check_balance(sizes, total, k, 0.05), sizes
    assert tele["cluster_base"] == base
    assert 0 < tele["clusters_found"] < g.num_vertices
    assert 0 < tele["coarsening_ratio"] < 1
    assert tele["coarse_edges"] > 0
    assert tele["repair_moves"] >= 0


def test_partition_cluster_deterministic(web_graph):
    a = partition_cluster(web_graph, 4, order="random", seed=7)
    b = partition_cluster(web_graph, 4, order="random", seed=7)
    assert a.tobytes() == b.tobytes()


def test_partition_cluster_rejects_bad_knobs(web_graph):
    with pytest.raises(ValueError, match="unknown cluster base"):
        partition_cluster(web_graph, 4, base="ldg")
    with pytest.raises(ValueError, match="cluster_cap_frac"):
        partition_cluster(web_graph, 4, cluster_cap_frac=0.0)


def test_partition_cluster_no_refinement_path(web_graph):
    tele = {}
    part = partition_cluster(
        web_graph, 4, use_refinement=False, order="natural", seed=0,
        telemetry=tele,
    )
    assert part.shape == (web_graph.num_vertices,)
    assert tele["refine_moves"] == 0


def test_partition_cluster_k1_and_tiny():
    g = rmat_graph(50, avg_degree=4, seed=0)
    part = partition_cluster(g, 1)
    assert (part == 0).all()
    # isolated vertices: clustering and projection must still be total
    edges = np.array([[0, 1]], dtype=np.int64)
    g2 = CSRGraph.from_edges(edges, num_vertices=5)
    part2 = partition_cluster(g2, 2, epsilon=1.0)
    assert part2.shape == (5,)
    assert part2.min() >= 0 and part2.max() < 2


# ---------------------------------------------------------------- spec layer
def test_cluster_spec_roundtrip_and_validation(web_graph):
    spec = PartitionSpec(
        algo="cluster+cuttana", k=4, order="random", seed=2,
        params={"hub_degree": 150, "cluster_cap_frac": 0.2},
    )
    assert PartitionSpec.from_json(spec.to_json()) == spec
    res = partition(web_graph, spec)
    assert res.assignment.shape == (web_graph.num_vertices,)
    assert res.telemetry["cluster_base"] == "cuttana"
    with pytest.raises(ValueError, match="hub_degree"):
        PartitionSpec(algo="cluster+cuttana", k=4, params={"hub_degree": 1})
    with pytest.raises(ValueError, match="cluster_cap_frac"):
        PartitionSpec(
            algo="cluster+fennel", k=4, params={"cluster_cap_frac": 1.5}
        )


def test_cluster_fennel_through_api(web_graph):
    res = partition(
        web_graph, PartitionSpec(algo="cluster+fennel", k=4, order="random")
    )
    assert res.telemetry["cluster_base"] == "fennel"
    sizes = partition_edge_counts(web_graph, res.assignment, 4)
    assert check_balance(sizes, web_graph.indices.shape[0], 4, 0.05)
