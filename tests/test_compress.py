"""Compressed CSR v2 subsystem tests.

Covers the delta-varint codec (``repro.graph.compress``) - deterministic
round-trips plus hypothesis property tests when the library is installed -
the v2 on-disk format (corruption rejection, v1 compatibility, measured
compression on power-law graphs), the parallel converter, converter cleanup
on failure, the prefetch pipeline (``repro.graph.prefetch``) and its
``prefetch`` knob threading through spec/CLI, all pinned to bit-identical
assignments.
"""
from __future__ import annotations

import importlib.util
import json
import os
import struct
import threading
import time

import numpy as np
import pytest

from repro.api import PartitionSpec, partition
from repro.graph.compress import (
    DEFAULT_BLOCK_CAP,
    MAX_VARINT_BYTES,
    decode_adjacency,
    encode_adjacency,
    varint_decode,
    varint_encode,
)
from repro.graph.external import (
    FORMAT_VERSION,
    FORMAT_VERSION_V2,
    HEADER_BYTES,
    ExternalCSRGraph,
    convert_csr,
    convert_edge_list,
    raw_file_bytes,
    write_external_csr,
)
from repro.graph.generators import rmat_graph
from repro.graph.prefetch import BatchPrefetcher, PrefetchStats

HAS_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


def _sorted_rows(rng, num_rows, max_id=10_000, max_deg=200):
    """Random strictly-increasing rows -> (flat, degs), the codec's input."""
    rows = []
    for _ in range(num_rows):
        deg = int(rng.integers(0, max_deg))
        row = np.unique(rng.integers(0, max_id, size=deg))
        rows.append(row.astype(np.int64))
    degs = np.array([r.shape[0] for r in rows], dtype=np.int64)
    flat = (
        np.concatenate(rows) if rows else np.empty(0, np.int64)
    )
    return flat, degs


# ------------------------------------------------------------------- varint
class TestVarint:
    def test_roundtrip_boundary_values(self):
        # every LEB128 width boundary, 1 through 9 bytes
        vals = [0, 1, 127, 128, 16383, 16384]
        vals += [(1 << (7 * j)) - 1 for j in range(3, 9)]
        vals += [1 << (7 * j) for j in range(3, 9)]
        vals += [2**63 - 1]
        vals = np.array(vals, dtype=np.int64)
        buf, nb = varint_encode(vals)
        assert int(nb.sum()) == buf.shape[0]
        assert int(nb.max()) <= MAX_VARINT_BYTES
        out, starts = varint_decode(buf, count=vals.shape[0])
        assert np.array_equal(out, vals)
        assert np.array_equal(starts, np.cumsum(nb) - nb)

    def test_empty(self):
        buf, nb = varint_encode(np.empty(0, np.int64))
        assert buf.shape == (0,) and nb.shape == (0,)
        out, _ = varint_decode(buf, count=0)
        assert out.shape == (0,)

    def test_rejects_negative(self):
        with pytest.raises(ValueError, match="negative"):
            varint_encode(np.array([3, -1], dtype=np.int64))

    def test_rejects_truncated_stream(self):
        buf, _ = varint_encode(np.array([300], dtype=np.int64))
        assert buf.shape[0] == 2  # chopping the tail leaves a dangling byte
        with pytest.raises(ValueError, match="truncated"):
            varint_decode(buf[:-1], count=1)

    def test_rejects_continuation_bit_on_last_byte(self):
        buf, _ = varint_encode(np.array([300, 5], dtype=np.int64))
        bad = buf.copy()
        bad[-1] |= 0x80  # last byte now claims a continuation
        with pytest.raises(ValueError, match="truncated"):
            varint_decode(bad, count=2)

    def test_rejects_count_mismatch(self):
        buf, _ = varint_encode(np.array([1, 2, 3], dtype=np.int64))
        with pytest.raises(ValueError, match="count mismatch"):
            varint_decode(buf, count=2)
        with pytest.raises(ValueError, match="expected 1"):
            varint_decode(np.empty(0, np.uint8), count=1)

    def test_rejects_overlong_varint(self):
        # 10 continuation-bit bytes then a terminator: wider than any int64
        bad = np.full(11, 0x81, dtype=np.uint8)
        bad[-1] = 0x01
        with pytest.raises(ValueError, match="longer than"):
            varint_decode(bad)


# --------------------------------------------------------------- adjacency
class TestAdjacencyCodec:
    @pytest.mark.parametrize("block_cap", (1, 2, 7, DEFAULT_BLOCK_CAP, 1000))
    def test_roundtrip_random_rows(self, block_cap):
        rng = np.random.default_rng(block_cap)
        flat, degs = _sorted_rows(rng, 60)
        data, row_bytes = encode_adjacency(flat, degs, block_cap)
        assert int(row_bytes.sum()) == data.shape[0]
        off = np.zeros(degs.shape[0] + 1, np.int64)
        np.cumsum(row_bytes, out=off[1:])
        out = decode_adjacency(data, degs, block_cap, row_byte_off=off)
        assert np.array_equal(out, flat)

    def test_empty_rows_cost_zero_bytes(self):
        flat = np.array([4, 9, 2], dtype=np.int64)
        degs = np.array([0, 2, 0, 1, 0], dtype=np.int64)
        data, row_bytes = encode_adjacency(flat, degs)
        assert np.array_equal(row_bytes[degs == 0], [0, 0, 0])
        assert np.array_equal(decode_adjacency(data, degs), flat)

    def test_rejects_unsorted_row(self):
        flat = np.array([5, 3], dtype=np.int64)  # decreasing
        degs = np.array([2], dtype=np.int64)
        with pytest.raises(ValueError, match="strictly sorted"):
            encode_adjacency(flat, degs)

    def test_rejects_duplicate_in_row(self):
        flat = np.array([3, 3], dtype=np.int64)  # delta 0
        degs = np.array([2], dtype=np.int64)
        with pytest.raises(ValueError, match="strictly sorted"):
            encode_adjacency(flat, degs)

    def test_rejects_degs_flat_mismatch(self):
        with pytest.raises(ValueError, match="degs sums"):
            encode_adjacency(
                np.array([1, 2], np.int64), np.array([3], np.int64)
            )

    def test_rejects_bad_block_cap(self):
        with pytest.raises(ValueError, match="block_cap"):
            encode_adjacency(np.empty(0, np.int64), np.empty(0, np.int64), 0)

    def test_offset_index_catches_shifted_rows(self):
        # a corrupt varint that changes byte widths shifts every later row;
        # the row_byte_off cross-check must refuse to decode
        flat = np.array([200, 300, 400, 7, 9], dtype=np.int64)
        degs = np.array([3, 2], dtype=np.int64)
        data, row_bytes = encode_adjacency(flat, degs)
        off = np.zeros(3, np.int64)
        np.cumsum(row_bytes, out=off[1:])
        bad = data.copy()
        # 200 encodes as 2 bytes; rewrite to a 1-byte value => widths shift
        one_byte, _ = varint_encode(np.array([5], np.int64))
        bad = np.concatenate([one_byte, data[2:]])
        with pytest.raises(ValueError):
            decode_adjacency(bad, degs, row_byte_off=off)


# ----------------------------------------------- property tests (hypothesis)
if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @st.composite
    def adjacency_rows(draw):
        num_rows = draw(st.integers(min_value=1, max_value=20))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        rng = np.random.default_rng(seed)
        return _sorted_rows(rng, num_rows, max_id=2**40, max_deg=300)

    class TestCodecProperties:
        @settings(max_examples=60, deadline=None)
        @given(
            vals=st.lists(
                st.integers(min_value=0, max_value=2**63 - 1),
                max_size=200,
            )
        )
        def test_varint_roundtrip(self, vals):
            arr = np.array(vals, dtype=np.int64)
            buf, nb = varint_encode(arr)
            out, _ = varint_decode(buf, count=arr.shape[0])
            assert np.array_equal(out, arr)

        @settings(max_examples=40, deadline=None)
        @given(
            rows=adjacency_rows(),
            block_cap=st.integers(min_value=1, max_value=128),
        )
        def test_adjacency_roundtrip(self, rows, block_cap):
            flat, degs = rows
            data, row_bytes = encode_adjacency(flat, degs, block_cap)
            off = np.zeros(degs.shape[0] + 1, np.int64)
            np.cumsum(row_bytes, out=off[1:])
            out = decode_adjacency(data, degs, block_cap, row_byte_off=off)
            assert np.array_equal(out, flat)

        @settings(max_examples=30, deadline=None)
        @given(
            rows=adjacency_rows(),
            cut=st.integers(min_value=1, max_value=64),
        )
        def test_truncated_data_never_decodes_silently(self, rows, cut):
            flat, degs = rows
            data, _ = encode_adjacency(flat, degs)
            if data.shape[0] == 0:
                return
            cut = min(cut, data.shape[0])
            with pytest.raises(ValueError):
                decode_adjacency(data[:-cut], degs)
else:  # pragma: no cover - exercised only without hypothesis

    class TestCodecProperties:
        def test_property_suite_needs_hypothesis(self):
            pytest.importorskip("hypothesis")


# ------------------------------------------------------------- v2 file format
@pytest.fixture(scope="module")
def graph():
    return rmat_graph(4000, avg_degree=12, seed=11)


@pytest.fixture(scope="module")
def v2_bin(graph, tmp_path_factory):
    path = tmp_path_factory.mktemp("v2") / "graph.bin"
    convert_csr(graph, path)  # v2 is the converter default
    return str(path)


class TestV2Format:
    def test_v2_decodes_bit_identical(self, graph, v2_bin):
        ext = ExternalCSRGraph(v2_bin)
        assert ext.format_version == FORMAT_VERSION_V2
        assert np.array_equal(np.asarray(ext.indptr), graph.indptr)
        assert np.array_equal(np.asarray(ext.indices), graph.indices)
        # per-row and slice reads agree with the resident CSR too
        for v in (0, 1, 17, graph.num_vertices - 1):
            lo, hi = graph.indptr[v], graph.indptr[v + 1]
            assert np.array_equal(ext.indices[lo:hi], graph.indices[lo:hi])

    def test_v1_files_still_load(self, graph, tmp_path):
        path = tmp_path / "v1.bin"
        # writer default stays v1
        write_external_csr(path, graph.indptr, graph.indices)
        ext = ExternalCSRGraph(path)
        assert ext.format_version == FORMAT_VERSION
        assert np.array_equal(np.asarray(ext.indices), graph.indices)
        assert ext.nbytes_compressed == 0

    def test_compression_ratio_on_power_law(self, graph, v2_bin):
        # acceptance bar: >= 1.4x on power-law (R-MAT) graphs
        file_bytes = os.path.getsize(v2_bin)
        raw = raw_file_bytes(graph.num_vertices, graph.indices.shape[0])
        assert raw / file_bytes >= 1.4

    def test_decode_accounting_advances(self, v2_bin):
        ext = ExternalCSRGraph(v2_bin)
        before = ext.indices.decode_calls
        _ = ext.indices[int(ext.indptr[0]):int(ext.indptr[10])]
        assert ext.indices.decode_calls > before
        assert ext.indices.decode_seconds >= 0.0

    def test_corrupt_data_region_rejected(self, graph, v2_bin, tmp_path):
        data = bytearray(open(v2_bin, "rb").read())
        # flip continuation bits across the tail of the varint data region
        for i in range(len(data) - 64, len(data)):
            data[i] |= 0x80
        bad = tmp_path / "bad.bin"
        bad.write_bytes(bytes(data))
        ext = ExternalCSRGraph(bad)
        with pytest.raises(ValueError):
            np.asarray(ext.indices)

    def test_truncated_v2_rejected(self, v2_bin, tmp_path):
        data = open(v2_bin, "rb").read()
        bad = tmp_path / "short.bin"
        bad.write_bytes(data[:-16])
        with pytest.raises(ValueError, match="truncated"):
            ExternalCSRGraph(bad)

    def test_bad_header_geometry_rejected(self, v2_bin, tmp_path):
        data = bytearray(open(v2_bin, "rb").read())
        # block_cap=0 (header offset 40) is never valid for a v2 file
        struct.pack_into("<I", data, 40, 0)
        bad = tmp_path / "cap0.bin"
        bad.write_bytes(bytes(data))
        with pytest.raises(ValueError, match="block_cap"):
            ExternalCSRGraph(bad)


# --------------------------------------------------------- parallel converter
class TestParallelConverter:
    def _edges(self, tmp_path, n=3000, seed=5):
        g = rmat_graph(n, avg_degree=10, seed=seed)
        path = tmp_path / "edges.npy"
        np.save(path, g.edges_array())
        return g, str(path)

    def test_workers_do_not_change_bytes(self, tmp_path):
        g, edges = self._edges(tmp_path)
        outs = []
        for w in (1, 4):
            out = tmp_path / f"w{w}.bin"
            stats = convert_edge_list(
                edges, out, num_vertices=g.num_vertices, max_workers=w,
                chunk_edges=4096,
            )
            assert stats["format_version"] == FORMAT_VERSION_V2
            outs.append(out.read_bytes())
        assert outs[0] == outs[1]  # byte-identical output file

    def test_parallel_output_matches_resident(self, tmp_path):
        g, edges = self._edges(tmp_path)
        out = tmp_path / "par.bin"
        convert_edge_list(
            edges, out, num_vertices=g.num_vertices, max_workers=4,
            chunk_edges=4096,
        )
        ext = ExternalCSRGraph(out)
        assert np.array_equal(np.asarray(ext.indptr), g.indptr)
        assert np.array_equal(np.asarray(ext.indices), g.indices)

    def test_failure_leaves_no_partial_files(self, tmp_path, monkeypatch):
        g, edges = self._edges(tmp_path)
        out = tmp_path / "fail.bin"
        scratch = tmp_path / "scratch"
        scratch.mkdir()
        from repro.graph import external as ext_mod

        def boom(*a, **kw):
            raise RuntimeError("injected compression failure")

        monkeypatch.setattr(ext_mod, "_encode_row_range", boom)
        with pytest.raises(RuntimeError, match="injected"):
            convert_edge_list(
                edges, out, num_vertices=g.num_vertices,
                tmp_dir=str(scratch),
            )
        assert not out.exists()  # no partial graph file
        assert list(scratch.iterdir()) == []  # all spill runs cleaned up


# ---------------------------------------------------------------- prefetcher
class TestBatchPrefetcher:
    def test_results_in_submission_order(self):
        stats = PrefetchStats()
        pf = BatchPrefetcher(lambda x: x * x, range(20), stats=stats)
        assert list(pf) == [x * x for x in range(20)]
        assert stats.hits + stats.misses == 20
        assert stats.decode_wall_s >= 0.0

    def test_slow_consumer_hits(self):
        stats = PrefetchStats()
        pf = BatchPrefetcher(lambda x: x, range(5), stats=stats)
        out = []
        for v in pf:
            time.sleep(0.01)  # consumer slower than fetch => decoded ahead
            out.append(v)
        assert out == list(range(5))
        assert stats.hits >= 3
        assert 0.0 <= stats.hit_rate <= 1.0

    def test_fetch_exception_surfaces(self):
        def fetch(x):
            if x == 3:
                raise KeyError("boom")
            return x

        pf = BatchPrefetcher(fetch, range(6), depth=1)
        try:
            assert next(pf) == 0
            assert next(pf) == 1
            assert next(pf) == 2
            with pytest.raises(KeyError):
                next(pf)
        finally:
            pf.close()

    def test_close_is_idempotent_and_stops_work(self):
        started = threading.Event()

        def fetch(x):
            started.wait(1.0)
            return x

        pf = BatchPrefetcher(fetch, range(100), depth=2)
        started.set()
        assert next(pf) == 0
        pf.close()
        pf.close()  # second close is a no-op
        with pytest.raises(StopIteration):
            next(pf)

    def test_rejects_bad_depth(self):
        with pytest.raises(ValueError, match="depth"):
            BatchPrefetcher(lambda x: x, range(3), depth=0)

    def test_telemetry_shape(self):
        stats = PrefetchStats()
        stats.record_wait(0.5, hit=True)
        stats.record_wait(0.25, hit=False)
        stats.record_decode(1.5)
        tel = stats.to_telemetry()
        assert tel == {
            "prefetch_hit_rate": 0.5,
            "prefetch_wait_s": 0.75,
            "decode_wall_s": 1.5,
        }


# -------------------------------------------------------- prefetch == inline
class TestPrefetchParity:
    @pytest.mark.parametrize("algo,params", [
        ("fennel", None),
        ("cuttana", None),
        ("cuttana-parallel", {"num_shards": 4}),
        ("fennel-parallel", {"num_shards": 4}),
    ])
    def test_on_off_auto_bit_identical(self, graph, v2_bin, algo, params):
        ext = ExternalCSRGraph(v2_bin)
        outs = {}
        for mode in ("on", "off", "auto"):
            p = dict(params or {}, prefetch=mode)
            spec = PartitionSpec(
                algo=algo, k=6, balance_mode="edge", order="random",
                seed=2, params=p,
            )
            outs[mode] = partition(ext, spec).assignment
        assert np.array_equal(outs["on"], outs["off"])
        assert np.array_equal(outs["on"], outs["auto"])
        # and the mapped stream matches the fully resident run
        spec = PartitionSpec(
            algo=algo, k=6, balance_mode="edge", order="random",
            seed=2, params=params,
        )
        assert np.array_equal(outs["auto"], partition(graph, spec).assignment)

    def test_mapped_run_reports_prefetch_telemetry(self, v2_bin):
        ext = ExternalCSRGraph(v2_bin)
        result = partition(ext, PartitionSpec(algo="fennel", k=4))
        tel = result.telemetry
        assert 0.0 <= tel["prefetch_hit_rate"] <= 1.0
        assert tel["decode_wall_s"] >= 0.0
        assert tel["compressed_graph_bytes"] > 0

    def test_resident_auto_reports_no_prefetch_telemetry(self, graph):
        result = partition(graph, PartitionSpec(algo="fennel", k=4))
        assert "prefetch_hit_rate" not in result.telemetry
        assert result.telemetry["compressed_graph_bytes"] == 0


# ------------------------------------------------------------ knob threading
class TestPrefetchKnob:
    def test_spec_rejects_bad_mode(self):
        with pytest.raises(ValueError, match="prefetch"):
            PartitionSpec(
                algo="fennel", k=4, params={"prefetch": "sometimes"}
            )

    def test_spec_round_trips_prefetch(self):
        spec = PartitionSpec(
            algo="cuttana-parallel", k=4,
            params={"num_shards": 2, "prefetch": "off"},
        )
        again = PartitionSpec.from_json(spec.to_json())
        assert again == spec
        assert again.params.prefetch == "off"

    def test_cli_prefetch_flag(self, v2_bin, tmp_path):
        from repro.api.cli import main as cli_main

        spec_path = tmp_path / "spec.json"
        spec_path.write_text(json.dumps({"algo": "fennel", "k": 4}))
        out = tmp_path / "report.json"
        rc = cli_main([
            "partition", "--spec", str(spec_path), "--graph", v2_bin,
            "--prefetch", "off", "--out", str(out),
        ])
        assert rc == 0
        report = json.loads(out.read_text())
        assert report["spec"]["params"]["prefetch"] == "off"

    def test_cli_prefetch_rejected_for_knobless_algo(self, v2_bin, tmp_path):
        from repro.api.cli import main as cli_main

        spec_path = tmp_path / "spec.json"
        spec_path.write_text(json.dumps({"algo": "hash", "k": 4}))
        with pytest.raises(SystemExit, match="prefetch"):
            cli_main([
                "partition", "--spec", str(spec_path), "--graph", v2_bin,
                "--prefetch", "on",
            ])
