"""Incremental repartitioning under churn (repro.core.incremental +
repro.graph.churn): parity pins, determinism, drift bookkeeping."""
from __future__ import annotations

import numpy as np
import pytest

from repro.api import PartitionSpec, partition
from repro.core import fennel
from repro.core.incremental import (
    IncrementalPartitioner,
    partition_incremental,
    update,
)
from repro.graph import edge_cut
from repro.graph.churn import ChurnStream, churn_from_graph, rmat_churn
from repro.graph.csr import CSRGraph
from repro.graph.generators import rmat_graph

K = 8


@pytest.fixture(scope="module")
def graph():
    """R-MAT plus a path so no vertex is isolated (the parity pin needs
    every vertex to appear in the edge stream)."""
    g0 = rmat_graph(3000, avg_degree=8, seed=1)
    path = np.stack(
        [np.arange(g0.num_vertices - 1), np.arange(1, g0.num_vertices)], axis=1
    )
    g = CSRGraph.from_edges(
        np.concatenate([g0.edges_array(), path]), num_vertices=g0.num_vertices
    )
    assert (g.degrees > 0).all()
    return g


@pytest.fixture(scope="module")
def stream(graph):
    return churn_from_graph(graph)


# --------------------------------------------------------------- ChurnStream
def test_churn_stream_canonicalizes():
    edges = np.array([[1, 2], [3, 3], [2, 1], [0, 4], [4, 0], [2, 5]])
    st = ChurnStream.from_edges(edges)
    # self loop dropped, duplicates keep first arrival, canonical (lo, hi)
    assert st.edges.tolist() == [[1, 2], [0, 4], [2, 5]]
    assert np.all(np.diff(st.timestamps) >= 0)
    assert st.num_vertices == 6


def test_churn_stream_timestamp_sort_and_windows():
    edges = np.array([[0, 1], [2, 3], [4, 5]])
    st = ChurnStream.from_edges(edges, timestamps=[5.0, 1.0, 3.0])
    assert st.edges.tolist() == [[2, 3], [4, 5], [0, 1]]
    # half-open [t0 + i*span, t0 + (i+1)*span) windows: 1 -> w0, 3 -> w1, 5 -> w2
    wins = st.windows(2.0)
    assert [w.tolist() for w in wins] == [[[2, 3]], [[4, 5]], [[0, 1]]]


def test_churn_stream_batches_and_final_graph(graph, stream):
    batches = stream.batches(7)
    assert len(batches) == 7
    assert sum(b.shape[0] for b in batches) == stream.num_edges
    final = stream.final_graph()
    assert final.num_edges == graph.num_edges
    assert np.array_equal(final.indptr, graph.indptr)
    assert np.array_equal(final.indices, graph.indices)


def test_churn_stream_save_load_round_trip(tmp_path):
    st = rmat_churn(500, avg_degree=6, seed=3)
    path = str(tmp_path / "stream.npz")
    st.save(path)
    back = ChurnStream.load(path)
    assert back.num_vertices == st.num_vertices
    assert np.array_equal(back.edges, st.edges)
    assert np.array_equal(back.timestamps, st.timestamps)


def test_rmat_churn_orderings_same_edge_set():
    growth = rmat_churn(1000, avg_degree=8, seed=2, ordering="growth")
    rand = rmat_churn(1000, avg_degree=8, seed=2, ordering="random")
    key = lambda st: set(map(tuple, st.edges.tolist()))
    assert key(growth) == key(rand)
    # growth ordering: the later endpoint is nondecreasing over the stream
    later = np.maximum(growth.edges[:, 0], growth.edges[:, 1])
    assert np.all(np.diff(later) >= 0)
    with pytest.raises(ValueError, match="ordering"):
        rmat_churn(100, seed=0, ordering="sorted")


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("order", ["natural", "random"])
@pytest.mark.parametrize("mode", ["vertex", "edge"])
def test_single_batch_matches_one_shot_fennel(graph, order, mode):
    """Replaying the whole stream as ONE batch is exactly the one-shot
    streaming partitioner: same vertex order, same neighbourhoods, same
    live loads - bit-identical assignments."""
    inc = partition_incremental(
        graph, K, balance_mode=mode, order=order, seed=3, num_batches=1
    )
    base = fennel.partition(graph, K, balance_mode=mode, order=order, seed=3)
    assert np.array_equal(inc, base)


def test_spec_run_matches_bare_callable(graph):
    spec = PartitionSpec(
        algo="cuttana-incremental", k=K, params={"num_batches": 4}
    )
    result = partition(graph, spec)
    bare = partition_incremental(graph, K, num_batches=4)
    assert np.array_equal(result.assignment, bare)
    assert result.telemetry["batches"] == 4
    assert "stream_seconds" in result.timings


# ------------------------------------------------------------- degenerate
def test_empty_batches_are_noops(graph, stream):
    inc = IncrementalPartitioner(graph.num_vertices, K)
    out = inc.ingest(np.empty((0, 2), dtype=np.int64))
    assert out == {"new_vertices": 0, "moved": 0, "edge_cut": 0.0}
    # interleaving empty batches never changes the result
    ref = IncrementalPartitioner(graph.num_vertices, K)
    for b in stream.batches(4):
        ref.ingest(b)
    mixed = IncrementalPartitioner(graph.num_vertices, K)
    for b in stream.batches(4):
        mixed.ingest(np.empty((0, 2), dtype=np.int64))
        mixed.ingest(b)
    assert np.array_equal(ref.finalize(), mixed.finalize())


def test_duplicate_edges_across_batches_dropped(graph, stream):
    inc = IncrementalPartitioner(graph.num_vertices, K)
    batches = stream.batches(3)
    for b in batches:
        inc.ingest(b)
    m_before, cut_before = inc.m, inc.cut
    out = inc.ingest(batches[0])  # replay an old batch: all duplicates
    assert out["new_vertices"] == 0
    assert (inc.m, inc.cut) == (m_before, cut_before)


def test_never_seen_vertices_assigned_at_finalize():
    # vertex 5 of 6 never appears in any edge
    inc = IncrementalPartitioner(6, 3)
    inc.ingest(np.array([[0, 1], [1, 2], [2, 3], [3, 4]]))
    part = inc.finalize()
    assert part.shape == (6,)
    assert (part >= 0).all() and (part < 3).all()
    assert inc.state.v_counts.sum() == 6


# ---------------------------------------------------------------- drift
def test_drift_never_fires_means_zero_moves(graph, stream):
    inc = IncrementalPartitioner(
        graph.num_vertices, K, drift_threshold=1e9
    )
    for b in stream.batches(12):
        inc.ingest(b)
    inc.finalize()
    assert inc.restream_windows == 0
    assert inc.moved_vertices == 0
    assert inc.drift_before == [] and inc.drift_after == []
    assert inc.stream_work == graph.num_vertices


def test_drift_triggers_windowed_restream_and_improves_cut():
    st = rmat_churn(4000, avg_degree=12, seed=9, ordering="random")
    g = st.final_graph()
    inc = IncrementalPartitioner(
        st.num_vertices, K, drift_threshold=0.05, seed=9
    )
    for b in st.batches(10):
        inc.ingest(b)
    seen = inc.seen  # vertices placed by streaming (rest are isolated)
    part = inc.finalize()
    isolated = st.num_vertices - seen
    assert inc.restream_windows > 0
    assert inc.moved_vertices > 0
    assert len(inc.drift_before) == len(inc.drift_after) == inc.restream_windows
    # every window strictly improved (or held) the tracked cut
    for before, after in zip(inc.drift_before, inc.drift_after):
        assert after <= before + 1e-12
    # telemetry maps the window bookkeeping onto BufferStats
    tel = inc.telemetry()
    assert (
        tel["buffer_drained"]
        == inc.stream_work - inc.new_vertices - isolated
    )
    assert tel["buffer_evictions"] == inc.moved_vertices
    assert tel["degree_bypass"] == inc.new_vertices
    assert tel["buffer_strategy"] == "incremental-window"
    # internal cut counter is exact
    assert inc.cut / max(inc.m, 1) == pytest.approx(edge_cut(g, part))


def test_load_invariants_after_churn(graph, stream):
    inc = IncrementalPartitioner(graph.num_vertices, K, drift_threshold=0.02)
    for b in stream.batches(9):
        inc.ingest(b)
    part = inc.finalize()
    deg = graph.degrees.astype(np.float64)
    assert np.allclose(
        inc.state.e_counts, np.bincount(part, weights=deg, minlength=K)
    )
    assert np.allclose(inc.state.v_counts, np.bincount(part, minlength=K))


# ----------------------------------------------------------- determinism
@pytest.mark.parametrize("workers", [1, 2, 8])
def test_deterministic_across_max_workers(graph, workers):
    ref = partition_incremental(
        graph, K, num_batches=6, num_shards=4, max_workers=1,
        drift_threshold=0.02,
    )
    got = partition_incremental(
        graph, K, num_batches=6, num_shards=4, max_workers=workers,
        drift_threshold=0.02,
    )
    assert np.array_equal(ref, got)


def test_repeat_runs_identical(graph):
    a = partition_incremental(graph, K, num_batches=5, seed=11)
    b = partition_incremental(graph, K, num_batches=5, seed=11)
    assert np.array_equal(a, b)


# ------------------------------------------------------------------ update
def test_update_warm_start_accumulates(tmp_path):
    st = rmat_churn(2000, avg_degree=8, seed=5)
    half = st.num_edges // 2
    first = ChurnStream.from_edges(
        st.edges[:half], num_vertices=st.num_vertices
    )
    rest = ChurnStream.from_edges(
        st.edges[half:], num_vertices=st.num_vertices
    )
    cold = update(None, first, k=4)
    assert cold.telemetry["warm_start"] is False
    warm = update(cold, rest)
    assert warm.telemetry["warm_start"] is True
    assert warm.graph.num_edges == st.num_edges
    assert warm.assignment.shape == (st.num_vertices,)
    assert warm.spec.algo == "cuttana-incremental"
    lam = edge_cut(warm.graph, warm.assignment)
    assert warm.telemetry["edge_cut_live"] == pytest.approx(lam)
    # warm start streams only the NEW arrivals, not the prior graph
    assert warm.telemetry["new_vertices"] < st.num_vertices


def test_update_requires_k_on_cold_start():
    with pytest.raises(ValueError, match="needs k"):
        update(None, [np.array([[0, 1]])])


# ------------------------------------------------------------- spec knobs
def test_spec_validates_incremental_knobs():
    with pytest.raises(ValueError, match="num_batches"):
        PartitionSpec(
            algo="cuttana-incremental", k=2, params={"num_batches": 0}
        )
    with pytest.raises(ValueError, match="drift_threshold"):
        PartitionSpec(
            algo="cuttana-incremental", k=2, params={"drift_threshold": -0.1}
        )
    with pytest.raises(ValueError, match="window_frac"):
        PartitionSpec(
            algo="cuttana-incremental", k=2, params={"window_frac": 0.0}
        )
    with pytest.raises(ValueError, match="window_frac"):
        PartitionSpec(
            algo="cuttana-incremental", k=2, params={"window_frac": 1.5}
        )
    spec = PartitionSpec(
        algo="cuttana-incremental", k=2, params={"num_shards": "auto"}
    )
    assert spec.params.num_shards == 0
