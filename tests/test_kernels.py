"""Per-kernel interpret-mode validation: sweep shapes/dtypes, allclose vs
the pure-jnp oracle in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ell_spmv.ops import ell_spmv
from repro.kernels.ell_spmv.ref import ell_spmv_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ops import selective_scan
from repro.kernels.mamba_scan.ref import selective_scan_ref
from repro.kernels.partition_score.ops import fennel_scores
from repro.kernels.partition_score.ref import fennel_scores_ref


# ------------------------------------------------------------ partition_score
@pytest.mark.parametrize("b,d,k", [(8, 16, 4), (128, 128, 8), (200, 100, 16),
                                    (256, 64, 128), (64, 256, 32)])
def test_partition_score_matches_ref(b, d, k):
    rng = np.random.default_rng(b * 1000 + d + k)
    nbr = rng.integers(-1, k, size=(b, d)).astype(np.int32)
    sizes = rng.random(k).astype(np.float32) * 100
    alpha, gamma = 0.37, 1.5
    got = fennel_scores(nbr, sizes, alpha, gamma, use_pallas=True, interpret=True)
    want = fennel_scores_ref(jnp.asarray(nbr), jnp.asarray(sizes), alpha, gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_partition_score_argmax_agrees_with_streaming_scores():
    """The kernel must reproduce the host partitioner's scoring decisions."""
    from repro.core.base import FennelParams, PartitionState, make_fennel_score
    from repro.graph import rmat_graph

    g = rmat_graph(500, avg_degree=8, seed=0)
    k = 8
    state = PartitionState.create(g, k, 0.1, "vertex", seed=0)
    rng = np.random.default_rng(0)
    state.part_of[:] = rng.integers(0, k, size=g.num_vertices)
    state.v_counts[:] = np.bincount(state.part_of, minlength=k)
    score_fn = make_fennel_score(g, k, FennelParams(hybrid=False), "vertex")
    n, m = g.num_vertices, g.num_edges
    alpha = np.sqrt(k) * m / n**1.5

    batch = rng.integers(0, g.num_vertices, size=64)
    dmax = int(g.degrees[batch].max())
    nbr_parts = np.full((64, max(dmax, 1)), -1, np.int32)
    for i, v in enumerate(batch):
        nb = g.neighbors(int(v))
        nbr_parts[i, : nb.size] = state.part_of[nb]
    got = np.asarray(
        fennel_scores(nbr_parts, state.v_counts.astype(np.float32), alpha,
                      use_pallas=True, interpret=True)
    )
    for i, v in enumerate(batch):
        hist = state.neighbor_histogram(g.neighbors(int(v)))
        want = score_fn(state, hist)
        np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ ell_spmv
@pytest.mark.parametrize("reduce", ["sum", "min"])
@pytest.mark.parametrize("r,d,v", [(16, 8, 64), (128, 32, 300), (333, 17, 1000)])
def test_ell_spmv_matches_ref(reduce, r, d, v):
    rng = np.random.default_rng(r + d)
    x = np.concatenate([
        rng.random(v).astype(np.float32),
        [0.0 if reduce == "sum" else 3e38],
    ]).astype(np.float32)
    cols = rng.integers(0, v + 1, size=(r, d)).astype(np.int32)
    got = ell_spmv(x, cols, reduce=reduce, use_pallas=True, interpret=True)
    want = ell_spmv_ref(jnp.asarray(x), jnp.asarray(cols), reduce)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_ell_spmv_engine_equivalence():
    """Kernel computes the same gather/sum the analytics engine uses."""
    from repro.analytics import localize, pagerank_program, GraphEngine
    from repro.core import get_partitioner
    from repro.graph import rmat_graph

    g = rmat_graph(400, avg_degree=6, seed=1)
    part = get_partitioner("fennel")(g, 2, seed=0)
    lg = localize(g, part, 2)
    p = 0
    rng = np.random.default_rng(0)
    full = rng.random(lg.state_len).astype(np.float32)
    full[lg.identity_slot] = 0.0
    # pack device p's CSR slots into ELL rows
    deg = np.zeros(lg.v_max, np.int64)
    rows, cols = lg.rows[p], lg.cols[p]
    real = rows != lg.v_max
    np.add.at(deg, rows[real], 1)
    width = max(int(deg.max()), 1)
    ell = np.full((lg.v_max, width), lg.identity_slot, np.int32)
    fill = np.zeros(lg.v_max, np.int64)
    for rr, cc in zip(rows[real], cols[real]):
        ell[rr, fill[rr]] = cc
        fill[rr] += 1
    got = np.asarray(ell_spmv(full, ell, "sum", use_pallas=True, interpret=True))
    want = np.zeros(lg.v_max, np.float32)
    np.add.at(want, rows[real], full[cols[real]])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------ flash_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,tq,tk,dh,causal,window",
    [
        (1, 2, 2, 128, 128, 64, True, None),
        (2, 4, 2, 128, 128, 64, True, None),   # GQA
        (1, 2, 1, 256, 256, 32, False, None),  # bidirectional
        (1, 2, 2, 128, 128, 64, True, 32),     # sliding window
        (2, 2, 2, 64, 64, 128, True, None),    # small seq
    ],
)
def test_flash_attention_matches_ref(b, hq, hkv, tq, tk, dh, causal, window, dtype):
    rng = np.random.default_rng(tq + dh)
    q = rng.standard_normal((b, hq, tq, dh)).astype(np.float32)
    k = rng.standard_normal((b, hkv, tk, dh)).astype(np.float32)
    v = rng.standard_normal((b, hkv, tk, dh)).astype(np.float32)
    q, k, v = (jnp.asarray(t, dtype) for t in (q, k, v))
    got = flash_attention(q, k, v, causal=causal, window=window,
                          use_pallas=True, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_attention_decode_offset():
    """One-token decode against a long KV cache (q_offset = Tk-1)."""
    rng = np.random.default_rng(0)
    b, h, tk, dh = 2, 4, 256, 64
    q = jnp.asarray(rng.standard_normal((b, h, 1, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, tk, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, tk, dh)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, q_offset=tk - 1,
                          use_pallas=True, interpret=True)
    want = attention_ref(q, k, v, causal=True, q_offset=tk - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# q_offset sweep around the kv-block boundary (block_k = 128): 0, 1, one
# below the block, exactly the block, and a non-multiple - the offsets where
# the seed's int-index drift (and any future regression of the decode path)
# changes which kv blocks the loop bounds visit.
@pytest.mark.parametrize("q_offset", [0, 1, 127, 128, 200])
@pytest.mark.parametrize("tq", [1, 4])
def test_flash_attention_decode_offset_sweep(q_offset, tq):
    rng = np.random.default_rng(q_offset * 7 + tq)
    b, h, tk, dh = 2, 4, 256, 64
    q = jnp.asarray(rng.standard_normal((b, h, tq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, tk, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, tk, dh)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, q_offset=q_offset,
                          use_pallas=True, interpret=True)
    want = attention_ref(q, k, v, causal=True, q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------- mamba_scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bsz,t,d,n", [(1, 16, 64, 8), (2, 32, 128, 16), (2, 8, 512, 16)])
def test_mamba_scan_matches_ref(bsz, t, d, n, dtype):
    rng = np.random.default_rng(d + t)
    x = jnp.asarray(rng.standard_normal((bsz, t, d)), dtype)
    dt = jnp.asarray(np.abs(rng.standard_normal((bsz, t, d))) * 0.1 + 0.01, dtype)
    a = jnp.asarray(-np.abs(rng.standard_normal((d, n))) - 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((bsz, t, n)), dtype)
    c = jnp.asarray(rng.standard_normal((bsz, t, n)), dtype)
    dskip = jnp.asarray(rng.standard_normal(d), jnp.float32)
    y_got, h_got = selective_scan(x, dt, a, b, c, dskip, use_pallas=True,
                                  interpret=True, block_d=64)
    y_want, h_want = selective_scan_ref(x, dt, a, b, c, dskip)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y_got, np.float32),
                               np.asarray(y_want, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                               rtol=tol, atol=tol)
