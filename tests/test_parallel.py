"""Shard-parallel engine tests: ShardedStream, num_shards=1 bit-parity with
the sequential engine, bounded quality regression for S in {2, 4, 8},
superstep telemetry, and the vectorized Refiner's invariants.

The parity contract: ``num_shards=1`` is *defined* as the sequential engine,
so ``cuttana-parallel``/``fennel-parallel`` at S=1 must return assignments
bit-identical to ``cuttana``/``fennel`` for every stream order. For S >= 2
the bulk-synchronous relaxation may change assignments, but edge-cut must
stay within 10% of the sequential baseline on R-MAT (the paper's "nearly the
same quality" claim, backed by the merge + coarsen + refine reconciliation).
"""
import numpy as np
import pytest

from repro.api import PartitionSpec, partition
from repro.core.cuttana import partition as cuttana_partition
from repro.core.fennel import partition as fennel_partition
from repro.core.parallel import fennel_parallel, partition_parallel
from repro.graph import edge_cut, rmat_graph
from repro.graph.stream import ShardedStream, stream_order

ORDERS = ("natural", "random", "bfs", "dfs")


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(4000, avg_degree=10, seed=3)


@pytest.fixture(scope="module")
def small_graph():
    return rmat_graph(1200, avg_degree=8, seed=4)


# ------------------------------------------------------------ sharded stream
def test_sharded_stream_partitions_the_order(graph):
    for s in (1, 2, 3, 7):
        sharded = ShardedStream.from_order(graph, s, order="random", seed=5)
        assert sharded.num_shards == s
        assert sharded.num_vertices == graph.num_vertices
        all_ids = np.concatenate(sharded.shards)
        assert np.array_equal(np.sort(all_ids), np.arange(graph.num_vertices))
        # round-robin interleave of the base order
        base = stream_order(graph, "random", 5)
        for i, shard in enumerate(sharded.shards):
            assert np.array_equal(shard, base[i::s])
    one = ShardedStream.from_order(graph, 1, order="bfs", seed=0)
    assert np.array_equal(one.shards[0], stream_order(graph, "bfs", 0))


def test_sharded_stream_superstep_batches(graph):
    sharded = ShardedStream.from_order(graph, 4, order="natural")
    chunk = 128
    steps = list(sharded.superstep_batches(chunk))
    assert len(steps) == sharded.num_supersteps(chunk)
    seen = []
    for batches in steps:
        assert len(batches) == 4
        for shard_batch in batches:
            assert shard_batch.shape[0] <= chunk
            seen.append(shard_batch)
    assert np.array_equal(
        np.sort(np.concatenate(seen)), np.arange(graph.num_vertices)
    )


def test_sharded_stream_shard_of(graph):
    sharded = ShardedStream.from_order(graph, 3, order="random", seed=1)
    shard_of = sharded.shard_of(graph.num_vertices)
    for s, shard in enumerate(sharded.shards):
        assert (shard_of[shard] == s).all()
    assert (shard_of >= 0).all()


def test_sharded_stream_rejects_bad_shard_count():
    with pytest.raises(ValueError, match="num_shards"):
        ShardedStream.from_ids(np.arange(10), 0)


@pytest.mark.parametrize(
    "num_shards,expected_dtype",
    [(1, np.int8), (127, np.int8), (128, np.int16), (200, np.int16),
     (32767, np.int16), (32768, np.int32)],
)
def test_sharded_stream_shard_of_dtype(num_shards, expected_dtype):
    # narrowest signed dtype that fits the shard count; the >127 branch used
    # to silently fall back to int32 against the docstring's int8/int16 promise
    n = max(num_shards * 2, 512)
    sharded = ShardedStream.from_ids(np.arange(n, dtype=np.int64), num_shards)
    shard_of = sharded.shard_of(n)
    assert shard_of.dtype == np.dtype(expected_dtype)
    for s in (0, num_shards - 1):
        assert (shard_of[sharded.shards[s]] == s).all()
    assert int(shard_of.max()) == num_shards - 1


# -------------------------------------------------------- num_shards=1 parity
@pytest.mark.parametrize("order", ORDERS)
def test_parallel_cuttana_single_shard_bit_identical(graph, small_graph, order):
    kw = dict(d_max=32, max_qsize=256, theta=0.7, seed=1)
    for g in (graph, small_graph):
        want = cuttana_partition(g, 4, order=order, **kw)
        got = partition_parallel(g, 4, num_shards=1, order=order, **kw)
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("balance_mode", ["vertex", "edge"])
def test_parallel_fennel_single_shard_bit_identical(small_graph, order, balance_mode):
    want = fennel_partition(
        small_graph, 4, balance_mode=balance_mode, order=order, seed=7
    )
    got = fennel_parallel(
        small_graph, 4, num_shards=1, balance_mode=balance_mode,
        order=order, seed=7,
    )
    np.testing.assert_array_equal(got, want)


def test_parallel_spec_single_shard_matches_sequential_spec(graph):
    seq = partition(graph, PartitionSpec(algo="cuttana", k=4, order="random"))
    par = partition(graph, PartitionSpec(
        algo="cuttana-parallel", k=4, order="random",
        params={"num_shards": 1},
    ))
    np.testing.assert_array_equal(par.assignment, seq.assignment)
    assert par.telemetry["supersteps"] == 0
    assert par.telemetry["num_shards"] == 1


# --------------------------------------------------- S >= 2 quality regression
@pytest.mark.parametrize("num_shards", [2, 4, 8])
def test_parallel_cuttana_quality_within_10_percent(graph, num_shards):
    seq = cuttana_partition(graph, 4, order="random", seed=1)
    ec_seq = edge_cut(graph, seq)
    par = partition_parallel(
        graph, 4, num_shards=num_shards, order="random", seed=1, chunk=128,
    )
    ec_par = edge_cut(graph, par)
    assert (par >= 0).all() and par.shape == seq.shape
    assert ec_par <= 1.10 * ec_seq, (
        f"S={num_shards}: parallel edge-cut {ec_par:.4f} vs "
        f"sequential {ec_seq:.4f}"
    )


@pytest.mark.parametrize("num_shards", [2, 4])
def test_parallel_fennel_quality_within_10_percent(graph, num_shards):
    seq = fennel_partition(graph, 4, balance_mode="edge", order="random", seed=1)
    ec_seq = edge_cut(graph, seq)
    par = fennel_parallel(
        graph, 4, num_shards=num_shards, balance_mode="edge",
        order="random", seed=1, chunk=128,
    )
    assert edge_cut(graph, par) <= 1.10 * ec_seq


def test_parallel_respects_balance_headroom(graph):
    """Per-superstep capacity is split across shards, so merged loads stay
    within the balance condition (up to the least-loaded fallback that the
    sequential engine shares)."""
    k, eps = 4, 0.05
    par = fennel_parallel(
        graph, k, epsilon=eps, num_shards=4, order="random", seed=0,
    )
    counts = np.bincount(par, minlength=k)
    cap = (1.0 + eps) * graph.num_vertices / k
    assert counts.max() <= cap + 1


# ------------------------------------------------------- superstep telemetry
@pytest.mark.parametrize("num_shards", [2, 4])
def test_parallel_superstep_telemetry(graph, num_shards):
    result = partition(graph, PartitionSpec(
        algo="cuttana-parallel", k=4, order="random", seed=1,
        params={"num_shards": num_shards, "chunk": 128},
    ))
    tel = result.telemetry
    assert tel["num_shards"] == num_shards
    assert tel["supersteps"] > 0
    assert 0 < tel["sync_rounds"] <= tel["supersteps"]
    assert tel["boundary_conflicts"] > 0  # cross-shard edges exist on R-MAT
    assert tel["kernel_calls"] == tel["sync_rounds"]
    # enough supersteps to cover the longest shard cursor
    longest = -(-graph.num_vertices // num_shards)
    assert tel["supersteps"] >= -(-longest // 128)
    assert result.timings["phase1_seconds"] > 0


def test_parallel_fennel_telemetry_counts_supersteps(graph):
    tel = {}
    fennel_parallel(graph, 4, num_shards=4, order="random", seed=0,
                    chunk=256, telemetry=tel)
    longest = -(-graph.num_vertices // 4)
    assert tel["supersteps"] == -(-longest // 256)
    assert tel["sync_rounds"] == tel["supersteps"]
    assert tel["num_shards"] == 4


# ------------------------------------------------------------- validation
def test_parallel_num_shards_validation(graph):
    # num_shards=0 now means "auto"; only negatives are rejected
    with pytest.raises(ValueError, match="num_shards"):
        partition_parallel(graph, 4, num_shards=-1)
    with pytest.raises(ValueError, match="num_shards"):
        fennel_parallel(graph, 4, num_shards=-2)
    with pytest.raises(ValueError, match="num_shards"):
        PartitionSpec(algo="cuttana-parallel", k=4, params={"num_shards": -1})
    with pytest.raises(ValueError, match="num_shards"):
        PartitionSpec(algo="fennel-parallel", k=4, params={"num_shards": 1.5})
    with pytest.raises(ValueError, match="max_workers"):
        PartitionSpec(algo="fennel-parallel", k=4, params={"max_workers": -1})
    with pytest.raises(ValueError, match="chunk"):
        PartitionSpec(algo="cuttana-parallel", k=4, params={"chunk": -1})
    # chunk=0 ("auto") is reserved to the parallel algos
    with pytest.raises(ValueError, match="chunk"):
        PartitionSpec(algo="cuttana-restream", k=4, params={"chunk": 0})


def test_num_shards_auto_spec_normalization(graph):
    spec = PartitionSpec(
        algo="fennel-parallel", k=4, params={"num_shards": "auto"}
    )
    assert spec.params.num_shards == 0
    assert PartitionSpec.from_json(spec.to_json()) == spec
    res = partition(graph, spec)
    assert res.assignment.shape == (graph.num_vertices,)
    auto = res.telemetry["autotune"]
    assert auto["num_shards"] == res.telemetry["num_shards"] >= 1
    assert auto["source"] in ("heuristic",) or auto["source"].startswith(
        "artifact:"
    )


def test_sharded_policy_requires_affine_scorer(small_graph):
    from repro.core.base import FennelParams, PartitionState
    from repro.core.engine import (
        FennelScorer,
        ShardedImmediatePolicy,
        StreamEngine,
    )

    class NoAffine:
        def __init__(self, inner):
            self._inner = inner

        def begin(self, state):
            self._inner.begin(state)

        def scores(self, state, hist):
            return self._inner.scores(state, hist)

        def on_assign(self, state, p, deg):
            self._inner.on_assign(state, p, deg)

        def on_unassign(self, state, p, deg):
            self._inner.on_unassign(state, p, deg)

    scorer = NoAffine(FennelScorer(small_graph, 4, FennelParams(), "vertex"))
    state = PartitionState.create(small_graph, 4, 0.05, "vertex", seed=0)
    eng = StreamEngine(
        small_graph, state, scorer, ShardedImmediatePolicy(2), order="natural",
    )
    with pytest.raises(ValueError, match="affine"):
        eng.run()


# ----------------------------------------------------------- kernel parity
def test_parallel_kernel_interpret_matches_host(small_graph):
    """The sharded Pallas kernel (interpret) and the flat host bincount
    companion must produce identical assignments."""
    kw = dict(num_shards=3, order="random", seed=2, chunk=64)
    host = fennel_parallel(small_graph, 4, use_pallas=False, **kw)
    kern = fennel_parallel(small_graph, 4, interpret=True, **kw)
    np.testing.assert_array_equal(host, kern)


def test_sharded_kernel_matches_flat_kernel():
    from repro.kernels.partition_score.ops import fennel_scores, fennel_scores_sharded

    rng = np.random.default_rng(0)
    s, c, d, k = 4, 33, 17, 6
    nbr = rng.integers(-1, k, size=(s, c, d)).astype(np.int32)
    sizes = (rng.random((s, k)) * 9).astype(np.float32)
    out = np.asarray(fennel_scores_sharded(nbr, sizes, 0.5, 1.5, use_pallas=False))
    assert out.shape == (s, c, k)
    for i in range(s):
        flat = np.asarray(fennel_scores(nbr[i], sizes[i], 0.5, 1.5, use_pallas=False))
        np.testing.assert_allclose(out[i], flat, atol=1e-5)


# ------------------------------------------- vectorized refiner invariants
def _make_refiner(seed=0, kp=48, k=4):
    from repro.core.refinement import Refiner

    rng = np.random.default_rng(seed)
    w = rng.random((kp, kp)) * (rng.random((kp, kp)) < 0.3)
    w = np.triu(w, 1)
    w = w + w.T
    sub_part = rng.integers(0, k, size=kp)
    size = rng.random(kp) + 0.25
    return Refiner(w, sub_part, size, k, epsilon=0.5)


def test_refiner_invariants_after_vectorized_moves():
    r = _make_refiner(seed=1)
    r.check_invariants()  # batched construction writes every leaf correctly
    moves = 0
    while moves < 12:
        mv = r.best_move(0.0)
        if mv is None:
            break
        i, dst, dec = mv
        got = r.apply_move(i, dst)
        assert abs(got - dec) < 1e-9
        r.check_invariants()  # every leaf + M + loads after each batched update
        moves += 1
    assert moves > 0


def test_refiner_refine_then_invariants_multiple_shapes():
    for seed, kp, k in ((0, 32, 2), (2, 64, 5), (3, 96, 8)):
        r = _make_refiner(seed=seed, kp=kp, k=k)
        before = r.current_cut()
        stats = r.refine()
        assert r.current_cut() <= before + 1e-9
        assert stats.stopped_reason == "maximal"
        assert r.best_move(0.0) is None
        r.check_invariants()


def test_refiner_invariants_through_parallel_partition(small_graph):
    """End-to-end: cuttana-parallel's phase 2 runs the vectorized refiner on
    real sub-partition graphs; the result must be a valid total assignment."""
    part = partition_parallel(
        small_graph, 4, num_shards=2, order="random", seed=0, chunk=128,
    )
    assert part.shape == (small_graph.num_vertices,)
    assert set(np.unique(part)) <= set(range(4))


# -------------------------------------------------------- parallel restream
@pytest.mark.parametrize("order", ORDERS)
def test_restream_single_shard_bit_identical(small_graph, order):
    """num_shards=1 restream is *defined* as the sequential restream: the
    assignments must match bit-for-bit on every stream order."""
    from repro.core.restream import partition_restream

    seq = partition_restream(small_graph, 4, order=order, seed=7)
    one = partition_restream(small_graph, 4, order=order, seed=7, num_shards=1)
    np.testing.assert_array_equal(seq, one)


@pytest.mark.parametrize("num_shards", (2, 4, 8))
def test_restream_parallel_quality_within_10_percent(graph, num_shards):
    from repro.core.restream import partition_restream

    seq = partition_restream(graph, 8, order="random", seed=1)
    par = partition_restream(
        graph, 8, order="random", seed=1, num_shards=num_shards
    )
    assert set(np.unique(par)) <= set(range(8))
    ratio = edge_cut(graph, par) / max(edge_cut(graph, seq), 1)
    assert ratio <= 1.10, f"S={num_shards} edge-cut ratio {ratio:.3f}"


def test_restream_parallel_via_spec(small_graph):
    spec = PartitionSpec(
        algo="cuttana-restream", k=4, order="random",
        params={"num_shards": 2, "passes": 2},
    )
    res = partition(small_graph, spec)
    assert res.assignment.shape == (small_graph.num_vertices,)
    assert res.telemetry["num_shards"] == 2


def test_restream_num_shards_validation(graph):
    from repro.core.restream import partition_restream

    with pytest.raises(ValueError, match="num_shards"):
        partition_restream(graph, 4, num_shards=-1)
    with pytest.raises(ValueError, match="num_shards"):
        PartitionSpec(algo="cuttana-restream", k=4, params={"num_shards": -1})


def test_restream_reassign_preserves_load_accounting(small_graph):
    """After a sharded restream pass the shared counts must equal the actual
    assignment histogram (the unassign/assign boundary exchange balances)."""
    from repro.core.base import FennelParams, PartitionState
    from repro.core.engine import (
        FennelScorer,
        ShardedImmediatePolicy,
        StreamEngine,
    )

    g, k = small_graph, 4
    rng = np.random.default_rng(0)
    start = rng.integers(0, k, size=g.num_vertices)
    state = PartitionState.create(g, k, 0.05, "edge", seed=0)
    state.part_of[:] = start
    state.v_counts[:] = np.bincount(start, minlength=k)
    state.e_counts[:] = np.bincount(
        start, weights=g.degrees.astype(np.float64), minlength=k
    )
    eng = StreamEngine(
        g, state,
        FennelScorer(g, k, FennelParams(hybrid=True), "edge"),
        ShardedImmediatePolicy(3, reassign=True),
        order="random", seed=1,
    )
    eng.run()
    np.testing.assert_allclose(
        state.v_counts, np.bincount(state.part_of, minlength=k)
    )
    np.testing.assert_allclose(
        state.e_counts,
        np.bincount(state.part_of, weights=g.degrees.astype(np.float64),
                    minlength=k),
    )
    assert eng.telemetry["supersteps"] > 0
    assert eng.telemetry["num_shards"] == 3
