"""Training substrate: optimizer math, checkpoint roundtrip + atomicity,
data-pipeline determinism/resume, end-to-end crash-restart driver."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.data import TokenPipeline
from repro.train.optimizer import adamw_init, adamw_update
from repro.train.schedule import cosine_schedule


def test_adamw_reduces_quadratic_loss():
    w = jnp.asarray([2.0, -3.0, 1.5])
    params = {"w": w}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, gnorm = adamw_update(
            g, opt, params, lr=0.05, weight_decay=0.0
        )
    assert float(loss(params)) < 1e-2
    assert int(opt.step) == 200


def test_adamw_bf16_states():
    params = {"w": jnp.ones((8, 8))}
    opt = adamw_init(params, jnp.bfloat16)
    g = {"w": jnp.full((8, 8), 0.1)}
    params2, opt2, _ = adamw_update(g, opt, params, lr=0.01)
    assert opt2.m["w"].dtype == jnp.bfloat16
    assert opt2.v["w"].dtype == jnp.bfloat16
    assert not np.isnan(np.asarray(params2["w"], np.float32)).any()


def test_cosine_schedule_shape():
    s = jnp.arange(0, 1000, 100)
    lrs = cosine_schedule(s, 1e-3, warmup=100, total=1000)
    assert float(lrs[0]) == 0.0
    assert float(lrs[1]) == pytest.approx(1e-3, rel=1e-5)
    assert float(lrs[-1]) < 5e-4


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": (jnp.ones((2,), jnp.bfloat16), {"c": jnp.int32(7)}),
    }
    save_checkpoint(str(tmp_path), 5, tree)
    save_checkpoint(str(tmp_path), 10, tree, keep=2)
    assert latest_step(str(tmp_path)) == 10
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"][1]["c"] == 7


def test_checkpoint_keep_n(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree, keep=3)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3
    assert latest_step(str(tmp_path)) == 5


def test_data_pipeline_deterministic_and_resumable():
    a = TokenPipeline(1000, 64, 4, seed=7)
    b1 = next(a)
    b2 = next(a)
    a.close()
    b = TokenPipeline(1000, 64, 4, seed=7)
    c1 = next(b)
    np.testing.assert_array_equal(b1["tokens"], c1["tokens"])
    b.close()
    # resume: skip_to(2) should hand out batch index 2 == b3
    c = TokenPipeline(1000, 64, 4, seed=7)
    b3 = next(TokenPipeline(1000, 64, 4, seed=7, prefetch=4).skip_iter(2)) \
        if hasattr(TokenPipeline, "skip_iter") else None
    c.skip_to(2)
    c2 = next(c)
    d = TokenPipeline(1000, 64, 4, seed=7)
    next(d), next(d)
    d3 = next(d)
    np.testing.assert_array_equal(c2["tokens"], d3["tokens"])
    c.close()
    d.close()
    # labels are tokens shifted by one
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


@pytest.mark.slow
def test_train_driver_crash_restart(tmp_path):
    """Paper-grade FT check: loss path with a crash+restore equals where the
    run would be, and training continues to improve."""
    from repro.launch.train import main as train_main

    ckpt = str(tmp_path / "ckpt")
    with pytest.raises(RuntimeError, match="injected failure"):
        train_main([
            "--arch", "repro-100m", "--steps", "30", "--global-batch", "4",
            "--seq-len", "64", "--ckpt-dir", ckpt, "--ckpt-every", "10",
            "--fail-at", "15", "--log-every", "100",
        ])
    assert latest_step(ckpt) == 10
    loss = train_main([
        "--arch", "repro-100m", "--steps", "30", "--global-batch", "4",
        "--seq-len", "64", "--ckpt-dir", ckpt, "--ckpt-every", "10",
        "--log-every", "100",
    ])
    assert np.isfinite(loss)
    assert latest_step(ckpt) == 30
