"""Graph DB engine: result correctness + partitioner-ordering of throughput."""
import numpy as np
import pytest

from repro.core import get_partitioner
from repro.db import QueryEngine, ldbc_query_mix
from repro.graph import ldbc_like_graph


@pytest.fixture(scope="module")
def graph():
    return ldbc_like_graph(4000, avg_degree=14, seed=0)


def test_one_hop_results_correct(graph):
    part = get_partitioner("cuttana")(graph, 4, seed=0)
    eng = QueryEngine(graph, part, 4)
    seeds = ldbc_query_mix(graph, 50, seed=1)
    results, stats = eng.one_hop(seeds)
    for s, r in zip(seeds, results):
        np.testing.assert_array_equal(np.sort(r), np.sort(graph.neighbors(int(s))))
    assert stats.total_rpcs >= 0 and stats.num_queries == 50


def test_two_hop_results_superset_of_one_hop(graph):
    part = get_partitioner("cuttana")(graph, 4, seed=0)
    eng = QueryEngine(graph, part, 4)
    seeds = ldbc_query_mix(graph, 20, seed=2)
    r1, _ = eng.one_hop(seeds)
    r2, stats2 = eng.two_hop(seeds, fanout_cap=32)
    for a, b in zip(r1, r2):
        assert np.isin(a, b).all()
    assert stats2.total_net_values >= 0


def test_better_partition_higher_throughput(graph):
    """Paper Table V: lower edge-cut + better balance -> more q/s."""
    seeds = ldbc_query_mix(graph, 300, seed=3)
    qps = {}
    for name in ("random", "cuttana"):
        part = get_partitioner(name)(graph, 4, balance_mode="edge", seed=0) \
            if name == "cuttana" else get_partitioner(name)(graph, 4, seed=0)
        eng = QueryEngine(graph, part, 4)
        _, stats = eng.two_hop(seeds)
        qps[name] = stats.throughput_qps()
    assert qps["cuttana"] > qps["random"]


def test_single_partition_no_rpcs(graph):
    part = np.zeros(graph.num_vertices, dtype=np.int32)
    eng = QueryEngine(graph, part, 1)
    seeds = ldbc_query_mix(graph, 25, seed=4)
    _, stats = eng.two_hop(seeds)
    assert stats.total_rpcs == 0
    assert stats.total_net_values == 0
