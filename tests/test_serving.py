"""Partition-aware serving layer: correctness, determinism, and ordering.

The load-bearing invariants:

* query answers are **bit-identical** across every serving configuration -
  partitioner, k, replication budget, worker count, adversarial scheduling
  jitter - and match the analytic DB engine exactly (serving changes *where*
  work happens, never *what* a query returns);
* sim metrics (qps/p99/rpcs/bytes) are deterministic, which is what lets CI
  gate them across runners;
* the analytic throughput model (``QueryStats.throughput_qps``) and the
  measured serving layer **agree on partitioner ordering** (cuttana >=
  random) even though absolute numbers differ;
* ``replication_budget > 0`` cuts cross-partition RPCs at fixed answers.
"""
import json
import random

import numpy as np
import pytest

from repro.api import PartitionSpec, partition
from repro.core import executor
from repro.db.engine import DBCostModel, QueryEngine, QueryStats
from repro.graph import rmat_graph
from repro.serve.graph import (
    QueryMix,
    build_workload,
    plan_replication,
    run_load,
)
from repro.serve.graph.replication import resolve_budget


def _spec(algo, k, seed=3):
    if algo in ("random", "hdrf"):
        return PartitionSpec(algo=algo, k=k, seed=seed)
    return PartitionSpec(
        algo=algo, k=k, balance_mode="edge", order="random", seed=seed
    )


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(600, avg_degree=10, seed=3)


@pytest.fixture(scope="module")
def workload(graph):
    return build_workload(graph, 120, QueryMix(), seed=4)


@pytest.fixture(scope="module")
def ref_report(graph, workload):
    """Reference answers: cuttana k=4, synchronous router, no replication."""
    result = partition(graph, _spec("cuttana", 4))
    return run_load(
        result.serve(max_workers=1), workload=workload, concurrency=16
    )


def _assert_same_answers(rep, ref):
    a, b = rep.answers(), ref.answers()
    assert set(a) == set(b)
    for qid, vb in b.items():
        va = a[qid]
        if isinstance(vb, np.ndarray):
            np.testing.assert_array_equal(va, vb, err_msg=f"qid={qid}")
        else:
            assert va == vb, f"qid={qid}"


# ------------------------------------------------------ answers vs db engine
def test_answers_match_db_engine(graph, workload, ref_report):
    """point == degree; one_hop/two_hop bit-match the analytic QueryEngine."""
    result = partition(graph, _spec("cuttana", 4))
    engine = QueryEngine(graph, result.vertex_assignment(), 4)
    answers = ref_report.answers()
    for qid, (kind, seed) in enumerate(workload):
        got = answers[qid]
        if kind == "point":
            assert got == graph.degree(seed)
        elif kind == "one_hop":
            (want,), _ = engine.one_hop(np.array([seed]))
            np.testing.assert_array_equal(got, want.astype(np.int64))
        else:
            (want,), _ = engine.two_hop(np.array([seed]))
            np.testing.assert_array_equal(got, want)


# -------------------------------------------------- parity across everything
@pytest.mark.parametrize(
    "algo,k,budget,workers",
    [
        ("cuttana", 4, 0.0, 2),
        ("cuttana", 4, 0.0, 8),
        ("cuttana", 2, 0.0, 0),
        ("cuttana", 4, 0.1, 0),
        ("cuttana", 4, 1.0, 1),
        ("random", 4, 0.0, 0),
        ("hdrf", 4, 0.0, 2),
    ],
)
def test_answer_parity_across_configs(
    graph, workload, ref_report, algo, k, budget, workers
):
    result = partition(graph, _spec(algo, k))
    rep = run_load(
        result.serve(replication_budget=budget, max_workers=workers),
        workload=workload,
        concurrency=16,
    )
    _assert_same_answers(rep, ref_report)


def test_answer_parity_under_scheduling_jitter(graph, workload, ref_report):
    """Adversarial jitter on every routed message: answers AND per-query
    message counts must not move (they are per-query/per-phase facts, not
    scheduling accidents)."""
    result = partition(graph, _spec("cuttana", 4))
    clean = run_load(
        result.serve(max_workers=8), workload=workload, concurrency=16
    )
    executor.JITTER = random.Random(0xBADBEEF)
    try:
        rep = run_load(
            result.serve(max_workers=8), workload=workload, concurrency=16
        )
    finally:
        executor.JITTER = None
    _assert_same_answers(rep, ref_report)
    assert rep.rpcs == clean.rpcs
    assert rep.wire_bytes == clean.wire_bytes
    assert rep.scanned_edges == clean.scanned_edges


def test_sim_metrics_deterministic(graph, workload):
    result = partition(graph, _spec("cuttana", 4))
    a = run_load(result.serve(), workload=workload, concurrency=16)
    b = run_load(result.serve(), workload=workload, concurrency=16)
    assert a.qps_sim == b.qps_sim
    assert a.latency_ms["sim"] == b.latency_ms["sim"]
    assert (a.rpcs, a.wire_bytes, a.scanned_edges) == (
        b.rpcs, b.wire_bytes, b.scanned_edges,
    )


# ------------------------------------------------------------- replication
def test_replication_reduces_rpcs_at_fixed_answers(graph, workload):
    result = partition(graph, _spec("cuttana", 4))
    base = run_load(result.serve(), workload=workload, concurrency=16)
    repl = run_load(
        result.serve(replication_budget=0.1), workload=workload,
        concurrency=16,
    )
    _assert_same_answers(repl, base)
    assert repl.rpcs < base.rpcs
    assert repl.wire_bytes < base.wire_bytes
    assert repl.replication["num_replicas"] > 0


def test_replication_budget_resolution_and_plan(graph):
    assert resolve_budget(0.0, 1000) == 0
    assert resolve_budget(0.25, 1000) == 250  # fraction of |V|
    assert resolve_budget(40, 1000) == 40  # absolute count
    part = partition(graph, _spec("cuttana", 4)).vertex_assignment()
    plan = plan_replication(graph, part, 4, 0.1)
    st = plan.stats()
    assert 0 < st["num_replicas"] <= resolve_budget(0.1, graph.num_vertices)
    # replicas are boundary vertices mirrored into a *different* partition
    assert np.all(part[plan.vertices] != plan.partitions)
    # deterministic plan
    plan2 = plan_replication(graph, part, 4, 0.1)
    np.testing.assert_array_equal(plan.vertices, plan2.vertices)
    np.testing.assert_array_equal(plan.partitions, plan2.partitions)


# ------------------------------------- analytic vs measured ordering agree
def test_analytic_and_measured_throughput_rank_partitioners_alike():
    """Satellite of the throughput fix: the repaired analytic model and the
    measured serving layer must agree that cuttana >= random, even though
    their absolute qps differ."""
    g = rmat_graph(4000, avg_degree=12, seed=1)
    wl = build_workload(g, 400, QueryMix(), seed=2)
    analytic, measured = {}, {}
    for algo in ("cuttana", "random"):
        result = partition(g, _spec(algo, 8, seed=1))
        analytic[algo] = result.db(
            num_queries=256, seed=1, concurrency=256
        )["qps"]
        measured[algo] = run_load(
            result.serve(store_results=False), workload=wl, concurrency=256
        ).qps_sim
    assert analytic["cuttana"] >= analytic["random"]
    assert measured["cuttana"] >= measured["random"]


def test_throughput_qps_two_resource_bounds():
    """concurrency scales the client bound only, and the server (straggler)
    bound caps it: the old formula multiplied the two."""
    lat = np.full(100, 0.01)
    busy = np.array([0.2, 0.05])
    st = QueryStats(
        num_queries=100, hops=1, total_scanned_edges=0, total_rpcs=0,
        total_net_values=0, per_worker_cpu=np.zeros(2),
        per_worker_net=np.zeros(2), latencies_s=lat,
        per_worker_busy_s=busy,
    )
    # client-bound at low concurrency: 100 / (1.0/1)
    assert st.throughput_qps(concurrency=1) == pytest.approx(100.0)
    # server-bound once clients stop being the bottleneck: 100 / 0.2
    assert st.throughput_qps(concurrency=1000) == pytest.approx(500.0)
    # monotone non-decreasing in concurrency, never exceeding the server cap
    qs = [st.throughput_qps(c) for c in (1, 2, 8, 32, 128, 1024)]
    assert all(a <= b + 1e-9 for a, b in zip(qs, qs[1:]))
    assert max(qs) <= 500.0 + 1e-9
    # without the model-costed busy array the defaults reconstruct it
    st2 = QueryStats(
        num_queries=100, hops=1, total_scanned_edges=0, total_rpcs=0,
        total_net_values=0, per_worker_cpu=np.array([1e7, 0.0]),
        per_worker_net=np.zeros(2), latencies_s=lat,
    )
    m = DBCostModel()
    assert st2.throughput_qps(concurrency=10**6) == pytest.approx(
        100.0 / (1e7 / m.edge_scan_rate)
    )


# ----------------------------------------------------------- load generator
def test_query_mix_validation_and_parse():
    assert QueryMix().point == pytest.approx(0.2)
    with pytest.raises(ValueError):
        QueryMix(point=0.5, one_hop=0.5, two_hop=0.5)
    with pytest.raises(ValueError):
        QueryMix(point=-0.1, one_hop=0.6, two_hop=0.5)
    mix = QueryMix.parse("point=0.5,one_hop=0.25,two_hop=0.25")
    assert mix.point == pytest.approx(0.5)
    with pytest.raises(ValueError):
        QueryMix.parse("pnt=1.0")


def test_build_workload_deterministic(graph):
    a = build_workload(graph, 50, QueryMix(), seed=7)
    b = build_workload(graph, 50, QueryMix(), seed=7)
    assert a == b
    assert len(a) == 50
    assert {k for k, _ in a} <= {"point", "one_hop", "two_hop"}


def test_open_loop_mode(graph):
    result = partition(graph, _spec("cuttana", 4))
    rep = run_load(
        result.serve(), num_queries=60, concurrency=8, seed=5,
        mode="open", rate_qps=5000.0,
    )
    assert rep.mode == "open"
    assert rep.num_queries == 60
    assert rep.latency_ms["sim"]["p99"] > 0


# -------------------------------------------------------------- api surface
def test_spec_replication_budget_roundtrip():
    spec = PartitionSpec(algo="cuttana", k=4, replication_budget=0.1)
    d = spec.to_dict()
    assert d["replication_budget"] == 0.1
    assert PartitionSpec.from_dict(d) == spec
    # default stays out of the serialized form (old specs round-trip clean)
    assert "replication_budget" not in PartitionSpec(algo="cuttana", k=4).to_dict()
    with pytest.raises(ValueError):
        PartitionSpec(algo="cuttana", k=4, replication_budget=-0.5)
    with pytest.raises(ValueError):
        PartitionSpec(algo="cuttana", k=4, replication_budget=True)


def test_result_serve_uses_spec_budget(graph):
    result = partition(
        graph, PartitionSpec(algo="cuttana", k=4, replication_budget=0.1,
                             balance_mode="edge", order="random", seed=3)
    )
    svc = result.serve()
    assert svc.replication_stats()["num_replicas"] > 0
    svc2 = result.serve(replication_budget=0.0)
    assert svc2.replication_stats()["num_replicas"] == 0


def test_cli_serve_bench(tmp_path):
    from repro.api.cli import main

    spec = tmp_path / "spec.json"
    spec.write_text(json.dumps({
        "algo": "cuttana", "k": 4, "balance_mode": "edge",
        "order": "random", "seed": 0,
    }))
    out = tmp_path / "serve.json"
    rc = main([
        "serve-bench", "--spec", str(spec), "--rmat", "800",
        "--avg-degree", "8", "--queries", "80", "--concurrency", "16",
        "--out", str(out),
    ])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["spec"]["algo"] == "cuttana"
    assert rep["graph"]["num_vertices"] == 800
    serving = rep["serving"]
    assert serving["num_queries"] == 80
    assert serving["qps_sim"] > 0
    assert serving["rpcs"] > 0


def test_serve_namespace_untangled():
    import repro.serve as s
    import repro.serve.graph as sg
    import repro.serve.lm as lm

    # the LM bits live in repro.serve.lm now...
    assert callable(lm.make_prefill_step) and callable(lm.make_decode_step)
    # ...the deprecated root re-exports still resolve to the same objects
    assert s.make_prefill_step is lm.make_prefill_step
    assert s.make_decode_step is lm.make_decode_step
    # and the graph-serving subsystem is a sibling namespace
    assert hasattr(sg, "GraphService") and hasattr(sg, "run_load")
    assert "graph" in dir(s) and "lm" in dir(s)


# -------------------------------------------------------- trajectory gating
def test_trajectory_gates_serving_throughput():
    from benchmarks.trajectory import compare_reports

    base = {"suites": {"serving": {"rows": [
        {"bench": "serving/x/cuttana", "qps_sim": 1000.0, "p99_sim_ms": 1.0},
    ]}}}

    def run_with(qps, p99):
        cur = {"suites": {"serving": {"rows": [
            {"bench": "serving/x/cuttana", "qps_sim": qps, "p99_sim_ms": p99},
        ]}}}
        return compare_reports(cur, base, 0.15, 0.5)

    regs, compared = run_with(1000.0, 1.0)
    assert compared == 2 and regs == []
    # qps is higher-is-better: a 2x drop must trip the gate...
    regs, _ = run_with(500.0, 1.0)
    assert any("qps_sim dropped" in r for r in regs)
    # ...a 2x gain must not
    regs, _ = run_with(2000.0, 1.0)
    assert regs == []
    # p99 is latency-style lower-is-better
    regs, _ = run_with(1000.0, 2.0)
    assert any("p99_sim_ms regressed" in r for r in regs)
    # a collapsed throughput (0) is a regression, not a skip
    regs, _ = run_with(0.0, 1.0)
    assert any("collapsed" in r for r in regs)
