"""Buffer-priority strategy layer (repro.core.priority).

Covers the three pillars of the refactor:

* the **default strategy is the pre-refactor buffer**: ``strategy="eq6"``
  reproduces the preserved seed loop (``cuttana-legacy``) bit-for-bit
  across every stream order, and S=1 sharded == sequential for *every*
  strategy;
* the **heap machinery is strategy-agnostic**: a hypothesis property
  drives random push / notify / pop interleavings against a
  recompute-argmax reference model, per strategy;
* the **spec layer mirrors the core**: the strategy-name tuples duplicated
  into ``repro.api.spec`` (to stay import-cycle-free) are pinned equal to
  the canonical ones here.
"""
import numpy as np
import pytest

try:  # hypothesis fuzzing is CI-installed; the seeded runs below always run
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.api import PartitionSpec, partition
from repro.api import spec as spec_mod
from repro.core.buffer import PriorityBuffer
from repro.core.cuttana import partition as cuttana_partition
from repro.core.engine import BufferedPolicy, ShardedBufferedPolicy
from repro.core.parallel import partition_parallel
from repro.core.priority import (
    BUFFER_STRATEGIES,
    BufferStats,
    CompletenessPriority,
    Eq6Priority,
    GainPriority,
    make_priority,
)
from repro.graph.generators import rmat_graph

ALL_ORDERS = ("natural", "random", "bfs", "dfs")


# ---------------------------------------------------------------- unit layer
def test_spec_strategy_tuples_pinned_to_core():
    # spec.py duplicates these literally (import-cycle-free); keep them honest
    assert spec_mod._BUFFER_STRATEGIES == BUFFER_STRATEGIES
    for algo, allowed in spec_mod._STRATEGY_CHOICES.items():
        assert set(allowed) <= set(BUFFER_STRATEGIES), (algo, allowed)
    assert spec_mod._STRATEGY_CHOICES["cuttana-legacy"] == ("eq6",)


def test_make_priority_resolves_and_rejects():
    assert isinstance(make_priority("eq6", 100), Eq6Priority)
    assert isinstance(make_priority("completeness", 100), CompletenessPriority)
    assert isinstance(make_priority("gain", 100), GainPriority)
    with pytest.raises(ValueError, match="unknown buffer strategy"):
        make_priority("nope", 100)
    # strategies are stateful: every call must return a fresh instance
    assert make_priority("gain", 10) is not make_priority("gain", 10)


def test_eq6_expressions_are_the_legacy_ones():
    # the exact IEEE-double expressions of the pre-refactor buffer
    p = Eq6Priority(d_max=37, theta=1.5)
    for deg, asg in [(0, 0), (1, 0), (5, 3), (40, 40), (7, 2)]:
        assert p.score_counts(0, deg, asg) == deg / 37 + 1.5 * asg / max(deg, 1)
    deg = np.array([0, 1, 5, 40, 7], dtype=np.int64)
    asg = np.array([0, 0, 3, 40, 2], dtype=np.int64)
    np.testing.assert_array_equal(
        p.score_counts_many(np.arange(5), deg, asg),
        deg / 37 + (1.5 * asg) / np.maximum(deg, 1),
    )


def test_completeness_delays_incomplete_hubs():
    p = CompletenessPriority(d_max=100, theta=1.0)
    hub_unknown = p.score_counts(0, deg=95, assigned=20)
    small_known = p.score_counts(1, deg=10, assigned=9)
    assert small_known > hub_unknown  # eq6 would order these the other way
    eq6 = Eq6Priority(d_max=100, theta=1.0)
    assert eq6.score_counts(0, 95, 20) > eq6.score_counts(1, 10, 9)


def test_gain_margin_tracking():
    p = GainPriority(d_max=10, theta=1.0)
    # untracked vertex: falls back to the assigned count (Eq. 6)
    assert p.score_counts(7, deg=5, assigned=3) == 5 / 10 + 3 / 5
    # decisive neighbourhood (3 vs 0) outranks a split one (2 vs 2)
    p.on_push(1, np.array([0, 0, 0, -1]))
    p.on_push(2, np.array([0, 0, 1, 1]))
    assert p._margin(1, 99) == 3.0
    assert p._margin(2, 99) == 0.0
    s = p.score_counts_many(
        np.array([1, 2]), np.array([4, 4]), np.array([3, 4])
    )
    assert s[0] > s[1]
    # notify (scalar part) shifts the margin; remove drops the tracking
    p.on_notify(np.array([2]), 1)
    assert p._margin(2, 99) == 1.0
    p.on_remove(1)
    assert p._margin(1, 6) == 6.0  # back to the fallback


def test_gain_memory_bounded_by_buffer():
    g = rmat_graph(400, avg_degree=8, seed=0)
    prio = make_priority("gain", d_max=1000)
    buf = PriorityBuffer(16, graph=g, priority=prio)
    part = np.full(g.num_vertices, -1, dtype=np.int64)
    for v in range(200):
        nbrs = g.neighbors(v)
        buf.push(v, assigned_count=0, nbr_parts=part[nbrs])
        if buf.full:
            w, _ = buf.pop_best()
            part[w] = w % 4
        assert len(prio._pc) <= 16  # counts exist only while buffered


def test_buffer_stats_telemetry_keys():
    s = BufferStats()
    s.observe_len(3)
    s.observe_len(2)
    s.evictions += 5
    t = s.to_telemetry("gain")
    assert t == {
        "buffer_evictions": 5,
        "buffer_drained": 0,
        "buffer_peak": 3,
        "degree_bypass": 0,
        "buffer_strategy": "gain",
    }


# -------------------------------------------------- heap-vs-reference model
def _run_against_reference(strategy: str, seed: int) -> None:
    """Drive random push / notify_many / pop_best interleavings and check
    every pop and every completion list against a recompute-argmax model.

    Valid oracle because every score change pushes a fresh versioned heap
    entry: the live entry for a vertex always carries its current score, so
    pop order must equal argmax by (score, -v) over buffered vertices.
    """
    rng = np.random.default_rng(seed)
    n = 40
    prio = make_priority(strategy, d_max=int(rng.integers(5, 50)), theta=1.0)
    buf = PriorityBuffer(capacity=12, priority=prio)
    model: dict[int, list] = {}  # v -> [deg, assigned]

    def ref_score(v):
        deg, asg = model[v]
        return buf.priority.score_counts(v, deg, asg)

    for _ in range(120):
        op = rng.integers(0, 3)
        if op == 0 and len(model) < 12:  # push
            free = [v for v in range(n) if v not in model]
            v = int(rng.choice(free))
            deg = int(rng.integers(1, 8))
            nbrs = rng.integers(0, n, size=deg).astype(np.int64)
            parts = rng.integers(-1, 3, size=deg).astype(np.int64)
            asg = int((parts >= 0).sum())
            buf.push(v, nbrs=nbrs, assigned_count=asg, nbr_parts=parts)
            model[v] = [deg, asg]
        elif op == 1 and model:  # notify a random multiset of vertices
            m = int(rng.integers(1, 6))
            vs = rng.integers(0, n, size=m).astype(np.int64)
            part = int(rng.integers(0, 3))
            got_complete = buf.notify_many(vs, part)
            # mirror: bump per occurrence, completions in first-occurrence order
            expect = []
            for v in vs.tolist():
                if v in model:
                    model[v][1] += 1
            seen = set()
            for v in vs.tolist():
                if v in model and v not in seen:
                    seen.add(v)
                    if model[v][1] >= model[v][0]:
                        expect.append(v)
            assert got_complete == expect, (strategy, seed)
            for v in expect:  # caller contract: completions are removed
                buf.remove(v)
                del model[v]
        elif op == 2 and model:  # pop_best
            best = max(model, key=lambda v: (ref_score(v), -v))
            v, _nbrs = buf.pop_best()
            assert v == best, (strategy, seed, ref_score(v), ref_score(best))
            del model[v]
    assert len(buf) == len(model)


@pytest.mark.parametrize("strategy", BUFFER_STRATEGIES)
@pytest.mark.parametrize("seed", [0, 1, 17, 123456, 2**31 - 1])
def test_eviction_order_matches_reference_seeded(strategy, seed):
    _run_against_reference(strategy, seed)


if HAVE_HYPOTHESIS:

    @pytest.mark.parametrize("strategy", BUFFER_STRATEGIES)
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_eviction_order_matches_reference_fuzz(strategy, seed):
        _run_against_reference(strategy, seed)


# -------------------------------------------------------------- parity layer
@pytest.fixture(scope="module")
def parity_graph():
    return rmat_graph(3000, avg_degree=10, seed=5)


@pytest.mark.parametrize("order", ALL_ORDERS)
def test_default_strategy_matches_legacy_loop(parity_graph, order):
    """strategy='eq6' (the default) must reproduce the preserved seed loop
    byte-for-byte on every stream order - the refactor moved the scoring,
    it must not have changed a single placement."""
    spec_kw = dict(k=6, epsilon=0.05, balance_mode="edge", order=order, seed=2)
    legacy = partition(parity_graph, PartitionSpec(algo="cuttana-legacy", **spec_kw))
    default = partition(parity_graph, PartitionSpec(algo="cuttana", **spec_kw))
    explicit = partition(
        parity_graph,
        PartitionSpec(algo="cuttana", params={"strategy": "eq6"}, **spec_kw),
    )
    np.testing.assert_array_equal(default.assignment, legacy.assignment)
    assert default.assignment.tobytes() == explicit.assignment.tobytes()


@pytest.mark.parametrize("strategy", BUFFER_STRATEGIES)
def test_sharded_s1_matches_sequential_per_strategy(parity_graph, strategy):
    """S=1 delegates to the sequential policy for every strategy."""
    g = parity_graph
    seq = cuttana_partition(
        g, 4, epsilon=0.05, balance_mode="edge", order="random", seed=3,
        strategy=strategy, use_refinement=False,
    )
    par = partition_parallel(
        g, 4, epsilon=0.05, balance_mode="edge", order="random", seed=3,
        num_shards=1, strategy=strategy, use_refinement=False,
    )
    np.testing.assert_array_equal(seq, par)


def test_sharded_strategy_runs_multishard(parity_graph):
    """S>=2 exercises the superstep need_parts plumbing for gain."""
    g = parity_graph
    for strategy in ("eq6", "gain"):
        tele = {}
        part = partition_parallel(
            g, 4, epsilon=0.05, balance_mode="edge", order="random", seed=3,
            num_shards=3, strategy=strategy, use_refinement=False,
            telemetry=tele,
        )
        assert part.shape == (g.num_vertices,)
        assert part.min() >= 0 and part.max() < 4
        assert tele["buffer_strategy"] == strategy


def test_policy_rejects_unknown_strategy():
    with pytest.raises(ValueError, match="unknown buffer strategy"):
        BufferedPolicy(64, d_max=100, strategy="bogus")
    with pytest.raises(ValueError, match="unknown buffer strategy"):
        ShardedBufferedPolicy(2, 64, d_max=100, strategy="bogus")


# ---------------------------------------------------------------- spec layer
def test_buffcut_spec_roundtrip_and_validation():
    spec = PartitionSpec(algo="cuttana-buffcut", k=8, order="random")
    assert spec.params.strategy == "gain"  # buffcut default
    assert PartitionSpec.from_json(spec.to_json()) == spec
    spec2 = PartitionSpec(
        algo="cuttana-buffcut", k=8, params={"strategy": "completeness"}
    )
    assert PartitionSpec.from_json(spec2.to_json()) == spec2
    # buffcut is *defined* as the prioritized variant: eq6 spells "cuttana"
    with pytest.raises(ValueError, match="strategy"):
        PartitionSpec(algo="cuttana-buffcut", k=8, params={"strategy": "eq6"})
    with pytest.raises(ValueError, match="strategy"):
        PartitionSpec(algo="cuttana", k=8, params={"strategy": "buffcut"})
    with pytest.raises(ValueError, match="strategy"):
        PartitionSpec(algo="cuttana-legacy", k=8, params={"strategy": "gain"})


def test_buffcut_runs_and_reports_strategy(parity_graph):
    res = partition(
        parity_graph,
        PartitionSpec(algo="cuttana-buffcut", k=4, order="random", seed=1),
    )
    assert res.telemetry["buffer_strategy"] == "gain"
    assert res.assignment.shape == (parity_graph.num_vertices,)
    # and it is genuinely a different run than cuttana on the same spec
    base = partition(
        parity_graph, PartitionSpec(algo="cuttana", k=4, order="random", seed=1)
    )
    assert base.telemetry["buffer_strategy"] == "eq6"
    assert not np.array_equal(res.assignment, base.assignment)


def test_completeness_strategy_through_core(parity_graph):
    """Non-default strategy through the sequential core entry point."""
    g = parity_graph
    tele = {}
    part = cuttana_partition(
        g, 4, epsilon=0.05, balance_mode="edge", order="random", seed=0,
        strategy="completeness", telemetry=tele,
    )
    assert part.shape == (g.num_vertices,)
    assert (part >= 0).all()
    assert tele["buffer_strategy"] == "completeness"
