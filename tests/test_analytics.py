"""Analytics engine correctness vs dense references + cost-model sanity."""
import numpy as np
import pytest

from repro.analytics import (
    GraphEngine,
    localize,
    pagerank_program,
    cc_program,
    sssp_program,
    workload_cost,
)
from repro.analytics.programs import (
    reference_cc,
    reference_pagerank,
    reference_sssp,
)
from repro.core import get_partitioner
from repro.core.hdrf import partition_hdrf
from repro.graph import rmat_graph, road_graph


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(1500, avg_degree=10, seed=3)


@pytest.fixture(scope="module")
def lg(graph):
    part = get_partitioner("cuttana")(graph, 4, balance_mode="edge", seed=0)
    return localize(graph, part, 4)


def test_localize_shapes_and_consistency(graph, lg):
    assert lg.local_count.sum() == graph.num_vertices
    # every real edge slot appears exactly once across devices
    real = (lg.rows != lg.v_max).sum()
    assert real == graph.indices.shape[0]
    # true halo messages == sum of send counts and matches comm-volume defn
    from repro.graph.metrics import communication_volume

    cv = communication_volume(graph, lg.part, lg.k)
    assert abs(lg.true_halo_messages() - cv * lg.k * graph.num_vertices) < 1e-6


def test_pagerank_matches_reference(graph, lg):
    eng = GraphEngine(lg, pagerank_program())
    got = eng.run_simulated(iters=15)
    want = reference_pagerank(graph, iters=15)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-9)
    # dangling (degree-0) vertices leak mass in both engine and reference;
    # what matters is agreement + positivity
    assert (got > 0).all()


def test_cc_matches_reference(graph, lg):
    eng = GraphEngine(lg, cc_program())
    got = eng.run_simulated(iters=30)
    want = reference_cc(graph, iters=30)
    np.testing.assert_allclose(got, want)


def test_sssp_matches_reference(graph, lg):
    eng = GraphEngine(lg, sssp_program(source=7))
    got = eng.run_simulated(iters=25)
    want = reference_sssp(graph, iters=25, source=7)
    finite = np.isfinite(want)
    np.testing.assert_allclose(got[finite], want[finite])
    assert (got[~finite] > 1e30).all()


def test_partition_quality_reduces_halo_traffic(graph):
    """The paper's whole point: better partitions -> less network."""
    k = 4
    rand = localize(graph, get_partitioner("random")(graph, k, seed=0), k)
    good = localize(
        graph, get_partitioner("cuttana")(graph, k, balance_mode="edge", seed=0), k
    )
    assert good.true_halo_messages() < rand.true_halo_messages()


def test_cost_model_orders_partitioners(graph):
    k = 4
    rand = workload_cost(graph, get_partitioner("random")(graph, k, seed=0), k, 30)
    cut = workload_cost(
        graph, get_partitioner("cuttana")(graph, k, balance_mode="edge", seed=0), k, 30
    )
    assert cut["network_s_per_iter"] < rand["network_s_per_iter"]
    assert cut["straggler_ratio"] < 1.5


def test_cost_model_vertex_cut(graph):
    ep = partition_hdrf(graph, 4, seed=0)
    res = workload_cost(graph, ep, 4, 10)
    assert res["total_s"] > 0
    assert res["straggler_ratio"] < 1.5  # edge partitioners balance edges


def test_engine_on_road_graph():
    g = road_graph(2000, seed=1)
    part = get_partitioner("fennel")(g, 4, seed=0)
    lg = localize(g, part, 4)
    got = GraphEngine(lg, pagerank_program()).run_simulated(iters=10)
    want = reference_pagerank(g, iters=10)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-9)
