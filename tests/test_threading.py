"""Threaded superstep determinism + autotune tests.

The parallel engine's contract is that the worker count NEVER changes the
assignment: shard tasks read frozen snapshots and write disjoint output
slices, so the merged superstep result is scheduling-independent. These
tests pin bit-parity for ``max_workers`` in {1, 2, 8} at fixed S across all
four stream orders and both parallel algorithms, and use the executor's
``JITTER`` hook to prove parity survives adversarial scheduling (a seeded
race on the merge reduction), not just the scheduler we happened to get.
"""
import json
import random

import numpy as np
import pytest

from repro.core import autotune, executor
from repro.core.parallel import fennel_parallel, partition_parallel
from repro.graph import rmat_graph

ORDERS = ("natural", "random", "bfs", "dfs")
ALGOS = {"cuttana-parallel": partition_parallel, "fennel-parallel": fennel_parallel}


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(2000, avg_degree=8, seed=7)


# ------------------------------------------------------ worker-count parity
@pytest.mark.parametrize("algo", sorted(ALGOS))
@pytest.mark.parametrize("order", ORDERS)
def test_bit_parity_across_worker_counts(graph, algo, order):
    fn = ALGOS[algo]
    ref = fn(graph, 4, num_shards=4, max_workers=1, order=order, seed=0)
    for workers in (2, 8):
        got = fn(graph, 4, num_shards=4, max_workers=workers, order=order, seed=0)
        np.testing.assert_array_equal(
            got, ref, err_msg=f"{algo} order={order} max_workers={workers}"
        )


def test_parity_with_seeded_scheduling_jitter(graph):
    """Seeded-race regression on the merge reduction: random per-task sleeps
    shuffle shard completion order; the vectorised merge must still commute."""
    ref = {
        a: fn(graph, 4, num_shards=4, max_workers=1, seed=0)
        for a, fn in ALGOS.items()
    }
    executor.JITTER = random.Random(0xC0FFEE)
    try:
        for a, fn in ALGOS.items():
            got = fn(graph, 4, num_shards=4, max_workers=8, seed=0)
            np.testing.assert_array_equal(got, ref[a], err_msg=a)
    finally:
        executor.JITTER = None


# --------------------------------------------------------- profile telemetry
@pytest.mark.parametrize("algo", sorted(ALGOS))
def test_profile_telemetry(graph, algo):
    tel: dict = {}
    ALGOS[algo](
        graph, 4, num_shards=4, max_workers=2, chunk=128, seed=0, telemetry=tel
    )
    prof = tel["profile"]
    assert prof["workers"] == tel["max_workers"] == 2
    # the profiler records supersteps that place vertices; the stream-level
    # count also includes empty drain rounds of the buffered policy
    assert 1 <= prof["supersteps"] <= tel["supersteps"]
    for phase in ("prep", "score", "place", "exchange", "merge"):
        assert prof[f"{phase}_s"] >= 0.0
    assert prof["parallel_wall_s"] >= 0.0
    assert prof["queue_wait_s"] >= 0.0
    rows = prof["per_superstep"]
    assert 1 <= len(rows) <= 64
    assert all(set(r) >= {"score", "place", "exchange", "merge"} for r in rows)
    # per-superstep rows sum (up to the cap) into the totals
    if prof["supersteps"] <= 64:
        total = sum(r["score"] for r in rows)
        assert total == pytest.approx(prof["score_s"], abs=1e-4)


def test_profile_serializes(graph):
    tel: dict = {}
    fennel_parallel(graph, 4, num_shards=2, telemetry=tel)
    json.dumps(tel["profile"])  # artifact-ready: plain floats/ints only


# ------------------------------------------------------------------ autotune
def test_choose_num_shards_knee():
    rows = [
        {"num_shards": 1, "stream_seconds": 1.00, "boundary_conflicts": 0},
        {"num_shards": 2, "stream_seconds": 0.60, "boundary_conflicts": 40},
        {"num_shards": 4, "stream_seconds": 0.52, "boundary_conflicts": 90},
        {"num_shards": 8, "stream_seconds": 0.50, "boundary_conflicts": 400},
    ]
    # 4 and 8 are within 10% of best (0.50); 2 is not; fewest conflicts wins
    assert autotune.choose_num_shards(rows) == 4
    assert autotune.choose_num_shards([]) is None
    assert autotune.choose_num_shards([{"num_shards": 2}]) is None  # no latency


def test_choose_chunk():
    rows = [
        {"chunk": 256, "stream_seconds": 0.40},
        {"chunk": 512, "stream_seconds": 0.30},
        {"chunk": 1024, "stream_seconds": 0.30},
    ]
    assert autotune.choose_chunk(rows) == 512  # tie -> smaller chunk
    assert autotune.choose_chunk([]) is None


def test_build_and_resolve_artifact(tmp_path, monkeypatch):
    art = autotune.build_artifact(
        {
            "cuttana-parallel": [
                {"num_shards": 1, "stream_seconds": 2.0, "boundary_conflicts": 0},
                {"num_shards": 4, "stream_seconds": 1.0, "boundary_conflicts": 10},
            ],
            "fennel-parallel": [
                {"num_shards": 1, "stream_seconds": 0.2, "boundary_conflicts": 0},
                {"num_shards": 2, "stream_seconds": 0.1, "boundary_conflicts": 5},
            ],
        },
        chunk_rows=[{"chunk": 256, "stream_seconds": 0.1}],
    )
    assert art["chosen"]["cuttana-parallel"]["num_shards"] == 4
    assert art["chosen"]["fennel-parallel"]["num_shards"] == 2
    assert art["chosen"]["default"]["num_shards"] == 2  # smallest knee
    p = tmp_path / "TUNING_partition.json"
    p.write_text(json.dumps(art))
    monkeypatch.setenv(autotune.ENV_PATH, str(p))
    t = autotune.resolve(0, 0, algo="cuttana-parallel")
    assert (t.num_shards, t.chunk) == (4, 256)
    assert t.source == f"artifact:{p}"
    # unknown algo falls back to the artifact default
    assert autotune.resolve(0, 512, algo="mystery").num_shards == 2
    # explicit knobs pass through untouched
    assert autotune.resolve(3, 64, algo="cuttana-parallel") == autotune.Tuning(
        3, 64, "explicit"
    )


def test_resolve_heuristic_fallback(tmp_path):
    # an explicit path overrides the whole search chain (env, cwd, repo
    # root - the committed repo-root artifact must not shadow this test)
    missing = tmp_path / "missing.json"
    t = autotune.resolve(
        0, 0, algo="fennel-parallel", num_vertices=100_000, path=missing
    )
    assert t.source == "heuristic"
    assert 1 <= t.num_shards <= 8
    assert t.chunk == 512
    # tiny graphs degrade to the sequential engine
    tiny = autotune.resolve(
        0, 512, algo="fennel-parallel", num_vertices=500, path=missing
    )
    assert tiny.num_shards == 1
    with pytest.raises(ValueError, match="num_shards"):
        autotune.resolve(-1, 512, algo="fennel-parallel")
    with pytest.raises(ValueError, match="chunk"):
        autotune.resolve(2, -5, algo="fennel-parallel")


# ------------------------------------------------------- executor primitives
def test_resolve_workers():
    assert executor.resolve_workers(1, 8) == 1
    assert executor.resolve_workers(16, 4) == 4  # clamped to S
    assert executor.resolve_workers(0, 4) >= 1  # auto
    with pytest.raises(ValueError, match="max_workers"):
        executor.resolve_workers(-2, 4)


def test_shard_pool_inline_and_chained():
    pool = executor.ShardPool(1, 4)
    assert pool.workers == 1 and pool._ex is None
    assert pool.submit(lambda a, b: a + b, 2, 3).result() == 5
    with pytest.raises(RuntimeError, match="boom"):
        pool.submit(_raise).result()
    order: list[int] = []
    f = None
    for i in range(4):
        f = pool.submit_after(f, order.append, i)
    f.result()
    assert order == [0, 1, 2, 3]
    pool.shutdown()


def test_shard_pool_chain_is_fifo_under_threads():
    pool = executor.ShardPool(2, 4)
    assert pool.workers == 2
    executor.JITTER = random.Random(42)
    try:
        order: list[int] = []
        f = None
        for i in range(32):
            f = pool.submit_after(f, order.append, i)
        f.result()
        assert order == list(range(32))
        assert pool.queue_wait_s >= 0.0
    finally:
        executor.JITTER = None
        pool.shutdown()


def _raise():
    raise RuntimeError("boom")
