"""Coverage for the remaining substrate: gradient compression, stream
orders, workload generators, localize edge cases, serve package."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.graph import CSRGraph, rmat_graph
from repro.graph.stream import stream_order


def test_stream_orders_are_permutations():
    g = rmat_graph(500, avg_degree=6, seed=0)
    for order in ("natural", "random", "bfs", "dfs"):
        ids = stream_order(g, order, seed=1)
        assert sorted(ids.tolist()) == list(range(g.num_vertices)), order


def test_compression_single_pod_noop():
    from repro.train.compression import compressed_psum_pod, init_residuals

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("pod", "data", "model"))
    g = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)), jnp.float32)
    r = jnp.zeros((8, 8), jnp.float32)
    out, new_r = compressed_psum_pod(g, r, mesh, "pod")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(g))


@pytest.mark.slow
def test_compression_error_feedback_subprocess():
    """int8 cross-pod psum: mean of pods within quantization error, residual
    carries the rounding error forward."""
    import json
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.compat import use_mesh
        from repro.train.compression import compressed_psum_pod

        mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 2, 2),
                    ("pod", "data", "model"))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
        r = jnp.zeros((16, 16), jnp.float32)
        with use_mesh(mesh):
            gd = jax.device_put(g, NamedSharding(mesh, P()))
            rd = jax.device_put(r, NamedSharding(mesh, P()))
            out, new_r = jax.jit(
                lambda a, b: compressed_psum_pod(a, b, mesh, "pod")
            )(gd, rd)
        # both pods held the same g -> mean == g up to int8 quantization
        err = float(np.abs(np.asarray(out) - np.asarray(g)).max())
        scale = float(np.abs(np.asarray(g)).max()) / 127.0
        ok = err <= scale + 1e-6
        # residual equals the quantization error of this round
        res_ok = float(np.abs(np.asarray(new_r)).max()) <= scale + 1e-6
        print(json.dumps({"ok": bool(ok and res_ok), "err": err}))
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert res.returncode == 0, res.stderr
    assert json.loads(res.stdout.strip().splitlines()[-1])["ok"]


def test_localize_with_isolated_vertices():
    from repro.analytics import GraphEngine, localize, pagerank_program
    from repro.analytics.programs import reference_pagerank

    edges = np.array([[0, 1], [1, 2], [5, 6]])
    g = CSRGraph.from_edges(edges, num_vertices=8)  # 3,4,7 isolated
    part = np.array([0, 0, 1, 1, 0, 1, 0, 1], dtype=np.int32)
    lg = localize(g, part, 2)
    got = GraphEngine(lg, pagerank_program()).run_simulated(5)
    want = reference_pagerank(g, 5)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_workload_degree_bias():
    from repro.db import ldbc_query_mix

    g = rmat_graph(2000, avg_degree=10, seed=0)
    biased = ldbc_query_mix(g, 2000, seed=0, degree_biased=True)
    uniform = ldbc_query_mix(g, 2000, seed=0, degree_biased=False)
    assert g.degrees[biased].mean() > g.degrees[uniform].mean()


def test_serve_package_exports():
    import repro.serve as s

    assert callable(s.make_prefill_step) and callable(s.make_decode_step)


def test_csr_permute_preserves_structure():
    g = rmat_graph(300, avg_degree=8, seed=0)
    rng = np.random.default_rng(1)
    perm = rng.permutation(g.num_vertices)
    g2 = g.permute(perm)
    assert g2.num_edges == g.num_edges
    np.testing.assert_array_equal(
        np.sort(g2.degrees[perm]), np.sort(g.degrees[perm])
    )
    # degree of relabeled vertex matches original
    for v in rng.integers(0, g.num_vertices, 10):
        assert g2.degree(int(perm[v])) == g.degree(int(v))


def test_checkpoint_save_restore_with_sharded_arrays(tmp_path):
    from repro.train.checkpoint import restore_checkpoint, save_checkpoint

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P

    x = jax.device_put(jnp.arange(16.0).reshape(4, 4),
                       NamedSharding(mesh, P("data", None)))
    save_checkpoint(str(tmp_path), 1, {"x": x})
    restored, step = restore_checkpoint(str(tmp_path), {"x": x})
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))


def test_benchmark_suite_imports_are_lazy():
    """--only must not import the other suites: a broken suite (import-time
    failure included) can then never mask the one being run."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import sys
        import benchmarks.run as r
        eager = [m for m in sys.modules
                 if m.startswith("benchmarks.") and m != "benchmarks.run"]
        assert not eager, f"benchmarks.run eagerly imported {eager}"
        # a missing/broken suite fails only when its thunk actually runs
        bad = r._suite("definitely_not_a_suite")
        try:
            bad()
        except ModuleNotFoundError:
            print("lazy-ok")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=120,
    )
    assert res.returncode == 0, res.stderr
    assert "lazy-ok" in res.stdout
