"""Out-of-core graph subsystem tests.

Covers the on-disk format + two-pass converter (``repro.graph.external``),
file-backed vs in-memory partition parity, the API/CLI threading of
``source``/``--graph``/``peak_graph_bytes``, and the bench-trajectory
comparator that gates CI (``benchmarks/trajectory.py``).
"""
from __future__ import annotations

import json
import os
import struct

import numpy as np
import pytest

from repro.api import PartitionSpec, partition
from repro.graph.csr import CSRGraph
from repro.graph.external import (
    FORMAT_VERSION,
    HEADER_BYTES,
    MAGIC,
    ExternalCSRGraph,
    convert_csr,
    convert_edge_list,
    load_graph_file,
    load_graph_source,
    validate_source,
    write_external_csr,
)
from repro.graph.generators import rmat_graph

ORDERS = ("natural", "random", "bfs", "dfs")


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(3000, avg_degree=10, seed=3)


@pytest.fixture(scope="module")
def graph_bin(graph, tmp_path_factory):
    path = tmp_path_factory.mktemp("ooc") / "graph.bin"
    convert_csr(graph, path)
    return str(path)


def _messy_edges(seed=0, n=400, m=4000):
    """Edge list with duplicates in both directions and self-loops."""
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(m, 2))
    dupes = edges[::5][:, ::-1]  # reversed duplicates
    loops = np.stack([np.arange(0, n, 7)] * 2, axis=1)
    return np.concatenate([edges, dupes, edges[::11], loops])


# ----------------------------------------------------------- format + reader
class TestFormat:
    def test_write_read_roundtrip(self, graph, graph_bin):
        ext = ExternalCSRGraph(graph_bin)
        assert ext.num_vertices == graph.num_vertices
        assert ext.num_edges == graph.num_edges
        assert np.array_equal(np.asarray(ext.indptr), graph.indptr)
        assert np.array_equal(np.asarray(ext.indices), graph.indices)
        assert np.array_equal(ext.degrees, graph.degrees)
        for v in (0, 1, graph.num_vertices - 1):
            assert np.array_equal(ext.neighbors(v), graph.neighbors(v))
            assert ext.degree(v) == graph.degree(v)

    def test_to_csr_materializes(self, graph, graph_bin):
        back = ExternalCSRGraph(graph_bin).to_csr()
        assert isinstance(back, CSRGraph)
        assert np.array_equal(back.indices, graph.indices)

    def test_memory_accounting(self, graph, graph_bin):
        ext = ExternalCSRGraph(graph_bin)
        assert ext.backing == "mapped"
        assert ext.nbytes_mapped == os.path.getsize(graph_bin)
        assert ext.nbytes_resident == 0  # nothing materialized yet
        _ = ext.degrees
        assert ext.nbytes_resident == ext.degrees.nbytes > 0

    def test_empty_graph(self, tmp_path):
        path = tmp_path / "empty.bin"
        write_external_csr(path, np.zeros(1, dtype=np.int64), np.empty(0, np.int32))
        ext = ExternalCSRGraph(path)
        assert ext.num_vertices == 0 and ext.num_edges == 0


class TestCorruption:
    def test_missing_file(self, tmp_path):
        with pytest.raises(ValueError, match="cannot open"):
            ExternalCSRGraph(tmp_path / "nope.bin")

    def test_too_small_for_header(self, tmp_path):
        path = tmp_path / "tiny.bin"
        path.write_bytes(b"XC")
        with pytest.raises(ValueError, match="smaller than"):
            ExternalCSRGraph(path)

    def test_bad_magic(self, tmp_path):
        path = tmp_path / "bad.bin"
        path.write_bytes(b"NOTAGRPH" + b"\0" * 100)
        with pytest.raises(ValueError, match="bad magic"):
            ExternalCSRGraph(path)

    def test_unsupported_version(self, tmp_path):
        path = tmp_path / "vers.bin"
        head = struct.pack("<8sII qq", MAGIC, FORMAT_VERSION + 9, 0, 0, 0)
        path.write_bytes(head + b"\0" * (HEADER_BYTES - len(head)) + b"\0" * 8)
        with pytest.raises(ValueError, match="version"):
            ExternalCSRGraph(path)

    def test_truncated_file(self, graph, graph_bin, tmp_path):
        data = open(graph_bin, "rb").read()
        path = tmp_path / "trunc.bin"
        path.write_bytes(data[: len(data) // 2])
        with pytest.raises(ValueError, match="truncated or corrupt"):
            ExternalCSRGraph(path)

    def test_trailing_garbage(self, graph_bin, tmp_path):
        data = open(graph_bin, "rb").read()
        path = tmp_path / "fat.bin"
        path.write_bytes(data + b"\0" * 64)
        with pytest.raises(ValueError, match="truncated or corrupt"):
            ExternalCSRGraph(path)

    def test_corrupt_indptr(self, graph, tmp_path):
        path = tmp_path / "badptr.bin"
        bad = graph.indptr.copy()
        bad[-1] += 4  # declares more neighbours than the indices region holds
        bad[0] = 0
        # keep the file size consistent with the header so only the indptr
        # consistency check can catch it
        write_external_csr(path, bad, graph.indices)
        with pytest.raises(ValueError, match="corrupt indptr"):
            ExternalCSRGraph(path)


# ---------------------------------------------------------------- converter
class TestConverter:
    @pytest.mark.parametrize("via", ["npy", "txt", "csv"])
    def test_roundtrip_matches_from_edges(self, tmp_path, via):
        edges = _messy_edges()
        ref = CSRGraph.from_edges(edges, num_vertices=400)
        if via == "npy":
            src = tmp_path / "e.npy"
            np.save(src, edges)
        else:
            sep = "," if via == "csv" else " "
            src = tmp_path / f"e.{via}"
            with open(src, "w") as f:
                f.write("# snap-style header comment\n")
                for a, b in edges:
                    f.write(f"{a}{sep}{b}\n")
        out = tmp_path / "g.bin"
        stats = convert_edge_list(src, out, num_vertices=400)
        ext = ExternalCSRGraph(out)
        assert np.array_equal(np.asarray(ext.indptr), ref.indptr)
        assert np.array_equal(np.asarray(ext.indices), ref.indices)
        assert stats["num_edges"] == ref.num_edges
        assert stats["input_edges"] == edges.shape[0]

    def test_multi_run_external_merge(self, tmp_path):
        # tiny chunk/merge blocks force many spill runs + many merge blocks
        edges = _messy_edges(seed=1, n=300, m=6000)
        ref = CSRGraph.from_edges(edges, num_vertices=300)
        src = tmp_path / "e.npy"
        np.save(src, edges)
        out = tmp_path / "g.bin"
        stats = convert_edge_list(
            src, out, num_vertices=300, chunk_edges=257, merge_block=61
        )
        assert stats["runs"] > 10
        ext = ExternalCSRGraph(out)
        assert np.array_equal(np.asarray(ext.indptr), ref.indptr)
        assert np.array_equal(np.asarray(ext.indices), ref.indices)

    def test_infers_num_vertices(self, tmp_path):
        edges = np.array([[0, 5], [5, 2], [2, 0]])
        src = tmp_path / "e.npy"
        np.save(src, edges)
        out = tmp_path / "g.bin"
        stats = convert_edge_list(src, out)
        assert stats["num_vertices"] == 6  # max id + 1, like from_edges
        assert ExternalCSRGraph(out).num_vertices == 6

    def test_self_loops_and_dupes_dropped(self, tmp_path):
        edges = np.array([[1, 1], [0, 1], [1, 0], [0, 1], [2, 2]])
        src = tmp_path / "e.npy"
        np.save(src, edges)
        out = tmp_path / "g.bin"
        stats = convert_edge_list(src, out, num_vertices=3)
        assert stats["num_edges"] == 1
        ext = ExternalCSRGraph(out)
        assert np.array_equal(ext.neighbors(0), [1])
        assert np.array_equal(ext.neighbors(1), [0])
        assert ext.degree(2) == 0

    def test_extra_columns_ignored(self, tmp_path):
        src = tmp_path / "weighted.txt"
        src.write_text("0 1 0.5\n1 2 0.25\n")
        out = tmp_path / "g.bin"
        assert convert_edge_list(src, out)["num_edges"] == 2

    def test_rejects_negative_ids(self, tmp_path):
        src = tmp_path / "e.npy"
        np.save(src, np.array([[0, 1], [-2, 3]]))
        with pytest.raises(ValueError, match="negative vertex id"):
            convert_edge_list(src, tmp_path / "g.bin")

    def test_rejects_id_beyond_num_vertices(self, tmp_path):
        src = tmp_path / "e.npy"
        np.save(src, np.array([[0, 7]]))
        with pytest.raises(ValueError, match="num_vertices"):
            convert_edge_list(src, tmp_path / "g.bin", num_vertices=5)

    def test_rejects_bad_npy_shape(self, tmp_path):
        src = tmp_path / "e.npy"
        np.save(src, np.arange(10))
        with pytest.raises(ValueError, match="edge array"):
            convert_edge_list(src, tmp_path / "g.bin")


# ------------------------------------------------------------ stream parity
class TestPartitionParity:
    @pytest.mark.parametrize("order", ORDERS)
    @pytest.mark.parametrize("algo", ["fennel", "cuttana"])
    def test_file_backed_bit_identical(self, graph, graph_bin, algo, order):
        ext = ExternalCSRGraph(graph_bin)
        spec = PartitionSpec(
            algo=algo, k=4, balance_mode="edge", order=order, seed=0
        )
        mem = partition(graph, spec)
        mapped = partition(ext, spec)
        assert np.array_equal(mem.assignment, mapped.assignment)

    @pytest.mark.parametrize("num_shards", [1, 4])
    def test_parallel_file_backed_bit_identical(
        self, graph, graph_bin, num_shards
    ):
        ext = ExternalCSRGraph(graph_bin)
        spec = PartitionSpec(
            algo="cuttana-parallel", k=4, balance_mode="edge", order="random",
            seed=0, params={"num_shards": num_shards},
        )
        mem = partition(graph, spec)
        mapped = partition(ext, spec)
        assert np.array_equal(mem.assignment, mapped.assignment)
        assert mapped.telemetry["num_shards"] == num_shards

    @pytest.mark.parametrize("algo", ["hdrf", "ginger"])
    def test_vertex_cut_file_backed_bit_identical(self, graph, graph_bin, algo):
        # the vertex-cut edge partitioners consume edges_array(), which the
        # mapped graph builds with a chunked scan - same edges, same cut
        ext = ExternalCSRGraph(graph_bin)
        assert np.array_equal(ext.edges_array(), graph.edges_array())
        spec = PartitionSpec(algo=algo, k=4, seed=0)
        mem = partition(graph, spec)
        mapped = partition(ext, spec)
        assert np.array_equal(mem.assignment, mapped.assignment)

    def test_subgraph_edge_count_matches(self, graph, graph_bin):
        ext = ExternalCSRGraph(graph_bin)
        mask = np.zeros(graph.num_vertices, dtype=bool)
        mask[::3] = True
        assert ext.subgraph_edge_count(mask) == graph.subgraph_edge_count(mask)

    def test_telemetry_backing_fields(self, graph, graph_bin):
        ext = ExternalCSRGraph(graph_bin)
        spec = PartitionSpec(algo="ldg", k=4, balance_mode="vertex")
        mem = partition(graph, spec)
        mapped = partition(ext, spec)
        assert mem.telemetry["graph_backing"] == "resident"
        assert mem.telemetry["peak_graph_bytes"] == (
            graph.indptr.nbytes + graph.indices.nbytes
        )
        assert mem.telemetry["mapped_graph_bytes"] == 0
        assert mapped.telemetry["graph_backing"] == "mapped"
        assert mapped.telemetry["mapped_graph_bytes"] == os.path.getsize(graph_bin)
        # mapped runs only keep O(|V|) bookkeeping resident
        assert (
            mapped.telemetry["peak_graph_bytes"]
            < mem.telemetry["peak_graph_bytes"]
        )


# ------------------------------------------------------------- spec source
class TestSpecSource:
    def test_source_round_trips_json(self):
        spec = PartitionSpec(
            algo="cuttana", k=4, source="rmat:2000:8", order="random"
        )
        assert PartitionSpec.from_json(spec.to_json()) == spec
        assert json.loads(spec.to_json())["source"] == "rmat:2000:8"

    def test_source_absent_from_json_when_none(self):
        spec = PartitionSpec(algo="fennel", k=2)
        assert "source" not in json.loads(spec.to_json())

    @pytest.mark.parametrize(
        "bad",
        ["", "rmat:", "rmat:0", "rmat:x", "rmat:100:0", "rmat:1:2:3",
         "dataset:no-such-dataset"],
    )
    def test_bad_sources_fail_at_construction(self, bad):
        with pytest.raises(ValueError):
            PartitionSpec(algo="fennel", k=2, source=bad)

    def test_plain_paths_pass_syntax_check(self):
        validate_source("some/dir/graph.bin")
        validate_source("dump.npz")
        # colons are legal in POSIX paths: not a scheme error, fails (with a
        # clear message) only at load time if the file is absent
        validate_source("/data/run:3/graph.bin")
        with pytest.raises(ValueError, match="cannot open"):
            load_graph_source("/data/run:3/graph.bin")

    def test_partition_from_spec_source(self):
        spec = PartitionSpec(
            algo="fennel", k=4, balance_mode="edge", order="random",
            seed=1, source="rmat:1500:8",
        )
        direct = partition(rmat_graph(1500, avg_degree=8, seed=1), spec)
        from_source = partition(spec)  # spec-only convenience form
        assert np.array_equal(direct.assignment, from_source.assignment)

    def test_partition_without_graph_or_source_raises(self):
        with pytest.raises(ValueError, match="needs a graph"):
            partition(PartitionSpec(algo="fennel", k=2))

    def test_load_graph_source_path(self, graph, graph_bin):
        loaded = load_graph_source(graph_bin)
        assert isinstance(loaded, ExternalCSRGraph)
        assert loaded.num_edges == graph.num_edges
        assert isinstance(load_graph_file(graph_bin), ExternalCSRGraph)

    def test_load_graph_file_npz(self, graph, tmp_path):
        path = tmp_path / "dump.npz"
        graph.save(str(path))
        loaded = load_graph_file(str(path))
        assert isinstance(loaded, CSRGraph)
        assert np.array_equal(loaded.indices, graph.indices)

    def test_load_graph_source_dataset(self):
        g = load_graph_source("dataset:road-s")
        assert g.num_vertices == 25_000


# -------------------------------------------------------------------- CLI
class TestCLI:
    def test_partition_graph_flag(self, graph, graph_bin, tmp_path):
        from repro.api.cli import main

        spec_path = tmp_path / "spec.json"
        spec_path.write_text(
            PartitionSpec(
                algo="fennel", k=4, balance_mode="edge", order="random"
            ).to_json()
        )
        out = tmp_path / "report.json"
        assign = tmp_path / "assign.npy"
        rc = main([
            "partition", "--spec", str(spec_path), "--graph", graph_bin,
            "--out", str(out), "--assignment-out", str(assign),
        ])
        assert rc == 0
        report = json.loads(out.read_text())
        assert report["graph"]["name"] == graph_bin
        assert report["graph"]["num_edges"] == graph.num_edges
        assert report["telemetry"]["graph_backing"] == "mapped"
        mem = partition(
            graph, PartitionSpec(
                algo="fennel", k=4, balance_mode="edge", order="random"
            )
        )
        assert np.array_equal(np.load(assign), mem.assignment)

    def test_spec_source_seed_matches_api(self, tmp_path):
        # the same spec JSON must mean the same graph through the CLI and
        # through repro.api.partition(spec): both resolve source with spec.seed
        from repro.api.cli import main

        spec = PartitionSpec(
            algo="fennel", k=4, balance_mode="edge", order="random",
            seed=3, source="rmat:1500:8",
        )
        spec_path = tmp_path / "spec.json"
        spec_path.write_text(spec.to_json())
        assign = tmp_path / "a.npy"
        assert main([
            "partition", "--spec", str(spec_path), "--out", "/dev/null",
            "--assignment-out", str(assign),
        ]) == 0
        assert np.array_equal(np.load(assign), partition(spec).assignment)

    def test_graph_flag_is_file_only(self, tmp_path):
        from repro.api.cli import main

        spec_path = tmp_path / "spec.json"
        spec_path.write_text(PartitionSpec(algo="fennel", k=2,
                                           balance_mode="vertex").to_json())
        with pytest.raises(ValueError, match="cannot open"):
            main(["partition", "--spec", str(spec_path), "--graph",
                  "rmat:5000", "--out", "/dev/null"])

    def test_skip_quality_flag(self, graph_bin, tmp_path):
        from repro.api.cli import main

        spec_path = tmp_path / "spec.json"
        spec_path.write_text(PartitionSpec(algo="fennel", k=4,
                                           balance_mode="edge").to_json())
        out = tmp_path / "report.json"
        assert main(["partition", "--spec", str(spec_path), "--graph",
                     graph_bin, "--out", str(out), "--skip-quality"]) == 0
        report = json.loads(out.read_text())
        assert "quality" not in report
        assert report["telemetry"]["graph_backing"] == "mapped"

    def test_convert_script(self, tmp_path):
        import importlib.util

        spec = importlib.util.spec_from_file_location(
            "convert_graph",
            os.path.join(os.path.dirname(__file__), "..", "scripts",
                         "convert_graph.py"),
        )
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        edges = _messy_edges(seed=5, n=200, m=1500)
        src = tmp_path / "e.txt"
        with open(src, "w") as f:
            for a, b in edges:
                f.write(f"{a}\t{b}\n")
        out = tmp_path / "g.bin"
        assert mod.main([str(src), str(out), "--num-vertices", "200"]) == 0
        ref = CSRGraph.from_edges(edges, num_vertices=200)
        ext = ExternalCSRGraph(out)
        assert np.array_equal(np.asarray(ext.indices), ref.indices)


# ------------------------------------------------- bench-trajectory gate
def _report(stream_s=1.0, edge_cut=0.5, convert_s=0.2):
    return {
        "suites": {
            "outofcore": {"rows": [
                {"bench": "outofcore/rmat1000/convert",
                 "convert_seconds": convert_s},
                {"bench": "outofcore/rmat1000/cuttana/mapped",
                 "algo": "cuttana", "backing": "mapped",
                 "stream_seconds": stream_s, "edge_cut": edge_cut},
            ]},
            "scaling": {"rows": [
                {"algo": "cuttana", "num_shards": 0,
                 "stream_seconds": stream_s, "edge_cut": edge_cut},
            ]},
        },
    }


class TestTrajectoryGate:
    def test_identical_reports_pass(self):
        from benchmarks.trajectory import compare_reports

        regs, compared = compare_reports(_report(), _report(), tolerance=0.15)
        assert regs == []
        assert compared == 5  # 2x(stream+cut) + convert

    def test_within_tolerance_passes(self):
        from benchmarks.trajectory import compare_reports

        cur = _report(stream_s=1.10, edge_cut=0.55)
        regs, _ = compare_reports(cur, _report(), tolerance=0.15)
        assert regs == []

    def test_injected_2x_latency_regression_fails(self):
        from benchmarks.trajectory import compare_reports

        cur = _report(stream_s=2.0)  # the acceptance-criteria scenario
        regs, _ = compare_reports(cur, _report(), tolerance=0.15)
        assert len(regs) == 2  # both stream_seconds rows
        assert all("stream_seconds regressed 2.00x" in r for r in regs)

    def test_latency_tolerance_loosens_only_latency(self):
        from benchmarks.trajectory import compare_reports

        cur = _report(stream_s=1.5, edge_cut=0.65)
        regs, _ = compare_reports(
            cur, _report(), tolerance=0.15, latency_tolerance=0.75
        )
        # 1.5x latency allowed at +75%; 1.3x edge-cut still fails at +15%
        assert len(regs) == 2
        assert all("edge_cut" in r for r in regs)

    def test_edge_cut_regression_fails(self):
        from benchmarks.trajectory import compare_reports

        cur = _report(edge_cut=0.60)
        regs, _ = compare_reports(cur, _report(), tolerance=0.15)
        assert any("edge_cut" in r for r in regs)

    def test_missing_row_in_run_suite_is_regression(self):
        from benchmarks.trajectory import compare_reports

        cur = _report()
        del cur["suites"]["outofcore"]["rows"][1]
        regs, _ = compare_reports(cur, _report(), tolerance=0.15)
        assert any("missing from this run" in r for r in regs)

    def test_suites_not_run_are_out_of_scope(self):
        from benchmarks.trajectory import compare_reports

        cur = _report()
        del cur["suites"]["scaling"]  # e.g. --only outofcore
        regs, compared = compare_reports(cur, _report(), tolerance=0.15)
        assert regs == []
        assert compared == 3

    def test_zero_overlap_reports_zero_compared(self):
        from benchmarks.trajectory import compare_reports

        regs, compared = compare_reports(
            {"suites": {"other": {"rows": []}}}, _report()
        )
        assert compared == 0  # run.py fails the gate on this

    def test_seeded_baseline_gates_green_against_itself(self):
        # the committed repo-root baseline must be self-consistent: a run
        # identical to it passes, an injected 2x latency on every row fails
        from benchmarks.trajectory import compare_reports

        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_partition.json")
        baseline = json.load(open(path))
        regs, compared = compare_reports(baseline, baseline, tolerance=0.15)
        assert regs == [] and compared > 0
        doctored = json.loads(json.dumps(baseline))
        for payload in doctored["suites"].values():
            for row in payload.get("rows", []):
                if "stream_seconds" in row:
                    row["stream_seconds"] *= 2.0
        regs, _ = compare_reports(
            doctored, baseline, tolerance=0.15, latency_tolerance=0.75
        )
        assert any("stream_seconds" in r for r in regs)
