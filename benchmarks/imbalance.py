"""Paper Fig. 7: edge imbalance of vertex-balanced partitioners (the
straggler problem CUTTANA's edge-balance mode fixes). Runs entirely through
``repro.api``: one ``PartitionSpec`` per cell, structured rows built from the
``PartitionResult``."""
from __future__ import annotations

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.graph.generators import load_dataset

ALGOS = ("fennel", "ldg", "heistream", "cuttana")


def run(k: int = 8, datasets=("social-s", "ldbc-s", "web-s"), seed: int = 0):
    rows = []
    for ds in datasets:
        graph = load_dataset(ds, seed=seed)
        for name in ALGOS:
            for balance in ("vertex", "edge"):
                spec = PartitionSpec(
                    algo=name, k=k, epsilon=0.05, balance_mode=balance,
                    order="random", seed=seed,
                )
                result = partition(graph, spec)
                imb = result.quality()["edge_imbalance"]
                rows.append(dict(dataset=ds, algo=name, balance=balance,
                                 edge_imbalance=imb, spec=spec.to_dict(),
                                 seconds=result.timings["total_s"]))
                emit(
                    f"imbalance/{ds}/{name}/{balance}",
                    result.timings["total_s"] * 1e6,
                    f"edge_imb={imb:.2f}",
                )
    return rows


if __name__ == "__main__":
    run()
