"""Paper Fig. 7: edge imbalance of vertex-balanced partitioners (the
straggler problem CUTTANA's edge-balance mode fixes)."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import get_partitioner
from repro.graph import edge_imbalance
from repro.graph.generators import load_dataset


def run(k: int = 8, datasets=("social-s", "ldbc-s", "web-s"), seed: int = 0):
    rows = []
    for ds in datasets:
        graph = load_dataset(ds, seed=seed)
        for name in ("fennel", "ldg", "heistream", "cuttana"):
            for balance in ("vertex", "edge"):
                part, us = timed(
                    get_partitioner(name), graph, k,
                    epsilon=0.05, balance_mode=balance, order="random", seed=seed,
                )
                imb = edge_imbalance(graph, part, k)
                rows.append(dict(dataset=ds, algo=name, balance=balance,
                                 edge_imbalance=imb))
                emit(f"imbalance/{ds}/{name}/{balance}", us, f"edge_imb={imb:.2f}")
    return rows


if __name__ == "__main__":
    run()
