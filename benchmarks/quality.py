"""Paper Table II: partitioning quality (λ_EC, λ_CV) across datasets,
partitioners, and balance conditions (K=8). Runs entirely through
``repro.api``: one ``PartitionSpec`` per cell, rows built from the
``PartitionResult``."""
from __future__ import annotations

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.graph.generators import load_dataset

PARTITIONERS = [
    "cuttana", "cuttana-buffcut", "cluster+cuttana", "fennel", "heistream",
    "ldg",
]
DATASETS = ["social-s", "web-s", "road-s", "ldbc-s"]


def run(k: int = 8, datasets=None, order: str = "random", seed: int = 0):
    rows = []
    for ds in datasets or DATASETS:
        graph = load_dataset(ds, seed=seed)
        for balance in ("edge", "vertex"):
            for name in PARTITIONERS:
                spec = PartitionSpec(
                    algo=name, k=k, epsilon=0.05, balance_mode=balance,
                    order=order, seed=seed,
                )
                result = partition(graph, spec)
                rep = result.quality()
                seconds = result.timings["total_s"]
                # explicit bench key: the trajectory comparator matches rows
                # by it - without one, every dataset's row would collapse
                # onto the same "quality/<algo>" identity
                bench = f"quality/{ds}/{balance}/{name}"
                rows.append(dict(bench=bench, dataset=ds, balance=balance,
                                 algo=name, seconds=seconds,
                                 spec=spec.to_dict(), **rep))
                emit(
                    bench,
                    seconds * 1e6,
                    f"edge_cut={rep['edge_cut']:.4f};cv={rep['comm_volume']:.4f};"
                    f"edge_imb={rep['edge_imbalance']:.2f}",
                )
    return rows


if __name__ == "__main__":
    run()
