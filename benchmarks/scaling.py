"""Shard-parallel scaling study (paper §V: "a parallel version for CUTTANA
that offers nearly the same partitioning latency as existing streaming
partitioners").

Sweeps ``num_shards`` for ``cuttana-parallel`` (and ``fennel-parallel``)
against their sequential baselines on an R-MAT graph and reports the
streaming-phase wall clock, edge-cut, and superstep telemetry - the
latency-vs-quality trade of the bulk-synchronous relaxation. Rows are built
from ``PartitionResult``s like every other api-driven suite.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.graph.generators import rmat_graph

SHARDS = (1, 2, 4, 8)


def _stream_seconds(result) -> float:
    t = result.timings
    return t.get("phase1_seconds", t.get("stream_seconds", t["total_s"]))


def run(n: int = 50_000, avg_degree: int = 12, k: int = 8, seed: int = 0):
    graph = rmat_graph(n, avg_degree=avg_degree, seed=seed)
    rows = []
    for algo, base in (("cuttana-parallel", "cuttana"),
                       ("fennel-parallel", "fennel")):
        base_spec = PartitionSpec(
            algo=base, k=k, balance_mode="edge", order="random", seed=seed,
        )
        base_result = partition(graph, base_spec)
        base_s = _stream_seconds(base_result)
        base_ec = base_result.quality()["edge_cut"]
        rows.append(dict(
            algo=base, num_shards=0, stream_seconds=base_s, edge_cut=base_ec,
            speedup=1.0, spec=base_spec.to_dict(),
        ))
        emit(f"scaling/rmat{n}/{base}", base_s * 1e6, f"edge_cut={base_ec:.4f}")
        for num_shards in SHARDS:
            spec = PartitionSpec(
                algo=algo, k=k, balance_mode="edge", order="random",
                seed=seed, params={"num_shards": num_shards},
            )
            result = partition(graph, spec)
            secs = _stream_seconds(result)
            ec = result.quality()["edge_cut"]
            tel = result.telemetry
            rows.append(dict(
                algo=algo, num_shards=num_shards, stream_seconds=secs,
                edge_cut=ec, speedup=base_s / max(secs, 1e-12),
                edge_cut_ratio=ec / max(base_ec, 1e-12),
                supersteps=tel.get("supersteps", 0),
                sync_rounds=tel.get("sync_rounds", 0),
                boundary_conflicts=tel.get("boundary_conflicts", 0),
                spec=spec.to_dict(),
            ))
            emit(
                f"scaling/rmat{n}/{algo}/s{num_shards}",
                secs * 1e6,
                f"edge_cut={ec:.4f};speedup={base_s / max(secs, 1e-12):.2f}x;"
                f"conflicts={tel.get('boundary_conflicts', 0)}",
            )
    return rows


if __name__ == "__main__":
    run()
