"""Shard-parallel scaling study (paper §V: "a parallel version for CUTTANA
that offers nearly the same partitioning latency as existing streaming
partitioners").

Sweeps ``num_shards`` for ``cuttana-parallel`` (and ``fennel-parallel``)
against their sequential baselines on an R-MAT graph and reports the
streaming-phase wall clock, edge-cut, and superstep telemetry - the
latency-vs-quality trade of the bulk-synchronous relaxation. On top of the
shard sweep:

* threaded rows (``.../s4/w{W}``) pin the multi-worker superstep engine's
  wall clock per worker count;
* a chunk sweep (``.../s4/c{C}``) feeds the auto-tuner's chunk choice;
* a ``superstep_setup`` micro-bench proves the contiguous per-shard cursors
  beat the old strided-view split (satellite of the threading PR);
* ``tuning_out`` serialises the latency-vs-conflicts curves into the
  ``TUNING_partition.json`` artifact consumed by ``num_shards=0``/"auto"
  (see :mod:`repro.core.autotune`).

Rows are built from ``PartitionResult``s like every other api-driven suite.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.core import autotune
from repro.graph.generators import rmat_graph
from repro.graph.stream import ShardedStream

SHARDS = (1, 2, 4, 8)
WORKERS = (1, 2)
CHUNKS = (128, 256, 512, 1024)


def _stream_seconds(result) -> float:
    t = result.timings
    return t.get("phase1_seconds", t.get("stream_seconds", t["total_s"]))


def _setup_microbench(n: int, s: int = 4, chunk: int = 512) -> dict:
    """Satellite proof: contiguous per-shard cursors (built once) vs the old
    strided-view split, measured over full passes of superstep batches the
    way the engine consumes them. Each superstep touches every batch several
    times (degree gather, CSR expansion, kernel packing), so the pass copies
    each batch ``touches`` times - against a strided view each touch re-pays
    a gather, against a contiguous cursor it is a straight memcpy."""
    n = max(n, 2_000_000)  # must exceed LLC, else the gathers are free
    touches = 3
    ids = np.random.default_rng(0).permutation(n).astype(np.int64)

    def consume(shards) -> float:
        # one full pass of superstep batches, one touch each (the engine
        # multiplies this by ``touches``)
        t0 = time.perf_counter()
        longest = max(sh.shape[0] for sh in shards)
        for lo in range(0, longest, chunk):
            for sh in shards:
                np.ascontiguousarray(sh[lo : lo + chunk])
        return time.perf_counter() - t0

    def build(fn):
        # min of 2: the first build pays one-time allocator page faults that
        # the strided variant's consumers would pay too - not a split cost
        best, out = float("inf"), None
        for _ in range(2):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return out, best

    contiguous, build_contiguous = build(
        lambda: ShardedStream.from_ids(ids, s).shards
    )
    strided, build_strided = build(
        lambda: tuple(ids[i::s] for i in range(s))  # the pre-PR split
    )
    # best-of-5 passes: min is robust to scheduler noise
    consume_contiguous = min(consume(contiguous) for _ in range(5))
    consume_strided = min(consume(strided) for _ in range(5))
    strided_s = build_strided + touches * consume_strided
    contiguous_s = build_contiguous + touches * consume_contiguous
    emit(
        f"scaling/superstep_setup/n{n}",
        contiguous_s * 1e6,
        f"strided={strided_s * 1e6:.1f}us;"
        f"run_speedup={strided_s / max(contiguous_s, 1e-12):.2f}x;"
        f"per_pass_speedup="
        f"{consume_strided / max(consume_contiguous, 1e-12):.1f}x",
    )
    # deliberately NOT named stream_seconds: a sub-30ms micro-bench under CI
    # scheduler noise would make the latency gate flaky. per_pass_speedup is
    # the satellite's proof (a batch pass off contiguous cursors is pure
    # views); setup_speedup folds in the one-time build, whose page-fault
    # share makes it hover nearer 1x on loaded machines.
    return dict(
        bench="scaling/superstep_setup",
        n=n,
        num_shards=s,
        chunk=chunk,
        setup_seconds=contiguous_s,
        strided_seconds=strided_s,
        build_seconds=build_contiguous,
        per_pass_speedup=consume_strided / max(consume_contiguous, 1e-12),
        setup_speedup=strided_s / max(contiguous_s, 1e-12),
    )


def run(
    n: int = 50_000,
    avg_degree: int = 12,
    k: int = 8,
    seed: int = 0,
    tuning_out: str | None = None,
):
    graph = rmat_graph(n, avg_degree=avg_degree, seed=seed)
    rows = []
    curves: dict[str, list[dict]] = {}
    chunk_rows: list[dict] = []
    for algo, base in (("cuttana-parallel", "cuttana"),
                       ("fennel-parallel", "fennel")):
        base_spec = PartitionSpec(
            algo=base, k=k, balance_mode="edge", order="random", seed=seed,
        )
        base_result = partition(graph, base_spec)
        base_s = _stream_seconds(base_result)
        base_ec = base_result.quality()["edge_cut"]
        rows.append(dict(
            algo=base, num_shards=0, stream_seconds=base_s, edge_cut=base_ec,
            speedup=1.0, spec=base_spec.to_dict(),
        ))
        emit(f"scaling/rmat{n}/{base}", base_s * 1e6, f"edge_cut={base_ec:.4f}")
        curves[algo] = []
        for num_shards in SHARDS:
            spec = PartitionSpec(
                algo=algo, k=k, balance_mode="edge", order="random",
                seed=seed, params={"num_shards": num_shards},
            )
            result = partition(graph, spec)
            secs = _stream_seconds(result)
            ec = result.quality()["edge_cut"]
            tel = result.telemetry
            row = dict(
                algo=algo, num_shards=num_shards, stream_seconds=secs,
                edge_cut=ec, speedup=base_s / max(secs, 1e-12),
                edge_cut_ratio=ec / max(base_ec, 1e-12),
                supersteps=tel.get("supersteps", 0),
                sync_rounds=tel.get("sync_rounds", 0),
                boundary_conflicts=tel.get("boundary_conflicts", 0),
                spec=spec.to_dict(),
            )
            rows.append(row)
            curves[algo].append(row)
            emit(
                f"scaling/rmat{n}/{algo}/s{num_shards}",
                secs * 1e6,
                f"edge_cut={ec:.4f};speedup={base_s / max(secs, 1e-12):.2f}x;"
                f"conflicts={tel.get('boundary_conflicts', 0)}",
            )
        # threaded rows: same S, explicit worker counts - the wall-clock of
        # the thread-pool superstep engine itself (assignments identical)
        for workers in WORKERS:
            spec = PartitionSpec(
                algo=algo, k=k, balance_mode="edge", order="random",
                seed=seed, params={"num_shards": 4, "max_workers": workers},
            )
            result = partition(graph, spec)
            secs = _stream_seconds(result)
            prof = result.profile or {}
            rows.append(dict(
                bench=f"scaling/{algo}/s4/w{workers}",
                algo=algo, num_shards=4, max_workers=workers,
                stream_seconds=secs,
                edge_cut=result.quality()["edge_cut"],
                speedup=base_s / max(secs, 1e-12),
                parallel_wall_seconds=prof.get("parallel_wall_s", 0.0),
                queue_wait_seconds=prof.get("queue_wait_s", 0.0),
                spec=spec.to_dict(),
            ))
            emit(
                f"scaling/rmat{n}/{algo}/s4/w{workers}",
                secs * 1e6,
                f"speedup={base_s / max(secs, 1e-12):.2f}x;"
                f"queue_wait={prof.get('queue_wait_s', 0.0) * 1e6:.0f}us",
            )
    # chunk sweep (fennel-parallel: the pure superstep engine, no phase 2
    # noise) - feeds the auto-tuner's chunk choice
    for chunk in CHUNKS:
        spec = PartitionSpec(
            algo="fennel-parallel", k=k, balance_mode="edge", order="random",
            seed=seed, params={"num_shards": 4, "chunk": chunk},
        )
        result = partition(graph, spec)
        secs = _stream_seconds(result)
        row = dict(
            bench=f"scaling/fennel-parallel/s4/c{chunk}",
            algo="fennel-parallel", num_shards=4, chunk=chunk,
            stream_seconds=secs,
            edge_cut=result.quality()["edge_cut"],
            boundary_conflicts=result.telemetry.get("boundary_conflicts", 0),
            spec=spec.to_dict(),
        )
        rows.append(row)
        chunk_rows.append(row)
        emit(f"scaling/rmat{n}/fennel-parallel/s4/c{chunk}", secs * 1e6)
    rows.append(_setup_microbench(n))
    if tuning_out:
        artifact = autotune.build_artifact(curves, chunk_rows)
        with open(tuning_out, "w") as fh:
            json.dump(artifact, fh, indent=2, sort_keys=True)
        print(f"# wrote {tuning_out}")
    return rows


if __name__ == "__main__":
    run()
