"""Bench-trajectory comparator: gate the perf history, not just today's run.

``benchmarks/run.py --json`` emits one structured report per run; this module
compares such a report against a committed baseline (repo-root
``BENCH_partition.json``) and flags **regressions**:

* quality metrics (``edge_cut``, ``comm_volume`` - the paper's two headline
  quality numbers, lambda_EC and lambda_CV) worse than
  ``baseline * (1 + tolerance)``;
* latency metrics (``stream_seconds``, ``convert_seconds``, the serving
  suite's deterministic ``p99_sim_ms`` tail, and the churn suite's
  per-batch ``update_ms``) worse than
  ``baseline * (1 + latency_tolerance)`` - wall clocks are noisier than the
  deterministic seeded quality numbers, so CI may loosen just this bound;
* throughput metrics (``qps_sim`` - higher is better) *below*
  ``baseline / (1 + latency_tolerance)``: the ratio is inverted so one
  tolerance grammar covers both directions;
* footprint metrics (``bytes_on_disk`` of the converted graph,
  ``peak_rss_mb`` of the partitioning process) worse than
  ``baseline * (1 + tolerance)`` - these are deterministic, so they use the
  tight quality tolerance;
* baseline rows that *disappeared* from a suite that still ran (silent
  coverage loss counts as a regression - a gate that compares nothing is no
  gate).

Rows are matched by a stable key: the row's explicit ``bench`` field when
present, else ``suite/algo[/sN][/backing]``. Only suites present in the
current report are compared, so ``--only scaling,outofcore`` runs gate
against the matching slice of a full baseline.

``compare_reports`` is pure (dicts in, findings out) and unit-tested in
``tests/test_outofcore.py``, including the injected-2x-latency case the CI
gate must catch.
"""
from __future__ import annotations

__all__ = [
    "row_key",
    "collect_rows",
    "compare_reports",
    "QUALITY_METRICS",
    "LATENCY_METRICS",
    "THROUGHPUT_METRICS",
    "FOOTPRINT_METRICS",
]

# metric name -> kind; QUALITY/LATENCY/FOOTPRINT are "lower is better",
# THROUGHPUT is "higher is better" (compared on the inverted ratio).
# FOOTPRINT metrics (on-disk bytes of the converted graph, process peak RSS)
# are deterministic like quality, so they gate at the tight tolerance - a
# format change that silently bloats the compressed CSR or a streaming change
# that re-materializes the graph in RAM fails the trajectory even when wall
# clocks look fine. superstep_ms (mean per-superstep wall of the sharded
# engines) is a wall clock and gates at the loose latency tolerance.
QUALITY_METRICS = ("edge_cut", "comm_volume")
LATENCY_METRICS = (
    "stream_seconds",
    "convert_seconds",
    "p99_sim_ms",
    "superstep_ms",
    "update_ms",
)
THROUGHPUT_METRICS = ("qps_sim",)
FOOTPRINT_METRICS = ("bytes_on_disk", "peak_rss_mb")


def row_key(suite: str, row: dict) -> str:
    """Stable identity of a benchmark row across runs."""
    if "bench" in row:
        return str(row["bench"])
    parts = [suite]
    if "algo" in row:
        parts.append(str(row["algo"]))
    if "num_shards" in row:
        parts.append(f"s{row['num_shards']}")
    if "backing" in row:
        parts.append(str(row["backing"]))
    return "/".join(parts)


def collect_rows(report: dict) -> dict[str, dict]:
    """Flatten a run report into ``key -> row`` (non-dict rows ignored)."""
    out: dict[str, dict] = {}
    for suite, payload in (report.get("suites") or {}).items():
        for row in (payload or {}).get("rows") or []:
            if isinstance(row, dict):
                out[row_key(suite, row)] = row
    return out


def _suite_of(key: str) -> str:
    return key.split("/", 1)[0]


def compare_reports(
    current: dict,
    baseline: dict,
    tolerance: float = 0.15,
    latency_tolerance: float | None = None,
) -> tuple[list[str], int]:
    """Compare a current run report against a baseline report.

    Returns ``(regressions, compared)``: human-readable regression lines
    (empty == within tolerance) and the number of metric comparisons made.
    A caller gating CI should fail on ``regressions`` *and* on
    ``compared == 0`` - zero overlap means the gate checked nothing.
    """
    lat_tol = tolerance if latency_tolerance is None else latency_tolerance
    cur_rows = collect_rows(current)
    base_rows = collect_rows(baseline)
    cur_suites = set((current.get("suites") or {}).keys())
    regressions: list[str] = []
    compared = 0
    for key in sorted(base_rows):
        if _suite_of(key) not in cur_suites:
            continue  # suite not run this time: out of scope, not a regression
        crow = cur_rows.get(key)
        if crow is None:
            regressions.append(
                f"{key}: row present in baseline but missing from this run"
            )
            continue
        brow = base_rows[key]
        for metric, tol, higher_is_better in (
            *((m, tolerance, False) for m in QUALITY_METRICS),
            *((m, lat_tol, False) for m in LATENCY_METRICS),
            *((m, lat_tol, True) for m in THROUGHPUT_METRICS),
            *((m, tolerance, False) for m in FOOTPRINT_METRICS),
        ):
            bval = brow.get(metric)
            cval = crow.get(metric)
            if not isinstance(bval, (int, float)) or not isinstance(
                cval, (int, float)
            ):
                continue
            if bval <= 0:
                continue  # degenerate baseline: nothing meaningful to gate
            compared += 1
            if higher_is_better and cval <= 0:
                regressions.append(
                    f"{key}: {metric} collapsed to {cval:.6g} "
                    f"(baseline {bval:.6g})"
                )
                continue
            ratio = bval / cval if higher_is_better else cval / bval
            if ratio > 1.0 + tol:
                direction = "dropped" if higher_is_better else "regressed"
                regressions.append(
                    f"{key}: {metric} {direction} {ratio:.2f}x "
                    f"({bval:.6g} -> {cval:.6g}, tolerance +{tol:.0%})"
                )
    return regressions, compared
