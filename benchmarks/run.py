"""Benchmark entry: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines; with ``--json`` also collects
every suite's structured rows (built from ``PartitionResult``s in the
api-driven suites) into one machine-readable report - the perf-trajectory
artifact CI uploads.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only quality,db,...]
                                           [--json out.json]
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import time


def _suite(module: str, kwargs=None):
    """Lazy suite thunk: the module is imported only when the suite actually
    runs, so one broken suite (an import-time failure included) can never
    mask the others - ``--only scaling`` must work even if e.g. the kernels
    suite's imports are broken. ``kwargs`` may be a dict or a zero-arg
    callable returning one (for --full-dependent arguments)."""

    def run_it():
        mod = importlib.import_module(f"benchmarks.{module}")
        kw = kwargs() if callable(kwargs) else (kwargs or {})
        return mod.run(**kw)

    return run_it


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="medium-size datasets (minutes instead of seconds)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write all suites' structured rows to this file")
    args = ap.parse_args()

    from repro.api.result import jsonify

    suites = {
        "quality": _suite("quality", lambda: dict(
            datasets=["social-s", "web-s", "road-s", "ldbc-s"]
            if not args.full
            else ["social-m", "web-m", "road-m", "ldbc-s"]
        )),
        "quality_vs_k": _suite("quality_vs_k", lambda: dict(
            ks=(2, 4, 8, 16) if not args.full else (2, 4, 8, 16, 32)
        )),
        "imbalance": _suite("imbalance"),
        "ablation": _suite("ablation"),
        "analytics": _suite("analytics"),
        "db": _suite("db"),
        "latency": _suite("latency", lambda: dict(
            dataset="social-s" if not args.full else "social-m"
        )),
        "engine": _suite("engine_compare", lambda: dict(
            n=30_000 if not args.full else 100_000
        )),
        "scaling": _suite("scaling", lambda: dict(
            n=20_000 if not args.full else 100_000
        )),
        "kernels": _suite("kernels"),
        "substrate": _suite("substrate"),
        "roofline": _suite("roofline"),
    }
    only = set(args.only.split(",")) if args.only else None
    report: dict = {"full": args.full, "suites": {}}
    t0 = time.time()
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", flush=True)
        try:
            rows = fn()
        except Exception as e:  # keep the suite running
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}", flush=True)
            report["suites"][name] = {"error": f"{type(e).__name__}: {e}"}
        else:
            report["suites"][name] = {"rows": jsonify(rows)}
    report["seconds"] = time.time() - t0
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    print(f"# total {report['seconds']:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
