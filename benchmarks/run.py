"""Benchmark entry: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines; with ``--json`` also collects
every suite's structured rows (built from ``PartitionResult``s in the
api-driven suites) into one machine-readable report - the perf-trajectory
artifact CI uploads.

With ``--baseline`` the run is additionally *gated* against a committed
report (repo-root ``BENCH_partition.json``): stream-phase latency or
edge-cut regressing past ``--tolerance`` (latency optionally loosened via
``--latency-tolerance`` - CI wall clocks are noisy) exits non-zero, so the
perf trajectory is enforced, not just recorded.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only quality,db,...]
        [--json out.json] [--baseline BENCH_partition.json]
        [--tolerance 0.15] [--latency-tolerance 0.75]
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import time


def _suite(module: str, kwargs=None):
    """Lazy suite thunk: the module is imported only when the suite actually
    runs, so one broken suite (an import-time failure included) can never
    mask the others - ``--only scaling`` must work even if e.g. the kernels
    suite's imports are broken. ``kwargs`` may be a dict or a zero-arg
    callable returning one (for --full-dependent arguments)."""

    def run_it():
        mod = importlib.import_module(f"benchmarks.{module}")
        kw = kwargs() if callable(kwargs) else (kwargs or {})
        return mod.run(**kw)

    return run_it


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="medium-size datasets (minutes instead of seconds)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write all suites' structured rows to this file")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="gate this run against a committed report; exits "
                         "non-zero on latency/edge-cut regressions")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="allowed relative regression vs the baseline")
    ap.add_argument("--latency-tolerance", type=float, default=None,
                    help="looser bound for wall-clock metrics only "
                         "(default: same as --tolerance)")
    ap.add_argument("--tuning", default=None, metavar="OUT",
                    help="have the scaling suite write its auto-tuning "
                         "artifact (TUNING_partition.json) here")
    args = ap.parse_args()

    from repro.api.result import jsonify

    suites = {
        "quality": _suite("quality", lambda: dict(
            datasets=["social-s", "web-s", "road-s", "ldbc-s"]
            if not args.full
            else ["social-m", "web-m", "road-m", "ldbc-s"]
        )),
        "quality_vs_k": _suite("quality_vs_k", lambda: dict(
            ks=(2, 4, 8, 16) if not args.full else (2, 4, 8, 16, 32)
        )),
        "imbalance": _suite("imbalance"),
        "ablation": _suite("ablation"),
        "analytics": _suite("analytics"),
        "db": _suite("db"),
        "latency": _suite("latency", lambda: dict(
            dataset="social-s" if not args.full else "social-m"
        )),
        "engine": _suite("engine_compare", lambda: dict(
            n=30_000 if not args.full else 100_000
        )),
        "scaling": _suite("scaling", lambda: dict(
            n=20_000 if not args.full else 100_000,
            tuning_out=args.tuning,
        )),
        "outofcore": _suite("outofcore", lambda: dict(
            n=40_000 if not args.full else 125_000
        )),
        "churn": _suite("churn", lambda: dict(
            n=25_000 if not args.full else 100_000
        )),
        "serving": _suite("serving", lambda: dict(
            n=8_000 if not args.full else 30_000,
            queries=2_000 if not args.full else 6_000,
        )),
        "kernels": _suite("kernels"),
        "substrate": _suite("substrate"),
        "roofline": _suite("roofline"),
    }
    # --only runs suites in the order GIVEN: peak_rss_mb rows report the
    # process-lifetime high-water mark (ru_maxrss cannot be reset), so a
    # memory-measuring suite (outofcore) must be able to run before the
    # allocation-heavy ones (quality loads every dataset) - CI's
    # "scaling,outofcore,serving,quality" relies on this
    if args.only:
        names = args.only.split(",")
        unknown = [n for n in names if n not in suites]
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; known: {sorted(suites)}")
    else:
        names = list(suites)
    report: dict = {"full": args.full, "suites": {}}
    t0 = time.time()
    for name in names:
        fn = suites[name]
        print(f"# === {name} ===", flush=True)
        try:
            rows = fn()
        except Exception as e:  # keep the suite running
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}", flush=True)
            report["suites"][name] = {"error": f"{type(e).__name__}: {e}"}
        else:
            report["suites"][name] = {"rows": jsonify(rows)}
    report["seconds"] = time.time() - t0
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    print(f"# total {report['seconds']:.1f}s", file=sys.stderr)
    if args.baseline:
        from benchmarks.trajectory import compare_reports

        with open(args.baseline) as f:
            baseline = json.load(f)
        regressions, compared = compare_reports(
            report, baseline, args.tolerance, args.latency_tolerance
        )
        if compared == 0:
            print(
                f"# BENCH GATE FAILED: no comparable rows between this run "
                f"and {args.baseline} - the gate checked nothing",
                file=sys.stderr,
            )
            sys.exit(2)
        for line in regressions:
            print(f"# REGRESSION {line}", file=sys.stderr)
        if regressions:
            print(
                f"# BENCH GATE FAILED: {len(regressions)} regression(s) vs "
                f"{args.baseline} ({compared} metrics compared)",
                file=sys.stderr,
            )
            sys.exit(2)
        print(
            f"# bench gate OK vs {args.baseline} ({compared} metrics compared)",
            file=sys.stderr,
        )


if __name__ == "__main__":
    main()
