"""Benchmark entry: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only quality,db,...]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="medium-size datasets (minutes instead of seconds)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        ablation,
        analytics,
        db,
        engine_compare,
        imbalance,
        kernels,
        latency,
        quality,
        quality_vs_k,
        roofline,
    )

    suites = {
        "quality": lambda: quality.run(
            datasets=["social-s", "web-s", "road-s", "ldbc-s"]
            if not args.full
            else ["social-m", "web-m", "road-m", "ldbc-s"]
        ),
        "quality_vs_k": lambda: quality_vs_k.run(
            ks=(2, 4, 8, 16) if not args.full else (2, 4, 8, 16, 32)
        ),
        "imbalance": imbalance.run,
        "ablation": ablation.run,
        "analytics": analytics.run,
        "db": db.run,
        "latency": lambda: latency.run(
            dataset="social-s" if not args.full else "social-m"
        ),
        "engine": lambda: engine_compare.run(
            n=30_000 if not args.full else 100_000
        ),
        "kernels": kernels.run,
        "roofline": roofline.run,
    }
    only = set(args.only.split(",")) if args.only else None
    t0 = time.time()
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception as e:  # keep the suite running
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}", flush=True)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
