"""Paper Table III: contribution of buffering and refinement (K=16)."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.cuttana import partition as cuttana
from repro.graph import edge_cut
from repro.graph.generators import load_dataset

VARIANTS = {
    "full": dict(),
    "no_refine": dict(use_refinement=False),
    "no_buffer": dict(use_buffer=False),
    "fennel(no_both)": dict(use_refinement=False, use_buffer=False),
}


def run(k: int = 16, datasets=("social-s", "web-s"), seed: int = 0):
    rows = []
    for ds in datasets:
        graph = load_dataset(ds, seed=seed)
        base = None
        for name, kwargs in VARIANTS.items():
            part, us = timed(
                cuttana, graph, k, balance_mode="edge", order="random",
                seed=seed, **kwargs,
            )
            ec = edge_cut(graph, part)
            if name == "fennel(no_both)":
                base = ec
            rows.append(dict(dataset=ds, variant=name, edge_cut=ec))
            emit(f"ablation/{ds}/{name}", us, f"edge_cut={ec:.4f}")
        for r in rows:
            if r["dataset"] == ds and base:
                r["improvement_vs_fennel"] = 1 - r["edge_cut"] / base
    return rows


if __name__ == "__main__":
    run()
