"""Paper Table III: contribution of buffering and refinement (K=16).
Each variant is a ``PartitionSpec`` params block over the same algorithm."""
from __future__ import annotations

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.graph.generators import load_dataset

VARIANTS = {
    "full": dict(),
    "no_refine": dict(use_refinement=False),
    "no_buffer": dict(use_buffer=False),
    "fennel(no_both)": dict(use_refinement=False, use_buffer=False),
}


def run(k: int = 16, datasets=("social-s", "web-s"), seed: int = 0):
    rows = []
    for ds in datasets:
        graph = load_dataset(ds, seed=seed)
        base = None
        for name, params in VARIANTS.items():
            spec = PartitionSpec(
                algo="cuttana", k=k, balance_mode="edge", order="random",
                seed=seed, params=params,
            )
            result = partition(graph, spec)
            ec = result.quality()["edge_cut"]
            if name == "fennel(no_both)":
                base = ec
            rows.append(dict(dataset=ds, variant=name, edge_cut=ec,
                             refine_moves=result.telemetry.get("refine_moves", 0),
                             buffer_evictions=result.telemetry.get(
                                 "buffer_evictions", 0)))
            emit(f"ablation/{ds}/{name}",
                 result.timings["total_s"] * 1e6, f"edge_cut={ec:.4f}")
        for r in rows:
            if r["dataset"] == ds and base:
                r["improvement_vs_fennel"] = 1 - r["edge_cut"] / base
    return rows


if __name__ == "__main__":
    run()
