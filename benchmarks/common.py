"""Shared benchmark helpers: timing + CSV emission."""
from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeats: int = 1, **kwargs):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # microseconds
