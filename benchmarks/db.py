"""Paper Table V: graph-DB one/two-hop throughput per partitioner on the
LDBC-like benchmark."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import get_partitioner
from repro.db import QueryEngine, ldbc_query_mix
from repro.graph import edge_cut, edge_imbalance, vertex_imbalance
from repro.graph.generators import load_dataset


def run(k: int = 4, dataset: str = "ldbc-s", num_queries: int = 400,
        seed: int = 0):
    graph = load_dataset(dataset, seed=seed)
    seeds = ldbc_query_mix(graph, num_queries, seed=seed + 1)
    rows = []
    for name in ("cuttana", "fennel", "heistream", "ldg", "random"):
        part = get_partitioner(name)(
            graph, k, balance_mode="edge" if name == "cuttana" else "vertex",
            order="random", seed=seed,
        )
        eng = QueryEngine(graph, part, k)
        _, s1 = eng.one_hop(seeds)
        _, s2 = eng.two_hop(seeds)
        row = dict(
            algo=name,
            edge_cut=edge_cut(graph, part),
            edge_imbalance=edge_imbalance(graph, part, k),
            vertex_imbalance=vertex_imbalance(part, k),
            one_hop_qps=s1.throughput_qps(),
            two_hop_qps=s2.throughput_qps(),
            two_hop_p99_ms=s2.p99_latency_s() * 1e3,
        )
        rows.append(row)
        emit(
            f"db/{dataset}/{name}",
            s2.latencies_s.mean() * 1e6,
            f"1hop_qps={row['one_hop_qps']:.0f};2hop_qps={row['two_hop_qps']:.0f};"
            f"ec={row['edge_cut']:.3f};eimb={row['edge_imbalance']:.2f}",
        )
    return rows


if __name__ == "__main__":
    run()
