"""Paper Table V: graph-DB one/two-hop throughput per partitioner on the
LDBC-like benchmark, driven through ``repro.api``
(spec -> result -> ``result.db(...)``)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.graph.generators import load_dataset


def run(k: int = 4, dataset: str = "ldbc-s", num_queries: int = 400,
        seed: int = 0):
    from repro.db import ldbc_query_mix

    graph = load_dataset(dataset, seed=seed)
    seeds = ldbc_query_mix(graph, num_queries, seed=seed + 1)
    rows = []
    for name in ("cuttana", "fennel", "heistream", "ldg", "random"):
        if name == "random":
            spec = PartitionSpec(algo=name, k=k, seed=seed)
        else:
            spec = PartitionSpec(
                algo=name, k=k,
                balance_mode="edge" if name == "cuttana" else "vertex",
                order="random", seed=seed,
            )
        result = partition(graph, spec)
        rep = result.quality()
        one = result.db(hops=1, seeds=seeds)
        two = result.db(hops=2, seeds=seeds)
        row = dict(
            algo=name,
            spec=spec.to_dict(),
            edge_cut=rep["edge_cut"],
            edge_imbalance=rep["edge_imbalance"],
            vertex_imbalance=rep["vertex_imbalance"],
            one_hop_qps=one["qps"],
            two_hop_qps=two["qps"],
            two_hop_p99_ms=two["p99_latency_ms"],
        )
        rows.append(row)
        emit(
            f"db/{dataset}/{name}",
            two["mean_latency_ms"] * 1e3,
            f"1hop_qps={row['one_hop_qps']:.0f};2hop_qps={row['two_hop_qps']:.0f};"
            f"ec={row['edge_cut']:.3f};eimb={row['edge_imbalance']:.2f}",
        )
    return rows


if __name__ == "__main__":
    run()
