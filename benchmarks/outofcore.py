"""Out-of-core parity + latency study: partition from disk, bit-identically.

Generates one R-MAT, dumps it as a binary edge list, converts it to the
on-disk external CSR format (``repro.graph.external``), and partitions the
*same* graph twice per algorithm: once fully resident (``CSRGraph``), once
memory-mapped (``ExternalCSRGraph``). Assignments must be **bit-identical**
(the file-backed stream feeds the identical engine loops); the rows report
the stream-phase latency of both paths, the mapped-vs-resident graph bytes
from ``PartitionResult`` telemetry, and the process peak RSS - the
bench-trajectory gate (``benchmarks/run.py --baseline``) tracks the latency
columns across PRs.

Gated trajectory columns beyond the classic latency/quality pair:

* ``bytes_on_disk`` - the converted (v2 block-compressed) file size; a codec
  change that bloats the on-disk CSR fails the gate;
* ``peak_rss_mb`` - process high-water RSS per row; a streaming change that
  re-materializes the mapped graph in RAM fails the gate;
* ``superstep_ms`` - mean per-superstep wall of the sharded engine, from
  ``telemetry["profile"]``;
* the sharded algorithm additionally runs the mapped graph with
  ``prefetch="off"`` (``.../mapped-sync``): the decode-ahead pipeline must
  keep the default mapped row at-or-under its own baseline while the sync
  row documents what the prefetcher buys (assignments stay bit-identical
  across all three runs).
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.graph.external import ExternalCSRGraph, convert_edge_list
from repro.graph.generators import rmat_graph

ALGOS = (
    ("fennel", None),
    ("cuttana", None),
    ("cuttana-parallel", {"num_shards": 4}),
)


def _peak_rss_bytes() -> int:
    """Process high-water RSS. Monotone within the process, so per-row
    values only bound the true footprint of a single run from above."""
    try:
        import resource

        rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        return int(rss_kb) * 1024
    except Exception:  # pragma: no cover - non-POSIX fallback
        return 0


def _stream_seconds(result) -> float:
    t = result.timings
    return t.get("phase1_seconds", t.get("stream_seconds", t["total_s"]))


def _superstep_ms(result) -> float | None:
    """Mean per-superstep wall from the sharded-engine profile, or None."""
    prof = result.telemetry.get("profile")
    if not isinstance(prof, dict) or not prof.get("supersteps"):
        return None
    return float(prof["parallel_wall_s"]) / int(prof["supersteps"]) * 1e3


def run(n: int = 40_000, avg_degree: int = 12, k: int = 8, seed: int = 0):
    graph = rmat_graph(n, avg_degree=avg_degree, seed=seed)
    rows = []
    with tempfile.TemporaryDirectory() as td:
        edges_path = os.path.join(td, "edges.npy")
        np.save(edges_path, graph.edges_array())
        bin_path = os.path.join(td, "graph.bin")
        t0 = time.perf_counter()
        stats = convert_edge_list(edges_path, bin_path, num_vertices=n)
        convert_s = time.perf_counter() - t0
        ext = ExternalCSRGraph(bin_path)
        if not np.array_equal(np.asarray(ext.indptr), graph.indptr) or not (
            np.array_equal(np.asarray(ext.indices), graph.indices)
        ):
            raise AssertionError("converted CSR differs from the in-memory build")
        rows.append(dict(
            bench=f"outofcore/rmat{n}/convert", convert_seconds=convert_s,
            file_bytes=stats["file_bytes"], num_edges=stats["num_edges"],
            bytes_on_disk=stats["file_bytes"],
            raw_bytes=stats.get("raw_bytes"),
            compression_ratio=stats.get("compression_ratio"),
            format_version=stats["format_version"],
            peak_rss_mb=_peak_rss_bytes() / 2**20,
        ))
        emit(f"outofcore/rmat{n}/convert", convert_s * 1e6,
             f"file_bytes={stats['file_bytes']}")

        for algo, params in ALGOS:
            spec = PartitionSpec(
                algo=algo, k=k, balance_mode="edge", order="random",
                seed=seed, params=params,
            )
            variants = [("resident", graph, spec), ("mapped", ext, spec)]
            if params and "num_shards" in params:
                # the sharded engine also runs the mapped graph with the
                # decode-ahead pipeline forced off: the synchronous baseline
                # the prefetcher must beat (assignments stay bit-identical)
                sync_spec = spec.replace(
                    params={**params, "prefetch": "off"}
                )
                variants.append(("mapped-sync", ext, sync_spec))
            results = {}
            for backing, g, vspec in variants:
                result = partition(g, vspec)
                results[backing] = result
                secs = _stream_seconds(result)
                tel = result.telemetry
                row = dict(
                    bench=f"outofcore/rmat{n}/{algo}/{backing}",
                    algo=algo, backing=backing, stream_seconds=secs,
                    total_seconds=result.timings["total_s"],
                    edge_cut=result.quality()["edge_cut"],
                    peak_graph_bytes=tel["peak_graph_bytes"],
                    mapped_graph_bytes=tel["mapped_graph_bytes"],
                    compressed_graph_bytes=tel.get("compressed_graph_bytes", 0),
                    peak_rss_bytes=_peak_rss_bytes(),
                    peak_rss_mb=_peak_rss_bytes() / 2**20,
                    spec=vspec.to_dict(),
                )
                if backing != "resident":
                    row["bytes_on_disk"] = stats["file_bytes"]
                for key in ("prefetch_hit_rate", "decode_wall_s",
                            "prefetch_wait_s"):
                    if key in tel:
                        row[key] = tel[key]
                sstep = _superstep_ms(result)
                if sstep is not None:
                    row["superstep_ms"] = sstep
                rows.append(row)
                emit(
                    f"outofcore/rmat{n}/{algo}/{backing}", secs * 1e6,
                    f"graph_bytes={tel['peak_graph_bytes']};"
                    f"rss={_peak_rss_bytes()}",
                )
            for backing in results:
                if backing == "resident":
                    continue
                if not np.array_equal(
                    results["resident"].assignment, results[backing].assignment
                ):
                    raise AssertionError(
                        f"{algo}/{backing}: file-backed assignments differ "
                        f"from in-memory"
                    )
    return rows


if __name__ == "__main__":
    run()
