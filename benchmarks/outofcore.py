"""Out-of-core parity + latency study: partition from disk, bit-identically.

Generates one R-MAT, dumps it as a binary edge list, converts it to the
on-disk external CSR format (``repro.graph.external``), and partitions the
*same* graph twice per algorithm: once fully resident (``CSRGraph``), once
memory-mapped (``ExternalCSRGraph``). Assignments must be **bit-identical**
(the file-backed stream feeds the identical engine loops); the rows report
the stream-phase latency of both paths, the mapped-vs-resident graph bytes
from ``PartitionResult`` telemetry, and the process peak RSS - the
bench-trajectory gate (``benchmarks/run.py --baseline``) tracks the latency
columns across PRs.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.graph.external import ExternalCSRGraph, convert_edge_list
from repro.graph.generators import rmat_graph

ALGOS = (
    ("fennel", None),
    ("cuttana", None),
    ("cuttana-parallel", {"num_shards": 4}),
)


def _peak_rss_bytes() -> int:
    """Process high-water RSS. Monotone within the process, so per-row
    values only bound the true footprint of a single run from above."""
    try:
        import resource

        rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        return int(rss_kb) * 1024
    except Exception:  # pragma: no cover - non-POSIX fallback
        return 0


def _stream_seconds(result) -> float:
    t = result.timings
    return t.get("phase1_seconds", t.get("stream_seconds", t["total_s"]))


def run(n: int = 40_000, avg_degree: int = 12, k: int = 8, seed: int = 0):
    graph = rmat_graph(n, avg_degree=avg_degree, seed=seed)
    rows = []
    with tempfile.TemporaryDirectory() as td:
        edges_path = os.path.join(td, "edges.npy")
        np.save(edges_path, graph.edges_array())
        bin_path = os.path.join(td, "graph.bin")
        t0 = time.perf_counter()
        stats = convert_edge_list(edges_path, bin_path, num_vertices=n)
        convert_s = time.perf_counter() - t0
        ext = ExternalCSRGraph(bin_path)
        if not np.array_equal(np.asarray(ext.indptr), graph.indptr) or not (
            np.array_equal(np.asarray(ext.indices), graph.indices)
        ):
            raise AssertionError("converted CSR differs from the in-memory build")
        rows.append(dict(
            bench=f"outofcore/rmat{n}/convert", convert_seconds=convert_s,
            file_bytes=stats["file_bytes"], num_edges=stats["num_edges"],
        ))
        emit(f"outofcore/rmat{n}/convert", convert_s * 1e6,
             f"file_bytes={stats['file_bytes']}")

        for algo, params in ALGOS:
            spec = PartitionSpec(
                algo=algo, k=k, balance_mode="edge", order="random",
                seed=seed, params=params,
            )
            results = {}
            for backing, g in (("resident", graph), ("mapped", ext)):
                result = partition(g, spec)
                results[backing] = result
                secs = _stream_seconds(result)
                tel = result.telemetry
                rows.append(dict(
                    bench=f"outofcore/rmat{n}/{algo}/{backing}",
                    algo=algo, backing=backing, stream_seconds=secs,
                    total_seconds=result.timings["total_s"],
                    edge_cut=result.quality()["edge_cut"],
                    peak_graph_bytes=tel["peak_graph_bytes"],
                    mapped_graph_bytes=tel["mapped_graph_bytes"],
                    peak_rss_bytes=_peak_rss_bytes(),
                    spec=spec.to_dict(),
                ))
                emit(
                    f"outofcore/rmat{n}/{algo}/{backing}", secs * 1e6,
                    f"graph_bytes={tel['peak_graph_bytes']};"
                    f"rss={_peak_rss_bytes()}",
                )
            if not np.array_equal(
                results["resident"].assignment, results["mapped"].assignment
            ):
                raise AssertionError(
                    f"{algo}: file-backed assignments differ from in-memory"
                )
    return rows


if __name__ == "__main__":
    run()
