"""Model/train substrate benchmark: the repaired consumer side of the
pipeline, timed so the bench trajectory tracks it from the repair onward.

Two signals, both runnable on CPU in seconds:

* models-smoke wall time - one reduced-config forward + grad step for a
  dense, an MoE, and an SSM architecture (the same path
  ``tests/test_models_smoke.py`` enforces for correctness);
* flash-attention kernel timing - the jnp reference at a training shape
  plus the Pallas kernel body in interpret mode at a small shape (interpret
  wall time tracks kernel-body complexity, not TPU speed).

    PYTHONPATH=src python -m benchmarks.run --only substrate \
        --json BENCH_substrate.json
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed

SMOKE_ARCHS = ("qwen3-8b", "jamba-v0.1-52b", "falcon-mamba-7b")


def _smoke_step(arch: str) -> dict:
    from jax.sharding import Mesh

    from repro.compat import use_mesh
    from repro.configs import get_reduced_config
    from repro.models import Axes, Model

    cfg = get_reduced_config(arch)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    model = Model(cfg, Axes(dp=("data",), tp="model"), mesh)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)

    def loss_fn(p):
        logits, aux = model.forward(p, {"tokens": tokens})
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    t0 = time.perf_counter()
    with use_mesh(mesh):
        params = model.init(jax.random.key(0))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        jax.block_until_ready(grads)
    wall_s = time.perf_counter() - t0
    assert np.isfinite(float(loss)), arch
    return {
        "bench": "substrate/models_smoke",
        "arch": arch,
        "wall_s": round(wall_s, 3),
        "loss": float(loss),
    }


def run():
    rows = []
    for arch in SMOKE_ARCHS:
        row = _smoke_step(arch)
        rows.append(row)
        emit(f"substrate/models_smoke/{arch}", row["wall_s"] * 1e6,
             f"loss={row['loss']:.3f}")

    from repro.kernels.flash_attention.ops import flash_attention

    rng = np.random.default_rng(0)
    # jnp reference at a training shape: B2 H8 T1024 D64, causal
    q = jnp.asarray(rng.standard_normal((2, 8, 1024, 64)), jnp.float32)
    ref = jax.jit(lambda q: flash_attention(q, q, q, use_pallas=False))
    ref(q).block_until_ready()
    _, us = timed(lambda: ref(q).block_until_ready(), repeats=3)
    flops = 4 * 2 * 8 * 1024 * 1024 // 2 * 64
    rows.append({
        "bench": "substrate/flash_attention_ref",
        "shape": "2x8x1024x64",
        "us_per_call": round(us, 1),
        "gflops": round(flops / (us / 1e6) / 1e9, 1),
    })
    emit("substrate/flash_attention_ref/2x8x1024x64", us,
         f"gflops={rows[-1]['gflops']}")

    # Pallas kernel body in interpret mode (small shape; correctness-bearing
    # decode-offset path included so a repeat of the seed drift shows up here
    # as an error, not a silent deselect)
    qs = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    dq = jnp.asarray(rng.standard_normal((1, 2, 1, 64)), jnp.float32)
    kv = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    interp = lambda: flash_attention(
        qs, kv, kv, use_pallas=True, interpret=True
    ).block_until_ready()
    interp()
    _, us = timed(interp, repeats=3)
    rows.append({
        "bench": "substrate/flash_attention_interpret",
        "shape": "1x2x128x64",
        "us_per_call": round(us, 1),
    })
    emit("substrate/flash_attention_interpret/1x2x128x64", us, "")
    decode = lambda: flash_attention(
        dq, kv, kv, q_offset=127, use_pallas=True, interpret=True
    ).block_until_ready()
    decode()
    _, us = timed(decode, repeats=3)
    rows.append({
        "bench": "substrate/flash_attention_decode_interpret",
        "shape": "1x2x1(kv128)x64,offset=127",
        "us_per_call": round(us, 1),
    })
    emit("substrate/flash_attention_decode_interpret/offset127", us, "")
    return rows


if __name__ == "__main__":
    run()
