"""Roofline aggregation: read runs/dryrun/*.json, compute the three terms per
(arch x shape x mesh), name the bottleneck, and emit the EXPERIMENTS.md
tables.

    compute term    = dot_FLOPs_total / (chips x 197 TFLOP/s)
    memory term     = HBM bytes / (chips x 819 GB/s)     [see note below]
    collective term = collective bytes per shard / 50 GB/s per link

Memory-term note: XLA's cost_analysis counts while bodies once, so its bytes
are a *lower bound*; we report an analytic HBM estimate (params + optimizer
+ KV traffic per step) alongside, and use max(xla_scaled, analytic) for the
bottleneck call.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.configs import ALIASES, SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analytic_hbm_bytes_per_chip(rec: dict) -> float:
    """Per-chip HBM traffic per step: every resident parameter byte is read
    once (weights are FSDP-sharded; the all-gathered copies are read from
    VMEM-adjacent buffers but still land in HBM once), optimizer state
    read+written for train, KV cache read for decode."""
    cfg = get_config(rec["arch"])
    chips = rec["chips"]
    shape = SHAPES[rec["shape"]]
    kind = shape["kind"]
    n = cfg.param_count()
    p_bytes = 2.0 * n / chips  # bf16 weights, sharded
    if kind == "train":
        # grads fp32 + m/v read+write (state dtype) + param write
        sd = 2 if cfg.opt_state_dtype == "bfloat16" else 4
        opt = (4 + 4 * sd + 2) * n / chips
        act = 2.0 * shape["global_batch"] * shape["seq_len"] * cfg.d_model \
            * cfg.num_layers * 2 / chips  # store+reload once w/ remat
        return p_bytes * 3 + opt + act  # fwd + 2x bwd passes read weights
    if kind == "prefill":
        act = 2.0 * shape["global_batch"] * shape["seq_len"] * cfg.d_model \
            * cfg.num_layers / chips
        return p_bytes + act
    # decode: weights + full KV cache read per token
    kv = 0.0
    b, s = shape["global_batch"], shape["seq_len"]
    for spec in cfg.layers():
        if spec.mixer == "attn":
            eff = min(spec.window or s, s)
            if cfg.use_mla:
                kv += b * eff * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
            else:
                kv += 2 * b * eff * cfg.n_kv_heads * cfg.head_dim * 2
        elif spec.mixer == "mamba":
            kv += b * cfg.mamba_expand * cfg.d_model * cfg.ssm_state * 4
    return p_bytes + kv / chips


def load_records(out_dir: str = "runs/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    compute_s = rec["dot_flops_total"] / (chips * PEAK_FLOPS)
    xla_bytes = (rec.get("cost") or {}).get("xla_bytes_body_once") or 0.0
    trip = max(rec.get("max_trip_count", 1.0), 1.0)
    mem_analytic = analytic_hbm_bytes_per_chip(rec)
    mem_s = max(xla_bytes * trip / chips, mem_analytic) / HBM_BW
    coll_s = rec["total_collective_bytes_per_shard"] / ICI_BW
    terms = {"compute": compute_s, "memory": mem_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    mfu_bound = (
        rec["model_flops"] / (chips * PEAK_FLOPS) / step_s if step_s else 0.0
    )
    return dict(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        chips=chips,
        compute_s=compute_s,
        memory_s=mem_s,
        collective_s=coll_s,
        bottleneck=bottleneck,
        model_flops_ratio=rec["model_flops"] / max(rec["dot_flops_total"], 1),
        roofline_fraction=mfu_bound,
        compile_s=rec.get("compile_s"),
    )


def markdown_table(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "bottleneck | useful/compiled FLOPs | roofline frac |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['bottleneck']}** | "
            f"{r['model_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |\n"
        )
    return hdr + body


def run(out_dir: str = "runs/dryrun"):
    rows = []
    for rec in load_records(out_dir):
        row = roofline_row(rec)
        if row is None:
            status = rec.get("status", "?")
            if status.startswith("skip"):
                continue
            emit(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}", 0.0,
                 f"status={status}")
            continue
        rows.append(row)
        emit(
            f"roofline/{row['arch']}/{row['shape']}/{row['mesh']}",
            row["compute_s"] * 1e6,
            f"bottleneck={row['bottleneck']};frac={row['roofline_fraction']:.3f};"
            f"useful={row['model_flops_ratio']:.2f}",
        )
    return rows


if __name__ == "__main__":
    rows = run()
    print(markdown_table(rows))
