"""Kernel micro-benchmarks: jnp reference throughput on CPU + interpret-mode
correctness spot-check (TPU wall-times require hardware; the roofline for
kernels comes from the dry-run)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.ell_spmv.ops import ell_spmv
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mamba_scan.ops import selective_scan
from repro.kernels.partition_score.ops import fennel_scores


def run():
    rng = np.random.default_rng(0)
    # partition_score: 4096 vertices x 128 nbrs x K=64
    nbr = rng.integers(-1, 64, size=(4096, 128)).astype(np.int32)
    sizes = rng.random(64).astype(np.float32)
    fn = jax.jit(lambda n, s: fennel_scores(n, s, 0.37, 1.5, use_pallas=False))
    fn(nbr, sizes).block_until_ready()
    _, us = timed(lambda: fn(nbr, sizes).block_until_ready(), repeats=5)
    emit("kernels/partition_score/4096x128xK64", us,
         f"scores_per_s={4096 * 64 / (us / 1e6):.2e}")

    # StreamEngine chunk shape: histogram-only (alpha=0), C=512 x D=128 x K=16
    # CPU host companion (what the engine dispatches to off-TPU) vs jnp ref
    from repro.kernels.partition_score.ops import neighbor_histograms_host

    cnbr = rng.integers(-1, 16, size=(512, 128)).astype(np.int32)
    rows = np.repeat(np.arange(512, dtype=np.int64), 128)
    flat = cnbr.ravel()
    _, us = timed(lambda: neighbor_histograms_host(rows, flat, 512, 16), repeats=20)
    emit("kernels/partition_score/host_hist/512x128xK16", us,
         f"verts_per_s={512 / (us / 1e6):.2e}")
    fnh = jax.jit(lambda n, s: fennel_scores(n, s, 0.0, 1.5, use_pallas=False))
    zs = np.zeros(16, np.float32)
    fnh(cnbr, zs).block_until_ready()
    _, us = timed(lambda: fnh(cnbr, zs).block_until_ready(), repeats=20)
    emit("kernels/partition_score/jnp_hist/512x128xK16", us,
         f"verts_per_s={512 / (us / 1e6):.2e}")
    got = np.asarray(fnh(cnbr, zs))
    want = neighbor_histograms_host(rows, flat, 512, 16)
    assert np.allclose(got, want), "host histogram != kernel histogram"

    # ell_spmv: 65536 rows x 32
    x = rng.random(65537).astype(np.float32)
    cols = rng.integers(0, 65537, size=(65536, 32)).astype(np.int32)
    fn2 = jax.jit(lambda x, c: ell_spmv(x, c, "sum", use_pallas=False))
    fn2(x, cols).block_until_ready()
    _, us = timed(lambda: fn2(x, cols).block_until_ready(), repeats=5)
    emit("kernels/ell_spmv/65536x32", us,
         f"edges_per_s={65536 * 32 / (us / 1e6):.2e}")

    # flash attention ref: B2 H8 T1024 D64
    q = jnp.asarray(rng.standard_normal((2, 8, 1024, 64)), jnp.float32)
    fn3 = jax.jit(lambda q: flash_attention(q, q, q, use_pallas=False))
    fn3(q).block_until_ready()
    _, us = timed(lambda: fn3(q).block_until_ready(), repeats=3)
    flops = 4 * 2 * 8 * 1024 * 1024 // 2 * 64
    emit("kernels/flash_attention/2x8x1024x64", us,
         f"gflops={flops / (us / 1e6) / 1e9:.1f}")

    # mamba scan: B2 T256 D512 N16
    x = jnp.asarray(rng.standard_normal((2, 256, 512)), jnp.float32)
    dt = jnp.abs(x) * 0.05 + 0.01
    a = jnp.asarray(-np.abs(rng.standard_normal((512, 16))) - 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((2, 256, 16)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((2, 256, 16)), jnp.float32)
    dk = jnp.ones(512)
    fn4 = jax.jit(lambda *a_: selective_scan(*a_, use_pallas=False)[0])
    fn4(x, dt, a, b, c, dk).block_until_ready()
    _, us = timed(lambda: fn4(x, dt, a, b, c, dk).block_until_ready(), repeats=3)
    emit("kernels/mamba_scan/2x256x512x16", us,
         f"steps_per_s={2 * 256 / (us / 1e6):.2e}")

    # interpret-mode correctness spot checks (kernel body == oracle)
    small = rng.integers(-1, 8, size=(16, 16)).astype(np.int32)
    sz = rng.random(8).astype(np.float32)
    got = fennel_scores(small, sz, 0.5, use_pallas=True, interpret=True)
    want = fennel_scores(small, sz, 0.5, use_pallas=False)
    ok = bool(np.allclose(np.asarray(got), np.asarray(want), atol=1e-5))
    emit("kernels/interpret_check", 0.0, f"allclose={ok}")
    assert ok
    return True


if __name__ == "__main__":
    run()
