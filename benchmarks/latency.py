"""Paper §IV-A partitioning-latency analysis + the kernel-backed
chunk-parallel variant's speed/quality trade (beyond-paper). Runs entirely
through ``repro.api``: one ``PartitionSpec`` per cell, structured rows built
from the ``PartitionResult``."""
from __future__ import annotations

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.graph.generators import load_dataset

ALGOS = ("fennel", "ldg", "heistream", "cuttana", "cuttana-batched")


def run(k: int = 8, dataset: str = "social-m", seed: int = 0):
    graph = load_dataset(dataset, seed=seed)
    rows = []
    for name in ALGOS:
        spec = PartitionSpec(
            algo=name, k=k, balance_mode="edge", order="random", seed=seed,
        )
        result = partition(graph, spec)
        ec = result.quality()["edge_cut"]
        seconds = result.timings["total_s"]
        rows.append(dict(algo=name, seconds=seconds, edge_cut=ec,
                         spec=spec.to_dict(), timings=result.timings))
        emit(f"latency/{dataset}/{name}", seconds * 1e6, f"edge_cut={ec:.4f}")
    return rows


if __name__ == "__main__":
    run()
