"""Paper §IV-A partitioning-latency analysis + the kernel-backed
chunk-parallel variant's speed/quality trade (beyond-paper)."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import get_partitioner
from repro.core.cuttana_batched import partition_batched
from repro.graph import edge_cut
from repro.graph.generators import load_dataset


def run(k: int = 8, dataset: str = "social-m", seed: int = 0):
    graph = load_dataset(dataset, seed=seed)
    rows = []
    for name in ("fennel", "ldg", "heistream", "cuttana"):
        part, us = timed(
            get_partitioner(name), graph, k,
            balance_mode="edge", order="random", seed=seed,
        )
        ec = edge_cut(graph, part)
        rows.append(dict(algo=name, seconds=us / 1e6, edge_cut=ec))
        emit(f"latency/{dataset}/{name}", us, f"edge_cut={ec:.4f}")
    part, us = timed(
        partition_batched, graph, k, balance_mode="edge", order="random",
        seed=seed,
    )
    ec = edge_cut(graph, part)
    rows.append(dict(algo="cuttana-batched", seconds=us / 1e6, edge_cut=ec))
    emit(f"latency/{dataset}/cuttana-batched", us, f"edge_cut={ec:.4f}")
    return rows


if __name__ == "__main__":
    run()
