"""Paper Table IV: distributed analytics latency (PageRank 30 iters, CC,
SSSP) under each partitioner, driven through ``repro.api``.

Two measurements per partition result:
  * ``result.analytics(mode="model")`` - the cluster cost model (v5e-pod
    constants) for every partitioner including the vertex-cut edge
    partitioners (HDRF/Ginger), and
  * ``result.analytics(mode="simulated")`` - a real run of the JAX engine
    (simulated-device mode) for the vertex partitioners, reporting measured
    halo traffic.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.graph.generators import load_dataset

WORKLOADS = {"pagerank": 30, "cc": 20, "sssp": 20}
VERTEX_PARTITIONERS = ["cuttana", "fennel", "ldg", "heistream"]
EDGE_PARTITIONERS = ["hdrf", "ginger"]


def run(k: int = 8, datasets=("social-s", "web-s"), seed: int = 0,
        engine_run: bool = True):
    rows = []
    for ds in datasets:
        graph = load_dataset(ds, seed=seed)
        results = {}
        for name in VERTEX_PARTITIONERS:
            spec = PartitionSpec(
                algo=name, k=k, balance_mode="edge", order="random", seed=seed
            )
            results[name] = partition(graph, spec)
        for name in EDGE_PARTITIONERS:
            results[name] = partition(
                graph, PartitionSpec(algo=name, k=k, seed=seed)
            )
        for wl, iters in WORKLOADS.items():
            for name, result in results.items():
                cost = result.analytics(program=wl, iters=iters, mode="model")
                rows.append(dict(dataset=ds, workload=wl, algo=name, **cost))
                emit(
                    f"analytics_model/{ds}/{wl}/{name}",
                    cost["total_s"] * 1e6,
                    f"straggler={cost['straggler_ratio']:.2f};"
                    f"netB/iter={cost['network_bytes_per_iter']:.2e}",
                )
        if engine_run:
            for name in ("cuttana", "fennel"):
                sim = results[name].analytics(
                    program="pagerank", iters=10, mode="simulated"
                )
                emit(
                    f"analytics_engine/{ds}/pagerank10/{name}",
                    sim["seconds"] * 1e6,
                    f"halo_msgs/iter={sim['halo_messages_per_iter']};"
                    f"max_edges={sim['max_local_edges']}",
                )
                rows.append(dict(dataset=ds, workload="pagerank10-engine",
                                 algo=name,
                                 halo=sim["halo_messages_per_iter"],
                                 max_edges=sim["max_local_edges"]))
    return rows


if __name__ == "__main__":
    run()
