"""Paper Table IV: distributed analytics latency (PageRank 30 iters, CC,
SSSP) under each partitioner.

Two measurements:
  * the cluster cost model (v5e-pod constants) for every partitioner
    including the vertex-cut edge partitioners (HDRF/Ginger), and
  * a real run of the JAX engine (simulated-device mode) for the vertex
    partitioners, reporting measured halo traffic.
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.analytics import (
    GraphEngine,
    localize,
    pagerank_program,
    cc_program,
    sssp_program,
    workload_cost,
)
from repro.core import get_edge_partitioner, get_partitioner
from repro.graph.generators import load_dataset

WORKLOADS = {"pagerank": 30, "cc": 20, "sssp": 20}
VERTEX_PARTITIONERS = ["cuttana", "fennel", "ldg", "heistream"]
EDGE_PARTITIONERS = ["hdrf", "ginger"]


def run(k: int = 8, datasets=("social-s", "web-s"), seed: int = 0,
        engine_run: bool = True):
    rows = []
    for ds in datasets:
        graph = load_dataset(ds, seed=seed)
        assignments = {}
        for name in VERTEX_PARTITIONERS:
            assignments[name] = get_partitioner(name)(
                graph, k, balance_mode="edge", order="random", seed=seed
            )
        for name in EDGE_PARTITIONERS:
            assignments[name] = get_edge_partitioner(name)(graph, k, seed=seed)
        for wl, iters in WORKLOADS.items():
            for name, assignment in assignments.items():
                cost = workload_cost(graph, assignment, k, iters)
                rows.append(dict(dataset=ds, workload=wl, algo=name, **cost))
                emit(
                    f"analytics_model/{ds}/{wl}/{name}",
                    cost["total_s"] * 1e6,
                    f"straggler={cost['straggler_ratio']:.2f};"
                    f"netB/iter={cost['network_bytes_per_iter']:.2e}",
                )
        if engine_run:
            programs = {
                "pagerank": pagerank_program(),
                "cc": cc_program(),
                "sssp": sssp_program(),
            }
            for name in ("cuttana", "fennel"):
                lg = localize(graph, assignments[name], k)
                eng = GraphEngine(lg, programs["pagerank"])
                _, us = timed(eng.run_simulated, 10)
                st = eng.stats(10)
                emit(
                    f"analytics_engine/{ds}/pagerank10/{name}",
                    us,
                    f"halo_msgs/iter={st.true_halo_messages_per_iter};"
                    f"max_edges={st.max_local_edges}",
                )
                rows.append(dict(dataset=ds, workload="pagerank10-engine",
                                 algo=name,
                                 halo=st.true_halo_messages_per_iter,
                                 max_edges=st.max_local_edges))
    return rows


if __name__ == "__main__":
    run()
