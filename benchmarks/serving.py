"""Serving suite: the paper's end-goal claim, measured instead of modelled.

Ranks cuttana vs fennel vs hdrf vs random under *identical* concurrent load
(same deterministic workload, >= 1k in-flight closed-loop queries) through
the partition-aware serving layer (:mod:`repro.serve.graph`), with RPC and
byte counts derived from the router's real message flow. Emits one row per
partitioner (throughput + tails + message counts + the partition's
edge-cut/communication volume, so the throughput/p99 ordering can be checked
against the cut metrics), one replication row showing ``replication_budget >
0`` reducing cross-partition RPCs at fixed answers, and an ``ordering`` row
CI asserts on: measured throughput must rank cuttana above random, and
cuttana's p99 must not regress past fennel/hdrf.

Gated metrics (``qps_sim`` higher-is-better, ``p99_sim_ms`` lower-is-better)
are deterministic - they come from message counts under the fixed DB cost
model, not the host's wall clock - so the trajectory gate can hold them to a
real tolerance across runners.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.graph.generators import rmat_graph
from repro.graph.metrics import communication_volume, edge_cut

ALGOS = ("cuttana", "fennel", "hdrf", "random")


def _spec(algo: str, k: int, seed: int) -> PartitionSpec:
    if algo in ("random", "hdrf"):
        return PartitionSpec(algo=algo, k=k, seed=seed)
    return PartitionSpec(
        algo=algo, k=k, balance_mode="edge", order="random", seed=seed
    )


def run(
    n: int = 8000,
    k: int = 8,
    queries: int = 2000,
    concurrency: int = 1000,
    seed: int = 0,
    replication_budget: float = 0.05,
    check_parity: bool = True,
):
    from repro.serve.graph import build_workload, QueryMix, run_load

    graph = rmat_graph(n, avg_degree=12, seed=seed)
    workload = build_workload(graph, queries, QueryMix(), seed=seed + 1)
    rows = []
    reports = {}
    for algo in ALGOS:
        result = partition(graph, _spec(algo, k, seed))
        part = result.vertex_assignment()
        rep = run_load(
            result.serve(store_results=False),
            workload=workload,
            concurrency=concurrency,
        )
        reports[algo] = rep
        row = dict(
            bench=f"serving/rmat{n}/{algo}",
            algo=algo,
            num_queries=rep.num_queries,
            concurrency=rep.concurrency,
            qps_sim=rep.qps_sim,
            p99_sim_ms=rep.latency_ms["sim"]["p99"],
            p50_sim_ms=rep.latency_ms["sim"]["p50"],
            qps_wall=rep.qps_wall,
            rpcs=rep.rpcs,
            messages=rep.messages,
            wire_bytes=rep.wire_bytes,
            local_queries=rep.local_queries,
            edge_cut=edge_cut(graph, part),
            communication_volume=communication_volume(graph, part, k),
        )
        rows.append(row)
        emit(
            row["bench"],
            rep.latency_ms["sim"]["mean"] * 1e3,
            f"qps={rep.qps_sim:.0f};p99={row['p99_sim_ms']:.3f}ms;"
            f"rpcs={rep.rpcs};ec={row['edge_cut']:.3f}",
        )

    # replication: same cuttana partition, budget > 0 must cut RPCs without
    # changing a single answer (parity checked on a stored-results rerun)
    result = partition(graph, _spec("cuttana", k, seed))
    base = run_load(
        result.serve(replication_budget=0.0),
        workload=workload[: min(queries, 500)],
        concurrency=concurrency,
    )
    repl = run_load(
        result.serve(replication_budget=replication_budget),
        workload=workload[: min(queries, 500)],
        concurrency=concurrency,
    )
    parity = True
    if check_parity:
        a, b = base.answers(), repl.answers()
        for qid, va in a.items():
            vb = b[qid]
            same = (
                np.array_equal(va, vb)
                if isinstance(va, np.ndarray)
                else va == vb
            )
            if not same:
                parity = False
                break
    rows.append(
        dict(
            bench=f"serving/rmat{n}/cuttana/replication",
            algo="cuttana",
            replication_budget=replication_budget,
            rpcs_base=base.rpcs,
            rpcs_replicated=repl.rpcs,
            rpc_reduction=1.0 - repl.rpcs / max(base.rpcs, 1),
            answers_identical=parity,
            **{f"replication_{k2}": v for k2, v in repl.replication.items()},
        )
    )
    emit(
        rows[-1]["bench"],
        0.0,
        f"rpcs {base.rpcs}->{repl.rpcs} "
        f"(-{rows[-1]['rpc_reduction']:.1%});parity={parity}",
    )

    # ordering: the figure-level claim - measured throughput/p99 must track
    # the cut metrics (cuttana above random, tails no worse than baselines)
    qps = {a: reports[a].qps_sim for a in ALGOS}
    p99 = {a: reports[a].latency_ms["sim"]["p99"] for a in ALGOS}
    rows.append(
        dict(
            bench=f"serving/rmat{n}/ordering",
            qps_cuttana_over_random=qps["cuttana"] / qps["random"],
            p99_cuttana_over_fennel=p99["cuttana"] / p99["fennel"],
            p99_cuttana_over_hdrf=p99["cuttana"] / p99["hdrf"],
            throughput_ordering_ok=bool(qps["cuttana"] > qps["random"]),
            tail_ordering_ok=bool(
                p99["cuttana"] <= 1.05 * min(p99["fennel"], p99["hdrf"])
            ),
        )
    )
    emit(
        rows[-1]["bench"],
        0.0,
        f"qps_ratio={rows[-1]['qps_cuttana_over_random']:.2f};"
        f"tail_ok={rows[-1]['tail_ordering_ok']}",
    )
    return rows


if __name__ == "__main__":
    run()
