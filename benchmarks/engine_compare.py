"""StreamEngine vs the seed per-vertex loops: latency on identical work.

Every pair runs the same partitioner configuration twice - once through the
unified engine (repro.core.*), once through the preserved seed loop
(repro.core.legacy) - asserts the partitions are identical (exact mode is
bit-parity, see tests/test_engine.py), and reports the speedup. The PR's
acceptance bar is engine-backed FENNEL >= 2x on a >= 100k-vertex graph.
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import legacy
from repro.core.cuttana import partition as cuttana
from repro.core.fennel import partition as fennel
from repro.core.ldg import partition as ldg
from repro.graph.generators import rmat_graph


def run(n: int = 100_000, k: int = 16, avg_degree: float = 16.0, seed: int = 0):
    g = rmat_graph(n, avg_degree=avg_degree, seed=seed)
    kw = dict(balance_mode="edge", order="random", seed=seed)
    pairs = [
        ("fennel", lambda: fennel(g, k, **kw),
         lambda: legacy.fennel_partition(g, k, **kw)),
        ("ldg", lambda: ldg(g, k, **kw),
         lambda: legacy.ldg_partition(g, k, **kw)),
        ("cuttana-unbuffered", lambda: cuttana(g, k, use_buffer=False, **kw),
         lambda: legacy.cuttana_partition(g, k, use_buffer=False, **kw)),
        ("cuttana", lambda: cuttana(g, k, **kw),
         lambda: legacy.cuttana_partition(g, k, **kw)),
    ]
    rows = []
    for name, eng_fn, leg_fn in pairs:
        pe, ue = timed(eng_fn)
        pl, ul = timed(leg_fn)
        assert (pe == pl).all(), f"{name}: engine/legacy parity broken"
        speedup = ul / ue
        rows.append(dict(algo=name, engine_s=ue / 1e6, legacy_s=ul / 1e6,
                         speedup=speedup))
        emit(f"engine_compare/{n}v/{name}", ue,
             f"legacy_us={ul:.0f},speedup={speedup:.2f}x")
    return rows


if __name__ == "__main__":
    run()
