"""Churn suite: incremental updates vs full re-partition on one timestamped
stream.

Replays a seeded R-MAT churn stream (random arrival ordering - the
adversarial case where a vertex's edges are scattered across the stream)
through the incremental partitioner and compares against the full
re-partition strategy on the same stream:

* quality: final edge-cut of each strategy on the post-churn snapshot;
* cost per batch: ``update_ms`` - mean wall per arrival batch for the
  incremental path, one full re-partition wall for the baseline (what the
  full strategy pays at *every* batch);
* stream work: vertex placements. Incremental places each arriving vertex
  once plus its re-stream windows; full re-partition replays every seen
  vertex at every batch (``sum_b |V_seen(b)|``).

The acceptance bar (gated by ``scripts/churn_smoke.py`` in CI): incremental
stays within 15% of the full re-partition edge-cut at under half its
cumulative stream work.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import fennel
from repro.core.incremental import IncrementalPartitioner
from repro.graph.churn import rmat_churn
from repro.graph.metrics import edge_cut


def full_repartition_work(stream, num_batches: int) -> int:
    """Cumulative stream work of re-partitioning from scratch at every
    batch: sum over batches of the vertices seen so far."""
    seen = np.zeros(stream.num_vertices, dtype=bool)
    total = 0
    for batch in stream.batches(num_batches):
        if batch.size:
            seen[np.unique(batch)] = True
        total += int(seen.sum())
    return total


def run(n: int = 25_000, k: int = 8, num_batches: int = 20, seed: int = 7):
    rows = []
    stream = rmat_churn(n, avg_degree=16, seed=seed, ordering="random")
    graph = stream.final_graph()

    # ---- incremental: ingest per batch, time each update
    inc = IncrementalPartitioner(
        stream.num_vertices, k, balance_mode="edge", seed=seed
    )
    batch_ms = []
    for batch in stream.batches(num_batches):
        t0 = time.perf_counter()
        inc.ingest(batch)
        batch_ms.append((time.perf_counter() - t0) * 1e3)
    part_inc = inc.finalize()
    cut_inc = edge_cut(graph, part_inc)
    inc_update_ms = float(np.mean(batch_ms))
    inc_work = inc.stream_work

    # ---- full re-partition: the cost the baseline pays per arrival batch
    t0 = time.perf_counter()
    part_full = fennel.partition(graph, k, balance_mode="edge", seed=seed)
    full_ms = (time.perf_counter() - t0) * 1e3
    cut_full = edge_cut(graph, part_full)
    full_work = full_repartition_work(stream, num_batches)

    cut_ratio = cut_inc / max(cut_full, 1e-12)
    work_ratio = inc_work / max(full_work, 1)
    rows.append({
        "bench": f"churn/rmat{n}/incremental",
        "algo": "cuttana-incremental",
        "n": stream.num_vertices,
        "m": stream.num_edges,
        "k": k,
        "num_batches": num_batches,
        "edge_cut": float(cut_inc),
        "update_ms": inc_update_ms,
        "stream_work": int(inc_work),
        "restream_windows": inc.restream_windows,
        "moved_vertices": inc.moved_vertices,
        "cut_ratio_vs_full": float(cut_ratio),
        "work_ratio_vs_full": float(work_ratio),
    })
    rows.append({
        "bench": f"churn/rmat{n}/full-repartition",
        "algo": "fennel",
        "n": stream.num_vertices,
        "m": stream.num_edges,
        "k": k,
        "num_batches": num_batches,
        "edge_cut": float(cut_full),
        "update_ms": float(full_ms),
        "stream_work": int(full_work),
    })
    emit(
        f"churn_incremental_n{n}",
        inc_update_ms * 1e3,
        f"cut={cut_inc:.4f},windows={inc.restream_windows},"
        f"moved={inc.moved_vertices},work_ratio={work_ratio:.3f}",
    )
    emit(
        f"churn_full_repartition_n{n}",
        full_ms * 1e3,
        f"cut={cut_full:.4f},cut_ratio={cut_ratio:.3f}",
    )
    return rows


if __name__ == "__main__":
    run()
