"""Paper Fig. 6: quality as a function of the number of partitions. Runs
entirely through ``repro.api``: one ``PartitionSpec`` per cell, structured
rows built from the ``PartitionResult``."""
from __future__ import annotations

from benchmarks.common import emit
from repro.api import PartitionSpec, partition
from repro.graph.generators import load_dataset

ALGOS = ("cuttana", "fennel", "heistream")


def run(ks=(2, 4, 8, 16, 32), datasets=("social-s", "web-s"), seed: int = 0):
    rows = []
    for ds in datasets:
        graph = load_dataset(ds, seed=seed)
        for k in ks:
            for name in ALGOS:
                spec = PartitionSpec(
                    algo=name, k=k, balance_mode="edge", order="random",
                    seed=seed,
                )
                result = partition(graph, spec)
                ec = result.quality()["edge_cut"]
                rows.append(dict(dataset=ds, k=k, algo=name, edge_cut=ec,
                                 spec=spec.to_dict(),
                                 seconds=result.timings["total_s"]))
                emit(
                    f"quality_vs_k/{ds}/k{k}/{name}",
                    result.timings["total_s"] * 1e6,
                    f"edge_cut={ec:.4f}",
                )
    return rows


if __name__ == "__main__":
    run()
