"""Paper Fig. 6: quality as a function of the number of partitions."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import get_partitioner
from repro.graph import edge_cut
from repro.graph.generators import load_dataset


def run(ks=(2, 4, 8, 16, 32), datasets=("social-s", "web-s"), seed: int = 0):
    rows = []
    for ds in datasets:
        graph = load_dataset(ds, seed=seed)
        for k in ks:
            for name in ("cuttana", "fennel", "heistream"):
                part, us = timed(
                    get_partitioner(name), graph, k,
                    balance_mode="edge", order="random", seed=seed,
                )
                ec = edge_cut(graph, part)
                rows.append(dict(dataset=ds, k=k, algo=name, edge_cut=ec))
                emit(f"quality_vs_k/{ds}/k{k}/{name}", us, f"edge_cut={ec:.4f}")
    return rows


if __name__ == "__main__":
    run()
