"""Quickstart: partition a graph with CUTTANA, compare against FENNEL, and
run distributed PageRank on the partition with the JAX engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.analytics import GraphEngine, localize, pagerank_program, workload_cost
from repro.core import get_partitioner
from repro.graph import quality_report, rmat_graph

K = 8
graph = rmat_graph(20_000, avg_degree=16, seed=0)
print(f"graph: {graph}")

parts = {}
for name in ("fennel", "cuttana"):
    part = get_partitioner(name)(
        graph, K, balance_mode="edge", order="random", seed=0
    )
    parts[name] = part
    rep = quality_report(graph, part, K)
    cost = workload_cost(graph, part, K, iters=30)
    print(
        f"{name:8s} edge_cut={rep['edge_cut']:.4f} cv={rep['comm_volume']:.4f} "
        f"edge_imb={rep['edge_imbalance']:.2f} "
        f"PR30_model_latency={cost['total_s']*1e3:.2f}ms"
    )

# run real PageRank on the CUTTANA partition (simulated K-device layout)
lg = localize(graph, parts["cuttana"], K)
eng = GraphEngine(lg, pagerank_program())
ranks = eng.run_simulated(iters=20)
stats = eng.stats(20)
top = np.argsort(ranks)[-5:][::-1]
print(f"top-5 vertices by rank: {top.tolist()}")
print(
    f"halo messages/iter: {stats.true_halo_messages_per_iter} "
    f"(= K*|V|*lambda_cv), max edges on one device: {stats.max_local_edges}"
)
