"""Quickstart: the full paper pipeline as three chained calls through
``repro.api`` - partition a graph with CUTTANA, compare against FENNEL, then
run distributed PageRank (real JAX engine, simulated K-device layout) and the
graph-DB workload on the winning partition.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import PartitionSpec, partition
from repro.graph import rmat_graph

K = 8
graph = rmat_graph(20_000, avg_degree=16, seed=0)
print(f"graph: {graph}")

results = {}
for name in ("fennel", "cuttana"):
    # call 1: spec -> result (uniform across the whole algorithm zoo)
    result = partition(
        graph, PartitionSpec(algo=name, k=K, balance_mode="edge",
                             order="random", seed=0)
    )
    results[name] = result
    rep = result.quality()  # lazily computed + cached
    cost = result.analytics(program="pagerank", iters=30, mode="model")
    print(
        f"{name:8s} edge_cut={rep['edge_cut']:.4f} cv={rep['comm_volume']:.4f} "
        f"edge_imb={rep['edge_imbalance']:.2f} "
        f"PR30_model_latency={cost['total_s']*1e3:.2f}ms"
    )

# call 2: real PageRank on the CUTTANA partition (simulated K-device layout)
sim = results["cuttana"].analytics(program="pagerank", iters=20, mode="simulated")
top = np.argsort(sim["values"])[-5:][::-1]
print(f"top-5 vertices by rank: {top.tolist()}")
print(
    f"halo messages/iter: {sim['halo_messages_per_iter']} "
    f"(= K*|V|*lambda_cv), max edges on one device: {sim['max_local_edges']}"
)

# call 3: the graph-DB workload study on the same result
db = results["cuttana"].db(hops=2, num_queries=200)
print(
    f"2-hop workload: {db['qps']:.0f} qps, p99 {db['p99_latency_ms']:.2f} ms, "
    f"{db['total_rpcs']} cross-partition RPCs"
)
