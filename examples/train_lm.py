"""End-to-end driver: train the ~100M-param model for a few hundred steps
with checkpointing (CPU: a few minutes; the same driver scales to pods).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()
    train_main([
        "--arch", "repro-100m",
        "--steps", str(args.steps),
        "--global-batch", "16",
        "--seq-len", "256",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--log-every", "20",
    ])
