"""Distributed analytics example: PageRank/CC/SSSP across partitioners with
the network cost model (paper Table IV in miniature).

    PYTHONPATH=src python examples/analytics_pagerank.py
"""
import numpy as np

from repro.analytics import (
    GraphEngine,
    cc_program,
    localize,
    pagerank_program,
    sssp_program,
    workload_cost,
)
from repro.analytics.programs import reference_pagerank
from repro.core import get_edge_partitioner, get_partitioner
from repro.graph import powerlaw_cluster_graph

K = 8
graph = powerlaw_cluster_graph(30_000, avg_degree=12, seed=1)

print(f"{'partitioner':<12} {'PR(30)':>9} {'CC(20)':>9} {'SSSP(20)':>9} straggler")
for name in ("random", "ldg", "fennel", "heistream", "cuttana", "hdrf", "ginger"):
    if name in ("hdrf", "ginger"):
        assignment = get_edge_partitioner(name)(graph, K, seed=0)
    else:
        assignment = get_partitioner(name)(
            graph, K, balance_mode="edge" if name == "cuttana" else "vertex",
            order="random", seed=0,
        )
    cols = []
    for iters in (30, 20, 20):
        cost = workload_cost(graph, assignment, K, iters)
        cols.append(cost["total_s"] * 1e3)
    print(
        f"{name:<12} {cols[0]:>8.2f}ms {cols[1]:>8.2f}ms {cols[2]:>8.2f}ms "
        f"{cost['straggler_ratio']:.2f}"
    )

# correctness: engine vs dense reference
part = get_partitioner("cuttana")(graph, K, balance_mode="edge", seed=0)
lg = localize(graph, part, K)
got = GraphEngine(lg, pagerank_program()).run_simulated(iters=15)
want = reference_pagerank(graph, iters=15)
err = float(np.abs(got - want).max())
print(f"engine vs dense reference max|err| = {err:.2e}")
assert err < 1e-6
