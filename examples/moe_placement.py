"""CUTTANA expert placement: partition the expert co-activation graph to cut
MoE all-to-all fanout (the paper's technique applied inside the LM half).

    PYTHONPATH=src python examples/moe_placement.py
"""
import numpy as np

from repro.core.placement import (
    evaluate_placement,
    place_experts,
    synthetic_routing_trace,
)

E, K_DEV, TOP_K = 160, 16, 6  # deepseek-v2-236b on a 16-way EP axis
trace = synthetic_routing_trace(50_000, E, TOP_K, skew=0.7, seed=0)

baseline = np.arange(E) % K_DEV  # round-robin (the default EP layout)
contig = np.repeat(np.arange(K_DEV), E // K_DEV)
placed = place_experts(trace, E, K_DEV, seed=0)

for name, pl in [("round-robin", baseline), ("contiguous", contig),
                 ("cuttana", placed)]:
    m = evaluate_placement(trace, pl)
    print(
        f"{name:<12} mean A2A fanout/token = {m['mean_fanout']:.3f} "
        f"(max {m['max_fanout']:.0f}), device load imb = "
        f"{m['device_load_imbalance']:.3f}"
    )

m0 = evaluate_placement(trace, baseline)
m1 = evaluate_placement(trace, placed)
gain = 1 - m1["mean_fanout"] / m0["mean_fanout"]
print(f"\nCUTTANA placement cuts mean per-token A2A fanout by {gain:.1%}")
assert m1["mean_fanout"] <= m0["mean_fanout"]
