"""Serve a (reduced) model: real prefill + jitted greedy decode loop.

    PYTHONPATH=src python examples/serve_lm.py [--arch reduced:jamba-v0.1-52b]
"""
import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="reduced:qwen3-8b")
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--batch", "2", "--prompt-len", "16",
                "--gen", "8"])
