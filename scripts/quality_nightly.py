"""Nightly (non-gating) quality run on larger graphs.

The gated CI quality rows run on the small seeded datasets; this script is
the scheduled, non-gating companion that runs the same quality matrix on

1. a larger R-MAT than the gated suite ever sees (``--rmat-n``, default
   120k vertices), and
2. a graph pulled through the real dataset pipeline - a synthetic
   SNAP-style ``.txt.gz`` edge list served over ``file://`` into
   ``scripts/fetch_dataset.py`` (hermetic: no network on the critical
   path), converted to the compressed external CSR, and partitioned
   memory-mapped

and writes a JSON report for CI to upload as an artifact. It is the first
step toward the LiveJournal-scale run in ROADMAP: swap the synthetic
``file://`` source for a registered SNAP dataset URL once runners are
allowed to download one.

    PYTHONPATH=src python scripts/quality_nightly.py --out quality_nightly.json
"""
from __future__ import annotations

import argparse
import gzip
import json
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.api import PartitionSpec, partition  # noqa: E402
from repro.graph.generators import powerlaw_cluster_graph, rmat_graph  # noqa: E402

REPO = Path(__file__).resolve().parent.parent
PARTITIONERS = [
    "cuttana", "cuttana-buffcut", "cluster+cuttana", "fennel", "ldg",
]


def quality_cells(tag: str, graph, k: int, seed: int) -> list[dict]:
    rows = []
    for balance in ("edge", "vertex"):
        for name in PARTITIONERS:
            spec = PartitionSpec(
                algo=name, k=k, epsilon=0.05, balance_mode=balance,
                order="random", seed=seed,
            )
            t0 = time.perf_counter()
            result = partition(graph, spec)
            rep = result.quality()
            row = dict(
                bench=f"quality-nightly/{tag}/{balance}/{name}",
                graph=tag, balance=balance, algo=name,
                seconds=time.perf_counter() - t0, **rep,
            )
            rows.append(row)
            print(
                f"{row['bench']:55s} ec={rep['edge_cut']:.4f} "
                f"cv={rep['comm_volume']:.4f} {row['seconds']:.1f}s",
                flush=True,
            )
    return rows


def fetched_file_graph(workdir: Path, n: int, seed: int):
    """Synthetic SNAP-style edge list through the real fetch -> convert ->
    mmap pipeline (file:// source, so the run is hermetic)."""
    from repro.graph.external import ExternalCSRGraph

    edges_gz = workdir / "nightly-edges.txt.gz"
    g = powerlaw_cluster_graph(n, avg_degree=14, seed=seed)
    with gzip.open(edges_gz, "wt") as fh:
        fh.write("# synthetic SNAP-style edge list (nightly)\n")
        np.savetxt(fh, g.edges_array(), fmt="%d")
    bin_path = workdir / "nightly.bin"
    subprocess.run(
        [
            sys.executable, str(REPO / "scripts" / "fetch_dataset.py"),
            "--url", edges_gz.resolve().as_uri(), "--name", "nightly-web",
            "--cache-dir", str(workdir / "cache"),
            "--convert", str(bin_path),
        ],
        check=True,
        env={**__import__("os").environ, "PYTHONPATH": str(REPO / "src")},
    )
    return ExternalCSRGraph(bin_path)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="quality_nightly.json")
    ap.add_argument("--rmat-n", type=int, default=120_000)
    ap.add_argument("--avg-degree", type=int, default=14)
    ap.add_argument("--file-n", type=int, default=60_000,
                    help="vertex count of the file://-pipeline graph")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workdir", default="/tmp/quality-nightly")
    args = ap.parse_args()

    workdir = Path(args.workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    rows: list[dict] = []

    g = rmat_graph(args.rmat_n, avg_degree=args.avg_degree, seed=args.seed)
    print(f"rmat-l: {g.num_vertices} vertices, {g.num_edges} edges", flush=True)
    rows += quality_cells("rmat-l", g, args.k, args.seed)

    gf = fetched_file_graph(workdir, args.file_n, args.seed)
    print(f"web-file: {gf.num_vertices} vertices, {gf.num_edges} edges",
          flush=True)
    rows += quality_cells("web-file", gf, args.k, args.seed)

    report = {
        "suites": {"quality-nightly": {"rows": rows}},
        "config": {
            "rmat_n": args.rmat_n, "avg_degree": args.avg_degree,
            "file_n": args.file_n, "k": args.k, "seed": args.seed,
        },
    }
    Path(args.out).write_text(json.dumps(report, indent=1, sort_keys=True))
    print(f"wrote {args.out} ({len(rows)} rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
