"""Convert an edge list into the on-disk external CSR format.

    PYTHONPATH=src python scripts/convert_graph.py edges.txt graph.bin \\
        [--num-vertices N] [--chunk-edges 4194304] [--delimiter ,]

Accepts SNAP-style text edge lists (``.txt``/``.csv``/``.tsv``: one ``u v``
pair per line, ``#`` comments and extra columns ignored) and binary ``.npy``
``(m, 2)`` arrays. The conversion is two-pass and bounded-memory (one chunk
plus ``O(|V|)`` degree bookkeeping resident at a time), and the output is
bit-identical to ``CSRGraph.from_edges`` on the same input: self-loops
dropped, duplicates (either direction) deduplicated, symmetric adjacency with
rows sorted by neighbour id.

The output partitions out-of-core:

    PYTHONPATH=src python -m repro.api.cli partition --spec spec.json \\
        --graph graph.bin
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python scripts/convert_graph.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("input", help="edge list: .txt/.csv/.tsv text or .npy (m,2)")
    ap.add_argument("output", help="output .bin external CSR path")
    ap.add_argument("--num-vertices", type=int, default=None, metavar="N",
                    help="vertex-count override (default: max id + 1)")
    ap.add_argument("--chunk-edges", type=int, default=1 << 22,
                    help="edges parsed per chunk (bounds converter memory)")
    ap.add_argument("--merge-block", type=int, default=1 << 20,
                    help="keys per merge/scatter block")
    ap.add_argument("--delimiter", default=None,
                    help="text column delimiter (default: whitespace; "
                         ".csv implies ',')")
    ap.add_argument("--tmp-dir", default=None,
                    help="spill directory for sort runs (default: system tmp)")
    ap.add_argument("--format", type=int, choices=(1, 2), default=2,
                    help="on-disk format: 2 = block-compressed delta-varint "
                         "(default), 1 = raw int32 neighbour arrays")
    ap.add_argument("--block-cap", type=int, default=None,
                    help="values per compression block (v2 only; default 64)")
    ap.add_argument("--workers", type=int, default=0,
                    help="converter threads for sort/compress passes "
                         "(0 = auto: cpu_count)")
    args = ap.parse_args(argv)

    from repro.graph.compress import DEFAULT_BLOCK_CAP
    from repro.graph.external import convert_edge_list

    t0 = time.perf_counter()
    stats = convert_edge_list(
        args.input,
        args.output,
        num_vertices=args.num_vertices,
        chunk_edges=args.chunk_edges,
        merge_block=args.merge_block,
        delimiter=args.delimiter,
        tmp_dir=args.tmp_dir,
        format_version=args.format,
        block_cap=(
            args.block_cap if args.block_cap is not None else DEFAULT_BLOCK_CAP
        ),
        max_workers=args.workers,
    )
    seconds = time.perf_counter() - t0
    ratio = stats.get("compression_ratio")
    compressed = (
        f", {stats['raw_bytes']} raw -> {stats['file_bytes']} on disk "
        f"({ratio:.2f}x)"
        if ratio
        else f", {stats['file_bytes']} bytes"
    )
    print(
        f"wrote {args.output} (v{stats['format_version']}): "
        f"|V|={stats['num_vertices']} |E|={stats['num_edges']} "
        f"({stats['input_edges']} input rows, {stats['runs']} sort runs, "
        f"{stats['workers']} workers{compressed}) in {seconds:.1f}s",
        file=sys.stderr,
    )
    return 0


if __name__ == "__main__":
    sys.path.insert(0, "src")  # allow running without PYTHONPATH from repo root
    raise SystemExit(main())
