"""Fetch a SNAP edge list into a local cache, then optionally convert it to
the compressed external CSR and partition it under an RSS budget.

    PYTHONPATH=src python scripts/fetch_dataset.py ego-facebook \\
        [--cache-dir ~/.cache/repro-graphs] [--convert graph.bin] \\
        [--partition 8 --algo cuttana] [--rss-budget-mb 512]
    PYTHONPATH=src python scripts/fetch_dataset.py --url file:///x/edges.txt.gz \\
        --name custom --sha256 <hex> --convert graph.bin

Downloads stream to a ``.part`` file and are renamed into the cache only
after the checksum is known, so a killed download never poisons the cache.
Integrity is sha256: pass ``--sha256`` (or rely on a registry pin) to verify;
otherwise the digest is recorded on first download in a ``.sha256`` sidecar
and every later cache hit is re-verified against it (trust on first use).
``file://`` URLs go through the same path, which is what the offline tests
use.

With ``--convert`` the (gunzipped) edge list is converted via
:func:`repro.graph.external.convert_edge_list` (v2 block-compressed by
default); with ``--partition K`` the result is memory-mapped and partitioned
out-of-core. ``--rss-budget-mb`` then asserts the whole pipeline stayed under
the given peak RSS (``resource.getrusage``) - the CI proof that conversion +
partitioning of a real SNAP graph is bounded-memory.
"""
from __future__ import annotations

import argparse
import gzip
import hashlib
import json
import os
import shutil
import sys
import time
import urllib.request
from pathlib import Path

# CI-sized SNAP graphs (https://snap.stanford.edu/data/): small enough to
# download and partition in a CI job, big enough to exercise the out-of-core
# path. sha256 pins are trust-on-first-use (recorded in a cache sidecar) so
# the registry works without baking in digests that SNAP may re-publish.
DATASETS = {
    "ego-facebook": {
        "url": "https://snap.stanford.edu/data/facebook_combined.txt.gz",
        "sha256": None,
    },
    "ca-grqc": {
        "url": "https://snap.stanford.edu/data/ca-GrQc.txt.gz",
        "sha256": None,
    },
    "wiki-vote": {
        "url": "https://snap.stanford.edu/data/wiki-Vote.txt.gz",
        "sha256": None,
    },
    "ca-astroph": {
        "url": "https://snap.stanford.edu/data/ca-AstroPh.txt.gz",
        "sha256": None,
    },
}

DEFAULT_CACHE = Path(
    os.environ.get("REPRO_GRAPH_CACHE", "~/.cache/repro-graphs")
).expanduser()


def _sha256_file(path: Path, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                break
            h.update(block)
    return h.hexdigest()


def fetch(
    name: str,
    url: str,
    cache_dir: Path,
    sha256: str | None = None,
    progress=None,
) -> Path:
    """Return the cached raw file for ``url``, downloading if needed.

    Verifies sha256 against ``sha256`` when given, else against the
    ``.sha256`` sidecar written on first download. Raises ``ValueError`` on
    mismatch (the corrupt file is left as ``<name>.corrupt`` for inspection).
    """
    cache_dir.mkdir(parents=True, exist_ok=True)
    suffix = "".join(Path(urllib.parse.urlparse(url).path).suffixes) or ".txt"
    target = cache_dir / f"{name}{suffix}"
    sidecar = cache_dir / f"{name}{suffix}.sha256"

    if target.exists():
        digest = _sha256_file(target)
        expect = sha256 or (
            sidecar.read_text().strip() if sidecar.exists() else None
        )
        if expect is not None and digest != expect:
            corrupt = target.with_suffix(target.suffix + ".corrupt")
            target.rename(corrupt)
            raise ValueError(
                f"cached {target.name} sha256 {digest[:16]}... != expected "
                f"{expect[:16]}... (moved to {corrupt.name}; re-run to re-fetch)"
            )
        if not sidecar.exists():
            sidecar.write_text(digest + "\n")
        return target

    part = target.with_suffix(target.suffix + ".part")
    h = hashlib.sha256()
    with urllib.request.urlopen(url) as resp, open(part, "wb") as out:
        total = 0
        while True:
            block = resp.read(1 << 20)
            if not block:
                break
            h.update(block)
            out.write(block)
            total += len(block)
            if progress is not None:
                progress(total)
    digest = h.hexdigest()
    if sha256 is not None and digest != sha256:
        part.unlink()
        raise ValueError(
            f"downloaded {url} sha256 {digest[:16]}... != expected "
            f"{sha256[:16]}..."
        )
    part.rename(target)
    sidecar.write_text(digest + "\n")
    return target


def ensure_text(raw: Path) -> Path:
    """Gunzip ``raw`` next to itself if needed; return the text edge list."""
    if raw.suffix != ".gz":
        return raw
    txt = raw.with_suffix("")
    if txt.exists() and txt.stat().st_mtime >= raw.stat().st_mtime:
        return txt
    tmp = txt.with_suffix(txt.suffix + ".part")
    with gzip.open(raw, "rb") as src, open(tmp, "wb") as dst:
        shutil.copyfileobj(src, dst, 1 << 20)
    tmp.rename(txt)
    return txt


def peak_rss_mb() -> float:
    import resource

    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    # linux reports KiB, macOS bytes
    return ru / 1024.0 if sys.platform != "darwin" else ru / (1024.0 * 1024.0)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python scripts/fetch_dataset.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("dataset", nargs="?", default=None,
                    help=f"registry name: {', '.join(sorted(DATASETS))}")
    ap.add_argument("--url", default=None,
                    help="explicit source URL (http(s):// or file://) "
                         "instead of a registry name")
    ap.add_argument("--name", default=None,
                    help="cache key for --url sources")
    ap.add_argument("--sha256", default=None,
                    help="expected sha256 of the raw download")
    ap.add_argument("--cache-dir", default=str(DEFAULT_CACHE),
                    help="download cache directory")
    ap.add_argument("--convert", default=None, metavar="OUT_BIN",
                    help="convert the edge list to this external CSR path")
    ap.add_argument("--format", type=int, choices=(1, 2), default=2,
                    help="CSR format for --convert (default 2, compressed)")
    ap.add_argument("--workers", type=int, default=0,
                    help="converter threads (0 = auto)")
    ap.add_argument("--partition", type=int, default=None, metavar="K",
                    help="partition the converted graph out-of-core into K")
    ap.add_argument("--algo", default="cuttana",
                    help="partitioner for --partition (default cuttana)")
    ap.add_argument("--rss-budget-mb", type=float, default=None,
                    help="fail (exit 1) if peak RSS exceeded this budget")
    ap.add_argument("--json", default=None,
                    help="write a JSON summary here")
    args = ap.parse_args(argv)

    if (args.dataset is None) == (args.url is None):
        ap.error("pass exactly one of a registry dataset name or --url")
    if args.url is not None:
        name = args.name or Path(urllib.parse.urlparse(args.url).path).stem
        url, sha = args.url, args.sha256
    else:
        entry = DATASETS.get(args.dataset)
        if entry is None:
            ap.error(
                f"unknown dataset {args.dataset!r}; "
                f"registry: {', '.join(sorted(DATASETS))}"
            )
        name, url = args.dataset, entry["url"]
        sha = args.sha256 or entry["sha256"]

    summary: dict = {"dataset": name, "url": url}
    t0 = time.perf_counter()
    raw = fetch(name, url, Path(args.cache_dir), sha)
    txt = ensure_text(raw)
    summary["raw_path"] = str(raw)
    summary["fetch_seconds"] = round(time.perf_counter() - t0, 3)
    print(f"fetched {name}: {raw} ({raw.stat().st_size} bytes)", file=sys.stderr)

    if args.convert:
        from repro.graph.external import convert_edge_list

        t1 = time.perf_counter()
        stats = convert_edge_list(
            txt, args.convert, format_version=args.format,
            max_workers=args.workers,
        )
        summary["convert"] = stats
        summary["convert_seconds"] = round(time.perf_counter() - t1, 3)
        print(
            f"converted -> {args.convert} (v{stats['format_version']}): "
            f"|V|={stats['num_vertices']} |E|={stats['num_edges']} "
            f"{stats['file_bytes']} bytes "
            f"({stats['compression_ratio']:.2f}x vs raw)",
            file=sys.stderr,
        )

    if args.partition is not None:
        if not args.convert:
            ap.error("--partition requires --convert")
        from repro.api import PartitionSpec, partition
        from repro.graph.external import ExternalCSRGraph

        graph = ExternalCSRGraph(args.convert)
        t2 = time.perf_counter()
        result = partition(graph, PartitionSpec(algo=args.algo, k=args.partition))
        summary["partition"] = {
            "algo": args.algo,
            "k": args.partition,
            "edge_cut": round(float(result.quality()["edge_cut"]), 6),
            "seconds": round(time.perf_counter() - t2, 3),
        }
        print(
            f"partitioned ({args.algo}, k={args.partition}): "
            f"edge_cut={summary['partition']['edge_cut']:.4f} "
            f"in {summary['partition']['seconds']}s",
            file=sys.stderr,
        )

    rss = peak_rss_mb()
    summary["peak_rss_mb"] = round(rss, 1)
    print(f"peak RSS {rss:.1f} MB", file=sys.stderr)
    ok = True
    if args.rss_budget_mb is not None:
        ok = rss <= args.rss_budget_mb
        summary["rss_budget_mb"] = args.rss_budget_mb
        summary["rss_within_budget"] = ok
        print(
            f"RSS budget {args.rss_budget_mb:.1f} MB: "
            f"{'OK' if ok else 'EXCEEDED'}",
            file=sys.stderr,
        )
    if args.json:
        Path(args.json).write_text(json.dumps(summary, indent=2) + "\n")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.path.insert(0, "src")  # allow running without PYTHONPATH from repo root
    raise SystemExit(main())
