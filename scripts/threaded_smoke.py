"""CI smoke for the threaded superstep engine.

Asserts the headline perf claim on the runner itself: at S=4, the parallel
algorithms' streaming wall-clock must be at most ``--ratio`` (default 0.9)
of their sequential counterparts', for BOTH ``cuttana-parallel`` and
``fennel-parallel``. Writes the per-superstep profile of every parallel run
to ``--out`` so CI uploads a machine-readable timing artifact.

Needs >= 2 cores for the thread pool to mean anything; on a single-core
runner it exits 0 with an explicit skip reason (the wave-vectorised engine
is still exercised by the scaling-suite gate there).

    PYTHONPATH=src python scripts/threaded_smoke.py --out threaded_profile.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--avg-degree", type=int, default=12)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--num-shards", type=int, default=4)
    ap.add_argument("--ratio", type=float, default=0.9,
                    help="required parallel/sequential wall-clock bound")
    ap.add_argument("--out", default="threaded_profile.json")
    args = ap.parse_args()

    cores = os.cpu_count() or 1
    if cores < 2:
        print(
            f"SKIP: threaded smoke needs >= 2 cores, runner has {cores}; "
            "thread-pool speedup is not measurable here"
        )
        with open(args.out, "w") as fh:
            json.dump({"skipped": f"{cores} core(s)"}, fh, indent=2)
        return 0

    from repro.api import PartitionSpec, partition
    from repro.graph.generators import rmat_graph

    def stream_seconds(result) -> float:
        # the paper's claim is about streaming latency; phase-2 refinement
        # is identical work on both sides and only dilutes the ratio
        t = result.timings
        return t.get("phase1_seconds", t.get("stream_seconds", t["total_s"]))

    graph = rmat_graph(args.n, avg_degree=args.avg_degree, seed=0)
    report: dict = {"cores": cores, "n": args.n, "num_shards": args.num_shards}
    failures = []
    for algo, base in (("cuttana-parallel", "cuttana"),
                       ("fennel-parallel", "fennel")):
        seq_s = stream_seconds(partition(graph, PartitionSpec(
            algo=base, k=args.k, balance_mode="edge", order="random",
        )))
        res = partition(graph, PartitionSpec(
            algo=algo, k=args.k, balance_mode="edge", order="random",
            params={"num_shards": args.num_shards},
        ))
        par_s = stream_seconds(res)
        ratio = par_s / max(seq_s, 1e-12)
        report[algo] = {
            "sequential_s": seq_s,
            "parallel_s": par_s,
            "ratio": ratio,
            "boundary_conflicts": res.telemetry.get("boundary_conflicts"),
            "max_workers": res.telemetry.get("max_workers"),
            "profile": res.profile,
        }
        status = "OK" if ratio <= args.ratio else "FAIL"
        print(
            f"{status}: {algo} S={args.num_shards} {par_s:.3f}s vs "
            f"{base} {seq_s:.3f}s (ratio {ratio:.2f}, bound {args.ratio})"
        )
        if ratio > args.ratio:
            failures.append(algo)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")
    if failures:
        print(f"FAILED: {failures} exceeded the {args.ratio} wall-clock bound")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
