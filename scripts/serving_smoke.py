"""CI smoke for the partition-aware serving layer.

Runs a short closed-loop load-gen burst through :mod:`repro.serve.graph` on
an R-MAT graph and asserts the figure-level ordering on deterministic sim
metrics (message-flow-derived, bit-reproducible across hosts):

* cuttana's measured throughput (``qps_sim``) must exceed random's;
* cuttana's p99 sim latency must be <= random's;
* ``replication_budget > 0`` must reduce cross-partition RPCs with
  byte-identical answers;
* rerunning the same load must reproduce the exact same sim metrics
  (determinism is what lets CI gate these numbers at all).

Writes the full ``ServingReport`` dicts to ``--out`` so CI uploads a
machine-readable artifact. Needs >= 2 cores for the threaded router to be a
real concurrency test; on a single-core runner it exits 0 with an explicit
skip reason (the synchronous router path is still covered by tier-1 tests).

    PYTHONPATH=src python scripts/serving_smoke.py --out serving_report.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=6_000)
    ap.add_argument("--avg-degree", type=int, default=12)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--queries", type=int, default=800)
    ap.add_argument("--concurrency", type=int, default=256)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--replication-budget", type=float, default=0.05)
    ap.add_argument("--out", default="serving_report.json")
    args = ap.parse_args()

    cores = os.cpu_count() or 1
    if cores < 2:
        print(
            f"SKIP: serving smoke needs >= 2 cores, runner has {cores}; "
            "concurrent router throughput is not a real test here"
        )
        with open(args.out, "w") as fh:
            json.dump({"skipped": f"{cores} core(s)"}, fh, indent=2)
        return 0

    from benchmarks.serving import _spec
    from repro.api import partition
    from repro.graph.generators import rmat_graph
    from repro.serve.graph import QueryMix, build_workload, run_load

    graph = rmat_graph(args.n, avg_degree=args.avg_degree, seed=args.seed)
    workload = build_workload(
        graph, args.queries, QueryMix(), seed=args.seed + 1
    )
    report: dict = {
        "cores": cores, "n": args.n, "k": args.k,
        "queries": args.queries, "concurrency": args.concurrency,
    }
    failures: list[str] = []

    reps = {}
    for algo in ("cuttana", "random"):
        result = partition(graph, _spec(algo, args.k, args.seed))
        reps[algo] = run_load(
            result.serve(store_results=False),
            workload=workload, concurrency=args.concurrency,
        )
        report[algo] = reps[algo].to_dict()
    c, r = reps["cuttana"], reps["random"]
    qps_ok = c.qps_sim > r.qps_sim
    p99_c = c.latency_ms["sim"]["p99"]
    p99_r = r.latency_ms["sim"]["p99"]
    p99_ok = p99_c <= p99_r
    print(
        f"{'OK' if qps_ok else 'FAIL'}: qps_sim cuttana {c.qps_sim:.0f} vs "
        f"random {r.qps_sim:.0f} (ratio {c.qps_sim / r.qps_sim:.2f})"
    )
    print(
        f"{'OK' if p99_ok else 'FAIL'}: p99_sim cuttana {p99_c:.4f}ms vs "
        f"random {p99_r:.4f}ms"
    )
    if not qps_ok:
        failures.append("cuttana qps_sim <= random qps_sim")
    if not p99_ok:
        failures.append("cuttana p99_sim > random p99_sim")

    # replication must cut RPCs without changing a single answer
    result = partition(graph, _spec("cuttana", args.k, args.seed))
    sub = workload[: min(args.queries, 300)]
    base = run_load(result.serve(replication_budget=0.0),
                    workload=sub, concurrency=args.concurrency)
    repl = run_load(result.serve(replication_budget=args.replication_budget),
                    workload=sub, concurrency=args.concurrency)
    parity = all(
        np.array_equal(va, vb) if isinstance(va, np.ndarray) else va == vb
        for (va, vb) in (
            (base.answers()[qid], repl.answers()[qid])
            for qid in base.answers()
        )
    )
    repl_ok = repl.rpcs < base.rpcs and parity
    print(
        f"{'OK' if repl_ok else 'FAIL'}: replication rpcs {base.rpcs} -> "
        f"{repl.rpcs} (parity={parity})"
    )
    if not repl_ok:
        failures.append("replication did not cut RPCs at fixed answers")
    report["replication"] = {
        "budget": args.replication_budget,
        "rpcs_base": base.rpcs, "rpcs_replicated": repl.rpcs,
        "answers_identical": bool(parity), **repl.replication,
    }

    # determinism: a rerun must reproduce the sim metrics bit-for-bit
    rerun = run_load(result.serve(replication_budget=0.0),
                     workload=sub, concurrency=args.concurrency)
    det_ok = (
        rerun.qps_sim == base.qps_sim
        and rerun.rpcs == base.rpcs
        and rerun.wire_bytes == base.wire_bytes
        and rerun.latency_ms["sim"] == base.latency_ms["sim"]
    )
    print(f"{'OK' if det_ok else 'FAIL'}: sim metrics reproduce exactly")
    if not det_ok:
        failures.append("sim metrics not deterministic across reruns")

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2, default=str)
    print(f"wrote {args.out}")
    if failures:
        print(f"FAILED: {failures}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
