"""CI smoke for incremental repartitioning under churn.

Replays a seeded ~200k-edge synthetic churn stream (random arrival ordering,
the adversarial case) through the incremental partitioner and asserts the
PR's acceptance bar against a full re-partition of the same stream:

* quality: incremental final edge-cut <= ``--cut-ratio`` (default 1.15) x
  the full re-partition edge-cut;
* cost: incremental stream work (vertex placements: arrivals + re-stream
  windows + isolated finalization) <= ``--work-ratio`` (default 0.5) x the
  full strategy's cumulative work (every seen vertex re-streamed at every
  batch).

Both sides are deterministic seeded NumPy, so the bound is stable across
runners. Needs >= 2 cores so the smoke can't crowd out the tier-1 job on a
single-core runner; there it exits 0 with an explicit skip reason
(``--force`` overrides, for local runs). Writes ``churn_report.json`` for CI
to upload either way.

    PYTHONPATH=src python scripts/churn_smoke.py --out churn_report.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, _ROOT)  # benchmarks package (shared work accounting)
sys.path.insert(0, os.path.join(_ROOT, "src"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=25_000)
    ap.add_argument("--avg-degree", type=int, default=16)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--num-batches", type=int, default=20)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--cut-ratio", type=float, default=1.15,
                    help="required incremental/full edge-cut bound")
    ap.add_argument("--work-ratio", type=float, default=0.5,
                    help="required incremental/full stream-work bound")
    ap.add_argument("--force", action="store_true",
                    help="run even on a single-core machine")
    ap.add_argument("--out", default="churn_report.json")
    args = ap.parse_args()

    cores = os.cpu_count() or 1
    if cores < 2 and not args.force:
        print(
            f"SKIP: churn smoke needs >= 2 cores, runner has {cores}; "
            "the churn suite still gates quality via the bench trajectory"
        )
        with open(args.out, "w") as fh:
            json.dump({"skipped": f"{cores} core(s)"}, fh, indent=2)
        return 0

    from benchmarks.churn import full_repartition_work
    from repro.core import fennel
    from repro.core.incremental import update
    from repro.graph.churn import rmat_churn
    from repro.graph.metrics import edge_cut

    stream = rmat_churn(
        args.n, avg_degree=args.avg_degree, seed=args.seed, ordering="random"
    )
    graph = stream.final_graph()
    print(
        f"stream: |V|={stream.num_vertices} m={stream.num_edges} "
        f"k={args.k} batches={args.num_batches}"
    )

    # incremental replay through the public update() API (cold start)
    result = update(
        None, stream, k=args.k, balance_mode="edge", seed=args.seed,
        num_batches=args.num_batches,
    )
    cut_inc = edge_cut(graph, result.assignment)
    work_inc = result.telemetry["stream_work"]

    # full re-partition on the final snapshot (quality target) + its
    # cumulative per-batch work (cost target)
    part_full = fennel.partition(
        graph, args.k, balance_mode="edge", seed=args.seed
    )
    cut_full = edge_cut(graph, part_full)
    work_full = full_repartition_work(stream, args.num_batches)

    cut_ratio = cut_inc / max(cut_full, 1e-12)
    work_ratio = work_inc / max(work_full, 1)
    report = {
        "cores": cores,
        "n": stream.num_vertices,
        "m": stream.num_edges,
        "k": args.k,
        "num_batches": args.num_batches,
        "edge_cut_incremental": float(cut_inc),
        "edge_cut_full": float(cut_full),
        "cut_ratio": float(cut_ratio),
        "cut_ratio_bound": args.cut_ratio,
        "stream_work_incremental": int(work_inc),
        "stream_work_full": int(work_full),
        "work_ratio": float(work_ratio),
        "work_ratio_bound": args.work_ratio,
        "restream_windows": result.telemetry["restream_windows"],
        "moved_vertices": result.telemetry["moved_vertices"],
        "drift_before": result.telemetry["drift_before"],
        "drift_after": result.telemetry["drift_after"],
        "update_seconds": result.timings["stream_seconds"],
    }
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")

    failures = []
    status = "OK" if cut_ratio <= args.cut_ratio else "FAIL"
    print(
        f"{status}: edge-cut {cut_inc:.4f} vs full {cut_full:.4f} "
        f"(ratio {cut_ratio:.3f}, bound {args.cut_ratio})"
    )
    if cut_ratio > args.cut_ratio:
        failures.append("cut_ratio")
    status = "OK" if work_ratio <= args.work_ratio else "FAIL"
    print(
        f"{status}: stream work {work_inc} vs full {work_full} "
        f"(ratio {work_ratio:.3f}, bound {args.work_ratio}, "
        f"{report['restream_windows']} re-stream windows, "
        f"{report['moved_vertices']} moved)"
    )
    if work_ratio > args.work_ratio:
        failures.append("work_ratio")
    if failures:
        print(f"FAILED: {failures} exceeded their bounds")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
