"""CI smoke for the compressed out-of-core CSR v2 + prefetch pipeline.

Generates a ~1M-edge R-MAT, dumps its edge list, converts it twice (v1 raw
and v2 block-compressed, parallel workers), and asserts on the runner
itself:

1. **compression** - the v2 file must be < ``--max-file-ratio`` (default
   0.7) of the v1 file;
2. **parity** - the v2 mapped partition (``cuttana-parallel``, S=4) is
   bit-identical to the fully resident run;
3. **overlap** - with >= 2 cores, the prefetch-on mapped stream must take at
   most ``--prefetch-ratio`` (default 0.9) of the prefetch-off (synchronous)
   mapped stream. On a single-core runner this check skips itself with an
   explicit reason (parity and compression still run - they do not need
   parallelism).

Writes a machine-readable report (convert stats, both stream walls, the
prefetch telemetry and per-superstep profile) to ``--out`` so CI uploads a
timing artifact.

    PYTHONPATH=src python scripts/outofcore_smoke.py --out outofcore_smoke.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65_000)
    ap.add_argument("--avg-degree", type=int, default=16)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--num-shards", type=int, default=4)
    ap.add_argument("--max-file-ratio", type=float, default=0.7,
                    help="required v2/v1 on-disk size bound")
    ap.add_argument("--prefetch-ratio", type=float, default=0.9,
                    help="required prefetch-on/prefetch-off stream bound "
                         "(needs >= 2 cores)")
    ap.add_argument("--out", default="outofcore_smoke.json")
    args = ap.parse_args()

    import numpy as np

    from repro.api import PartitionSpec, partition
    from repro.graph.external import ExternalCSRGraph, convert_edge_list
    from repro.graph.generators import rmat_graph

    cores = os.cpu_count() or 1
    graph = rmat_graph(args.n, avg_degree=args.avg_degree, seed=3)
    report: dict = {
        "cores": cores, "n": args.n, "num_edges": int(graph.num_edges),
        "num_shards": args.num_shards,
    }
    failures: list[str] = []
    with tempfile.TemporaryDirectory() as td:
        edges = os.path.join(td, "edges.npy")
        np.save(edges, graph.edges_array())
        print(f"graph: |V|={graph.num_vertices} |E|={graph.num_edges} "
              f"({2 * graph.num_edges} half-edges)")

        # ---- conversion: v1 raw vs v2 compressed (parallel workers)
        paths = {}
        for ver in (1, 2):
            out = os.path.join(td, f"graph.v{ver}.bin")
            t0 = time.perf_counter()
            stats = convert_edge_list(
                edges, out, num_vertices=args.n, format_version=ver,
            )
            stats["convert_seconds"] = round(time.perf_counter() - t0, 3)
            report[f"v{ver}"] = stats
            paths[ver] = out
            print(f"v{ver}: {stats['file_bytes']} bytes in "
                  f"{stats['convert_seconds']}s ({stats['workers']} workers)")
        file_ratio = report["v2"]["file_bytes"] / report["v1"]["file_bytes"]
        report["file_ratio"] = round(file_ratio, 4)
        status = "OK" if file_ratio < args.max_file_ratio else "FAIL"
        print(f"{status}: v2/v1 file ratio {file_ratio:.3f} "
              f"(bound {args.max_file_ratio})")
        if file_ratio >= args.max_file_ratio:
            failures.append("compression")

        # ---- parity + prefetch overlap on the sharded engine
        ext = ExternalCSRGraph(paths[2])

        def run(g, prefetch):
            spec = PartitionSpec(
                algo="cuttana-parallel", k=args.k, balance_mode="edge",
                order="random", seed=3,
                params={"num_shards": args.num_shards, "prefetch": prefetch},
            )
            return partition(g, spec)

        resident = run(graph, "auto")
        mapped_on = run(ext, "on")
        mapped_off = run(ext, "off")
        for name, res in (("mapped-on", mapped_on), ("mapped-off", mapped_off)):
            if not np.array_equal(resident.assignment, res.assignment):
                print(f"FAIL: {name} assignments differ from resident")
                failures.append(f"parity:{name}")
        if not any(f.startswith("parity") for f in failures):
            print("OK: mapped assignments bit-identical to resident "
                  "(prefetch on and off)")

        def stream_seconds(res) -> float:
            t = res.timings
            return t.get("phase1_seconds", t.get("stream_seconds", t["total_s"]))

        on_s, off_s = stream_seconds(mapped_on), stream_seconds(mapped_off)
        report["stream"] = {
            "prefetch_on_s": on_s,
            "prefetch_off_s": off_s,
            "ratio": round(on_s / max(off_s, 1e-12), 4),
            "prefetch_hit_rate": mapped_on.telemetry.get("prefetch_hit_rate"),
            "decode_wall_s": mapped_on.telemetry.get("decode_wall_s"),
            "profile": mapped_on.telemetry.get("profile"),
        }
        if cores < 2:
            report["stream"]["skipped"] = (
                f"prefetch-overlap bound needs >= 2 cores, runner has {cores}"
            )
            print(f"SKIP: {report['stream']['skipped']} "
                  f"(measured ratio {report['stream']['ratio']:.2f})")
        else:
            ratio = on_s / max(off_s, 1e-12)
            status = "OK" if ratio <= args.prefetch_ratio else "FAIL"
            print(f"{status}: prefetch-on {on_s:.3f}s vs off {off_s:.3f}s "
                  f"(ratio {ratio:.2f}, bound {args.prefetch_ratio})")
            if ratio > args.prefetch_ratio:
                failures.append("prefetch-overlap")

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")
    if failures:
        print(f"FAILED: {failures}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
