import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ must precede all other imports

"""Hillclimb profiler: lower+compile one cell and print the dominant
collectives (bytes x loop multiplier) and dot groups.

    PYTHONPATH=src python scripts/inspect_cell.py --arch deepseek-coder-33b \
        --shape train_4k [--multi-pod] [--top 15]
"""
import argparse
import re
from collections import defaultdict

from repro.launch.dryrun import lower_cell
from repro.launch.hlo_analysis import (
    COLLECTIVE_OPS,
    HloModule,
    _DEF_RE,
    _result_type,
    _type_bytes,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    lowered, mesh, meta = lower_cell(args.arch, args.shape, args.multi_pod)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    mod = HloModule(hlo)

    items = []
    for comp, lines in mod.comps.items():
        mult = mod.mult.get(comp, 1.0)
        for line in lines:
            for op in COLLECTIVE_OPS:
                if re.search(rf"\b{op}(?:-start)?\(", line):
                    dm = _DEF_RE.match(line)
                    if not dm:
                        continue
                    t = _result_type(dm.group(2))
                    b = _type_bytes(t)
                    meta_m = re.search(r'op_name="([^"]+)"', line)
                    items.append(
                        (b * mult, b, mult, op, t[:60],
                         (meta_m.group(1)[-90:] if meta_m else comp[:40]))
                    )
                    break
    items.sort(reverse=True)
    total = sum(i[0] for i in items)
    print(f"total collective bytes/shard/step: {total/1e9:.2f} GB "
          f"({len(items)} collective ops)")
    print(f"{'GB(total)':>10} {'MB(one)':>9} {'xN':>6}  op                shape/source")
    for tot, b, m, op, t, src in items[: args.top]:
        print(f"{tot/1e9:>10.2f} {b/1e6:>9.1f} {m:>6.0f}  {op:<17} {t}  <- {src}")


if __name__ == "__main__":
    main()
