"""Characterize run-to-run variance of the db (and optionally substrate)
benchmark suites.

The bench-trajectory gate currently covers the deterministic quality/
footprint metrics and a loosened latency bound, but the db/substrate wall
clocks are ungated because their CI variance has never been measured. This
probe runs a suite N times in one process and reports the per-metric spread
(min/max/mean and relative range, keyed by the trajectory row key), so the
next PR can pick a real gating tolerance instead of a guess.

Dispatched manually from CI (``workflow_dispatch`` -> variance-probe job);
the JSON artifact is the deliverable.

    PYTHONPATH=src python scripts/variance_probe.py --runs 3 \
        --suites db --out variance_report.json
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, _ROOT)  # benchmarks package
sys.path.insert(0, os.path.join(_ROOT, "src"))


def _numeric_metrics(row: dict) -> dict:
    return {
        key: float(val)
        for key, val in row.items()
        if isinstance(val, (int, float)) and not isinstance(val, bool)
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=3)
    ap.add_argument("--suites", default="db",
                    help="comma-separated benchmark suite modules to probe")
    ap.add_argument("--out", default="variance_report.json")
    args = ap.parse_args()

    from benchmarks.trajectory import row_key
    from repro.api.result import jsonify

    suites = args.suites.split(",")
    # key -> metric -> [value per run]
    samples: dict[str, dict[str, list[float]]] = {}
    for i in range(args.runs):
        for suite in suites:
            print(f"# === run {i + 1}/{args.runs} {suite} ===", flush=True)
            mod = importlib.import_module(f"benchmarks.{suite}")
            rows = mod.run()
            for row in jsonify(rows):
                if not isinstance(row, dict):
                    continue
                key = row_key(suite, row)
                bucket = samples.setdefault(key, {})
                for metric, val in _numeric_metrics(row).items():
                    bucket.setdefault(metric, []).append(val)

    spread: dict[str, dict] = {}
    worst = 0.0
    for key in sorted(samples):
        spread[key] = {}
        for metric, vals in sorted(samples[key].items()):
            lo, hi = min(vals), max(vals)
            mean = sum(vals) / len(vals)
            rel = (hi - lo) / abs(mean) if mean else 0.0
            spread[key][metric] = {
                "min": lo, "max": hi, "mean": mean,
                "rel_range": round(rel, 4), "values": vals,
            }
            worst = max(worst, rel)
    report = {
        "runs": args.runs,
        "suites": suites,
        "cores": os.cpu_count() or 1,
        "worst_rel_range": round(worst, 4),
        "spread": spread,
    }
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
    print(f"wrote {args.out} (worst relative range {worst:.1%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
