"""deepseek-coder-33b [arXiv:2401.14196; hf]: llama-arch dense 62L
d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256."""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        d_model=7168,
        vocab_size=32256,
        block=(LayerSpec("attn", "dense"),),
        n_blocks=62,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        activation="swiglu",
    )
