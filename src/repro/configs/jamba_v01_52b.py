"""jamba-v0.1-52b [arXiv:2403.19887; hf]: hybrid Mamba+attention 1:7
interleave, 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
16-expert top-2 MoE every other layer."""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = (
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("attn", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        d_model=4096,
        vocab_size=65536,
        block=_PERIOD,
        n_blocks=4,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        d_ff_expert=14336,
        n_experts=16,
        top_k=2,
        ssm_state=16,
        d_conv=4,
        mamba_expand=2,
        activation="swiglu",
    )
