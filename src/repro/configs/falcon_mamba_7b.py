"""falcon-mamba-7b [arXiv:2410.05355; unverified]: attention-free mamba1
arch, 64L d_model=4096 ssm_state=16 vocab=65024."""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        d_model=4096,
        vocab_size=65024,
        block=(LayerSpec("mamba", "none"),),
        n_blocks=64,
        ssm_state=16,
        d_conv=4,
        mamba_expand=2,
    )
