"""minitron-8b [arXiv:2407.14679; hf]: pruned nemotron, dense 32L
d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000, squared-ReLU FFN."""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        d_model=4096,
        vocab_size=256000,
        block=(LayerSpec("attn", "dense"),),
        n_blocks=32,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        activation="sq_relu",
    )
