"""deepseek-v2-236b [arXiv:2405.04434; hf]: 60L d_model=5120 128H MLA
(kv_lora=512, rope 64) d_ff_expert=1536 vocab=102400, MoE 2 shared + 160
routed top-6; first layer dense (d_ff 12288)."""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        d_model=5120,
        vocab_size=102400,
        prefix=(LayerSpec("attn", "dense"),),
        block=(LayerSpec("attn", "moe"),),
        n_blocks=59,
        n_heads=128,
        n_kv_heads=128,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_rope_dim=64,
        qk_nope_dim=128,
        v_head_dim=128,
        d_ff=12288,
        d_ff_expert=1536,
        n_experts=160,
        top_k=6,
        n_shared_experts=2,
        activation="swiglu",
        opt_state_dtype="bfloat16",
    )
