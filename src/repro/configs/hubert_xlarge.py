"""hubert-xlarge [arXiv:2106.07447; unverified]: encoder-only 48L
d_model=1280 16H d_ff=5120 vocab=504 (masked-unit prediction targets).
The conv waveform frontend is a STUB: input_specs() provides precomputed
frame embeddings at d_model width (per the assignment)."""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        d_model=1280,
        vocab_size=504,
        block=(LayerSpec("attn", "dense"),),
        n_blocks=48,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        causal=False,  # encoder-only: no decode shapes
        activation="gelu",
        frontend="frames",
    )
