"""qwen3-8b [hf:Qwen/Qwen3-8B; hf]: dense 36L d_model=4096 32H (GQA kv=8,
head_dim 128) d_ff=12288 vocab=151936, qk-norm."""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        family="dense",
        d_model=4096,
        vocab_size=151936,
        block=(LayerSpec("attn", "dense"),),
        n_blocks=36,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=12288,
        qk_norm=True,
        activation="swiglu",
        rope_theta=1e6,
    )
