"""gemma3-12b [hf:google/gemma-3-1b-pt family; unverified]: 48L d_model=3840
16H (GQA kv=8, head_dim 256) d_ff=15360 vocab=262144; 5:1 local:global
(sliding window 1024), qk-norm, scaled embeddings."""
from repro.models.config import LayerSpec, ModelConfig

_LOCAL = LayerSpec("attn", "dense", window=1024)
_GLOBAL = LayerSpec("attn", "dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        family="dense",
        d_model=3840,
        vocab_size=262144,
        block=(_LOCAL,) * 5 + (_GLOBAL,),
        n_blocks=8,
        n_heads=16,
        n_kv_heads=8,
        d_head=256,
        d_ff=15360,
        qk_norm=True,
        embed_scale=True,
        tie_embeddings=True,
        activation="gelu",
        rope_theta=1e6,
    )
