"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-11B-Vision family;
unverified]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 with
gated cross-attention image layers every 5th layer. The vision tower is a
STUB: input_specs() provides precomputed patch embeddings [B, 1024, d_model]."""
from repro.models.config import LayerSpec, ModelConfig

_SELF = LayerSpec("attn", "dense")
_CROSS = LayerSpec("cross_attn", "dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        d_model=8192,
        vocab_size=128256,
        block=(_SELF,) * 4 + (_CROSS,),
        n_blocks=20,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        activation="swiglu",
        n_img_tokens=1024,
        cross_attn_gated=True,
        rope_theta=5e5,
        opt_state_dtype="bfloat16",
    )
