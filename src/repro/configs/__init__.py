"""Assigned-architecture registry: ``get_config(arch_id)`` and the reduced
smoke-test variants. One module per architecture, exact public-literature
configs (see each file's provenance comment)."""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = [
    "deepseek_v2_236b",
    "arctic_480b",
    "deepseek_coder_33b",
    "minitron_8b",
    "gemma3_12b",
    "qwen3_8b",
    "hubert_xlarge",
    "llama32_vision_90b",
    "falcon_mamba_7b",
    "jamba_v01_52b",
]

# canonical ids as given in the assignment
ALIASES = {
    "deepseek-v2-236b": "deepseek_v2_236b",
    "arctic-480b": "arctic_480b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "minitron-8b": "minitron_8b",
    "gemma3-12b": "gemma3_12b",
    "qwen3-8b": "qwen3_8b",
    "hubert-xlarge": "hubert_xlarge",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "jamba-v0.1-52b": "jamba_v01_52b",
}


def get_config(arch: str):
    mod_name = ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.config()


def get_reduced_config(arch: str):
    """Tiny same-family config for CPU smoke tests."""
    mod_name = ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    if hasattr(mod, "reduced_config"):
        return mod.reduced_config()
    return shrink(mod.config())


def shrink(cfg):
    """Generic reduction: small width/depth/vocab/experts, same structure."""
    from repro.models.config import LayerSpec

    def small_spec(s: LayerSpec) -> LayerSpec:
        return dataclasses.replace(s, window=min(s.window, 16) if s.window else None)

    changes = dict(
        d_model=128,
        d_ff=256 if cfg.d_ff else 0,
        d_ff_expert=128 if cfg.d_ff_expert else 0,
        vocab_size=512,
        n_blocks=2,
        prefix=tuple(small_spec(s) for s in cfg.prefix),
        block=tuple(small_spec(s) for s in cfg.block),
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_head=32 if cfg.d_head else None,
        n_experts=8 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        n_img_tokens=16 if cfg.n_img_tokens else 0,
        remat=False,
    )
    if cfg.use_mla:
        changes.update(
            kv_lora_rank=32, q_lora_rank=48 if cfg.q_lora_rank else None,
            qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32,
        )
    return dataclasses.replace(cfg, **changes)


SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def cells_for(arch: str, cfg=None) -> dict[str, str]:
    """shape -> "run" | skip-reason, per the assignment's skip rules."""
    cfg = cfg or get_config(arch)
    out = {}
    for shape, spec in SHAPES.items():
        if spec["kind"] == "decode" and cfg.is_encoder_only:
            out[shape] = "skip: encoder-only arch has no decode step"
        elif shape == "long_500k" and not cfg.supports_long_context:
            out[shape] = "skip: pure full-attention arch (needs sub-quadratic)"
        else:
            out[shape] = "run"
    return out
