"""arctic-480b [hf:Snowflake/snowflake-arctic-base; hf]: 35L d_model=7168
56H (GQA kv=8) vocab=32000; dense residual MLP (d_ff 4864) in parallel with
128-expert top-2 MoE (expert ff 4864)."""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        d_model=7168,
        vocab_size=32000,
        block=(LayerSpec("attn", "moe_dense"),),
        n_blocks=35,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        d_ff_expert=4864,
        n_experts=128,
        top_k=2,
        activation="swiglu",
        opt_state_dtype="bfloat16",
    )
