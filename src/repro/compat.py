"""JAX version-compatibility shims: the ONE place API drift gets absorbed.

The model/train substrate targets the jax 0.4.3x line but newer jax renamed
or moved two load-bearing surfaces:

* the ambient-mesh context: ``jax.set_mesh`` (newest) was previously
  ``jax.sharding.use_mesh``, and before that the ``Mesh`` object itself was
  the context manager;
* ``shard_map``: promoted from ``jax.experimental.shard_map`` to
  ``jax.shard_map``, with ``check_rep`` renamed to ``check_vma``.

Every call site in ``repro`` imports :func:`use_mesh` / :func:`shard_map`
from here instead of touching ``jax`` directly, so the next rename lands in
this file and nowhere else. ``JAX_VERSION`` / ``MIN_JAX_VERSION`` make the
supported range introspectable (and testable) at runtime; the declared pip
range lives in ``pyproject.toml``.
"""
from __future__ import annotations

import re

import jax

__all__ = [
    "JAX_VERSION",
    "MIN_JAX_VERSION",
    "jax_at_least",
    "use_mesh",
    "shard_map",
]


def _parse_version(v: str) -> tuple[int, int, int]:
    parts = []
    for p in v.split(".")[:3]:
        m = re.match(r"\d+", p)  # leading digits only ("37rc1" -> 37)
        parts.append(int(m.group(0)) if m else 0)
    while len(parts) < 3:
        parts.append(0)
    return tuple(parts)  # type: ignore[return-value]


#: the running jax version as an (major, minor, patch) int tuple
JAX_VERSION: tuple[int, int, int] = _parse_version(jax.__version__)

#: oldest jax this substrate is tested against (see pyproject.toml)
MIN_JAX_VERSION: tuple[int, int, int] = (0, 4, 30)


def jax_at_least(*version: int) -> bool:
    """True when the running jax is >= ``version`` (e.g. ``(0, 5)``)."""
    return JAX_VERSION >= tuple(version)


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Newest jax spells this ``jax.set_mesh``; the 0.5/0.6 line had
    ``jax.sharding.use_mesh``; on the 0.4.x line the ``Mesh`` object itself
    is the context manager (entering it sets the physical resource env that
    ``with_sharding_constraint`` and bare-``PartitionSpec`` lowering read).
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    sharding_use_mesh = getattr(jax.sharding, "use_mesh", None)
    if sharding_use_mesh is not None:
        return sharding_use_mesh(mesh)
    return mesh


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable ``shard_map``.

    Maps ``check_vma`` onto the old ``check_rep`` flag when running on a jax
    that still hosts shard_map under ``jax.experimental``.
    """
    new_shard_map = getattr(jax, "shard_map", None)
    if new_shard_map is not None:
        return new_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
