"""Compressed-sparse-row graph structure.

All partitioners and engines in this repo consume :class:`CSRGraph`. Graphs
are undirected and stored symmetrically (every edge appears in both rows), the
same convention the paper uses for its quality metrics (|E| counts each
undirected edge once; ``2|E|`` is the sum of degrees, Eq. 2 of the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Undirected graph in CSR form.

    Attributes:
      indptr:  int64[|V|+1] row offsets into ``indices``.
      indices: int32[2|E|]  neighbour ids, symmetric (u in N(v) <=> v in N(u)).
    """

    indptr: np.ndarray
    indices: np.ndarray

    # ---------------------------------------------------------------- basics
    @property
    def num_vertices(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        """Number of undirected edges (each counted once)."""
        return int(self.indices.shape[0] // 2)

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    # ------------------------------------------------------------ construction
    @staticmethod
    def from_edges(
        edges: np.ndarray, num_vertices: int | None = None, dedupe: bool = True
    ) -> "CSRGraph":
        """Build a symmetric CSR graph from an (m, 2) int array of edges.

        Self-loops are dropped; duplicate edges (in either direction) are
        deduplicated when ``dedupe`` is set.
        """
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        edges = edges[edges[:, 0] != edges[:, 1]]  # no self loops
        if num_vertices is None:
            num_vertices = int(edges.max()) + 1 if edges.size else 0
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        if dedupe and edges.size:
            key = lo * np.int64(num_vertices) + hi
            _, first = np.unique(key, return_index=True)
            lo, hi = lo[first], hi[first]
        src = np.concatenate([lo, hi])
        dst = np.concatenate([hi, lo])
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        indptr = np.zeros(num_vertices + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        np.cumsum(indptr, out=indptr)
        # vectorised per-row neighbour sort: lexsort by (src, dst)
        order2 = np.lexsort((dst, src))
        indices = dst[order2].astype(np.int32)
        return CSRGraph(indptr=indptr, indices=indices)

    # ------------------------------------------------------------- iteration
    def iter_adjacency(
        self, order: Sequence[int] | None = None
    ) -> Iterator[tuple[int, np.ndarray]]:
        """Yield ``(v, N(v))`` in the given stream order (default: natural)."""
        ids = range(self.num_vertices) if order is None else order
        for v in ids:
            yield int(v), self.neighbors(int(v))

    def edges_array(self) -> np.ndarray:
        """(|E|, 2) array with each undirected edge listed once (u < v)."""
        src = np.repeat(np.arange(self.num_vertices, dtype=np.int64), self.degrees)
        dst = self.indices.astype(np.int64)
        mask = src < dst
        return np.stack([src[mask], dst[mask]], axis=1)

    # ------------------------------------------------------------- utilities
    def subgraph_edge_count(self, mask: np.ndarray) -> int:
        """Number of edges with both endpoints inside ``mask`` (bool[|V|])."""
        src = np.repeat(np.arange(self.num_vertices, dtype=np.int64), self.degrees)
        both = mask[src] & mask[self.indices]
        return int(both.sum() // 2)

    def permute(self, perm: np.ndarray) -> "CSRGraph":
        """Relabel vertices: new id of old vertex v is ``perm[v]``."""
        edges = self.edges_array()
        new_edges = np.stack([perm[edges[:, 0]], perm[edges[:, 1]]], axis=1)
        return CSRGraph.from_edges(new_edges, num_vertices=self.num_vertices)

    def save(self, path: str) -> None:
        np.savez_compressed(path, indptr=self.indptr, indices=self.indices)

    @staticmethod
    def load(path: str) -> "CSRGraph":
        data = np.load(path)
        return CSRGraph(indptr=data["indptr"], indices=data["indices"])

    def __repr__(self) -> str:  # pragma: no cover
        return f"CSRGraph(|V|={self.num_vertices}, |E|={self.num_edges})"
