"""Async double-buffered prefetch for out-of-core streaming.

With a compressed mapped graph (:class:`~repro.graph.external.ExternalCSRGraph`
v2), every engine chunk pays a decode before it can score: disk pages fault
in and varint blocks expand while the Pallas scorer sits idle, then the
scorer runs while the disk sits idle. :class:`BatchPrefetcher` overlaps the
two phases - a dedicated thread decodes batch t+1 while the caller scores
batch t, keeping ``depth`` results in flight (double buffering at the
default ``depth=2``).

The prefetcher never reorders or transforms work: the caller supplies a pure
``fetch(item)`` and consumes results strictly in submission order, so the
assignment stream is bit-identical to calling ``fetch`` inline.
:class:`PrefetchStats` counts how often the overlap actually won (the result
was already decoded when the consumer asked - a *hit*) and aggregates decode
and wait wall time for the ``prefetch_hit_rate`` / ``decode_wall_s`` /
``prefetch_wait_s`` telemetry keys.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Iterable, Iterator

__all__ = ["PrefetchStats", "BatchPrefetcher"]


class PrefetchStats:
    """Thread-safe counters for the prefetch pipeline.

    ``hits``/``misses`` count dequeues whose result was/wasn't ready;
    ``decode_wall_s`` is total time spent producing results (on whichever
    thread ran the fetch), ``wait_s`` the time consumers stalled waiting.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.decode_wall_s = 0.0
        self.wait_s = 0.0

    def record_decode(self, seconds: float) -> None:
        with self._lock:
            self.decode_wall_s += seconds

    def record_wait(self, seconds: float, hit: bool) -> None:
        with self._lock:
            self.wait_s += seconds
            if hit:
                self.hits += 1
            else:
                self.misses += 1

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def to_telemetry(self) -> dict:
        return {
            "prefetch_hit_rate": round(self.hit_rate, 4),
            "prefetch_wait_s": round(self.wait_s, 6),
            "decode_wall_s": round(self.decode_wall_s, 6),
        }


class BatchPrefetcher:
    """Iterate ``fetch(item)`` results in order, decoding ahead on a thread.

    ``depth`` results are kept in flight on a dedicated single worker (one
    thread suffices: fetches are executed in order, the only goal is
    overlapping them with the consumer). Exceptions from ``fetch`` surface
    at the corresponding ``__next__``; the worker is always shut down, even
    on early exit (``close`` / generator cleanup).
    """

    def __init__(
        self,
        fetch: Callable,
        items: Iterable,
        depth: int = 2,
        stats: PrefetchStats | None = None,
    ) -> None:
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._fetch = fetch
        self._items = iter(items)
        self._depth = depth
        self._stats = stats
        self._ex = ThreadPoolExecutor(1, thread_name_prefix="prefetch")
        self._queue: deque = deque()
        self._fill()

    def _timed_fetch(self, item):
        t0 = time.perf_counter()
        try:
            return self._fetch(item)
        finally:
            if self._stats is not None:
                self._stats.record_decode(time.perf_counter() - t0)

    def _fill(self) -> None:
        while len(self._queue) < self._depth:
            try:
                item = next(self._items)
            except StopIteration:
                return
            self._queue.append(self._ex.submit(self._timed_fetch, item))

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if not self._queue:
            self.close()
            raise StopIteration
        fut = self._queue.popleft()
        hit = fut.done()
        t0 = time.perf_counter()
        try:
            result = fut.result()
        finally:
            if self._stats is not None:
                self._stats.record_wait(time.perf_counter() - t0, hit)
        self._fill()
        return result

    def close(self) -> None:
        for fut in self._queue:
            fut.cancel()
        self._queue.clear()
        self._ex.shutdown(wait=True)

    def __enter__(self) -> "BatchPrefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - safety net
        try:
            self.close()
        except Exception:
            pass
