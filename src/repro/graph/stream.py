"""Vertex-stream abstraction (paper §II, "general streaming model").

A stream yields ``(vertex_id, neighbor_array)`` exactly once per vertex; the
partitioner may not look ahead. Supports the orderings the streaming
literature studies (natural / random / BFS / DFS) since CUTTANA's headline
property is robustness to input order.
"""
from __future__ import annotations

from collections import deque
from typing import Iterator

import numpy as np

from repro.graph.csr import CSRGraph


def stream_order(graph: CSRGraph, order: str = "natural", seed: int = 0) -> np.ndarray:
    n = graph.num_vertices
    if order == "natural":
        return np.arange(n, dtype=np.int64)
    if order == "random":
        rng = np.random.default_rng(seed)
        return rng.permutation(n).astype(np.int64)
    if order in ("bfs", "dfs"):
        return _traversal_order(graph, dfs=(order == "dfs"), seed=seed)
    raise ValueError(f"unknown stream order: {order}")


def _traversal_order(graph: CSRGraph, dfs: bool, seed: int) -> np.ndarray:
    n = graph.num_vertices
    rng = np.random.default_rng(seed)
    visited = np.zeros(n, dtype=bool)
    out = np.empty(n, dtype=np.int64)
    pos = 0
    roots = rng.permutation(n)
    for root in roots:
        if visited[root]:
            continue
        stack = deque([int(root)])
        visited[root] = True
        while stack:
            # deque.popleft is O(1); list.pop(0) made BFS O(n^2)
            v = stack.pop() if dfs else stack.popleft()
            out[pos] = v
            pos += 1
            for u in graph.neighbors(v):
                if not visited[u]:
                    visited[u] = True
                    stack.append(int(u))
    return out


def vertex_stream(
    graph: CSRGraph, order: str = "natural", seed: int = 0
) -> Iterator[tuple[int, np.ndarray]]:
    for v in stream_order(graph, order, seed):
        yield int(v), graph.neighbors(int(v))
