"""Vertex-stream abstraction (paper §II, "general streaming model").

A stream yields ``(vertex_id, neighbor_array)`` exactly once per vertex; the
partitioner may not look ahead. Supports the orderings the streaming
literature studies (natural / random / BFS / DFS) since CUTTANA's headline
property is robustness to input order.

:class:`ShardedStream` splits any such order into ``S`` interleaved shard
cursors for the parallel engine (paper §V: "a parallel version for CUTTANA"):
shard ``s`` sees every ``S``-th vertex of the base order, so each shard's
substream preserves the statistical character of the full stream (a BFS order
stays neighbourhood-coherent per shard, a random order stays random).

Everything here is duck-typed over the CSR read surface, so a memory-mapped
:class:`~repro.graph.external.ExternalCSRGraph` streams exactly like a
resident :class:`CSRGraph` - neighbour arrays come straight off the mapped
file.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterator

import numpy as np

from repro.graph.csr import CSRGraph


def stream_order(graph: CSRGraph, order: str = "natural", seed: int = 0) -> np.ndarray:
    n = graph.num_vertices
    if order == "natural":
        return np.arange(n, dtype=np.int64)
    if order == "random":
        rng = np.random.default_rng(seed)
        return rng.permutation(n).astype(np.int64)
    if order in ("bfs", "dfs"):
        return _traversal_order(graph, dfs=(order == "dfs"), seed=seed)
    raise ValueError(f"unknown stream order: {order}")


def _traversal_order(graph: CSRGraph, dfs: bool, seed: int) -> np.ndarray:
    n = graph.num_vertices
    rng = np.random.default_rng(seed)
    visited = np.zeros(n, dtype=bool)
    out = np.empty(n, dtype=np.int64)
    pos = 0
    roots = rng.permutation(n)
    for root in roots:
        if visited[root]:
            continue
        stack = deque([int(root)])
        visited[root] = True
        while stack:
            # deque.popleft is O(1); list.pop(0) made BFS O(n^2)
            v = stack.pop() if dfs else stack.popleft()
            out[pos] = v
            pos += 1
            for u in graph.neighbors(v):
                if not visited[u]:
                    visited[u] = True
                    stack.append(int(u))
    return out


def vertex_stream(
    graph: CSRGraph, order: str = "natural", seed: int = 0
) -> Iterator[tuple[int, np.ndarray]]:
    for v in stream_order(graph, order, seed):
        yield int(v), graph.neighbors(int(v))


@dataclasses.dataclass(frozen=True)
class ShardedStream:
    """``S`` interleaved shard cursors over one base stream order.

    ``shards[s] == ids[s::S]`` - a round-robin split, so every vertex appears
    in exactly one shard and shard lengths differ by at most one. The
    parallel engine advances all cursors in lock step (one *superstep* per
    round) and exchanges assignments only at superstep boundaries.
    """

    num_shards: int
    shards: tuple[np.ndarray, ...]

    @classmethod
    def from_ids(cls, ids: np.ndarray, num_shards: int) -> "ShardedStream":
        s = int(num_shards)
        if s < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        # materialize each cursor contiguously ONCE (O(n) total): `ids[i::s]`
        # is a strided view, so every superstep batch sliced from it stayed
        # strided and each consumer (degree gather, CSR expansion, kernel
        # packing) re-paid a strided copy per superstep - O(n) of cache-
        # hostile traffic per superstep instead of O(S) view bookkeeping
        return cls(s, tuple(np.ascontiguousarray(ids[i::s]) for i in range(s)))

    @classmethod
    def from_order(
        cls,
        graph: CSRGraph,
        num_shards: int,
        order: str = "natural",
        seed: int = 0,
    ) -> "ShardedStream":
        return cls.from_ids(stream_order(graph, order, seed), num_shards)

    @property
    def num_vertices(self) -> int:
        return sum(shard.shape[0] for shard in self.shards)

    def shard_of(self, num_vertices: int) -> np.ndarray:
        """Which shard streams each vertex (-1 if the vertex is in no shard -
        only possible with an ``ids`` subset). The dtype is the narrowest
        signed integer that fits ``num_shards``: int8 up to 127 shards,
        int16 up to 32767, int32 beyond."""
        if self.num_shards <= np.iinfo(np.int8).max:
            dtype = np.int8
        elif self.num_shards <= np.iinfo(np.int16).max:
            dtype = np.int16
        else:
            dtype = np.int32
        out = np.full(num_vertices, -1, dtype=dtype)
        for s, shard in enumerate(self.shards):
            out[shard] = s
        return out

    def num_supersteps(self, chunk: int) -> int:
        longest = max((shard.shape[0] for shard in self.shards), default=0)
        return -(-longest // max(int(chunk), 1))

    def superstep_batches(self, chunk: int) -> Iterator[list[np.ndarray]]:
        """Yield one list of per-shard id batches per superstep; exhausted
        shards contribute empty batches until the longest cursor finishes."""
        chunk = max(int(chunk), 1)
        for step in range(self.num_supersteps(chunk)):
            lo = step * chunk
            yield [shard[lo : lo + chunk] for shard in self.shards]
