"""Seeded synthetic graph generators.

The evaluation container is offline, so the paper's datasets (Twitter, UK07,
Orkut, usroad, LDBC-SNB) are stood in for by seeded generators that match the
*structural class* of each dataset:

  - ``rmat_graph``              -> social networks (orkut/twitter): power-law,
                                   low diameter, weak locality.
  - ``powerlaw_cluster_graph``  -> web graphs (uk02/uk07): power-law with high
                                   clustering + strong id-locality (crawl order).
  - ``road_graph``              -> usroad: bounded degree, huge diameter,
                                   planar-ish lattice.
  - ``ldbc_like_graph``         -> LDBC SNB: community structure (SBM-ish) with
                                   power-law degrees inside communities.

All generators take ``num_vertices``/``avg_degree`` so experiments can scale
from unit-test size to the multi-million-edge quality benchmarks.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(np.random.PCG64(seed))


def rmat_graph(
    num_vertices: int,
    avg_degree: float = 16.0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> CSRGraph:
    """R-MAT generator (Chakrabarti et al.) - power-law, social-network-like."""
    rng = _rng(seed)
    scale = int(np.ceil(np.log2(max(num_vertices, 2))))
    n = 1 << scale
    num_edges = int(num_vertices * avg_degree / 2)
    src = np.zeros(num_edges, dtype=np.int64)
    dst = np.zeros(num_edges, dtype=np.int64)
    # vectorised bit-by-bit quadrant sampling
    for bit in range(scale):
        r = rng.random(num_edges)
        go_right_src = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        go_right_dst = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src |= go_right_src.astype(np.int64) << bit
        dst |= go_right_dst.astype(np.int64) << bit
    # fold down into [0, num_vertices)
    src %= num_vertices
    dst %= num_vertices
    edges = np.stack([src, dst], axis=1)
    return CSRGraph.from_edges(edges, num_vertices=num_vertices)


def powerlaw_cluster_graph(
    num_vertices: int,
    avg_degree: float = 12.0,
    locality: float = 0.85,
    seed: int = 0,
) -> CSRGraph:
    """Web-graph-like: preferential attachment + strong id locality.

    Each new vertex v connects m = avg_degree/2 times; with prob ``locality``
    to a vertex in a nearby id window (crawl locality), otherwise by
    preferential attachment to earlier high-degree vertices (hubs).
    """
    rng = _rng(seed)
    m = max(1, int(round(avg_degree / 2)))
    srcs: list[np.ndarray] = []
    dsts: list[np.ndarray] = []
    # seed clique
    seed_n = m + 1
    sv, dv = np.triu_indices(seed_n, k=1)
    srcs.append(sv.astype(np.int64))
    dsts.append(dv.astype(np.int64))
    # degree-proportional sampling via an endpoint pool (BA trick)
    pool = np.concatenate([sv, dv]).astype(np.int64)
    pool_list = [pool]
    pool_size = pool.shape[0]
    batch = 4096
    v = seed_n
    while v < num_vertices:
        vb = min(batch, num_vertices - v)
        new_ids = np.arange(v, v + vb, dtype=np.int64)
        src_b = np.repeat(new_ids, m)
        r = rng.random(vb * m)
        # local edges: a window of ~1000 ids behind the new vertex
        window = np.minimum(new_ids, 1000)
        offs = (rng.random(vb * m) * np.repeat(window, m)).astype(np.int64) + 1
        local = src_b - offs
        # preferential edges: uniform sample from the endpoint pool
        flat_pool = np.concatenate(pool_list) if len(pool_list) > 1 else pool_list[0]
        pool_list = [flat_pool]
        pref = flat_pool[(rng.random(vb * m) * pool_size).astype(np.int64)]
        dst_b = np.where(r < locality, local, pref)
        srcs.append(src_b)
        dsts.append(dst_b)
        pool_list.append(np.concatenate([src_b, dst_b]))
        pool_size += src_b.shape[0] * 2
        v += vb
    edges = np.stack([np.concatenate(srcs), np.concatenate(dsts)], axis=1)
    return CSRGraph.from_edges(edges, num_vertices=num_vertices)


def road_graph(num_vertices: int, seed: int = 0, rewire: float = 0.01) -> CSRGraph:
    """Road-network-like: 2D lattice with sporadic shortcuts.

    Degree ~4, enormous diameter, perfect id locality - the regime where the
    paper observed HeiStream's batching winning on usroad.
    """
    rng = _rng(seed)
    side = int(np.ceil(np.sqrt(num_vertices)))
    ids = np.arange(num_vertices, dtype=np.int64)
    x, y = ids % side, ids // side
    right = ids + 1
    right_ok = (x < side - 1) & (right < num_vertices)
    down = ids + side
    down_ok = down < num_vertices
    edges = np.concatenate(
        [
            np.stack([ids[right_ok], right[right_ok]], axis=1),
            np.stack([ids[down_ok], down[down_ok]], axis=1),
        ]
    )
    n_rewire = int(rewire * edges.shape[0])
    if n_rewire:
        extra = np.stack(
            [
                (rng.random(n_rewire) * num_vertices).astype(np.int64),
                (rng.random(n_rewire) * num_vertices).astype(np.int64),
            ],
            axis=1,
        )
        edges = np.concatenate([edges, extra])
    return CSRGraph.from_edges(edges, num_vertices=num_vertices)


def ldbc_like_graph(
    num_vertices: int,
    avg_degree: float = 18.0,
    num_communities: int | None = None,
    intra_prob: float = 0.7,
    seed: int = 0,
) -> CSRGraph:
    """LDBC-SNB-like social graph: communities + power-law degrees.

    Vertices are assigned to communities of power-law size; each edge is
    intra-community with prob ``intra_prob`` (uniform target inside the
    community), else a global preferential target (degree-skewed via a zipf
    draw over vertex ids after a random permutation).
    """
    rng = _rng(seed)
    if num_communities is None:
        num_communities = max(4, num_vertices // 1500)
    # power-law community sizes
    raw = rng.zipf(1.6, size=num_communities).astype(np.float64)
    sizes = np.maximum(1, (raw / raw.sum() * num_vertices)).astype(np.int64)
    while sizes.sum() < num_vertices:
        sizes[rng.integers(num_communities)] += 1
    comm_of = np.repeat(np.arange(num_communities), sizes)[:num_vertices]
    comm_start = np.concatenate([[0], np.cumsum(sizes)])[:num_communities]
    comm_size = sizes

    num_edges = int(num_vertices * avg_degree / 2)
    src = (rng.random(num_edges) * num_vertices).astype(np.int64)
    intra = rng.random(num_edges) < intra_prob
    c = comm_of[src]
    intra_dst = comm_start[c] + (rng.random(num_edges) * comm_size[c]).astype(np.int64)
    intra_dst = np.minimum(intra_dst, num_vertices - 1)
    # global power-law targets
    zipf_draw = rng.zipf(1.3, size=num_edges) % num_vertices
    dst = np.where(intra, intra_dst, zipf_draw)
    edges = np.stack([src, dst], axis=1)
    return CSRGraph.from_edges(edges, num_vertices=num_vertices)


DATASETS = {
    # name -> (generator, kwargs). Sizes chosen to run in seconds on 1 CPU
    # while keeping the structural contrast the paper's Table I spans.
    "social-s": (rmat_graph, dict(num_vertices=20_000, avg_degree=16)),
    "social-m": (rmat_graph, dict(num_vertices=100_000, avg_degree=20)),
    "web-s": (powerlaw_cluster_graph, dict(num_vertices=20_000, avg_degree=12)),
    "web-m": (powerlaw_cluster_graph, dict(num_vertices=120_000, avg_degree=14)),
    "road-s": (road_graph, dict(num_vertices=25_000)),
    "road-m": (road_graph, dict(num_vertices=250_000)),
    "ldbc-s": (ldbc_like_graph, dict(num_vertices=30_000, avg_degree=18)),
}


def load_dataset(name: str, seed: int = 0) -> CSRGraph:
    gen, kwargs = DATASETS[name]
    return gen(seed=seed, **kwargs)
