"""Timestamped edge-arrival streams for incremental partitioning.

A :class:`ChurnStream` is the dynamic-graph counterpart of a static
:class:`~repro.graph.csr.CSRGraph`: an ordered, deduplicated edge list with
nondecreasing arrival timestamps. :mod:`repro.core.incremental` replays it in
batches, assigning newly seen vertices against live partition loads.

Two synthesizers cover tests/CI and the benchmarks:

* :func:`rmat_churn` - an R-MAT graph whose edges arrive over time, either in
  ``"growth"`` order (vertices join the graph one by one, each bringing its
  back-edges - the social-network arrival model) or fully ``"random"``;
* :func:`churn_from_graph` - derives an arrival order for an existing graph
  from a registered stream order (``natural``/``random``/``bfs``/``dfs``), so
  an incremental replay of the whole stream is comparable to a one-shot
  streaming run under the same order.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.stream import stream_order

__all__ = ["ChurnStream", "rmat_churn", "churn_from_graph"]


@dataclasses.dataclass(frozen=True)
class ChurnStream:
    """An ordered stream of unique undirected edges with arrival times.

    Attributes:
      edges:      int64[m, 2] canonical ``(lo, hi)`` endpoint pairs in
                  arrival order - no self-loops, each undirected edge once
                  (the first arrival wins; later duplicates are dropped).
      timestamps: float64[m] nondecreasing arrival times.
      num_vertices: size of the vertex id space (ids are ``< num_vertices``).
    """

    edges: np.ndarray
    timestamps: np.ndarray
    num_vertices: int

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    # ------------------------------------------------------------ construction
    @staticmethod
    def from_edges(
        edges: np.ndarray,
        timestamps: np.ndarray | None = None,
        num_vertices: int | None = None,
    ) -> "ChurnStream":
        """Canonicalize a raw timestamped edge list into a stream.

        Rows are stably sorted by timestamp (given order breaks ties), self
        loops are dropped, and duplicate undirected edges keep only their
        first arrival. Without timestamps the given order *is* the arrival
        order and timestamps become ``0, 1, 2, ...``.
        """
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if timestamps is None:
            ts = np.arange(edges.shape[0], dtype=np.float64)
        else:
            ts = np.asarray(timestamps, dtype=np.float64).reshape(-1)
            if ts.shape[0] != edges.shape[0]:
                raise ValueError(
                    f"timestamps length {ts.shape[0]} != edges length "
                    f"{edges.shape[0]}"
                )
            order = np.argsort(ts, kind="stable")
            edges, ts = edges[order], ts[order]
        keep = edges[:, 0] != edges[:, 1]  # no self loops
        edges, ts = edges[keep], ts[keep]
        if num_vertices is None:
            num_vertices = int(edges.max()) + 1 if edges.size else 0
        elif edges.size and int(edges.max()) >= num_vertices:
            raise ValueError(
                f"edge endpoint {int(edges.max())} out of range for "
                f"num_vertices={num_vertices}"
            )
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        if edges.size:
            key = lo * np.int64(num_vertices) + hi
            _, first = np.unique(key, return_index=True)
            first.sort()  # keep first arrivals, in arrival order
            lo, hi, ts = lo[first], hi[first], ts[first]
        return ChurnStream(
            edges=np.stack([lo, hi], axis=1),
            timestamps=ts,
            num_vertices=int(num_vertices),
        )

    # ---------------------------------------------------------------- replay
    def batches(self, num_batches: int) -> list[np.ndarray]:
        """Split the stream into ``num_batches`` near-equal arrival batches
        (earliest first). Trailing batches may be empty for tiny streams."""
        if num_batches < 1:
            raise ValueError(f"num_batches must be >= 1, got {num_batches}")
        return np.array_split(self.edges, num_batches)

    def windows(self, span: float) -> list[np.ndarray]:
        """Split by time instead of count: consecutive ``span``-wide windows
        starting at the first timestamp. Empty windows are preserved so the
        replay cadence matches wall time."""
        if span <= 0:
            raise ValueError(f"span must be > 0, got {span}")
        if self.num_edges == 0:
            return []
        t0 = float(self.timestamps[0])
        n_win = int(np.floor((float(self.timestamps[-1]) - t0) / span)) + 1
        bounds = t0 + span * np.arange(1, n_win)
        cuts = np.searchsorted(self.timestamps, bounds, side="left")
        return np.split(self.edges, cuts)

    def final_graph(self) -> CSRGraph:
        """The static graph after the whole stream has arrived."""
        return CSRGraph.from_edges(
            self.edges, num_vertices=self.num_vertices, dedupe=False
        )

    # ------------------------------------------------------------ persistence
    def save(self, path: str) -> None:
        np.savez_compressed(
            path,
            edges=self.edges,
            timestamps=self.timestamps,
            num_vertices=np.int64(self.num_vertices),
        )

    @staticmethod
    def load(path: str) -> "ChurnStream":
        data = np.load(path)
        return ChurnStream(
            edges=data["edges"],
            timestamps=data["timestamps"],
            num_vertices=int(data["num_vertices"]),
        )

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"ChurnStream(|V|={self.num_vertices}, m={self.num_edges}, "
            f"t=[{self.timestamps[0] if self.num_edges else 0:.3g}, "
            f"{self.timestamps[-1] if self.num_edges else 0:.3g}])"
        )


def rmat_churn(
    num_vertices: int,
    avg_degree: float = 16.0,
    seed: int = 0,
    ordering: str = "growth",
) -> ChurnStream:
    """Synthesize a churn stream from a seeded R-MAT graph.

    ``ordering="growth"`` models a growing network: edges arrive grouped by
    their later-joining endpoint (seeded shuffle within each group), so a
    vertex's whole back-edge set lands when the vertex first appears.
    ``ordering="random"`` is a seeded uniform shuffle of the edge list -
    the adversarial case where a vertex's edges are scattered across the
    whole stream.
    """
    from repro.graph.generators import rmat_graph

    graph = rmat_graph(num_vertices, avg_degree=avg_degree, seed=seed)
    edges = graph.edges_array()
    rng = np.random.default_rng(seed + 1)
    jitter = rng.permutation(edges.shape[0])
    if ordering == "growth":
        order = np.lexsort((jitter, np.maximum(edges[:, 0], edges[:, 1])))
    elif ordering == "random":
        order = jitter
    else:
        raise ValueError(
            f'ordering must be "growth" or "random", got {ordering!r}'
        )
    return ChurnStream.from_edges(
        edges[order], num_vertices=graph.num_vertices
    )


def churn_from_graph(
    graph: CSRGraph, order: str = "natural", seed: int = 0
) -> ChurnStream:
    """Derive an arrival stream for an existing graph from a stream order.

    An edge arrives when its *later* endpoint (by the vertex stream order)
    does, ties broken by the earlier endpoint's position - exactly the edge
    information a one-shot streaming partitioner has seen by the time it
    places that vertex. Replaying this stream as a single batch therefore
    feeds the incremental partitioner the same vertex order and the same
    neighbourhoods as the one-shot run (the parity pin in
    ``tests/test_incremental.py``).
    """
    so = stream_order(graph, order, seed)
    pos = np.empty(graph.num_vertices, dtype=np.int64)
    pos[so] = np.arange(graph.num_vertices, dtype=np.int64)
    edges = graph.edges_array()
    pu, pv = pos[edges[:, 0]], pos[edges[:, 1]]
    arrival = np.lexsort((np.minimum(pu, pv), np.maximum(pu, pv)))
    return ChurnStream.from_edges(
        edges[arrival], num_vertices=graph.num_vertices
    )
