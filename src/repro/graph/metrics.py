"""Partition quality metrics (paper §II).

All metrics take the graph and an assignment array ``part`` of shape [|V|]
with values in [0, K).
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def _edge_endpoints(graph: CSRGraph) -> tuple[np.ndarray, np.ndarray]:
    src = np.repeat(np.arange(graph.num_vertices, dtype=np.int64), graph.degrees)
    return src, graph.indices.astype(np.int64)


def edge_cut(graph: CSRGraph, part: np.ndarray) -> float:
    """Normalized edge-cut  λ_EC  (paper Eq. 3), in [0, 1]."""
    src, dst = _edge_endpoints(graph)
    cut = int((part[src] != part[dst]).sum()) // 2  # symmetric storage
    return cut / max(graph.num_edges, 1)


def communication_volume(graph: CSRGraph, part: np.ndarray, k: int) -> float:
    """Normalized communication volume  λ_CV  (paper Eq. 4).

    D(u) = number of *other* partitions in which u has a neighbour;
    λ_CV = Σ_u D(u) / (K |V|).
    """
    src, dst = _edge_endpoints(graph)
    pd = part[dst].astype(np.int64)
    # unique (u, neighbour-partition) pairs, excluding u's own partition
    key = src * np.int64(k) + pd
    uniq = np.unique(key)
    u = uniq // k
    p = uniq % k
    external = int((p != part[u]).sum())
    return external / (k * max(graph.num_vertices, 1))


def partition_vertex_counts(part: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(part, minlength=k)


def partition_edge_counts(graph: CSRGraph, part: np.ndarray, k: int) -> np.ndarray:
    """Σ_{v∈V_i} |N(v)| per partition (degree mass, paper Eq. 2 LHS)."""
    return np.bincount(part, weights=graph.degrees.astype(np.float64), minlength=k)


def vertex_imbalance(part: np.ndarray, k: int) -> float:
    counts = partition_vertex_counts(part, k)
    return float(counts.max() / max(counts.mean(), 1e-12))


def edge_imbalance(graph: CSRGraph, part: np.ndarray, k: int) -> float:
    """max_i Σ_{v∈V_i}|N(v)| over its mean - Fig. 7's straggler metric."""
    counts = partition_edge_counts(graph, part, k)
    return float(counts.max() / max(counts.mean(), 1e-12))


def quality_report(graph: CSRGraph, part: np.ndarray, k: int) -> dict:
    part = np.asarray(part)
    assert part.shape == (graph.num_vertices,)
    assert part.min() >= 0 and part.max() < k, "invalid partition ids"
    return {
        "k": k,
        "edge_cut": edge_cut(graph, part),
        "comm_volume": communication_volume(graph, part, k),
        "vertex_imbalance": vertex_imbalance(part, k),
        "edge_imbalance": edge_imbalance(graph, part, k),
    }


def check_balance(
    sizes: np.ndarray, total: float, k: int, epsilon: float
) -> bool:
    """Balance condition (paper Eq. 1 / Eq. 2): max_i size_i <= (1+eps) total/K."""
    return bool(sizes.max() <= (1.0 + epsilon) * total / k + 1e-9)
