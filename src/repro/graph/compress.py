"""Delta-varint block codec for the v2 external CSR format.

The v2 on-disk format (see :mod:`repro.graph.external` and
``src/repro/graph/README.md``) stores each vertex's sorted neighbour list as a
sequence of fixed-capacity *blocks*: the first value of every block is an
absolute vertex id, the rest are deltas against the previous value. Rows are
strictly sorted with no duplicates, so every delta is >= 1 and small on
power-law graphs — LEB128 varints then pack the common case into 1-2 bytes
instead of the raw 4 of an int32.

Everything here is NumPy-vectorised: encode/decode cost is a handful of
masked passes bounded by the *longest* varint in the batch (<= 9 bytes for
any non-negative int64), never a per-edge Python loop. The codec is pure
(arrays in, arrays out) and the property/corruption tests in
``tests/test_compress.py`` pin the contract:

* ``decode(encode(x)) == x`` for any strictly-row-sorted adjacency;
* a truncated, bit-flipped, or count-inconsistent stream raises ``ValueError``
  rather than decoding to garbage.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "DEFAULT_BLOCK_CAP",
    "MAX_VARINT_BYTES",
    "varint_encode",
    "varint_decode",
    "encode_adjacency",
    "decode_adjacency",
]

# Restart interval: every block_cap-th value within a row is stored as an
# absolute id so a corrupt delta cannot poison more than one block. 64 keeps
# the absolute-value overhead under ~2% on power-law rows while bounding the
# blast radius of a bad byte.
DEFAULT_BLOCK_CAP = 64

# Any non-negative int64 fits in ceil(63/7) = 9 LEB128 bytes.
MAX_VARINT_BYTES = 9


def varint_sizes(vals: np.ndarray) -> np.ndarray:
    """Encoded byte length of each value (int64[m], each in [1, 9])."""
    vals = np.asarray(vals, dtype=np.int64)
    nb = np.ones(vals.shape[0], dtype=np.int64)
    for j in range(1, MAX_VARINT_BYTES):
        nb += vals >= np.int64(1) << np.int64(7 * j)
    return nb


def varint_encode(vals: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """LEB128-encode non-negative int64 values.

    Returns ``(buf, nb)``: the packed uint8 stream and the per-value byte
    lengths (``nb.sum() == buf.shape[0]``).
    """
    vals = np.ascontiguousarray(vals, dtype=np.int64)
    if vals.size == 0:
        return np.empty(0, dtype=np.uint8), np.empty(0, dtype=np.int64)
    if int(vals.min()) < 0:
        raise ValueError("varint_encode: negative value")
    nb = varint_sizes(vals)
    starts = np.cumsum(nb) - nb
    out = np.empty(int(nb.sum()), dtype=np.uint8)
    for j in range(int(nb.max())):
        m = nb > j
        byte = (vals[m] >> np.int64(7 * j)) & np.int64(0x7F)
        cont = np.where(nb[m] - 1 > j, np.int64(0x80), np.int64(0))
        out[starts[m] + j] = (byte | cont).astype(np.uint8)
    return out, nb


def varint_decode(
    buf: np.ndarray, count: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Decode a packed LEB128 stream back to int64 values.

    Returns ``(vals, starts)`` where ``starts[i]`` is the byte offset of
    value ``i`` inside ``buf``. Raises ``ValueError`` on a truncated stream
    (last byte has its continuation bit set), an over-long varint, or — when
    ``count`` is given — a value count that does not match.
    """
    buf = np.ascontiguousarray(buf, dtype=np.uint8)
    if buf.size == 0:
        if count not in (None, 0):
            raise ValueError(
                f"varint stream empty, expected {count} values"
            )
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
    ends = np.flatnonzero(buf < 0x80)
    if ends.size == 0 or int(ends[-1]) != buf.shape[0] - 1:
        raise ValueError("varint stream truncated: missing terminator byte")
    if count is not None and ends.size != count:
        raise ValueError(
            f"varint count mismatch: decoded {ends.size}, expected {count}"
        )
    starts = np.empty_like(ends)
    starts[0] = 0
    starts[1:] = ends[:-1] + 1
    lens = ends - starts + 1
    width = int(lens.max())
    if width > MAX_VARINT_BYTES:
        raise ValueError(
            f"varint longer than {MAX_VARINT_BYTES} bytes (corrupt stream)"
        )
    vals = (buf[starts] & np.uint8(0x7F)).astype(np.int64)
    for j in range(1, width):
        m = lens > j
        vals[m] |= (buf[starts[m] + j] & np.uint8(0x7F)).astype(np.int64) << (
            np.int64(7 * j)
        )
    return vals, starts


def _restart_mask(degs: np.ndarray, block_cap: int) -> np.ndarray:
    """bool[m]: True where a value opens a block (stored as an absolute id)."""
    degs = np.asarray(degs, dtype=np.int64)
    m = int(degs.sum())
    row_first = np.cumsum(degs) - degs
    idx_in_row = np.arange(m, dtype=np.int64) - np.repeat(row_first, degs)
    return (idx_in_row % block_cap) == 0


def encode_adjacency(
    flat: np.ndarray, degs: np.ndarray, block_cap: int = DEFAULT_BLOCK_CAP
) -> tuple[np.ndarray, np.ndarray]:
    """Block-delta + varint encode a concatenation of sorted neighbour rows.

    ``flat`` holds the rows back to back (``degs[i]`` values each); every row
    must be strictly increasing (the CSR invariant). Returns
    ``(data, row_bytes)``: the packed uint8 stream and the encoded byte length
    of each row (``row_bytes.sum() == data.shape[0]``).
    """
    if block_cap < 1:
        raise ValueError(f"block_cap must be >= 1, got {block_cap}")
    flat = np.ascontiguousarray(flat, dtype=np.int64)
    degs = np.asarray(degs, dtype=np.int64)
    if flat.shape[0] != int(degs.sum()):
        raise ValueError(
            f"flat has {flat.shape[0]} values but degs sums to {int(degs.sum())}"
        )
    if flat.size == 0:
        return np.empty(0, dtype=np.uint8), np.zeros(degs.shape[0], np.int64)
    restart = _restart_mask(degs, block_cap)
    prev = np.empty_like(flat)
    prev[0] = 0
    prev[1:] = flat[:-1]
    enc = np.where(restart, flat, flat - prev)
    if int(enc.min()) < 0 or (enc[~restart] <= 0).any():
        raise ValueError(
            "adjacency rows must be strictly sorted non-negative ids"
        )
    data, nb = varint_encode(enc)
    row_bytes = np.bincount(
        np.repeat(np.arange(degs.shape[0], dtype=np.int64), degs),
        weights=nb,
        minlength=degs.shape[0],
    ).astype(np.int64)
    return data, row_bytes


def decode_adjacency(
    data: np.ndarray,
    degs: np.ndarray,
    block_cap: int = DEFAULT_BLOCK_CAP,
    row_byte_off: np.ndarray | None = None,
) -> np.ndarray:
    """Inverse of :func:`encode_adjacency`: recover the flat neighbour values.

    ``row_byte_off`` (int64[r+1], optional) is the expected byte offset of
    each row inside ``data``; when given, the decoded stream's row boundaries
    are validated against it so a corrupt block cannot silently shift
    neighbours between rows.
    """
    degs = np.asarray(degs, dtype=np.int64)
    count = int(degs.sum())
    vals, starts = varint_decode(data, count=count)
    if count == 0:
        return vals
    restart = _restart_mask(degs, block_cap)
    # segmented un-delta: within each block, out[j] = abs_at_block_start +
    # sum of deltas since; cumsum once, subtract each block's base.
    cs = np.cumsum(vals)
    seg_starts = np.flatnonzero(restart)
    base = cs[seg_starts] - vals[seg_starts]
    seg_id = np.cumsum(restart) - 1
    out = cs - base[seg_id]
    if row_byte_off is not None:
        row_first = np.cumsum(degs) - degs
        nz = degs > 0
        expect = np.asarray(row_byte_off, dtype=np.int64)
        if int(expect[-1]) != data.shape[0] or not np.array_equal(
            starts[row_first[nz]], expect[:-1][nz]
        ):
            raise ValueError(
                "compressed row offsets inconsistent with block index "
                "(corrupt data region)"
            )
    return out
