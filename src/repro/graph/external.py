"""Out-of-core graph substrate: partition from disk without materializing CSR.

The paper's premise is that "graphs that require distributed settings are
often too large to fit in the main memory of a single machine" (§I), yet a
fully resident :class:`~repro.graph.csr.CSRGraph` needs ``8(|V|+1) + 8|E|``
bytes before the first vertex streams. This module closes that gap with a
binary on-disk CSR format plus two consumers:

* :func:`convert_edge_list` - a bounded-memory two-pass converter that turns
  a text (SNAP-style ``.txt``/``.csv``) or binary (``.npy``) edge list into
  the on-disk format. Pass 1 canonicalizes edges in chunks (drop self-loops,
  ``(lo, hi)`` ordering), sorts each chunk and spills it as a run; a
  vectorised k-way run merge dedupes globally while counting degrees. Pass 2
  re-streams the deduped sorted edges and scatters both directions into the
  memory-mapped ``indices`` region. Peak host memory is ``O(|V|)`` plus one
  chunk - the edge set is never resident. Rows come out sorted by neighbour
  id, so the result is *byte-identical* to ``CSRGraph.from_edges`` on the
  same input (pinned in ``tests/test_outofcore.py``).
* :class:`ExternalCSRGraph` - memory-maps ``indptr``/``indices`` straight
  from the file and exposes the same ``num_vertices`` / ``neighbors`` /
  ``degrees`` surface ``CSRGraph`` does, so ``vertex_stream``,
  ``ShardedStream.superstep_batches`` and the chunked ``StreamEngine`` loops
  consume it unchanged: neighbour batches are sliced from the mapped file per
  chunk, and assignments are bit-identical to the in-memory path.

File layout (version 1, little-endian)::

    [ 0:8 ]   magic  b"XCSRGRPH"
    [ 8:12]   uint32 format version (1)
    [12:16]   uint32 flags (reserved, 0)
    [16:24]   int64  num_vertices                  (n)
    [24:32]   int64  len(indices) == 2|E|          (h)
    [32:64]   reserved (zeros)
    [64:64+8(n+1)]          indptr  int64[n+1]
    [64+8(n+1): +4h]        indices int32[h]

:func:`load_graph_source` resolves the ``PartitionSpec.source`` grammar
(``rmat:*`` / ``dataset:*`` / a path) into a graph object;
:func:`validate_source` is its construction-time syntax check.
"""
from __future__ import annotations

import itertools
import os
import struct
import tempfile
import warnings
from typing import Iterator

import numpy as np

from repro.graph.csr import CSRGraph

__all__ = [
    "MAGIC",
    "FORMAT_VERSION",
    "HEADER_BYTES",
    "ExternalCSRGraph",
    "write_external_csr",
    "convert_edge_list",
    "convert_csr",
    "load_graph_file",
    "load_graph_source",
    "validate_source",
]

MAGIC = b"XCSRGRPH"
FORMAT_VERSION = 1
HEADER_BYTES = 64
_INDPTR_DTYPE = np.dtype("<i8")
_INDICES_DTYPE = np.dtype("<i4")
# keys pack (lo, hi) into one int64: ids must fit the int32 indices anyway
_MAX_VERTEX_ID = np.int64(2**31 - 1)


def _pack_header(num_vertices: int, half_edges: int) -> bytes:
    head = struct.pack(
        "<8sII qq", MAGIC, FORMAT_VERSION, 0, int(num_vertices), int(half_edges)
    )
    return head + b"\0" * (HEADER_BYTES - len(head))


def _file_layout(num_vertices: int, half_edges: int) -> tuple[int, int, int]:
    """(indptr_offset, indices_offset, total_file_bytes)."""
    indptr_off = HEADER_BYTES
    indices_off = indptr_off + _INDPTR_DTYPE.itemsize * (num_vertices + 1)
    total = indices_off + _INDICES_DTYPE.itemsize * half_edges
    return indptr_off, indices_off, total


# ---------------------------------------------------------------- the graph
class ExternalCSRGraph:
    """A CSR graph memory-mapped from the on-disk binary format.

    Exposes the ``CSRGraph`` read surface (``indptr`` / ``indices`` /
    ``num_vertices`` / ``num_edges`` / ``degrees`` / ``neighbors`` /
    ``degree`` / ``iter_adjacency``) over ``np.memmap`` arrays, so every
    partitioner, stream order, and engine chunk loop works unchanged - a
    chunk's neighbour batch is a fancy-indexed *copy* of the mapped pages it
    touches, never the whole graph. The OS pages adjacency in and out as the
    stream advances; only ``O(|V|)`` bookkeeping is ever resident.
    """

    backing = "mapped"

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        try:
            size = os.path.getsize(self.path)
        except OSError as e:
            raise ValueError(f"cannot open external graph {self.path!r}: {e}") from e
        if size < HEADER_BYTES:
            raise ValueError(
                f"{self.path!r} is not an external CSR graph: file is "
                f"{size} bytes, smaller than the {HEADER_BYTES}-byte header"
            )
        with open(self.path, "rb") as f:
            head = f.read(HEADER_BYTES)
        magic, version, _flags, n, h = struct.unpack("<8sII qq", head[:32])
        if magic != MAGIC:
            raise ValueError(
                f"{self.path!r} is not an external CSR graph "
                f"(bad magic {magic!r}; expected {MAGIC!r})"
            )
        if version != FORMAT_VERSION:
            raise ValueError(
                f"{self.path!r}: unsupported format version {version} "
                f"(this build reads version {FORMAT_VERSION})"
            )
        if n < 0 or h < 0 or h % 2:
            raise ValueError(
                f"{self.path!r}: corrupt header (num_vertices={n}, "
                f"len(indices)={h})"
            )
        indptr_off, indices_off, expected = _file_layout(n, h)
        if size != expected:
            raise ValueError(
                f"{self.path!r}: truncated or corrupt - file is {size} bytes "
                f"but the header declares {expected} "
                f"(num_vertices={n}, len(indices)={h})"
            )
        self._n = int(n)
        self._half = int(h)
        self.indptr = np.memmap(
            self.path, dtype=_INDPTR_DTYPE, mode="r", offset=indptr_off,
            shape=(self._n + 1,),
        )
        self.indices = np.memmap(
            self.path, dtype=_INDICES_DTYPE, mode="r", offset=indices_off,
            shape=(self._half,),
        )
        if self._n and (
            int(self.indptr[0]) != 0 or int(self.indptr[-1]) != self._half
        ):
            raise ValueError(
                f"{self.path!r}: corrupt indptr (indptr[0]={int(self.indptr[0])}, "
                f"indptr[-1]={int(self.indptr[-1])}, len(indices)={self._half})"
            )
        self._degrees: np.ndarray | None = None

    # ----------------------------------------------------- CSRGraph surface
    @property
    def num_vertices(self) -> int:
        return self._n

    @property
    def num_edges(self) -> int:
        return self._half // 2

    @property
    def degrees(self) -> np.ndarray:
        # cached: the engines ask repeatedly and a diff over the mapped
        # indptr is the only O(|V|) array this graph ever materializes
        if self._degrees is None:
            self._degrees = np.diff(self.indptr)
        return self._degrees

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    def iter_adjacency(self, order=None) -> Iterator[tuple[int, np.ndarray]]:
        ids = range(self._n) if order is None else order
        for v in ids:
            yield int(v), self.neighbors(int(v))

    def edges_array(self) -> np.ndarray:
        """(|E|, 2) array with each undirected edge listed once (u < v).

        Same contract as ``CSRGraph.edges_array`` - the vertex-cut edge
        partitioners (hdrf/ginger) consume it. Note the *result* is O(|E|)
        resident by definition; the scan over the mapped file is chunked so
        no symmetric 2|E| intermediate is ever materialized.
        """
        out = np.empty((self.num_edges, 2), dtype=np.int64)
        filled = 0
        chunk = 1 << 20
        indptr = self.indptr
        for lo in range(0, self._n, chunk):
            hi = min(lo + chunk, self._n)
            degs = np.asarray(indptr[lo + 1 : hi + 1]) - np.asarray(indptr[lo:hi])
            src = np.repeat(np.arange(lo, hi, dtype=np.int64), degs)
            dst = np.asarray(
                self.indices[indptr[lo] : indptr[hi]], dtype=np.int64
            )
            mask = src < dst
            m = int(mask.sum())
            out[filled : filled + m, 0] = src[mask]
            out[filled : filled + m, 1] = dst[mask]
            filled += m
        assert filled == out.shape[0]
        return out

    def subgraph_edge_count(self, mask: np.ndarray) -> int:
        """Edges with both endpoints inside ``mask`` (bool[|V|]), chunked
        over the mapped adjacency like ``CSRGraph.subgraph_edge_count``."""
        total = 0
        chunk = 1 << 20
        indptr = self.indptr
        for lo in range(0, self._n, chunk):
            hi = min(lo + chunk, self._n)
            degs = np.asarray(indptr[lo + 1 : hi + 1]) - np.asarray(indptr[lo:hi])
            src = np.repeat(np.arange(lo, hi, dtype=np.int64), degs)
            dst = np.asarray(self.indices[indptr[lo] : indptr[hi]])
            total += int((mask[src] & mask[dst]).sum())
        return total // 2

    # --------------------------------------------------------------- memory
    @property
    def nbytes_mapped(self) -> int:
        """Bytes of graph data reachable through the mapping (the file)."""
        return _file_layout(self._n, self._half)[2]

    @property
    def nbytes_resident(self) -> int:
        """Bytes of graph data held in ordinary host arrays (the degree
        cache, once computed) - what an OOM accountant should charge."""
        return 0 if self._degrees is None else int(self._degrees.nbytes)

    # -------------------------------------------------------------- escape
    def to_csr(self) -> CSRGraph:
        """Materialize a fully resident ``CSRGraph`` (for small graphs)."""
        return CSRGraph(
            indptr=np.asarray(self.indptr).copy(),
            indices=np.asarray(self.indices).copy(),
        )

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"ExternalCSRGraph(|V|={self.num_vertices}, |E|={self.num_edges}, "
            f"path={self.path!r})"
        )


# ----------------------------------------------------------------- writers
def write_external_csr(
    path: str | os.PathLike, indptr: np.ndarray, indices: np.ndarray
) -> None:
    """Write CSR arrays in the on-disk format (header + indptr + indices)."""
    indptr = np.ascontiguousarray(indptr, dtype=_INDPTR_DTYPE)
    indices = np.ascontiguousarray(indices, dtype=_INDICES_DTYPE)
    n = int(indptr.shape[0]) - 1
    if n < 0:
        raise ValueError("indptr must have at least one entry")
    with open(path, "wb") as f:
        f.write(_pack_header(n, int(indices.shape[0])))
        indptr.tofile(f)
        indices.tofile(f)


def convert_csr(graph: CSRGraph, path: str | os.PathLike) -> None:
    """Dump an in-memory ``CSRGraph`` into the on-disk format."""
    write_external_csr(path, graph.indptr, graph.indices)


# --------------------------------------------------------------- converter
def _iter_edge_chunks(
    path: str, chunk_edges: int, delimiter: str | None
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield ``(src, dst)`` int64 chunks from a text or ``.npy`` edge list.

    Text: ``#``-comment lines skipped, first two whitespace- (or
    ``delimiter``-) separated columns used, extra columns (weights,
    timestamps) ignored. ``.npy``: the array is memory-mapped and sliced.
    """
    if path.endswith(".npy"):
        arr = np.load(path, mmap_mode="r")
        if arr.ndim != 2 or arr.shape[1] < 2:
            raise ValueError(
                f"{path!r}: expected an (m, >=2) edge array, got shape "
                f"{arr.shape}"
            )
        for lo in range(0, arr.shape[0], chunk_edges):
            block = np.asarray(arr[lo : lo + chunk_edges, :2], dtype=np.int64)
            yield block[:, 0], block[:, 1]
        return
    if delimiter is None and path.endswith(".csv"):
        delimiter = ","
    with open(path, "rt") as f:
        while True:
            lines = list(itertools.islice(f, chunk_edges))
            if not lines:
                return
            with warnings.catch_warnings():
                # a chunk of only comment/blank lines (SNAP headers) is fine
                warnings.filterwarnings(
                    "ignore", message=".*input contained no data.*"
                )
                block = np.loadtxt(
                    lines, dtype=np.int64, comments="#", delimiter=delimiter,
                    usecols=(0, 1), ndmin=2,
                )
            if block.size:
                yield block[:, 0], block[:, 1]


def _merge_sorted_runs(
    runs: list[np.ndarray], block: int
) -> Iterator[np.ndarray]:
    """Globally sorted, deduplicated int64 blocks from sorted-unique runs.

    Vectorised k-way merge: refill a bounded buffer per run, emit everything
    up to the smallest "safe boundary" (the last loaded key of any run that
    still has unread data - every unread key of such a run is greater), and
    carry the remainder. Memory is ``O(len(runs) * block)``.
    """
    pos = [0] * len(runs)
    bufs: list[np.ndarray] = [np.empty(0, dtype=np.int64) for _ in runs]
    while True:
        for i, run in enumerate(runs):
            if bufs[i].size == 0 and pos[i] < run.shape[0]:
                take = min(block, run.shape[0] - pos[i])
                bufs[i] = np.asarray(run[pos[i] : pos[i] + take], dtype=np.int64)
                pos[i] += take
        active = [i for i in range(len(runs)) if bufs[i].size]
        if not active:
            return
        unread = [i for i in active if pos[i] < runs[i].shape[0]]
        if unread:
            bound = min(int(bufs[i][-1]) for i in unread)
        else:
            bound = max(int(bufs[i][-1]) for i in active)
        parts = []
        for i in active:
            cut = int(np.searchsorted(bufs[i], bound, side="right"))
            if cut:
                parts.append(bufs[i][:cut])
                bufs[i] = bufs[i][cut:]
        merged = parts[0] if len(parts) == 1 else np.concatenate(parts)
        out = np.unique(merged)  # runs are unique; cross-run dupes collapse here
        if out.size:
            yield out


def convert_edge_list(
    src_path: str | os.PathLike,
    out_path: str | os.PathLike,
    *,
    num_vertices: int | None = None,
    chunk_edges: int = 1 << 22,
    merge_block: int = 1 << 20,
    delimiter: str | None = None,
    tmp_dir: str | None = None,
) -> dict:
    """Two-pass, bounded-memory edge-list -> on-disk CSR conversion.

    Semantics match ``CSRGraph.from_edges(edges, num_vertices)`` exactly:
    self-loops dropped, duplicate edges (either direction) deduplicated,
    symmetric storage, each adjacency row sorted ascending - so
    ``ExternalCSRGraph(out_path)`` is bit-identical to the in-memory build.

    Returns a stats dict (``num_vertices``, ``num_edges``, ``input_edges``,
    ``runs``, ``file_bytes``).
    """
    src_path = os.fspath(src_path)
    out_path = os.fspath(out_path)
    chunk_edges = max(int(chunk_edges), 1)
    merge_block = max(int(merge_block), 1)

    # ---- pass 1a: canonicalize chunks, spill sorted-unique key runs
    input_edges = 0
    max_id = -1
    run_files: list[str] = []
    with tempfile.TemporaryDirectory(dir=tmp_dir) as td:
        for s, d in _iter_edge_chunks(src_path, chunk_edges, delimiter):
            input_edges += int(s.shape[0])
            keep = s != d  # no self loops
            s, d = s[keep], d[keep]
            if s.size == 0:
                continue
            cmin = min(int(s.min()), int(d.min()))
            cmax = max(int(s.max()), int(d.max()))
            if cmin < 0:
                raise ValueError(
                    f"{src_path!r}: negative vertex id {cmin} in edge list"
                )
            if cmax > int(_MAX_VERTEX_ID):
                raise ValueError(
                    f"{src_path!r}: vertex id {cmax} exceeds the int32 "
                    f"index range of the on-disk format"
                )
            max_id = max(max_id, cmax)
            lo = np.minimum(s, d)
            hi = np.maximum(s, d)
            key = np.unique((lo << np.int64(32)) | hi)
            run = os.path.join(td, f"run{len(run_files)}.i64")
            key.tofile(run)
            run_files.append(run)
            del lo, hi, key

        if num_vertices is None:
            n = max_id + 1
        else:
            n = int(num_vertices)
            if max_id >= n:
                raise ValueError(
                    f"{src_path!r}: vertex id {max_id} >= num_vertices={n}"
                )
        num_runs = len(run_files)

        # ---- pass 1b: merge runs -> deduped sorted edge file + degrees
        runs = [
            np.memmap(r, dtype=np.int64, mode="r") for r in run_files
        ]
        degrees = np.zeros(n, dtype=np.int64)
        dedup_path = os.path.join(td, "edges.sorted.i64")
        unique_edges = 0
        with open(dedup_path, "wb") as f:
            for block in _merge_sorted_runs(runs, merge_block):
                lo = (block >> np.int64(32)).astype(np.int64)
                hi = (block & np.int64(0xFFFFFFFF)).astype(np.int64)
                degrees += np.bincount(lo, minlength=n)
                degrees += np.bincount(hi, minlength=n)
                block.tofile(f)
                unique_edges += int(block.shape[0])
        del runs
        half = 2 * unique_edges

        # ---- pass 2: scatter both edge directions into the mapped indices
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(degrees, out=indptr[1:])
        indptr_off, indices_off, total = _file_layout(n, half)
        with open(out_path, "wb") as f:
            f.write(_pack_header(n, half))
            indptr.astype(_INDPTR_DTYPE).tofile(f)
            f.truncate(total)
        cursor = indptr[:-1].copy()
        if half:
            indices = np.memmap(
                out_path, dtype=_INDICES_DTYPE, mode="r+",
                offset=indices_off, shape=(half,),
            )
            dedup = np.memmap(dedup_path, dtype=np.int64, mode="r")
            for blo in range(0, unique_edges, merge_block):
                block = np.asarray(dedup[blo : blo + merge_block])
                lo = (block >> np.int64(32)).astype(np.int64)
                hi = (block & np.int64(0xFFFFFFFF)).astype(np.int64)
                # within a key-sorted block, every (u, v) contribution to a
                # row v (u < v) precedes every (v, w) contribution (the key
                # (u, v) sorts before (v, w)), so writing the hi side first,
                # then the lo side, fills each row ascending - the exact
                # per-row order CSRGraph.from_edges produces
                order = np.argsort(hi, kind="stable")
                indices[_grouped_positions(cursor, hi[order])] = lo[order].astype(
                    _INDICES_DTYPE
                )
                indices[_grouped_positions(cursor, lo)] = hi.astype(_INDICES_DTYPE)
            indices.flush()
            del indices, dedup
        if not np.array_equal(cursor, indptr[1:]):
            raise AssertionError(
                "internal error: adjacency rows not completely filled"
            )
    return {
        "num_vertices": int(n),
        "num_edges": int(unique_edges),
        "input_edges": int(input_edges),
        "runs": num_runs,
        "file_bytes": int(total),
    }


def _grouped_positions(cursor: np.ndarray, grp: np.ndarray) -> np.ndarray:
    """Write positions ``cursor[grp] + rank-within-group`` for a *sorted*
    group-id array, advancing ``cursor`` by each group's count."""
    m = grp.shape[0]
    if m == 0:
        return np.empty(0, dtype=np.int64)
    seg_starts = np.concatenate(([0], np.flatnonzero(np.diff(grp)) + 1))
    counts = np.diff(np.concatenate((seg_starts, [m])))
    offsets = np.arange(m, dtype=np.int64) - np.repeat(seg_starts, counts)
    pos = cursor[grp] + offsets
    cursor[grp[seg_starts]] += counts
    return pos


# ------------------------------------------------------------ spec sources
def validate_source(source: str) -> None:
    """Syntax-check a ``PartitionSpec.source`` string (no filesystem I/O).

    Grammar: ``rmat:<n>[:<avg_degree>]`` | ``dataset:<name>`` | a file path
    to an on-disk graph (``.bin`` external CSR or ``.npz`` CSRGraph dump).
    """
    if not isinstance(source, str) or not source:
        raise ValueError(f"source must be a non-empty string, got {source!r}")
    if source.startswith("rmat:"):
        fields = source.split(":")[1:]
        if not 1 <= len(fields) <= 2:
            raise ValueError(
                f"bad source {source!r}: expected rmat:<n>[:<avg_degree>]"
            )
        try:
            n = int(fields[0])
            deg = float(fields[1]) if len(fields) == 2 else 16.0
        except ValueError:
            raise ValueError(
                f"bad source {source!r}: expected rmat:<n>[:<avg_degree>]"
            ) from None
        if n < 1 or deg <= 0:
            raise ValueError(
                f"bad source {source!r}: n must be >= 1 and avg_degree > 0"
            )
        return
    if source.startswith("dataset:"):
        from repro.graph.generators import DATASETS

        name = source.split(":", 1)[1]
        if name not in DATASETS:
            raise ValueError(
                f"bad source {source!r}: unknown dataset {name!r} "
                f"(available: {', '.join(sorted(DATASETS))})"
            )
        return
    # anything else is a file path; colons are legal in POSIX paths, so no
    # scheme guessing - a missing file fails with a clear error at load time


def load_graph_source(source: str, *, seed: int = 0):
    """Resolve a spec ``source`` into a graph object.

    ``rmat:<n>[:<avg_degree>]`` generates a seeded R-MAT; ``dataset:<name>``
    loads a named benchmark dataset; anything else is a path - ``.npz`` loads
    a ``CSRGraph`` dump, everything else opens the file as a memory-mapped
    :class:`ExternalCSRGraph`.
    """
    validate_source(source)
    if source.startswith("rmat:"):
        from repro.graph.generators import rmat_graph

        fields = source.split(":")[1:]
        n = int(fields[0])
        deg = float(fields[1]) if len(fields) == 2 else 16.0
        return rmat_graph(n, avg_degree=deg, seed=seed)
    if source.startswith("dataset:"):
        from repro.graph.generators import load_dataset

        return load_dataset(source.split(":", 1)[1], seed=seed)
    return load_graph_file(source)


def load_graph_file(path: str):
    """Open an on-disk graph: ``.npz`` loads a ``CSRGraph`` dump resident,
    anything else is memory-mapped as an :class:`ExternalCSRGraph`."""
    if path.endswith(".npz"):
        return CSRGraph.load(path)
    return ExternalCSRGraph(path)
