"""Out-of-core graph substrate: partition from disk without materializing CSR.

The paper's premise is that "graphs that require distributed settings are
often too large to fit in the main memory of a single machine" (§I), yet a
fully resident :class:`~repro.graph.csr.CSRGraph` needs ``8(|V|+1) + 8|E|``
bytes before the first vertex streams. This module closes that gap with a
binary on-disk CSR format plus two consumers:

* :func:`convert_edge_list` - a bounded-memory two-pass converter that turns
  a text (SNAP-style ``.txt``/``.csv``) or binary (``.npy``) edge list into
  the on-disk format. Pass 1 canonicalizes edges in chunks (drop self-loops,
  ``(lo, hi)`` ordering), sorts each chunk and spills it as a run; the
  chunk sort/dedupe work runs on a :class:`~repro.core.executor.ShardPool`
  so conversion scales with cores, and a vectorised k-way run merge dedupes
  globally while counting degrees. Pass 2 re-streams the deduped sorted
  edges, scatters both directions into a row-sorted adjacency, and (for the
  default version-2 output) block-compresses the rows in parallel. Peak host
  memory is ``O(|V|)`` plus a bounded number of in-flight chunks - the edge
  set is never resident. Rows come out sorted by neighbour id, so the
  decoded result is *byte-identical* to ``CSRGraph.from_edges`` on the same
  input (pinned in ``tests/test_outofcore.py``).
* :class:`ExternalCSRGraph` - memory-maps the file and exposes the same
  ``num_vertices`` / ``neighbors`` / ``degrees`` surface ``CSRGraph`` does,
  so ``vertex_stream``, ``ShardedStream.superstep_batches`` and the chunked
  ``StreamEngine`` loops consume it unchanged. Version-1 files map the raw
  int32 ``indices`` region directly; version-2 files expose
  :class:`_CompressedIndices`, a lazy array proxy that decodes exactly the
  rows an access touches (one vectorised codec call per batch) and yields
  the same int32 values position for position.

File layout (little-endian); v1 stores raw neighbours, v2 delta-varint
blocks (see :mod:`repro.graph.compress`)::

    [ 0:8 ]   magic  b"XCSRGRPH"
    [ 8:12]   uint32 format version (1 or 2)
    [12:16]   uint32 flags (v2: bit 0 = 64-bit byte offsets)
    [16:24]   int64  num_vertices                  (n)
    [24:32]   int64  len(indices) == 2|E|          (h)
    [32:40]   int64  v2: compressed data bytes     (d)   (v1: 0)
    [40:44]   uint32 v2: block capacity                  (v1: 0)
    [44:64]   reserved (zeros)
    [64:64+8(n+1)]          indptr   int64[n+1]
    v1: [.. +4h]            indices  int32[h]
    v2: [.. +4(n+1) or 8(n+1)]  byte_off uint32[n+1] (int64 when bit 0 set)
        [.. +d]             data     uint8[d]  (delta-varint blocks)

:func:`load_graph_source` resolves the ``PartitionSpec.source`` grammar
(``rmat:*`` / ``dataset:*`` / a path) into a graph object;
:func:`validate_source` is its construction-time syntax check.
"""
from __future__ import annotations

import itertools
import os
import struct
import tempfile
import threading
import time
import warnings
from collections import deque
from typing import Iterator

import numpy as np

from repro.graph.compress import (
    DEFAULT_BLOCK_CAP,
    decode_adjacency,
    encode_adjacency,
)
from repro.graph.csr import CSRGraph

__all__ = [
    "MAGIC",
    "FORMAT_VERSION",
    "FORMAT_VERSION_V2",
    "SUPPORTED_VERSIONS",
    "HEADER_BYTES",
    "DEFAULT_BLOCK_CAP",
    "ExternalCSRGraph",
    "write_external_csr",
    "convert_edge_list",
    "convert_csr",
    "raw_file_bytes",
    "load_graph_file",
    "load_graph_source",
    "validate_source",
]

MAGIC = b"XCSRGRPH"
FORMAT_VERSION = 1  # raw int32 neighbours
FORMAT_VERSION_V2 = 2  # delta-varint neighbour blocks + byte-offset index
SUPPORTED_VERSIONS = (1, 2)
HEADER_BYTES = 64
_HEADER_STRUCT = "<8sII qq q I"
_FLAG_WIDE_OFFSETS = 1  # v2: byte_off stored as int64 (data region >= 4 GiB)
_INDPTR_DTYPE = np.dtype("<i8")
_INDICES_DTYPE = np.dtype("<i4")
_OFF32_DTYPE = np.dtype("<u4")
_OFF64_DTYPE = np.dtype("<i8")
# keys pack (lo, hi) into one int64: ids must fit the int32 indices anyway
_MAX_VERTEX_ID = np.int64(2**31 - 1)
# target decoded values per codec call when chunking whole-graph scans
_DECODE_CHUNK_VALUES = 1 << 21


def _pack_header(
    num_vertices: int,
    half_edges: int,
    *,
    version: int = FORMAT_VERSION,
    flags: int = 0,
    data_bytes: int = 0,
    block_cap: int = 0,
) -> bytes:
    head = struct.pack(
        _HEADER_STRUCT, MAGIC, int(version), int(flags), int(num_vertices),
        int(half_edges), int(data_bytes), int(block_cap),
    )
    return head + b"\0" * (HEADER_BYTES - len(head))


def _file_layout(num_vertices: int, half_edges: int) -> tuple[int, int, int]:
    """v1 layout: (indptr_offset, indices_offset, total_file_bytes)."""
    indptr_off = HEADER_BYTES
    indices_off = indptr_off + _INDPTR_DTYPE.itemsize * (num_vertices + 1)
    total = indices_off + _INDICES_DTYPE.itemsize * half_edges
    return indptr_off, indices_off, total


def _file_layout_v2(
    num_vertices: int, data_bytes: int, wide: bool
) -> tuple[int, int, int, int]:
    """v2 layout: (indptr_off, byte_off_off, data_off, total_file_bytes)."""
    indptr_off = HEADER_BYTES
    byte_off_off = indptr_off + _INDPTR_DTYPE.itemsize * (num_vertices + 1)
    itemsize = _OFF64_DTYPE.itemsize if wide else _OFF32_DTYPE.itemsize
    data_off = byte_off_off + itemsize * (num_vertices + 1)
    return indptr_off, byte_off_off, data_off, data_off + data_bytes


def raw_file_bytes(num_vertices: int, half_edges: int) -> int:
    """Size a v1 (raw int32) file of this shape would occupy - the
    denominator of every compression-ratio report."""
    return _file_layout(num_vertices, half_edges)[2]


# ----------------------------------------------------- compressed adjacency
class _CompressedIndices:
    """Lazy ``indices`` array proxy over a v2 compressed data region.

    Quacks like the int32[h] neighbour array (``shape`` / ``len`` /
    ``__getitem__`` with ints, slices, index arrays and masks /
    ``__array__``) but holds no decoded data: every access maps the flat
    positions it touches to adjacency rows via ``searchsorted(indptr)``,
    gathers those rows' byte extents from the mmapped block index, and runs
    **one** vectorised :func:`~repro.graph.compress.decode_adjacency` call.
    Block restarts inside the codec mean a row is always decodable on its
    own - no neighbouring state needed.

    Decoded values are bounds-checked against ``num_vertices`` so a corrupt
    data region raises instead of silently mis-partitioning. Cumulative
    decode wall time / call count feed the ``decode_wall_s`` telemetry.
    """

    dtype = _INDICES_DTYPE
    ndim = 1

    def __init__(self, graph: "ExternalCSRGraph"):
        self._g = graph
        self.decode_seconds = 0.0
        self.decode_calls = 0
        self._lock = threading.Lock()

    @property
    def shape(self) -> tuple[int]:
        return (self._g._half,)

    @property
    def nbytes(self) -> int:
        """Logical (decoded) size, mirroring the raw-array surface."""
        return self._g._half * _INDICES_DTYPE.itemsize

    def __len__(self) -> int:
        return self._g._half

    # ------------------------------------------------------------- decoding
    def _checked(self, vals: np.ndarray) -> np.ndarray:
        if vals.size and (
            int(vals.min()) < 0 or int(vals.max()) >= self._g._n
        ):
            raise ValueError(
                f"{self._g.path!r}: decoded neighbour id out of range "
                f"(corrupt compressed data)"
            )
        return vals.astype(_INDICES_DTYPE)

    def _decode_range(self, r0: int, r1: int) -> np.ndarray:
        """Decode rows [r0, r1) into one flat int32 array."""
        g = self._g
        if r1 <= r0:
            return np.empty(0, dtype=_INDICES_DTYPE)
        t0 = time.perf_counter()
        b0, b1 = int(g.byte_off[r0]), int(g.byte_off[r1])
        buf = np.asarray(g.data[b0:b1])
        degs = np.asarray(g.indptr[r0 + 1 : r1 + 1]) - np.asarray(
            g.indptr[r0:r1]
        )
        off = np.asarray(g.byte_off[r0 : r1 + 1], dtype=np.int64) - b0
        vals = self._checked(
            decode_adjacency(buf, degs, g.block_cap, row_byte_off=off)
        )
        self._account(time.perf_counter() - t0)
        return vals

    def _decode_row_set(
        self, rows: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Decode a sorted-unique row set; returns ``(flat, value_starts)``
        where ``flat[value_starts[i] : value_starts[i] + deg(rows[i])]`` is
        row ``rows[i]``. One codec call regardless of row count."""
        g = self._g
        t0 = time.perf_counter()
        degs = np.asarray(g.indptr[rows + 1]) - np.asarray(g.indptr[rows])
        bo_lo = np.asarray(g.byte_off[rows], dtype=np.int64)
        bo_hi = np.asarray(g.byte_off[rows + 1], dtype=np.int64)
        # slice contiguous runs of rows in one go instead of per row
        breaks = np.flatnonzero(np.diff(rows) != 1) + 1
        run_lo = np.concatenate(([0], breaks))
        run_hi = np.concatenate((breaks, [rows.shape[0]]))
        bufs = [
            g.data[bo_lo[a] : bo_hi[b - 1]] for a, b in zip(run_lo, run_hi)
        ]
        buf = np.concatenate(bufs) if len(bufs) > 1 else np.asarray(bufs[0])
        row_bytes = bo_hi - bo_lo
        syn_off = np.zeros(rows.shape[0] + 1, dtype=np.int64)
        np.cumsum(row_bytes, out=syn_off[1:])
        vals = self._checked(
            decode_adjacency(buf, degs, g.block_cap, row_byte_off=syn_off)
        )
        starts = np.cumsum(degs) - degs
        self._account(time.perf_counter() - t0)
        return vals, starts

    def _account(self, dt: float) -> None:
        with self._lock:
            self.decode_seconds += dt
            self.decode_calls += 1

    # ------------------------------------------------------------- indexing
    def _row_of(self, pos: np.ndarray) -> np.ndarray:
        return np.searchsorted(self._g.indptr, pos, side="right") - 1

    def _gather(self, pos: np.ndarray) -> np.ndarray:
        g = self._g
        if pos.size == 0:
            return np.empty(0, dtype=_INDICES_DTYPE)
        lo, hi = int(pos.min()), int(pos.max())
        if lo < 0 or hi >= g._half:
            raise IndexError(
                f"index out of bounds for compressed indices of length "
                f"{g._half}"
            )
        rows = self._row_of(pos)
        rows_u, inv = np.unique(rows, return_inverse=True)
        flat, starts = self._decode_row_set(rows_u)
        row_base = np.asarray(g.indptr[rows], dtype=np.int64)
        return flat[starts[inv] + (pos - row_base)]

    def __getitem__(self, key):
        g = self._g
        if isinstance(key, (int, np.integer)):
            pos = int(key)
            if pos < 0:
                pos += g._half
            if not 0 <= pos < g._half:
                raise IndexError(
                    f"index {key} out of bounds for length {g._half}"
                )
            r = int(self._row_of(np.asarray([pos]))[0])
            row = self._decode_range(r, r + 1)
            return row[pos - int(g.indptr[r])]
        if isinstance(key, slice):
            start, stop, step = key.indices(g._half)
            if step != 1:
                return self._gather(
                    np.arange(start, stop, step, dtype=np.int64)
                )
            if stop <= start:
                return np.empty(0, dtype=_INDICES_DTYPE)
            r0 = int(np.searchsorted(g.indptr, start, side="right")) - 1
            r1 = max(
                int(np.searchsorted(g.indptr, stop, side="left")), r0 + 1
            )
            flat = self._decode_range(r0, r1)
            base = int(g.indptr[r0])
            return flat[start - base : stop - base]
        key = np.asarray(key)
        if key.dtype == bool:
            key = np.flatnonzero(key)
        return self._gather(key.astype(np.int64, copy=False))

    # --------------------------------------------------------- materializing
    def __array__(self, dtype=None, copy=None):
        g = self._g
        out = np.empty(g._half, dtype=_INDICES_DTYPE)
        r0 = 0
        while r0 < g._n:
            r1 = max(
                int(
                    np.searchsorted(
                        g.indptr, int(g.indptr[r0]) + _DECODE_CHUNK_VALUES
                    )
                ),
                r0 + 1,
            )
            r1 = min(r1, g._n)
            out[int(g.indptr[r0]) : int(g.indptr[r1])] = self._decode_range(
                r0, r1
            )
            r0 = r1
        return out if dtype is None else out.astype(dtype, copy=False)

    def astype(self, dtype, copy: bool = True):
        return np.asarray(self).astype(dtype, copy=False)

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"_CompressedIndices(h={self._g._half}, "
            f"data_bytes={self._g._data_bytes})"
        )


# ---------------------------------------------------------------- the graph
class ExternalCSRGraph:
    """A CSR graph memory-mapped from the on-disk binary format.

    Exposes the ``CSRGraph`` read surface (``indptr`` / ``indices`` /
    ``num_vertices`` / ``num_edges`` / ``degrees`` / ``neighbors`` /
    ``degree`` / ``iter_adjacency``) over ``np.memmap`` arrays, so every
    partitioner, stream order, and engine chunk loop works unchanged - a
    chunk's neighbour batch is a fancy-indexed *copy* of the mapped pages it
    touches, never the whole graph. Version-2 files interpose
    :class:`_CompressedIndices`, which decodes exactly the rows an access
    needs; decoded values are identical to the v1/resident arrays, so
    assignments stay bit-identical. Only ``O(|V|)`` bookkeeping is ever
    resident.
    """

    backing = "mapped"

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        try:
            size = os.path.getsize(self.path)
        except OSError as e:
            raise ValueError(f"cannot open external graph {self.path!r}: {e}") from e
        if size < HEADER_BYTES:
            raise ValueError(
                f"{self.path!r} is not an external CSR graph: file is "
                f"{size} bytes, smaller than the {HEADER_BYTES}-byte header"
            )
        with open(self.path, "rb") as f:
            head = f.read(HEADER_BYTES)
        magic, version, flags, n, h, data_bytes, block_cap = struct.unpack(
            _HEADER_STRUCT, head[: struct.calcsize(_HEADER_STRUCT)]
        )
        if magic != MAGIC:
            raise ValueError(
                f"{self.path!r} is not an external CSR graph "
                f"(bad magic {magic!r}; expected {MAGIC!r})"
            )
        if version not in SUPPORTED_VERSIONS:
            raise ValueError(
                f"{self.path!r}: unsupported format version {version} "
                f"(this build reads versions "
                f"{', '.join(map(str, SUPPORTED_VERSIONS))})"
            )
        if n < 0 or h < 0 or h % 2:
            raise ValueError(
                f"{self.path!r}: corrupt header (num_vertices={n}, "
                f"len(indices)={h})"
            )
        self._n = int(n)
        self._half = int(h)
        self.format_version = int(version)
        self.block_cap = int(block_cap)
        self._data_bytes = int(data_bytes)
        if version == FORMAT_VERSION:
            indptr_off, indices_off, expected = _file_layout(n, h)
            if size != expected:
                raise ValueError(
                    f"{self.path!r}: truncated or corrupt - file is {size} "
                    f"bytes but the header declares {expected} "
                    f"(num_vertices={n}, len(indices)={h})"
                )
            self._total_bytes = expected
            self.indptr = np.memmap(
                self.path, dtype=_INDPTR_DTYPE, mode="r", offset=indptr_off,
                shape=(self._n + 1,),
            )
            self.byte_off = None
            self.data = None
            self.indices = np.memmap(
                self.path, dtype=_INDICES_DTYPE, mode="r", offset=indices_off,
                shape=(self._half,),
            )
        else:
            if data_bytes < 0 or block_cap < 1:
                raise ValueError(
                    f"{self.path!r}: corrupt v2 header (data_bytes="
                    f"{data_bytes}, block_cap={block_cap})"
                )
            wide = bool(flags & _FLAG_WIDE_OFFSETS)
            indptr_off, byte_off_off, data_off, expected = _file_layout_v2(
                n, data_bytes, wide
            )
            if size != expected:
                raise ValueError(
                    f"{self.path!r}: truncated or corrupt - file is {size} "
                    f"bytes but the header declares {expected} "
                    f"(num_vertices={n}, data_bytes={data_bytes})"
                )
            self._total_bytes = expected
            self.indptr = np.memmap(
                self.path, dtype=_INDPTR_DTYPE, mode="r", offset=indptr_off,
                shape=(self._n + 1,),
            )
            self.byte_off = np.memmap(
                self.path,
                dtype=_OFF64_DTYPE if wide else _OFF32_DTYPE,
                mode="r",
                offset=byte_off_off,
                shape=(self._n + 1,),
            )
            self.data = np.memmap(
                self.path, dtype=np.uint8, mode="r", offset=data_off,
                shape=(self._data_bytes,),
            )
            if self._n and (
                int(self.byte_off[0]) != 0
                or int(self.byte_off[-1]) != self._data_bytes
            ):
                raise ValueError(
                    f"{self.path!r}: corrupt block index (byte_off[0]="
                    f"{int(self.byte_off[0])}, byte_off[-1]="
                    f"{int(self.byte_off[-1])}, data_bytes={self._data_bytes})"
                )
            self.indices = _CompressedIndices(self)
        if self._n and (
            int(self.indptr[0]) != 0 or int(self.indptr[-1]) != self._half
        ):
            raise ValueError(
                f"{self.path!r}: corrupt indptr (indptr[0]={int(self.indptr[0])}, "
                f"indptr[-1]={int(self.indptr[-1])}, len(indices)={self._half})"
            )
        self._degrees: np.ndarray | None = None

    # ----------------------------------------------------- CSRGraph surface
    @property
    def num_vertices(self) -> int:
        return self._n

    @property
    def num_edges(self) -> int:
        return self._half // 2

    @property
    def degrees(self) -> np.ndarray:
        # cached: the engines ask repeatedly and a diff over the mapped
        # indptr is the only O(|V|) array this graph ever materializes
        if self._degrees is None:
            self._degrees = np.diff(self.indptr)
        return self._degrees

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    def iter_adjacency(self, order=None) -> Iterator[tuple[int, np.ndarray]]:
        ids = range(self._n) if order is None else order
        for v in ids:
            yield int(v), self.neighbors(int(v))

    def edges_array(self) -> np.ndarray:
        """(|E|, 2) array with each undirected edge listed once (u < v).

        Same contract as ``CSRGraph.edges_array`` - the vertex-cut edge
        partitioners (hdrf/ginger) consume it. Note the *result* is O(|E|)
        resident by definition; the scan over the mapped file is chunked so
        no symmetric 2|E| intermediate is ever materialized.
        """
        out = np.empty((self.num_edges, 2), dtype=np.int64)
        filled = 0
        chunk = 1 << 20
        indptr = self.indptr
        for lo in range(0, self._n, chunk):
            hi = min(lo + chunk, self._n)
            degs = np.asarray(indptr[lo + 1 : hi + 1]) - np.asarray(indptr[lo:hi])
            src = np.repeat(np.arange(lo, hi, dtype=np.int64), degs)
            dst = np.asarray(
                self.indices[indptr[lo] : indptr[hi]], dtype=np.int64
            )
            mask = src < dst
            m = int(mask.sum())
            out[filled : filled + m, 0] = src[mask]
            out[filled : filled + m, 1] = dst[mask]
            filled += m
        assert filled == out.shape[0]
        return out

    def subgraph_edge_count(self, mask: np.ndarray) -> int:
        """Edges with both endpoints inside ``mask`` (bool[|V|]), chunked
        over the mapped adjacency like ``CSRGraph.subgraph_edge_count``."""
        total = 0
        chunk = 1 << 20
        indptr = self.indptr
        for lo in range(0, self._n, chunk):
            hi = min(lo + chunk, self._n)
            degs = np.asarray(indptr[lo + 1 : hi + 1]) - np.asarray(indptr[lo:hi])
            src = np.repeat(np.arange(lo, hi, dtype=np.int64), degs)
            dst = np.asarray(self.indices[indptr[lo] : indptr[hi]])
            total += int((mask[src] & mask[dst]).sum())
        return total // 2

    # --------------------------------------------------------------- memory
    @property
    def nbytes_mapped(self) -> int:
        """Bytes of graph data reachable through the mapping (the file)."""
        return self._total_bytes

    @property
    def nbytes_resident(self) -> int:
        """Bytes of graph data held in ordinary host arrays (the degree
        cache, once computed) - what an OOM accountant should charge."""
        return 0 if self._degrees is None else int(self._degrees.nbytes)

    @property
    def nbytes_compressed(self) -> int:
        """Bytes of the compressed adjacency representation (block index +
        varint data) for v2 files; 0 for raw v1 files."""
        if self.format_version != FORMAT_VERSION_V2:
            return 0
        return int(self.byte_off.nbytes) + self._data_bytes

    @property
    def decode_wall_s(self) -> float:
        """Cumulative adjacency-decode wall time (0.0 for raw v1 files)."""
        return float(getattr(self.indices, "decode_seconds", 0.0))

    # -------------------------------------------------------------- escape
    def to_csr(self) -> CSRGraph:
        """Materialize a fully resident ``CSRGraph`` (for small graphs)."""
        return CSRGraph(
            indptr=np.asarray(self.indptr).copy(),
            indices=np.asarray(self.indices).copy(),
        )

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"ExternalCSRGraph(|V|={self.num_vertices}, |E|={self.num_edges}, "
            f"v{self.format_version}, path={self.path!r})"
        )


# ----------------------------------------------------------------- writers
def _iter_row_chunks(
    indptr: np.ndarray, target_values: int = _DECODE_CHUNK_VALUES
) -> Iterator[tuple[int, int]]:
    """Split rows into ``(r0, r1)`` ranges of ~``target_values`` adjacency
    entries each (always whole rows, always >= 1 row of progress)."""
    n = int(indptr.shape[0]) - 1
    r0 = 0
    while r0 < n:
        r1 = int(np.searchsorted(indptr, int(indptr[r0]) + target_values))
        r1 = min(max(r1, r0 + 1), n)
        yield r0, r1
        r0 = r1


def write_external_csr(
    path: str | os.PathLike,
    indptr: np.ndarray,
    indices: np.ndarray,
    *,
    version: int = FORMAT_VERSION,
    block_cap: int = DEFAULT_BLOCK_CAP,
) -> None:
    """Write CSR arrays in the on-disk format.

    ``version=1`` (default) writes the raw int32 layout; ``version=2``
    delta-varint compresses the rows (requires each row sorted strictly
    ascending, the ``CSRGraph.from_edges`` invariant).
    """
    indptr = np.ascontiguousarray(indptr, dtype=_INDPTR_DTYPE)
    indices = np.ascontiguousarray(indices, dtype=_INDICES_DTYPE)
    n = int(indptr.shape[0]) - 1
    if n < 0:
        raise ValueError("indptr must have at least one entry")
    if version == FORMAT_VERSION:
        with open(path, "wb") as f:
            f.write(_pack_header(n, int(indices.shape[0])))
            indptr.tofile(f)
            indices.tofile(f)
        return
    if version != FORMAT_VERSION_V2:
        raise ValueError(
            f"unsupported format version {version} (can write "
            f"{', '.join(map(str, SUPPORTED_VERSIONS))})"
        )
    pieces: list[np.ndarray] = []
    row_bytes = np.zeros(max(n, 1), dtype=np.int64)[:n]
    for r0, r1 in _iter_row_chunks(indptr):
        flat = np.asarray(
            indices[int(indptr[r0]) : int(indptr[r1])], dtype=np.int64
        )
        degs = indptr[r0 + 1 : r1 + 1] - indptr[r0:r1]
        data, rb = encode_adjacency(flat, degs, block_cap)
        pieces.append(data)
        row_bytes[r0:r1] = rb
    data_bytes = int(row_bytes.sum())
    wide = data_bytes > 0xFFFFFFFF
    byte_off = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(row_bytes, out=byte_off[1:])
    with open(path, "wb") as f:
        f.write(
            _pack_header(
                n,
                int(indices.shape[0]),
                version=FORMAT_VERSION_V2,
                flags=_FLAG_WIDE_OFFSETS if wide else 0,
                data_bytes=data_bytes,
                block_cap=block_cap,
            )
        )
        indptr.tofile(f)
        byte_off.astype(_OFF64_DTYPE if wide else _OFF32_DTYPE).tofile(f)
        for piece in pieces:
            piece.tofile(f)


def convert_csr(
    graph: CSRGraph,
    path: str | os.PathLike,
    *,
    format_version: int = FORMAT_VERSION_V2,
    block_cap: int = DEFAULT_BLOCK_CAP,
) -> None:
    """Dump an in-memory ``CSRGraph`` into the on-disk format (compressed
    v2 by default)."""
    write_external_csr(
        path, graph.indptr, graph.indices,
        version=format_version, block_cap=block_cap,
    )


# --------------------------------------------------------------- converter
def _iter_edge_chunks(
    path: str, chunk_edges: int, delimiter: str | None
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield ``(src, dst)`` int64 chunks from a text or ``.npy`` edge list.

    Text: ``#``-comment lines skipped, first two whitespace- (or
    ``delimiter``-) separated columns used, extra columns (weights,
    timestamps) ignored. ``.npy``: the array is memory-mapped and sliced.
    """
    if path.endswith(".npy"):
        arr = np.load(path, mmap_mode="r")
        if arr.ndim != 2 or arr.shape[1] < 2:
            raise ValueError(
                f"{path!r}: expected an (m, >=2) edge array, got shape "
                f"{arr.shape}"
            )
        for lo in range(0, arr.shape[0], chunk_edges):
            block = np.asarray(arr[lo : lo + chunk_edges, :2], dtype=np.int64)
            yield block[:, 0], block[:, 1]
        return
    if delimiter is None and path.endswith(".csv"):
        delimiter = ","
    with open(path, "rt") as f:
        while True:
            lines = list(itertools.islice(f, chunk_edges))
            if not lines:
                return
            with warnings.catch_warnings():
                # a chunk of only comment/blank lines (SNAP headers) is fine
                warnings.filterwarnings(
                    "ignore", message=".*input contained no data.*"
                )
                block = np.loadtxt(
                    lines, dtype=np.int64, comments="#", delimiter=delimiter,
                    usecols=(0, 1), ndmin=2,
                )
            if block.size:
                yield block[:, 0], block[:, 1]


def _merge_sorted_runs(
    runs: list[np.ndarray], block: int
) -> Iterator[np.ndarray]:
    """Globally sorted, deduplicated int64 blocks from sorted-unique runs.

    Vectorised k-way merge: refill a bounded buffer per run, emit everything
    up to the smallest "safe boundary" (the last loaded key of any run that
    still has unread data - every unread key of such a run is greater), and
    carry the remainder. Memory is ``O(len(runs) * block)``.
    """
    pos = [0] * len(runs)
    bufs: list[np.ndarray] = [np.empty(0, dtype=np.int64) for _ in runs]
    while True:
        for i, run in enumerate(runs):
            if bufs[i].size == 0 and pos[i] < run.shape[0]:
                take = min(block, run.shape[0] - pos[i])
                bufs[i] = np.asarray(run[pos[i] : pos[i] + take], dtype=np.int64)
                pos[i] += take
        active = [i for i in range(len(runs)) if bufs[i].size]
        if not active:
            return
        unread = [i for i in active if pos[i] < runs[i].shape[0]]
        if unread:
            bound = min(int(bufs[i][-1]) for i in unread)
        else:
            bound = max(int(bufs[i][-1]) for i in active)
        parts = []
        for i in active:
            cut = int(np.searchsorted(bufs[i], bound, side="right"))
            if cut:
                parts.append(bufs[i][:cut])
                bufs[i] = bufs[i][cut:]
        merged = parts[0] if len(parts) == 1 else np.concatenate(parts)
        out = np.unique(merged)  # runs are unique; cross-run dupes collapse here
        if out.size:
            yield out


def _spill_run(
    s: np.ndarray, d: np.ndarray, run_path: str, src_path: str
) -> tuple[int, int]:
    """Canonicalize + sort + dedupe one edge chunk and spill it as a run.

    Pure function of its chunk (runs on pool workers): drops self-loops,
    validates the id range, packs ``(lo, hi)`` keys, writes the sorted
    unique keys to ``run_path``. Returns ``(keys_written, max_id)``.
    """
    keep = s != d  # no self loops
    s, d = s[keep], d[keep]
    if s.size == 0:
        return 0, -1
    cmin = min(int(s.min()), int(d.min()))
    cmax = max(int(s.max()), int(d.max()))
    if cmin < 0:
        raise ValueError(
            f"{src_path!r}: negative vertex id {cmin} in edge list"
        )
    if cmax > int(_MAX_VERTEX_ID):
        raise ValueError(
            f"{src_path!r}: vertex id {cmax} exceeds the int32 "
            f"index range of the on-disk format"
        )
    lo = np.minimum(s, d)
    hi = np.maximum(s, d)
    key = np.unique((lo << np.int64(32)) | hi)
    key.tofile(run_path)
    return int(key.shape[0]), cmax


def _encode_row_range(
    raw: np.ndarray, indptr: np.ndarray, r0: int, r1: int, block_cap: int
) -> tuple[np.ndarray, np.ndarray]:
    """Compress rows [r0, r1) of the scattered raw adjacency (pool task)."""
    flat = np.asarray(raw[int(indptr[r0]) : int(indptr[r1])], dtype=np.int64)
    return encode_adjacency(flat, indptr[r0 + 1 : r1 + 1] - indptr[r0:r1],
                            block_cap)


def convert_edge_list(
    src_path: str | os.PathLike,
    out_path: str | os.PathLike,
    *,
    num_vertices: int | None = None,
    chunk_edges: int = 1 << 22,
    merge_block: int = 1 << 20,
    delimiter: str | None = None,
    tmp_dir: str | None = None,
    format_version: int = FORMAT_VERSION_V2,
    block_cap: int = DEFAULT_BLOCK_CAP,
    max_workers: int = 0,
) -> dict:
    """Two-pass, bounded-memory edge-list -> on-disk CSR conversion.

    Semantics match ``CSRGraph.from_edges(edges, num_vertices)`` exactly:
    self-loops dropped, duplicate edges (either direction) deduplicated,
    symmetric storage, each adjacency row sorted ascending - so
    ``ExternalCSRGraph(out_path)`` decodes bit-identical to the in-memory
    build. The per-chunk sort/dedupe of pass 1 and the per-row-range block
    compression of pass 2 run on a ``ShardPool`` (``max_workers=0`` = one
    per core, ``1`` = fully sequential); a bounded in-flight window keeps
    memory at O(workers * chunk). All scratch files live in a temporary
    directory that is removed even when conversion fails, and a partially
    written ``out_path`` is unlinked on error.

    Returns a stats dict (``num_vertices``, ``num_edges``, ``input_edges``,
    ``runs``, ``file_bytes``, ``raw_bytes``, ``compression_ratio``,
    ``format_version``, ``workers``).
    """
    from repro.core.executor import ShardPool

    src_path = os.fspath(src_path)
    out_path = os.fspath(out_path)
    chunk_edges = max(int(chunk_edges), 1)
    merge_block = max(int(merge_block), 1)
    if format_version not in SUPPORTED_VERSIONS:
        raise ValueError(
            f"unsupported format version {format_version} (can write "
            f"{', '.join(map(str, SUPPORTED_VERSIONS))})"
        )
    pool = ShardPool(max_workers, 1 << 16)
    window = pool.workers + 2  # bounded in-flight chunks
    wrote_out = False
    try:
        with tempfile.TemporaryDirectory(dir=tmp_dir) as td:
            # ---- pass 1a: canonicalize chunks, spill sorted-unique runs
            # (chunk reads stay sequential - the file is one stream - but
            # sort/dedupe/spill overlap across the in-flight window)
            input_edges = 0
            max_id = -1
            run_files: list[str] = []
            pending: deque = deque()  # (future, run_path) in chunk order

            def _harvest() -> None:
                nonlocal max_id
                fut, run_path = pending.popleft()
                written, cmax = fut.result()
                max_id = max(max_id, cmax)
                if written:
                    run_files.append(run_path)

            try:
                for ci, (s, d) in enumerate(
                    _iter_edge_chunks(src_path, chunk_edges, delimiter)
                ):
                    input_edges += int(s.shape[0])
                    run = os.path.join(td, f"run{ci}.i64")
                    pending.append(
                        (pool.submit(_spill_run, s, d, run, src_path), run)
                    )
                    if len(pending) >= window:
                        _harvest()
                while pending:
                    _harvest()
            finally:
                # a failed chunk must not leave workers writing into td
                # while TemporaryDirectory tears it down
                while pending:
                    try:
                        pending.popleft()[0].result()
                    except BaseException:
                        pass

            if num_vertices is None:
                n = max_id + 1
            else:
                n = int(num_vertices)
                if max_id >= n:
                    raise ValueError(
                        f"{src_path!r}: vertex id {max_id} >= num_vertices={n}"
                    )
            num_runs = len(run_files)

            # ---- pass 1b: merge runs -> deduped sorted edge file + degrees
            runs = [np.memmap(r, dtype=np.int64, mode="r") for r in run_files]
            degrees = np.zeros(n, dtype=np.int64)
            dedup_path = os.path.join(td, "edges.sorted.i64")
            unique_edges = 0
            try:
                with open(dedup_path, "wb") as f:
                    for block in _merge_sorted_runs(runs, merge_block):
                        lo = (block >> np.int64(32)).astype(np.int64)
                        hi = (block & np.int64(0xFFFFFFFF)).astype(np.int64)
                        degrees += np.bincount(lo, minlength=n)
                        degrees += np.bincount(hi, minlength=n)
                        block.tofile(f)
                        unique_edges += int(block.shape[0])
            finally:
                del runs  # release run memmaps before td teardown
            half = 2 * unique_edges

            # ---- pass 2: scatter both edge directions into a row-sorted
            # adjacency; v1 writes it straight into out_path, v2 scatters
            # into scratch and block-compresses the rows in parallel
            indptr = np.zeros(n + 1, dtype=np.int64)
            np.cumsum(degrees, out=indptr[1:])
            if format_version == FORMAT_VERSION:
                indptr_off, indices_off, total = _file_layout(n, half)
                wrote_out = True
                with open(out_path, "wb") as f:
                    f.write(_pack_header(n, half))
                    indptr.astype(_INDPTR_DTYPE).tofile(f)
                    f.truncate(total)
                _scatter_adjacency(
                    out_path, indices_off, dedup_path, indptr,
                    unique_edges, merge_block,
                )
                data_bytes = 0
            else:
                raw_path = os.path.join(td, "raw.i32")
                with open(raw_path, "wb") as f:
                    f.truncate(max(_INDICES_DTYPE.itemsize * half, 1))
                _scatter_adjacency(
                    raw_path, 0, dedup_path, indptr, unique_edges, merge_block
                )
                # flag before the call: a failure during final assembly must
                # still unlink the partially written out_path
                wrote_out = True
                total, data_bytes = _compress_scattered(
                    raw_path, out_path, indptr, half, block_cap, pool, window,
                )
    except BaseException:
        if wrote_out and os.path.exists(out_path):
            try:
                os.unlink(out_path)  # no partial graph files left behind
            except OSError:
                pass
        raise
    finally:
        pool.shutdown()
    raw_bytes = _file_layout(n, half)[2]
    return {
        "num_vertices": int(n),
        "num_edges": int(unique_edges),
        "input_edges": int(input_edges),
        "runs": num_runs,
        "file_bytes": int(total),
        "raw_bytes": int(raw_bytes),
        "data_bytes": int(data_bytes),
        "compression_ratio": round(raw_bytes / total, 4) if total else 0.0,
        "format_version": int(format_version),
        "workers": pool.workers,
    }


def _scatter_adjacency(
    path: str,
    offset: int,
    dedup_path: str,
    indptr: np.ndarray,
    unique_edges: int,
    merge_block: int,
) -> None:
    """Scatter both directions of the deduped sorted edge stream into the
    int32 adjacency region at ``path[offset:]``, each row ascending."""
    n = indptr.shape[0] - 1
    half = 2 * unique_edges
    cursor = indptr[:-1].copy()
    if half:
        indices = np.memmap(
            path, dtype=_INDICES_DTYPE, mode="r+", offset=offset,
            shape=(half,),
        )
        dedup = np.memmap(dedup_path, dtype=np.int64, mode="r")
        try:
            for blo in range(0, unique_edges, merge_block):
                block = np.asarray(dedup[blo : blo + merge_block])
                lo = (block >> np.int64(32)).astype(np.int64)
                hi = (block & np.int64(0xFFFFFFFF)).astype(np.int64)
                # within a key-sorted block, every (u, v) contribution to a
                # row v (u < v) precedes every (v, w) contribution (the key
                # (u, v) sorts before (v, w)), so writing the hi side first,
                # then the lo side, fills each row ascending - the exact
                # per-row order CSRGraph.from_edges produces
                order = np.argsort(hi, kind="stable")
                indices[_grouped_positions(cursor, hi[order])] = lo[
                    order
                ].astype(_INDICES_DTYPE)
                indices[_grouped_positions(cursor, lo)] = hi.astype(
                    _INDICES_DTYPE
                )
            indices.flush()
        finally:
            del indices, dedup
    if not np.array_equal(cursor, indptr[1:]):
        raise AssertionError(
            "internal error: adjacency rows not completely filled"
        )


def _compress_scattered(
    raw_path: str,
    out_path: str,
    indptr: np.ndarray,
    half: int,
    block_cap: int,
    pool,
    window: int,
) -> tuple[int, int]:
    """Block-compress the scattered raw adjacency into a v2 ``out_path``.

    Row ranges are encoded on pool workers (results consumed in order, a
    bounded window in flight) and streamed to a scratch data file; the final
    file is assembled once ``data_bytes`` - and with it the byte-offset
    dtype - is known. Returns ``(total_file_bytes, data_bytes)``.
    """
    n = indptr.shape[0] - 1
    raw = np.memmap(raw_path, dtype=_INDICES_DTYPE, mode="r", shape=(half,))
    row_bytes = np.zeros(n, dtype=np.int64)
    data_path = raw_path + ".data"
    try:
        with open(data_path, "wb") as df:
            pending: deque = deque()  # (future, r0, r1) in row order

            def _drain() -> None:
                fut, r0, r1 = pending.popleft()
                data, rb = fut.result()
                row_bytes[r0:r1] = rb
                data.tofile(df)

            try:
                for r0, r1 in _iter_row_chunks(indptr):
                    pending.append(
                        (
                            pool.submit(
                                _encode_row_range, raw, indptr, r0, r1,
                                block_cap,
                            ),
                            r0,
                            r1,
                        )
                    )
                    if len(pending) >= window:
                        _drain()
                while pending:
                    _drain()
            finally:
                while pending:
                    try:
                        pending.popleft()[0].result()
                    except BaseException:
                        pass
    finally:
        del raw
    data_bytes = int(row_bytes.sum())
    wide = data_bytes > 0xFFFFFFFF
    byte_off = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(row_bytes, out=byte_off[1:])
    total = _file_layout_v2(n, data_bytes, wide)[3]
    with open(out_path, "wb") as f:
        f.write(
            _pack_header(
                n, half,
                version=FORMAT_VERSION_V2,
                flags=_FLAG_WIDE_OFFSETS if wide else 0,
                data_bytes=data_bytes,
                block_cap=block_cap,
            )
        )
        indptr.astype(_INDPTR_DTYPE).tofile(f)
        byte_off.astype(_OFF64_DTYPE if wide else _OFF32_DTYPE).tofile(f)
        with open(data_path, "rb") as df:
            while True:
                piece = df.read(1 << 24)
                if not piece:
                    break
                f.write(piece)
    return total, data_bytes


def _grouped_positions(cursor: np.ndarray, grp: np.ndarray) -> np.ndarray:
    """Write positions ``cursor[grp] + rank-within-group`` for a *sorted*
    group-id array, advancing ``cursor`` by each group's count."""
    m = grp.shape[0]
    if m == 0:
        return np.empty(0, dtype=np.int64)
    seg_starts = np.concatenate(([0], np.flatnonzero(np.diff(grp)) + 1))
    counts = np.diff(np.concatenate((seg_starts, [m])))
    offsets = np.arange(m, dtype=np.int64) - np.repeat(seg_starts, counts)
    pos = cursor[grp] + offsets
    cursor[grp[seg_starts]] += counts
    return pos


# ------------------------------------------------------------ spec sources
def validate_source(source: str) -> None:
    """Syntax-check a ``PartitionSpec.source`` string (no filesystem I/O).

    Grammar: ``rmat:<n>[:<avg_degree>]`` | ``dataset:<name>`` | a file path
    to an on-disk graph (``.bin`` external CSR or ``.npz`` CSRGraph dump).
    """
    if not isinstance(source, str) or not source:
        raise ValueError(f"source must be a non-empty string, got {source!r}")
    if source.startswith("rmat:"):
        fields = source.split(":")[1:]
        if not 1 <= len(fields) <= 2:
            raise ValueError(
                f"bad source {source!r}: expected rmat:<n>[:<avg_degree>]"
            )
        try:
            n = int(fields[0])
            deg = float(fields[1]) if len(fields) == 2 else 16.0
        except ValueError:
            raise ValueError(
                f"bad source {source!r}: expected rmat:<n>[:<avg_degree>]"
            ) from None
        if n < 1 or deg <= 0:
            raise ValueError(
                f"bad source {source!r}: n must be >= 1 and avg_degree > 0"
            )
        return
    if source.startswith("dataset:"):
        from repro.graph.generators import DATASETS

        name = source.split(":", 1)[1]
        if name not in DATASETS:
            raise ValueError(
                f"bad source {source!r}: unknown dataset {name!r} "
                f"(available: {', '.join(sorted(DATASETS))})"
            )
        return
    # anything else is a file path; colons are legal in POSIX paths, so no
    # scheme guessing - a missing file fails with a clear error at load time


def load_graph_source(source: str, *, seed: int = 0):
    """Resolve a spec ``source`` into a graph object.

    ``rmat:<n>[:<avg_degree>]`` generates a seeded R-MAT; ``dataset:<name>``
    loads a named benchmark dataset; anything else is a path - ``.npz`` loads
    a ``CSRGraph`` dump, everything else opens the file as a memory-mapped
    :class:`ExternalCSRGraph`.
    """
    validate_source(source)
    if source.startswith("rmat:"):
        from repro.graph.generators import rmat_graph

        fields = source.split(":")[1:]
        n = int(fields[0])
        deg = float(fields[1]) if len(fields) == 2 else 16.0
        return rmat_graph(n, avg_degree=deg, seed=seed)
    if source.startswith("dataset:"):
        from repro.graph.generators import load_dataset

        return load_dataset(source.split(":", 1)[1], seed=seed)
    return load_graph_file(source)


def load_graph_file(path: str):
    """Open an on-disk graph: ``.npz`` loads a ``CSRGraph`` dump resident,
    anything else is memory-mapped as an :class:`ExternalCSRGraph`."""
    if path.endswith(".npz"):
        return CSRGraph.load(path)
    return ExternalCSRGraph(path)
