"""Graph substrate: CSR structures, generators, streaming readers, metrics."""
from repro.graph.csr import CSRGraph
from repro.graph.generators import (
    rmat_graph,
    powerlaw_cluster_graph,
    road_graph,
    ldbc_like_graph,
)
from repro.graph.metrics import (
    edge_cut,
    communication_volume,
    vertex_imbalance,
    edge_imbalance,
    quality_report,
)

__all__ = [
    "CSRGraph",
    "rmat_graph",
    "powerlaw_cluster_graph",
    "road_graph",
    "ldbc_like_graph",
    "edge_cut",
    "communication_volume",
    "vertex_imbalance",
    "edge_imbalance",
    "quality_report",
]
