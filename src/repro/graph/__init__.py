"""Graph substrate: CSR structures, generators, streaming readers, metrics."""
from repro.graph.churn import ChurnStream, churn_from_graph, rmat_churn
from repro.graph.csr import CSRGraph
from repro.graph.external import (
    ExternalCSRGraph,
    convert_csr,
    convert_edge_list,
    load_graph_file,
    load_graph_source,
    validate_source,
    write_external_csr,
)
from repro.graph.generators import (
    rmat_graph,
    powerlaw_cluster_graph,
    road_graph,
    ldbc_like_graph,
)
from repro.graph.metrics import (
    edge_cut,
    communication_volume,
    vertex_imbalance,
    edge_imbalance,
    quality_report,
)

__all__ = [
    "CSRGraph",
    "ChurnStream",
    "churn_from_graph",
    "rmat_churn",
    "ExternalCSRGraph",
    "convert_csr",
    "convert_edge_list",
    "load_graph_file",
    "load_graph_source",
    "validate_source",
    "write_external_csr",
    "rmat_graph",
    "powerlaw_cluster_graph",
    "road_graph",
    "ldbc_like_graph",
    "edge_cut",
    "communication_volume",
    "vertex_imbalance",
    "edge_imbalance",
    "quality_report",
]
