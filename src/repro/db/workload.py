"""LDBC-SNB-interactive-like query mix: seeds biased to active users
(degree-proportional, as person-centric SNB reads are)."""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def ldbc_query_mix(
    graph: CSRGraph, num_queries: int, seed: int = 0, degree_biased: bool = True
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if not degree_biased:
        return rng.integers(0, graph.num_vertices, size=num_queries)
    deg = graph.degrees.astype(np.float64)
    p = deg / deg.sum()
    return rng.choice(graph.num_vertices, size=num_queries, p=p)
