"""Distributed graph database engine (paper §IV-B, Table V).

Batched one/two-hop neighbourhood retrieval over a partitioned graph with
per-worker work and cross-partition RPC accounting - the JanusGraph/LDBC
study's analogue.
"""
from repro.db.engine import QueryEngine, QueryStats
from repro.db.workload import ldbc_query_mix

__all__ = ["QueryEngine", "QueryStats", "ldbc_query_mix"]
