"""Batched k-hop neighbourhood query engine with distributed cost accounting.

Execution model (JanusGraph-style, vertex-partitioned storage):
  * a query for seed ``s`` is routed to the worker owning ``s`` (master);
  * hop 1: the master scans s's adjacency locally; neighbour *properties*
    held by other workers are fetched with one RPC per distinct remote
    partition (message batching, as Cassandra/JanusGraph do);
  * hop 2: adjacency of each frontier vertex lives on its owner, so the
    master issues one RPC per distinct owning partition of the frontier,
    each response carrying that shard of the second frontier.

Per-query latency = cpu(scanned edges) + rtt * rounds + bytes / bandwidth.
Throughput is workers-in-parallel with the busiest worker as the bottleneck
(the paper's edge-imbalance -> straggler story), measured over a query batch.

`run_batch` also *executes* the queries (vectorised numpy gathers) so results
are real and testable, not just costed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class QueryStats:
    num_queries: int
    hops: int
    total_scanned_edges: int
    total_rpcs: int
    total_net_values: int  # vertex-sized payload units crossing the network
    per_worker_cpu: np.ndarray  # scanned edges attributed to each worker
    per_worker_net: np.ndarray  # payload units attributed to each worker
    latencies_s: np.ndarray  # per-query latency estimate
    per_worker_busy_s: np.ndarray | None = None  # model-costed busy time

    def throughput_qps(self, concurrency: int = 24) -> float:
        """Closed-loop clients: two independent resources bound throughput.

        The client side has ``concurrency`` in-flight slots, each waiting a
        full latency per query, so it finishes N queries in
        ``sum(latency)/concurrency``. The server side is bounded by the
        busiest worker's busy time (the paper's edge-imbalance straggler
        story). Wall time is the max of the two - client concurrency and
        worker parallel efficiency are separate terms, not a product (the
        old formula multiplied them, overstating throughput whenever the
        client side, not the straggler, was the bottleneck). The serving
        layer (:mod:`repro.serve.graph`) measures the same two bounds from
        real message flow; tests pin that both models rank partitioners
        identically.
        """
        if self.num_queries == 0:
            return 0.0
        client_wall = float(self.latencies_s.sum()) / max(int(concurrency), 1)
        busy = self.per_worker_busy_s
        if busy is None:
            # stats built without the cost model: reconstruct from defaults
            m = DBCostModel()
            busy = (
                self.per_worker_cpu / m.edge_scan_rate
                + self.per_worker_net * m.value_bytes / m.bandwidth
            )
        server_wall = float(np.max(busy)) if len(busy) else 0.0
        wall = max(client_wall, server_wall)
        if wall <= 0:
            return float("inf")
        return self.num_queries / wall

    def p99_latency_s(self) -> float:
        return float(np.quantile(self.latencies_s, 0.99))


@dataclasses.dataclass(frozen=True)
class DBCostModel:
    edge_scan_rate: float = 5.0e7  # adjacency entries/s per worker
    rtt_s: float = 2.0e-4  # one batched RPC round trip
    bandwidth: float = 1.25e9  # bytes/s per worker (10 GbE-ish)
    value_bytes: float = 64.0  # property payload per vertex


class QueryEngine:
    def __init__(self, graph: CSRGraph, part: np.ndarray, k: int,
                 model: DBCostModel | None = None):
        self.graph = graph
        self.part = np.asarray(part, dtype=np.int64)
        self.k = k
        self.model = model or DBCostModel()

    # ------------------------------------------------------------- execution
    def one_hop(self, seeds: np.ndarray) -> tuple[list[np.ndarray], QueryStats]:
        return self._run(seeds, hops=1)

    def two_hop(self, seeds: np.ndarray, fanout_cap: int = 64):
        return self._run(seeds, hops=2, fanout_cap=fanout_cap)

    def _run(self, seeds: np.ndarray, hops: int, fanout_cap: int = 64):
        g, part, k, m = self.graph, self.part, self.k, self.model
        results: list[np.ndarray] = []
        per_worker_cpu = np.zeros(k, dtype=np.float64)
        per_worker_net = np.zeros(k, dtype=np.float64)
        lat = np.zeros(len(seeds), dtype=np.float64)
        tot_scan = tot_rpc = tot_net = 0
        for qi, s in enumerate(np.asarray(seeds)):
            s = int(s)
            master = int(part[s])
            frontier = g.neighbors(s).astype(np.int64)
            scanned = frontier.shape[0]
            rpcs = 0
            net_values = 0
            # hop-1 property fetches for remote neighbours
            remote_parts = np.unique(part[frontier])
            remote_parts = remote_parts[remote_parts != master]
            rpcs += remote_parts.shape[0]
            net_values += int((part[frontier] != master).sum())
            if hops == 2 and frontier.size:
                cap = frontier[:fanout_cap]
                # adjacency of each frontier vertex is scanned on its owner
                owners = part[cap]
                deg = g.degrees[cap]
                for p in np.unique(owners):
                    sel = owners == p
                    work = int(deg[sel].sum())
                    per_worker_cpu[p] += work
                    scanned += work
                    if p != master:
                        rpcs += 1
                        net_values += work  # second frontier ships back
                second = np.concatenate(
                    [g.neighbors(int(v)) for v in cap]
                ) if cap.size else np.empty(0, dtype=np.int32)
                result = np.unique(np.concatenate([frontier, second.astype(np.int64)]))
            else:
                result = frontier
            per_worker_cpu[master] += frontier.shape[0]
            per_worker_net[master] += net_values
            results.append(result)
            rounds = 1 if hops == 1 else 2
            lat[qi] = (
                scanned / m.edge_scan_rate
                + m.rtt_s * max(rounds if rpcs else 0, 0)
                + net_values * m.value_bytes / m.bandwidth
            )
            tot_scan += scanned
            tot_rpc += rpcs
            tot_net += net_values
        stats = QueryStats(
            num_queries=len(seeds),
            hops=hops,
            total_scanned_edges=tot_scan,
            total_rpcs=tot_rpc,
            total_net_values=tot_net,
            per_worker_cpu=per_worker_cpu,
            per_worker_net=per_worker_net,
            latencies_s=lat,
            per_worker_busy_s=(
                per_worker_cpu / m.edge_scan_rate
                + per_worker_net * m.value_bytes / m.bandwidth
            ),
        )
        return results, stats
