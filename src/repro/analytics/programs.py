"""Vertex programs as (init, message, combine, apply) semirings.

All three paper workloads share one gather-apply skeleton:

    msgs_e   = message(state[col_e], deg[col_e])
    agg_v    = combine-reduce over edges with row == v
    state_v' = apply(state_v, agg_v, ctx)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    name: str
    identity: float  # identity of the combine reduction
    reduce_kind: str  # "sum" | "min"
    init: Callable  # (local_to_global, local_count, ctx) -> float32[v_max]
    message: Callable  # (src_state, src_deg) -> msg
    apply: Callable  # (old_state, agg, ctx) -> new_state

    def init_state(self, lg, ctx) -> np.ndarray:
        return np.stack(
            [
                self.init(lg.local_to_global[p], int(lg.local_count[p]), ctx)
                for p in range(lg.k)
            ]
        )


def pagerank_program(damping: float = 0.85) -> VertexProgram:
    def init(l2g, count, ctx):
        n = ctx["num_vertices"]
        x = np.full(l2g.shape[0], 1.0 / n, dtype=np.float32)
        x[count:] = 0.0
        return x

    def message(src_state, src_deg):
        return src_state / jnp.maximum(src_deg, 1.0)

    def apply(old, agg, ctx):
        n = ctx["num_vertices"]
        return (1.0 - damping) / n + damping * agg

    return VertexProgram(
        name="pagerank", identity=0.0, reduce_kind="sum",
        init=init, message=message, apply=apply,
    )


_INF = np.float32(3.0e38)


def cc_program() -> VertexProgram:
    """Connected components via label propagation (labels = vertex ids)."""

    def init(l2g, count, ctx):
        x = l2g.astype(np.float32).copy()
        x[count:] = _INF
        return x

    def message(src_state, src_deg):
        return src_state

    def apply(old, agg, ctx):
        return jnp.minimum(old, agg)

    return VertexProgram(
        name="cc", identity=float(_INF), reduce_kind="min",
        init=init, message=message, apply=apply,
    )


def sssp_program(source: int = 0) -> VertexProgram:
    """Single-source shortest path, unit weights (Bellman-Ford)."""

    def init(l2g, count, ctx):
        x = np.full(l2g.shape[0], _INF, dtype=np.float32)
        x[np.flatnonzero(l2g == source)] = 0.0
        return x

    def message(src_state, src_deg):
        return src_state + 1.0

    def apply(old, agg, ctx):
        return jnp.minimum(old, agg)

    return VertexProgram(
        name="sssp", identity=float(_INF), reduce_kind="min",
        init=init, message=message, apply=apply,
    )


PROGRAMS = {
    "pagerank": pagerank_program,
    "cc": cc_program,
    "sssp": sssp_program,
}


# ----------------------------------------------------------- dense references
def reference_pagerank(graph, iters: int, damping: float = 0.85) -> np.ndarray:
    n = graph.num_vertices
    x = np.full(n, 1.0 / n, dtype=np.float64)
    deg = np.maximum(graph.degrees, 1).astype(np.float64)
    src = np.repeat(np.arange(n), graph.degrees)
    dst = graph.indices
    for _ in range(iters):
        contrib = x[dst] / deg[dst]
        agg = np.zeros(n)
        np.add.at(agg, src, contrib)
        x = (1 - damping) / n + damping * agg
    return x


def reference_cc(graph, iters: int) -> np.ndarray:
    n = graph.num_vertices
    x = np.arange(n, dtype=np.float64)
    src = np.repeat(np.arange(n), graph.degrees)
    dst = graph.indices
    for _ in range(iters):
        agg = np.full(n, np.inf)
        np.minimum.at(agg, src, x[dst])
        x = np.minimum(x, agg)
    return x


def reference_sssp(graph, iters: int, source: int = 0) -> np.ndarray:
    n = graph.num_vertices
    x = np.full(n, np.inf)
    x[source] = 0.0
    src = np.repeat(np.arange(n), graph.degrees)
    dst = graph.indices
    for _ in range(iters):
        agg = np.full(n, np.inf)
        np.minimum.at(agg, src, x[dst] + 1.0)
        x = np.minimum(x, agg)
    return x
