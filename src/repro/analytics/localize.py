"""Turn (graph, partition) into per-device padded local structures.

Index space on device p (all devices identical shapes, SPMD):

    [0, Vmax)                  local vertex states
    [Vmax, Vmax + K*H)         ghost states: slot Vmax + q*H + j holds the
                               j-th vertex imported from partition q
    Vmax + K*H                 identity slot (padding edges point here)

``send_gather[q]`` on device p lists the local indices p must ship to q each
iteration; after an all-to-all, ``recv[q]`` holds what q shipped to p, laid
out exactly as p's ghost table expects. All shapes are static (padded to the
max across devices) so one compiled program serves every device.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class LocalizedGraph:
    k: int
    v_max: int  # max local vertices per device
    h_max: int  # max ghosts imported from any single partition
    e_max: int  # max local edge slots per device
    num_vertices: int
    num_edges: int
    # --- per-device arrays, leading axis = device/partition
    local_to_global: np.ndarray  # int32[k, v_max], -1 pad
    local_count: np.ndarray  # int32[k]
    rows: np.ndarray  # int32[k, e_max] local row of each edge slot (v_max pad)
    cols: np.ndarray  # int32[k, e_max] combined-index col (identity pad)
    send_gather: np.ndarray  # int32[k, k, h_max] local idx to send (0 pad)
    send_count: np.ndarray  # int32[k, k] true ghosts q imports from p
    degrees_full: np.ndarray  # float32[k, v_max + k*h_max + 1] degree table
    local_degrees: np.ndarray  # float32[k, v_max]
    part: np.ndarray  # int32[|V|] original assignment
    global_to_local: np.ndarray  # int32[|V|] local index of each vertex

    @property
    def state_len(self) -> int:
        return self.v_max + self.k * self.h_max + 1

    @property
    def identity_slot(self) -> int:
        return self.state_len - 1

    # ---- communication accounting -----------------------------------------
    def true_halo_messages(self) -> int:
        """Σ_u D(u): exactly K·|V|·λ_CV (paper Eq. 4)."""
        return int(self.send_count.sum())

    def padded_halo_elements_per_iter(self) -> int:
        """Elements actually moved by the padded all-to-all per iteration."""
        return int(self.k * self.k * self.h_max)

    def max_local_edges(self) -> int:
        return int((self.rows != self.v_max).sum(axis=1).max())


def localize(graph: CSRGraph, part: np.ndarray, k: int) -> LocalizedGraph:
    part = np.asarray(part, dtype=np.int32)
    n = graph.num_vertices
    global_to_local = np.zeros(n, dtype=np.int32)
    locals_of: list[np.ndarray] = []
    for p in range(k):
        ids = np.flatnonzero(part == p).astype(np.int32)
        locals_of.append(ids)
        global_to_local[ids] = np.arange(ids.shape[0], dtype=np.int32)
    v_max = max(int(ids.shape[0]) for ids in locals_of) if k else 0
    v_max = max(v_max, 1)

    src_all = np.repeat(np.arange(n, dtype=np.int64), graph.degrees)
    dst_all = graph.indices.astype(np.int64)
    psrc = part[src_all]
    pdst = part[dst_all]

    # ghosts[p][q] = sorted unique vertices of partition q needed by p
    ghosts: list[list[np.ndarray]] = [[None] * k for _ in range(k)]
    h_max = 1
    for p in range(k):
        mask_p = psrc == p
        for q in range(k):
            if q == p:
                ghosts[p][q] = np.empty(0, dtype=np.int64)
                continue
            need = np.unique(dst_all[mask_p & (pdst == q)])
            ghosts[p][q] = need
            h_max = max(h_max, need.shape[0])

    e_counts = np.bincount(psrc, minlength=k)
    e_max = max(int(e_counts.max()), 1)

    local_to_global = np.full((k, v_max), -1, dtype=np.int32)
    local_count = np.zeros(k, dtype=np.int32)
    rows = np.full((k, e_max), v_max, dtype=np.int32)
    state_len = v_max + k * h_max + 1
    cols = np.full((k, e_max), state_len - 1, dtype=np.int32)
    send_gather = np.zeros((k, k, h_max), dtype=np.int32)
    send_count = np.zeros((k, k), dtype=np.int32)
    degrees_full = np.zeros((k, state_len), dtype=np.float32)
    local_degrees = np.zeros((k, v_max), dtype=np.float32)
    deg = graph.degrees.astype(np.float32)

    for p in range(k):
        ids = locals_of[p]
        local_to_global[p, : ids.shape[0]] = ids
        local_count[p] = ids.shape[0]
        local_degrees[p, : ids.shape[0]] = deg[ids]
        degrees_full[p, : ids.shape[0]] = deg[ids]
        # edges owned by p
        mask_p = psrc == p
        e_src = src_all[mask_p]
        e_dst = dst_all[mask_p]
        e_pdst = pdst[mask_p]
        rows[p, : e_src.shape[0]] = global_to_local[e_src]
        col_vals = np.empty(e_src.shape[0], dtype=np.int32)
        intern = e_pdst == p
        col_vals[intern] = global_to_local[e_dst[intern]]
        for q in range(k):
            sel = e_pdst == q
            if q == p or not sel.any():
                if q != p:
                    # still need degree table slots zeroed (already zero)
                    pass
                continue
            g = ghosts[p][q]
            slot_base = v_max + q * h_max
            # position of each dst within the sorted unique ghost list
            pos = np.searchsorted(g, e_dst[sel])
            col_vals[sel] = (slot_base + pos).astype(np.int32)
            degrees_full[p, slot_base : slot_base + g.shape[0]] = deg[g]
        cols[p, : e_src.shape[0]] = col_vals
        # what every OTHER device must send to p -> recorded on the sender q
        for q in range(k):
            g = ghosts[p][q]
            if q == p or g.shape[0] == 0:
                continue
            send_gather[q, p, : g.shape[0]] = global_to_local[g]
            send_count[q, p] = g.shape[0]

    return LocalizedGraph(
        k=k,
        v_max=v_max,
        h_max=h_max,
        e_max=e_max,
        num_vertices=n,
        num_edges=graph.num_edges,
        local_to_global=local_to_global,
        local_count=local_count,
        rows=rows,
        cols=cols,
        send_gather=send_gather,
        send_count=send_count,
        degrees_full=degrees_full,
        local_degrees=local_degrees,
        part=part,
        global_to_local=global_to_local,
    )
