"""Distributed graph analytics on the TPU mesh (paper §IV-B, Table IV).

A Pregel-style vertex-program engine where vertex->device placement comes
from a partitioner; halo-exchange volume is exactly the paper's
communication-volume metric, and per-device edge counts are its straggler
metric.
"""
from repro.analytics.engine import GraphEngine
from repro.analytics.localize import LocalizedGraph, localize
from repro.analytics.programs import PROGRAMS, cc_program, pagerank_program, sssp_program
from repro.analytics.costmodel import CostModel, workload_cost

__all__ = [
    "GraphEngine",
    "LocalizedGraph",
    "localize",
    "PROGRAMS",
    "pagerank_program",
    "cc_program",
    "sssp_program",
    "CostModel",
    "workload_cost",
]
