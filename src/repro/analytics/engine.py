"""Partition-aware vertex-program engine.

Two execution modes sharing one per-device step:

  * ``simulated`` - the K devices live on the leading axis of every array on
    a single real device; the halo all-to-all is an axis transpose. Used for
    unit tests and CPU benchmarks.
  * ``shard_map`` - the K devices are a real 1-D JAX mesh axis ``"w"``; the
    halo exchange is ``jax.lax.all_to_all`` over ICI. This is what runs on a
    pod, and what the dry-run lowers.

The engine's communication volume is *exactly* the paper's λ_CV·K·|V| when
counting true (unpadded) messages - partition quality translates directly
into collective bytes.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analytics.localize import LocalizedGraph
from repro.analytics.programs import VertexProgram
from repro.compat import shard_map


@dataclasses.dataclass
class RunStats:
    iterations: int
    true_halo_messages_per_iter: int
    padded_halo_elements_per_iter: int
    bytes_per_iter_true: int
    bytes_per_iter_padded: int
    max_local_edges: int
    mean_local_edges: float


def _segment_reduce(msgs, rows, out_len, kind: str, identity: float):
    if kind == "sum":
        return jnp.zeros(out_len, msgs.dtype).at[rows].add(msgs)
    return jnp.full(out_len, identity, msgs.dtype).at[rows].min(msgs)


def _local_step(
    local_state,  # [v_max]
    recv,  # [k, h_max] ghost states as laid out in the ghost table
    rows,  # [e_max]
    cols,  # [e_max]
    deg_full,  # [state_len]
    program: VertexProgram,
    ctx: dict,
    v_max: int,
):
    identity = jnp.asarray(program.identity, local_state.dtype)
    full = jnp.concatenate([local_state, recv.reshape(-1), identity[None]])
    msgs = program.message(full[cols], deg_full[cols])
    agg = _segment_reduce(msgs, rows, v_max + 1, program.reduce_kind, program.identity)
    return program.apply(local_state, agg[:v_max], ctx)


class GraphEngine:
    def __init__(self, lg: LocalizedGraph, program: VertexProgram, ctx: dict | None = None):
        self.lg = lg
        self.program = program
        self.ctx = dict(ctx or {})
        self.ctx.setdefault("num_vertices", lg.num_vertices)

    # ------------------------------------------------------------ simulated
    @functools.cached_property
    def _sim_step(self):
        lg, program, ctx = self.lg, self.program, self.ctx
        rows = jnp.asarray(lg.rows)
        cols = jnp.asarray(lg.cols)
        deg_full = jnp.asarray(lg.degrees_full)
        send_gather = jnp.asarray(lg.send_gather)
        k = lg.k

        local = functools.partial(
            _local_step, program=program, ctx=ctx, v_max=lg.v_max
        )
        vstep = jax.vmap(local)

        @jax.jit
        def step(state):  # state: [k, v_max]
            send = state[jnp.arange(k)[:, None, None], send_gather]  # [k,k,h]
            recv = jnp.transpose(send, (1, 0, 2))  # all-to-all
            return vstep(state, recv, rows, cols, deg_full)

        return step

    def run_simulated(self, iters: int) -> np.ndarray:
        state = jnp.asarray(self.program.init_state(self.lg, self.ctx))
        step = self._sim_step
        for _ in range(iters):
            state = step(state)
        return self._gather_global(np.asarray(state))

    # ------------------------------------------------------------ shard_map
    def build_sharded(self, mesh: Mesh, axis: str = "w", iters: int = 1):
        """Returns (fn, sharded_inputs). ``fn(state)`` runs ``iters``
        iterations under ``shard_map`` on ``mesh`` (one device per
        partition along ``axis``)."""
        lg, program, ctx = self.lg, self.program, self.ctx
        if mesh.shape[axis] != lg.k:
            raise ValueError(
                f"mesh axis {axis}={mesh.shape[axis]} != k={lg.k} partitions"
            )
        local = functools.partial(
            _local_step, program=program, ctx=ctx, v_max=lg.v_max
        )

        def device_fn(state, rows, cols, deg_full, send_gather):
            # blocks carry a leading device axis of size 1
            state, rows, cols = state[0], rows[0], cols[0]
            deg_full, send_gather = deg_full[0], send_gather[0]

            def one_iter(_, st):
                send = st[send_gather]  # [k, h_max]
                recv = jax.lax.all_to_all(
                    send, axis, split_axis=0, concat_axis=0, tiled=True
                )
                return local(st, recv, rows, cols, deg_full)

            out = jax.lax.fori_loop(0, iters, one_iter, state)
            return out[None]

        spec = P(axis)
        shard = shard_map(
            device_fn,
            mesh=mesh,
            in_specs=(spec, spec, spec, spec, spec),
            out_specs=spec,
        )
        sharding = NamedSharding(mesh, spec)
        inputs = dict(
            rows=jax.device_put(self.lg.rows, sharding),
            cols=jax.device_put(self.lg.cols, sharding),
            deg_full=jax.device_put(self.lg.degrees_full, sharding),
            send_gather=jax.device_put(self.lg.send_gather, sharding),
        )

        @jax.jit
        def fn(state):
            return shard(
                state,
                inputs["rows"],
                inputs["cols"],
                inputs["deg_full"],
                inputs["send_gather"],
            )

        return fn, sharding

    def run_sharded(self, mesh: Mesh, iters: int, axis: str = "w") -> np.ndarray:
        fn, sharding = self.build_sharded(mesh, axis=axis, iters=iters)
        state = jax.device_put(
            jnp.asarray(self.program.init_state(self.lg, self.ctx)), sharding
        )
        out = fn(state)
        return self._gather_global(np.asarray(out))

    def lower_sharded(self, mesh: Mesh, iters: int, axis: str = "w"):
        """Lower (no execution) for dry-run/roofline inspection."""
        fn, sharding = self.build_sharded(mesh, axis=axis, iters=iters)
        state_spec = jax.ShapeDtypeStruct(
            (self.lg.k, self.lg.v_max), jnp.float32, sharding=sharding
        )
        return jax.jit(fn).lower(state_spec)

    # -------------------------------------------------------------- helpers
    def _gather_global(self, state_kv: np.ndarray) -> np.ndarray:
        out = np.zeros(self.lg.num_vertices, dtype=state_kv.dtype)
        for p in range(self.lg.k):
            c = int(self.lg.local_count[p])
            out[self.lg.local_to_global[p, :c]] = state_kv[p, :c]
        return out

    def stats(self, iters: int, bytes_per_elem: int = 4) -> RunStats:
        lg = self.lg
        true_m = lg.true_halo_messages()
        padded = lg.padded_halo_elements_per_iter()
        edges_per_dev = (lg.rows != lg.v_max).sum(axis=1)
        return RunStats(
            iterations=iters,
            true_halo_messages_per_iter=true_m,
            padded_halo_elements_per_iter=padded,
            bytes_per_iter_true=true_m * bytes_per_elem,
            bytes_per_iter_padded=padded * bytes_per_elem,
            max_local_edges=int(edges_per_dev.max()),
            mean_local_edges=float(edges_per_dev.mean()),
        )
