"""Analytic workload cost model (paper Table IV / Fig. 2 analogue).

On real hardware per-iteration time =
    max_p(compute_p) + max_p(network_p)
with
    compute_p = local_edges_p / edge_rate        (all programs iterate edges)
    network_p = (sent_p + recv_p) * msg_bytes / bandwidth

Edge-cut (vertex-partitioned) engines with sender-side aggregation send each
vertex once per remote partition containing a neighbour (Σ_u D(u) messages -
the paper's communication volume). Vertex-cut (edge-partitioned) engines
(HDRF/Ginger) sync each replicated vertex mirror->master and back:
2 * (|A(v)| - 1) messages per vertex per iteration.

The defaults approximate a v5e pod: 819 GB/s HBM bounds the local SpMV
(~10 bytes/edge -> ~8e10 edges/s ceiling; we assume a conservative gather-
bound 2e10), 50 GB/s/link ICI for halo traffic.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hdrf import EdgePartition
from repro.graph.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class CostModel:
    edge_rate: float = 2.0e10  # edges/s processed per worker (gather-bound)
    bandwidth: float = 50.0e9  # bytes/s per worker interconnect
    msg_bytes: float = 8.0  # payload per halo message (id + value)
    per_iter_overhead_s: float = 1e-4  # barrier/launch overhead


def _edge_cut_traffic(graph: CSRGraph, part: np.ndarray, k: int):
    """Per-worker sent/received message counts (sender-side aggregation)."""
    src = np.repeat(np.arange(graph.num_vertices, dtype=np.int64), graph.degrees)
    dst = graph.indices.astype(np.int64)
    pd = part[dst].astype(np.int64)
    key = src * np.int64(k) + pd
    uniq = np.unique(key)
    u = uniq // k
    p = uniq % k
    ext = p != part[u]
    sent = np.bincount(part[u][ext], minlength=k).astype(np.float64)
    recv = np.bincount(p[ext], minlength=k).astype(np.float64)
    return sent, recv


def workload_cost(
    graph: CSRGraph,
    assignment,
    k: int,
    iters: int,
    model: CostModel | None = None,
) -> dict:
    """``assignment`` is either a vertex partition array (edge-cut engines)
    or an :class:`EdgePartition` (vertex-cut engines)."""
    model = model or CostModel()
    if isinstance(assignment, EdgePartition):
        edges_per_worker = assignment.edge_counts.astype(np.float64)
        reps = assignment.replicas.sum(axis=1).astype(np.float64)
        # mirrors -> master partial aggregates, then master -> mirrors values
        v_msgs = 2.0 * np.maximum(reps - 1.0, 0.0)
        # attribute send/recv to the master's partition (upper bound on the
        # hot worker; mirrors' traffic is spread across their partitions)
        sent = np.bincount(
            assignment.masters, weights=v_msgs, minlength=k
        ).astype(np.float64)
        recv = sent.copy()
    else:
        part = np.asarray(assignment)
        deg = graph.degrees.astype(np.float64)
        edges_per_worker = np.bincount(part, weights=deg, minlength=k)
        sent, recv = _edge_cut_traffic(graph, part, k)

    compute_s = edges_per_worker.max() / model.edge_rate
    network_s = (sent + recv).max() * model.msg_bytes / model.bandwidth
    per_iter = compute_s + network_s + model.per_iter_overhead_s
    return {
        "iters": iters,
        "compute_s_per_iter": compute_s,
        "network_s_per_iter": network_s,
        "total_s": per_iter * iters,
        "straggler_ratio": float(
            edges_per_worker.max() / max(edges_per_worker.mean(), 1e-12)
        ),
        "total_messages_per_iter": float(sent.sum()),
        "network_bytes_per_iter": float(sent.sum() * model.msg_bytes),
    }
