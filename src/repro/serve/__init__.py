"""Serving layer: prefill + decode step builders and the sharded flash-decode
attention live in their natural homes; this package re-exports the public
serving API (see launch/serve.py for the driver)."""
from repro.models.attention import gqa_flash_decode, mla_flash_decode
from repro.train.step import make_decode_step, make_prefill_step

__all__ = [
    "make_prefill_step",
    "make_decode_step",
    "gqa_flash_decode",
    "mla_flash_decode",
]
