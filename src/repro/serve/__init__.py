"""Serving layer - two subsystems under one namespace:

* :mod:`repro.serve.graph` - partition-aware graph query serving (router,
  boundary replication, load generator, tail-latency metrics);
* :mod:`repro.serve.lm` - LM prefill/decode step builders and the sharded
  flash-decode attention (see ``launch/serve.py`` for the driver).

The LM names were historically re-exported from this package root; those
re-exports are kept (lazily, so importing graph serving never drags in jax)
but deprecated - import from :mod:`repro.serve.lm` instead.
"""
import importlib

_LM_EXPORTS = (
    "make_prefill_step",
    "make_decode_step",
    "gqa_flash_decode",
    "mla_flash_decode",
)
_SUBMODULES = ("graph", "lm")

__all__ = [*_LM_EXPORTS, *_SUBMODULES]


def __getattr__(name):  # PEP 562: lazy + deprecated root re-exports
    if name in _LM_EXPORTS:
        from repro.serve import lm

        return getattr(lm, name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.serve.{name}")
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
