"""Serving metrics: per-query records, per-partition load, and the report.

Two clocks run through every query:

* **wall** - real elapsed time from arrival to completion on this machine's
  thread pool. Includes genuine queueing and scheduling effects but also the
  noise of the host, so it is reported, never gated.
* **sim** - deterministic network-model time accumulated from the *actual*
  message flow the router produced: ``scanned/edge_scan_rate + rounds * rtt
  + wire_bytes/bandwidth`` with the same :class:`~repro.db.engine.DBCostModel`
  constants the analytic DB study uses. Because RPC rounds and bytes come
  from real routed messages (not the closed-form formula), sim numbers move
  when the partition, the replication plan, or the batching changes - and
  they are bit-reproducible across hosts, so CI can gate on them.

Throughput under closed-loop load is bounded by two resources and the report
exposes both: the client side (``concurrency`` in-flight slots each waiting a
full latency per query -> ``sum(sim_latency)/concurrency``) and the server
side (the busiest partition's busy time - the paper's straggler story).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "MSG_HEADER_BYTES",
    "ID_BYTES",
    "QueryRecord",
    "PartitionLoad",
    "ServingReport",
    "latency_quantiles",
    "latency_histogram",
    "summarize",
]

# wire-format accounting: one batched message costs a header plus its ids
# (requests) or values/adjacency entries (responses)
MSG_HEADER_BYTES = 64
ID_BYTES = 8


@dataclasses.dataclass
class QueryRecord:
    """One completed query, as observed by its master partition."""

    qid: int
    kind: str  # "point" | "one_hop" | "two_hop"
    seed: int
    master: int
    wall_s: float
    sim_s: float
    rounds: int  # batched RPC round trips on the query's critical path
    rpcs: int  # request/response pairs the query shipped
    wire_bytes: int  # request + response bytes across all its messages
    scanned_edges: int  # adjacency entries scanned on its behalf (all workers)
    result: object = None  # int degree (point) or sorted int64 ids (hops)


@dataclasses.dataclass
class PartitionLoad:
    """Counters one partition's worker accumulates; single-writer by design
    (only the thread owning the partition touches them)."""

    queries: int = 0  # queries mastered here
    scanned_edges: int = 0
    remote_entries: int = 0  # payload entries ingested from remote responses
    msgs_in: int = 0
    msgs_out: int = 0
    bytes_in: int = 0
    bytes_out: int = 0

    def busy_s(self, model) -> float:
        """Deterministic busy time under the DB cost model: local scan work,
        the CPU spent deserializing remote payloads (an ingested adjacency
        entry or property value costs like a scanned one - this is where
        cross-partition traffic hurts throughput, the paper's communication-
        volume story), plus this partition's share of the wire."""
        return (
            (self.scanned_edges + self.remote_entries) / model.edge_scan_rate
            + (self.bytes_in + self.bytes_out) / model.bandwidth
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def latency_quantiles(lat_s: np.ndarray) -> dict:
    """p50/p95/p99 + mean/max in milliseconds."""
    if lat_s.size == 0:
        return {k: 0.0 for k in ("p50", "p95", "p99", "mean", "max")}
    q50, q95, q99 = np.quantile(lat_s, (0.50, 0.95, 0.99))
    return {
        "p50": float(q50) * 1e3,
        "p95": float(q95) * 1e3,
        "p99": float(q99) * 1e3,
        "mean": float(lat_s.mean()) * 1e3,
        "max": float(lat_s.max()) * 1e3,
    }


def latency_histogram(lat_s: np.ndarray, buckets: int = 24) -> dict:
    """Log-spaced latency histogram from 1us to 10s (tail-friendly)."""
    edges = np.logspace(-6, 1, buckets + 1)
    counts, _ = np.histogram(lat_s, bins=edges)
    return {
        "edges_ms": (edges * 1e3).tolist(),
        "counts": counts.astype(int).tolist(),
    }


@dataclasses.dataclass
class ServingReport:
    """The load generator's product: throughput, tails, and message flow."""

    mode: str
    num_queries: int
    concurrency: int
    wall_s: float
    qps_wall: float
    sim_client_wall_s: float
    sim_server_wall_s: float
    qps_sim: float
    latency_ms: dict  # {"wall": quantiles, "sim": quantiles}
    histogram: dict  # sim-latency log histogram
    rpcs: int
    messages: int  # physical messages = 2 * rpcs (request + response)
    wire_bytes: int
    scanned_edges: int
    local_queries: int  # queries that completed without any RPC
    kind_counts: dict
    per_partition: list
    replication: dict
    records: list = dataclasses.field(default_factory=list, repr=False)

    def to_dict(self) -> dict:
        d = {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if f.name != "records"
        }
        d["per_partition"] = [p.to_dict() for p in self.per_partition]
        return d

    def answers(self) -> dict:
        """``qid -> result`` for bit-parity checks across configurations."""
        return {r.qid: r.result for r in self.records}


def summarize(
    records: list,
    loads: list,
    wall_s: float,
    concurrency: int,
    model,
    mode: str,
    replication: dict | None = None,
) -> ServingReport:
    records = sorted(records, key=lambda r: r.qid)
    n = len(records)
    sim = np.array([r.sim_s for r in records], dtype=np.float64)
    wall = np.array([r.wall_s for r in records], dtype=np.float64)
    client_wall = float(sim.sum()) / max(int(concurrency), 1)
    server_wall = max((ld.busy_s(model) for ld in loads), default=0.0)
    sim_total = max(client_wall, server_wall)
    return ServingReport(
        mode=mode,
        num_queries=n,
        concurrency=int(concurrency),
        wall_s=wall_s,
        qps_wall=n / wall_s if wall_s > 0 else 0.0,
        sim_client_wall_s=client_wall,
        sim_server_wall_s=server_wall,
        qps_sim=n / sim_total if sim_total > 0 else 0.0,
        latency_ms={
            "wall": latency_quantiles(wall),
            "sim": latency_quantiles(sim),
        },
        histogram=latency_histogram(sim),
        rpcs=sum(r.rpcs for r in records),
        messages=2 * sum(r.rpcs for r in records),
        wire_bytes=sum(r.wire_bytes for r in records),
        scanned_edges=sum(r.scanned_edges for r in records),
        local_queries=sum(1 for r in records if r.rpcs == 0),
        kind_counts={
            kind: sum(1 for r in records if r.kind == kind)
            for kind in ("point", "one_hop", "two_hop")
        },
        per_partition=list(loads),
        replication=dict(replication or {}),
        records=records,
    )
