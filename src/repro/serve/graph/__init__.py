"""Partition-aware graph serving: router, replication, load gen, metrics.

Turns a partition assignment into a running multi-worker query service and
measures it under production-style load::

    from repro.api import PartitionSpec, partition
    from repro.serve.graph import run_load

    result = partition(graph, PartitionSpec(algo="cuttana", k=8,
                                            balance_mode="edge"))
    report = run_load(result.serve(replication_budget=0.05),
                      num_queries=5000, concurrency=1000)
    print(report.qps_sim, report.latency_ms["sim"]["p99"], report.rpcs)

See ``src/repro/serve/README.md`` for the architecture.
"""
from repro.serve.graph.loadgen import QueryMix, build_workload, run_load
from repro.serve.graph.metrics import (
    PartitionLoad,
    QueryRecord,
    ServingReport,
    summarize,
)
from repro.serve.graph.replication import ReplicationPlan, plan_replication
from repro.serve.graph.router import QUERY_KINDS, GraphService

__all__ = [
    "GraphService",
    "QUERY_KINDS",
    "QueryMix",
    "QueryRecord",
    "PartitionLoad",
    "ServingReport",
    "ReplicationPlan",
    "plan_replication",
    "build_workload",
    "run_load",
    "summarize",
]
