"""Partition-aware query service: a router over per-partition workers.

Execution model (one "machine" per partition, JanusGraph-style vertex
partitioning):

* the **router** maps a query's seed vertex to its home partition (the
  *master*) and enqueues it on that partition's worker;
* each **worker** is an event loop owning one or more partitions. It may
  only scan adjacency of vertices its partitions own (or hold replicas of);
  anything else becomes a batched request *message* to the owner's worker;
* a query is a small state machine held at its master: scan the seed's
  adjacency locally, ship one batched property request per distinct remote
  partition of the frontier (hop 1), then - for 2-hop queries - one batched
  adjacency request per distinct remote owner of the capped frontier
  (hop 2). Each batch of concurrent requests is one RPC *round* on the
  query's critical path.

RPC and byte counts are therefore derived from real message flow through
real queues, not from a closed-form formula: the counters move exactly when
a message is put on another worker's inbox. The
:class:`~repro.serve.graph.replication.ReplicationPlan` short-circuits both
request kinds for replicated vertices, which is how ``replication_budget``
buys fewer cross-partition messages without changing any answer.

Threading reuses the :mod:`repro.core.executor` machinery: worker count
resolves via :func:`~repro.core.executor.resolve_workers` (partitions are
striped over threads, each partition's state touched by exactly one
thread), the loops are hosted on a :class:`~repro.core.executor.ShardPool`,
and the ``executor.JITTER`` test hook injects random per-message sleeps so
tests can prove answers are scheduling-independent. ``max_workers=1``
degrades to a deterministic synchronous drain on the calling thread - no
threads, same message flow, same counters.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from queue import Queue

import numpy as np

from repro.core import executor
from repro.db.engine import DBCostModel
from repro.serve.graph.metrics import (
    ID_BYTES,
    MSG_HEADER_BYTES,
    PartitionLoad,
    QueryRecord,
)
from repro.serve.graph.replication import ReplicationPlan, plan_replication

__all__ = ["GraphService", "QUERY_KINDS"]

QUERY_KINDS = ("point", "one_hop", "two_hop")

_STOP = object()


class _Query:
    """In-flight query state, owned by its master partition's thread."""

    __slots__ = (
        "qid", "kind", "seed", "master", "on_done", "arrival",
        "frontier", "parts", "pending", "phase", "rounds", "rpcs",
        "wire_bytes", "scanned", "remote_entries", "result",
    )

    def __init__(self, qid, kind, seed, master, on_done, arrival):
        self.qid = qid
        self.kind = kind
        self.seed = seed
        self.master = master
        self.on_done = on_done
        self.arrival = arrival
        self.frontier = None
        self.parts = []
        self.pending = 0
        self.phase = "start"
        self.rounds = 0
        self.rpcs = 0
        self.wire_bytes = 0
        self.scanned = 0
        self.remote_entries = 0
        self.result = None


class GraphService:
    """A running (or startable) partition-aware query service.

    Usage::

        with result.serve(max_workers=4) as svc:
            report = run_load(svc, num_queries=5000, concurrency=1000)

    ``store_results=False`` keeps only per-query counters (for large load
    runs); answers are then unavailable for bit-parity checks.
    """

    def __init__(
        self,
        graph,
        assignment,
        k: int,
        *,
        replication_budget: float = 0.0,
        max_workers: int = 0,
        cost_model: DBCostModel | None = None,
        fanout_cap: int = 64,
        store_results: bool = True,
    ):
        assignment = np.asarray(assignment, dtype=np.int64)
        if assignment.shape[0] != graph.num_vertices:
            raise ValueError(
                f"assignment covers {assignment.shape[0]} vertices, graph "
                f"has {graph.num_vertices}"
            )
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if assignment.size and int(assignment.max()) >= k:
            raise ValueError("assignment references partitions >= k")
        self.graph = graph
        self.assignment = assignment
        self.k = int(k)
        self.model = cost_model or DBCostModel()
        self.fanout_cap = int(fanout_cap)
        self.store_results = bool(store_results)
        self.workers = executor.resolve_workers(max_workers, k)
        self.plan: ReplicationPlan = plan_replication(
            graph, assignment, k, replication_budget
        )
        # per-partition replica lookup: sorted id array (membership test) +
        # the mirrored adjacency rows (scans must not touch the owner)
        self._replica_ids = [self.plan.replicas_into(p) for p in range(k)]
        self._replica_adj = [
            {int(v): graph.neighbors(int(v)) for v in ids}
            for ids in self._replica_ids
        ]
        self._loads = [PartitionLoad() for _ in range(k)]
        self._states: list[dict[int, _Query]] = [{} for _ in range(k)]
        self._records: list[list[QueryRecord]] = [[] for _ in range(k)]
        self._qid = itertools.count()
        self._running = False
        self._pool = None
        self._futures = []
        self._inboxes = []
        self._sync_queue: deque | None = None
        self._draining = False

    # ---------------------------------------------------------------- lifecycle
    @property
    def running(self) -> bool:
        return self._running

    def start(self) -> "GraphService":
        if self._running:
            return self
        if self.workers == 1:
            self._sync_queue = deque()
        else:
            self._inboxes = [Queue() for _ in range(self.workers)]
            self._pool = executor.ShardPool(self.workers, self.workers)
            self._futures = [
                self._pool.submit(self._loop, t) for t in range(self.workers)
            ]
        self._running = True
        return self

    def stop(self) -> None:
        if not self._running:
            return
        self._running = False
        if self._pool is not None:
            for inbox in self._inboxes:
                inbox.put(_STOP)
            for fut in self._futures:
                fut.result()  # surfaces worker exceptions
            self._pool.shutdown()
            self._pool = None
            self._futures = []
            self._inboxes = []
        self._sync_queue = None

    def __enter__(self) -> "GraphService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------ routing
    def submit(self, kind: str, seed: int, *, qid: int | None = None,
               on_done=None, arrival_s: float | None = None) -> int:
        """Route one query to its home partition. Returns the query id.

        ``arrival_s`` (a ``perf_counter`` timestamp) lets open-loop load
        generators charge queue wait from the *scheduled* arrival, avoiding
        coordinated omission.
        """
        if not self._running:
            raise RuntimeError("service is not running; call start() first")
        if kind not in QUERY_KINDS:
            raise ValueError(
                f"unknown query kind {kind!r}; expected one of {QUERY_KINDS}"
            )
        seed = int(seed)
        if not 0 <= seed < self.graph.num_vertices:
            raise ValueError(f"seed vertex {seed} out of range")
        if qid is None:
            qid = next(self._qid)
        master = int(self.assignment[seed])
        arrival = time.perf_counter() if arrival_s is None else arrival_s
        q = _Query(qid, kind, seed, master, on_done, arrival)
        self._send(master, ("new", q))
        return qid

    def _send(self, dest_partition: int, msg) -> None:
        if self._sync_queue is not None:
            self._sync_queue.append(msg)
            if not self._draining:
                self._draining = True
                try:
                    while self._sync_queue:
                        self._dispatch(self._sync_queue.popleft())
                finally:
                    self._draining = False
        else:
            self._inboxes[dest_partition % self.workers].put(msg)

    def _loop(self, t: int) -> None:
        inbox = self._inboxes[t]
        while True:
            msg = inbox.get()
            if msg is _STOP:
                return
            if executor.JITTER is not None:
                time.sleep(executor.JITTER.random() * 0.003)
            self._dispatch(msg)

    # ----------------------------------------------------------- state machine
    def _dispatch(self, msg) -> None:
        tag = msg[0]
        if tag == "new":
            self._on_new(msg[1])
        elif tag == "req":
            self._on_req(*msg[1])
        elif tag == "resp":
            self._on_resp(*msg[1])
        else:  # pragma: no cover - routing bug
            raise RuntimeError(f"unknown message tag {tag!r}")

    def _is_replica(self, p: int, v: int) -> bool:
        ids = self._replica_ids[p]
        if ids.size == 0:
            return False
        i = np.searchsorted(ids, v)
        return i < ids.size and ids[i] == v

    def _on_new(self, q: _Query) -> None:
        p = q.master
        self._states[p][q.qid] = q
        self._loads[p].queries += 1
        if q.kind == "point":
            # the seed's record lives on its master: fully local
            q.result = int(self.graph.degree(q.seed))
            self._finish(q)
            return
        frontier = self.graph.neighbors(q.seed)
        q.frontier = frontier
        n_scan = int(frontier.shape[0])
        q.scanned += n_scan
        self._loads[p].scanned_edges += n_scan
        # hop-1 property fetch for remote, non-replicated neighbours
        owners = self.assignment[frontier]
        remote = frontier[owners != p]
        if remote.size and self._replica_ids[p].size:
            remote = remote[~np.isin(remote, self._replica_ids[p])]
        if remote.size:
            q.phase = "props"
            q.rounds += 1
            self._ship_requests(q, "props", remote)
        else:
            self._after_props(q)

    def _ship_requests(self, q: _Query, rkind: str, vertices: np.ndarray) -> None:
        """One batched request per distinct owning partition - each put on a
        real inbox and counted as it crosses."""
        owners = self.assignment[vertices]
        dests = np.unique(owners)
        q.pending = int(dests.shape[0])
        p = q.master
        for d in dests:
            ids = vertices[owners == d].astype(np.int64)
            req_bytes = MSG_HEADER_BYTES + ID_BYTES * int(ids.shape[0])
            q.rpcs += 1
            q.wire_bytes += req_bytes
            self._loads[p].msgs_out += 1
            self._loads[p].bytes_out += req_bytes
            self._send(int(d), ("req", (int(d), p, q.qid, rkind, ids)))

    def _on_req(self, dest: int, master: int, qid: int, rkind: str,
                ids: np.ndarray) -> None:
        ld = self._loads[dest]
        req_bytes = MSG_HEADER_BYTES + ID_BYTES * int(ids.shape[0])
        ld.msgs_in += 1
        ld.bytes_in += req_bytes
        if rkind == "props":
            # property read per id: a value-sized payload ships back
            scanned = 0
            entries = int(ids.shape[0])
            arrs = None
            resp_bytes = MSG_HEADER_BYTES + int(
                ids.shape[0] * self.model.value_bytes
            )
        else:  # "adj": scan each id's adjacency here, ship the rows back
            arrs = [self.graph.neighbors(int(v)) for v in ids]
            scanned = int(sum(a.shape[0] for a in arrs))
            entries = scanned
            ld.scanned_edges += scanned
            resp_bytes = MSG_HEADER_BYTES + ID_BYTES * scanned
        ld.msgs_out += 1
        ld.bytes_out += resp_bytes
        self._send(master, ("resp", (master, qid, rkind, scanned, entries,
                                     resp_bytes, arrs)))

    def _on_resp(self, master: int, qid: int, rkind: str, scanned: int,
                 entries: int, resp_bytes: int, arrs) -> None:
        ld = self._loads[master]
        ld.msgs_in += 1
        ld.bytes_in += resp_bytes
        # the master pays CPU to deserialize what it asked for
        ld.remote_entries += entries
        q = self._states[master][qid]
        q.scanned += scanned
        q.remote_entries += entries
        q.wire_bytes += resp_bytes
        if arrs is not None:
            q.parts.extend(arrs)
        q.pending -= 1
        if q.pending:
            return
        if q.phase == "props":
            self._after_props(q)
        else:
            self._finalize_two_hop(q)

    def _after_props(self, q: _Query) -> None:
        if q.kind == "one_hop":
            q.result = q.frontier.astype(np.int64)
            self._finish(q)
            return
        # two_hop: scan the capped frontier's adjacency - locally for owned
        # or replicated vertices, one batched RPC per remaining owner
        p = q.master
        cap = q.frontier[: self.fanout_cap]
        if cap.size == 0:
            self._finalize_two_hop(q)
            return
        owners = self.assignment[cap]
        local_mask = owners == p
        if self._replica_ids[p].size:
            local_mask |= np.isin(cap, self._replica_ids[p])
        n_local_scan = 0
        for v in cap[local_mask]:
            v = int(v)
            row = (
                self.graph.neighbors(v)
                if self.assignment[v] == p
                else self._replica_adj[p][v]
            )
            q.parts.append(row)
            n_local_scan += int(row.shape[0])
        q.scanned += n_local_scan
        self._loads[p].scanned_edges += n_local_scan
        remote = cap[~local_mask]
        if remote.size:
            q.phase = "adj"
            q.rounds += 1
            self._ship_requests(q, "adj", remote)
        else:
            self._finalize_two_hop(q)

    def _finalize_two_hop(self, q: _Query) -> None:
        second = (
            np.concatenate([a.astype(np.int64) for a in q.parts])
            if q.parts
            else np.empty(0, dtype=np.int64)
        )
        q.result = np.unique(
            np.concatenate([q.frontier.astype(np.int64), second])
        )
        self._finish(q)

    def _finish(self, q: _Query) -> None:
        p = q.master
        self._states[p].pop(q.qid, None)
        m = self.model
        sim_s = (
            (q.scanned + q.remote_entries) / m.edge_scan_rate
            + q.rounds * m.rtt_s
            + q.wire_bytes / m.bandwidth
        )
        rec = QueryRecord(
            qid=q.qid,
            kind=q.kind,
            seed=q.seed,
            master=p,
            wall_s=time.perf_counter() - q.arrival,
            sim_s=sim_s,
            rounds=q.rounds,
            rpcs=q.rpcs,
            wire_bytes=q.wire_bytes,
            scanned_edges=q.scanned,
            result=q.result if self.store_results else None,
        )
        self._records[p].append(rec)
        if q.on_done is not None:
            q.on_done(rec)

    # ----------------------------------------------------------------- results
    def loads(self) -> list:
        """Per-partition load counters (read after the service quiesces)."""
        return self._loads

    def drain_records(self) -> list:
        """All completed query records, sorted by qid (read after stop())."""
        out = [r for per_p in self._records for r in per_p]
        out.sort(key=lambda r: r.qid)
        return out

    def replication_stats(self) -> dict:
        return self.plan.stats()
