"""Load generator: thousands of concurrent mixed queries against a service.

Two arrival disciplines:

* **closed loop** - ``concurrency`` client slots, each submitting its next
  query the moment the previous one completes (the completion callback runs
  on the worker thread that finished the query and immediately routes the
  next one, so ``concurrency`` queries are genuinely in flight without a
  thread per client);
* **open loop** - queries arrive on a fixed-rate schedule regardless of
  completions; wall latency is charged from the *scheduled* arrival, so
  queue buildup shows up in the tail instead of being coordinated away.

The workload itself is deterministic given ``seed``: seeds come from the
degree-biased LDBC-like mix (:func:`repro.db.workload.ldbc_query_mix`) and
kinds are drawn from a :class:`QueryMix`. The same ``(qid, kind, seed)``
list is produced for any concurrency/worker count, which is what lets tests
pin bit-identical answers across serving configurations.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import numpy as np

from repro.serve.graph.metrics import ServingReport, summarize

__all__ = ["QueryMix", "build_workload", "run_load"]


@dataclasses.dataclass(frozen=True)
class QueryMix:
    """Fractions of each query kind; must sum to 1."""

    point: float = 0.2
    one_hop: float = 0.4
    two_hop: float = 0.4

    def __post_init__(self) -> None:
        fr = (self.point, self.one_hop, self.two_hop)
        if any(f < 0 for f in fr):
            raise ValueError(f"mix fractions must be >= 0, got {fr}")
        if abs(sum(fr) - 1.0) > 1e-6:
            raise ValueError(f"mix fractions must sum to 1, got {sum(fr)}")

    @classmethod
    def parse(cls, text: str) -> "QueryMix":
        """``"point=0.2,one_hop=0.4,two_hop=0.4"`` -> QueryMix."""
        fields = {f.name for f in dataclasses.fields(cls)}
        out = {}
        for part in text.split(","):
            part = part.strip()
            if not part:
                continue
            name, _, val = part.partition("=")
            if name not in fields:
                raise ValueError(
                    f"unknown mix component {name!r}; expected {sorted(fields)}"
                )
            out[name] = float(val)
        return cls(**out)


def build_workload(
    graph, num_queries: int, mix: QueryMix, seed: int = 0,
    degree_biased: bool = True,
) -> list[tuple[str, int]]:
    """Deterministic ``[(kind, seed_vertex), ...]`` of length num_queries."""
    from repro.db.workload import ldbc_query_mix

    seeds = ldbc_query_mix(
        graph, num_queries, seed=seed, degree_biased=degree_biased
    )
    rng = np.random.default_rng(seed + 0x5EED)
    kinds = rng.choice(
        ("point", "one_hop", "two_hop"),
        size=num_queries,
        p=(mix.point, mix.one_hop, mix.two_hop),
    )
    return [(str(k), int(s)) for k, s in zip(kinds, seeds)]


def run_load(
    service,
    num_queries: int = 1000,
    concurrency: int = 64,
    mix: QueryMix | str | None = None,
    seed: int = 0,
    mode: str = "closed",
    rate_qps: float | None = None,
    workload: list[tuple[str, int]] | None = None,
    degree_biased: bool = True,
) -> ServingReport:
    """Drive ``service`` with a mixed query load and summarize the outcome.

    The service is started/stopped here when it is not already running, so
    ``run_load(result.serve(), ...)`` is a one-liner.
    """
    if isinstance(mix, str):
        mix = QueryMix.parse(mix)
    mix = mix or QueryMix()
    if mode not in ("closed", "open"):
        raise ValueError(f"mode must be 'closed' or 'open', got {mode!r}")
    if workload is None:
        workload = build_workload(
            service.graph, num_queries, mix, seed=seed,
            degree_biased=degree_biased,
        )
    total = len(workload)
    owns_service = not service.running
    if owns_service:
        service.start()
    try:
        if total == 0:
            wall_s, records = 0.0, []
        elif mode == "closed":
            wall_s, records = _closed_loop(service, workload, concurrency)
        else:
            if not rate_qps or rate_qps <= 0:
                raise ValueError("open-loop mode needs rate_qps > 0")
            wall_s, records = _open_loop(service, workload, rate_qps)
        # quiesce before reading the per-partition counters
        if owns_service:
            service.stop()
            owns_service = False
        return summarize(
            records,
            service.loads(),
            wall_s,
            concurrency if mode == "closed" else max(int(concurrency), 1),
            service.model,
            mode,
            replication=service.replication_stats(),
        )
    finally:
        if owns_service:
            service.stop()


def _closed_loop(service, workload, concurrency):
    concurrency = max(int(concurrency), 1)
    pending = deque(enumerate(workload))
    records: list = []
    lock = threading.Lock()
    done = threading.Event()
    total = len(workload)

    def on_done(rec):
        with lock:
            records.append(rec)
            nxt = pending.popleft() if pending else None
            finished = len(records) == total
        if nxt is not None:
            qid, (kind, vseed) = nxt
            service.submit(kind, vseed, qid=qid, on_done=on_done)
        if finished:
            done.set()

    t0 = time.perf_counter()
    with lock:
        first = [pending.popleft() for _ in range(min(concurrency, total))]
    for qid, (kind, vseed) in first:
        service.submit(kind, vseed, qid=qid, on_done=on_done)
    if not done.wait(timeout=600):  # pragma: no cover - hang guard
        raise RuntimeError(
            f"closed-loop load timed out: {len(records)}/{total} completed"
        )
    return time.perf_counter() - t0, records


def _open_loop(service, workload, rate_qps):
    records: list = []
    lock = threading.Lock()
    done = threading.Event()
    total = len(workload)

    def on_done(rec):
        with lock:
            records.append(rec)
            finished = len(records) == total
        if finished:
            done.set()

    t0 = time.perf_counter()
    gap = 1.0 / float(rate_qps)
    for qid, (kind, vseed) in enumerate(workload):
        arrival = t0 + qid * gap
        now = time.perf_counter()
        if arrival > now:
            time.sleep(arrival - now)
        service.submit(
            kind, vseed, qid=qid, on_done=on_done, arrival_s=arrival
        )
    if not done.wait(timeout=600):  # pragma: no cover - hang guard
        raise RuntimeError(
            f"open-loop load timed out: {len(records)}/{total} completed"
        )
    return time.perf_counter() - t0, records
