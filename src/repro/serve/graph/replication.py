"""Boundary-vertex replication: mirror hot boundary vertices into neighbours.

A vertex ``v`` owned by partition ``q`` is a *boundary* vertex for partition
``p`` when some vertex of ``p`` has an edge to ``v``. Every 2-hop query that
is mastered at ``p`` and reaches ``v`` in its first hop must ship an RPC to
``q`` to scan ``v``'s adjacency - the dominant cross-partition cost of the
serving layer. Replicating ``v``'s record (property + adjacency list) into
``p`` removes that RPC for every such query, at the storage cost of one more
copy of ``v``'s adjacency.

:func:`plan_replication` chooses which ``(vertex, partition)`` replica pairs
to materialize under a budget, greedily by *demand*: the number of cut edges
from partition ``p`` into ``v`` (an unbiased proxy for how often a ``p``-
mastered traversal will need ``v``, exact under a uniform seed distribution
and a strong signal under the degree-biased LDBC mix, since high-degree
boundary vertices accumulate demand from many neighbours). Ties break on the
replica key so the plan is deterministic for a given assignment.

The plan never changes query *answers* - a replica is a byte-identical copy
of the owner's adjacency row - only where the scan happens. Tests pin this
bit-parity across budgets.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ReplicationPlan", "plan_replication", "resolve_budget"]


@dataclasses.dataclass(frozen=True)
class ReplicationPlan:
    """Chosen replicas: ``vertices[i]`` is mirrored into ``partitions[i]``.

    ``demand[i]`` is the number of cut edges that replica absorbs (how many
    (p-vertex -> v) edges stop needing the owner). ``adjacency_entries`` is
    the total number of adjacency entries mirrored - the storage bill.
    """

    k: int
    budget_pairs: int
    vertices: np.ndarray  # int64[R]
    partitions: np.ndarray  # int64[R] destination partition of each replica
    demand: np.ndarray  # int64[R] cut edges covered by each replica
    adjacency_entries: int

    @property
    def num_replicas(self) -> int:
        return int(self.vertices.shape[0])

    def replicas_into(self, p: int) -> np.ndarray:
        """Sorted vertex ids replicated into partition ``p``."""
        return np.sort(self.vertices[self.partitions == p])

    def stats(self) -> dict:
        return {
            "budget_pairs": self.budget_pairs,
            "num_replicas": self.num_replicas,
            "demand_covered": int(self.demand.sum()),
            "adjacency_entries": self.adjacency_entries,
        }


def resolve_budget(budget: float, num_vertices: int) -> int:
    """``replication_budget`` semantics: a value in ``(0, 1)`` is a fraction
    of ``|V|`` replica pairs; ``>= 1`` is an absolute pair count; ``0`` means
    no replication."""
    if budget < 0:
        raise ValueError(f"replication_budget must be >= 0, got {budget!r}")
    if budget == 0:
        return 0
    if budget < 1:
        return int(budget * num_vertices)
    return int(budget)


def plan_replication(graph, assignment, k: int, budget: float) -> ReplicationPlan:
    """Greedy demand-ordered boundary replication under ``budget`` pairs."""
    assignment = np.asarray(assignment, dtype=np.int64)
    pairs = resolve_budget(float(budget), graph.num_vertices)
    empty = np.empty(0, dtype=np.int64)
    if pairs == 0 or graph.num_edges == 0 or k < 2:
        return ReplicationPlan(k, pairs, empty, empty, empty, 0)
    edges = graph.edges_array()  # (|E|, 2), each undirected edge once
    pu, pv = assignment[edges[:, 0]], assignment[edges[:, 1]]
    cut = pu != pv
    if not cut.any():
        return ReplicationPlan(k, pairs, empty, empty, empty, 0)
    # demand keys: replicating v into part(u) covers edge (u, v); both
    # directions of every cut edge generate one candidate pair
    cand_v = np.concatenate([edges[cut, 1], edges[cut, 0]])
    cand_p = np.concatenate([pu[cut], pv[cut]])
    key = cand_v * np.int64(k) + cand_p
    uniq, demand = np.unique(key, return_counts=True)
    # highest demand first; ties break on the key for determinism
    order = np.lexsort((uniq, -demand))[:pairs]
    chosen = uniq[order]
    verts = chosen // k
    dests = chosen % k
    adjacency_entries = int(np.diff(graph.indptr)[verts].sum())
    return ReplicationPlan(
        k=k,
        budget_pairs=pairs,
        vertices=verts.astype(np.int64),
        partitions=dests.astype(np.int64),
        demand=demand[order].astype(np.int64),
        adjacency_entries=adjacency_entries,
    )
