"""LM serving: prefill + decode step builders and sharded flash-decode
attention. The implementations live in their natural homes
(:mod:`repro.train.step`, :mod:`repro.models.attention`; see
``launch/serve.py`` for the driver) - this module is the public LM-serving
namespace, moved here from the ``repro.serve`` package root so graph serving
(:mod:`repro.serve.graph`) and LM serving coexist without collision."""
from repro.models.attention import gqa_flash_decode, mla_flash_decode
from repro.train.step import make_decode_step, make_prefill_step

__all__ = [
    "make_prefill_step",
    "make_decode_step",
    "gqa_flash_decode",
    "mla_flash_decode",
]
