"""Attention variants: GQA (qk-norm, sliding window), MLA, cross-attention.

Three execution regimes:
  * train / short prefill  - plain einsum attention (XLA fuses fine at 4k);
  * long prefill (>= 8k)   - chunked online-softmax attention (lax.scan over
    kv blocks; jnp mirror of the Pallas flash kernel, bounded memory);
  * decode                 - the KV cache shards its *sequence* dim over the
    TP axis ("model"); a shard_map flash-decode computes per-stripe partial
    softmax and merges with pmax/psum. This sidesteps GQA head-count /
    mesh-size divisibility entirely (heads stay whole, sequence splits) and
    is what makes decode_32k / long_500k fit in HBM.

MLA (DeepSeek-V2) caches only the compressed latent (c_kv + rope key) and
decodes in the absorbed form - the paper-faithful memory win.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.layers import Axes, apply_rope, qk_head_norm, rms_norm

CHUNKED_THRESHOLD = 8192


# ------------------------------------------------------------------- params
def init_attention(key, cfg, dtype, cross: bool = False) -> dict:
    d = cfg.d_model
    dh = cfg.head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    s = d**-0.5
    if cfg.use_mla and not cross:
        keys = jax.random.split(key, 6)
        qr = cfg.q_lora_rank
        nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        p = {
            "wkv_a": jax.random.normal(keys[0], (d, cfg.kv_lora_rank + rope_d), dtype) * s,
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
            "wkv_b": jax.random.normal(
                keys[1], (cfg.kv_lora_rank, h * (nope + vd)), dtype
            ) * (cfg.kv_lora_rank**-0.5),
            "wo": jax.random.normal(keys[2], (h * vd, d), dtype) * ((h * vd) ** -0.5),
        }
        if qr:
            p["wq_a"] = jax.random.normal(keys[3], (d, qr), dtype) * s
            p["q_norm"] = jnp.ones((qr,), dtype)
            p["wq_b"] = jax.random.normal(
                keys[4], (qr, h * (nope + rope_d)), dtype
            ) * (qr**-0.5)
        else:
            p["wq"] = jax.random.normal(keys[3], (d, h * (nope + rope_d)), dtype) * s
        return p
    keys = jax.random.split(key, 5)
    p = {
        "wq": jax.random.normal(keys[0], (d, h * dh), dtype) * s,
        "wk": jax.random.normal(keys[1], (d, hkv * dh), dtype) * s,
        "wv": jax.random.normal(keys[2], (d, hkv * dh), dtype) * s,
        "wo": jax.random.normal(keys[3], (h * dh, d), dtype) * ((h * dh) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((dh,), dtype)
        p["k_scale"] = jnp.ones((dh,), dtype)
    if cross and cfg.cross_attn_gated:
        p["gate"] = jnp.zeros((1,), dtype)
    return p


def specs_attention(cfg, ax: Axes, cross: bool = False) -> dict:
    if cfg.use_mla and not cross:
        p = {
            "wkv_a": P(ax.dp, None),
            "kv_norm": P(None),
            "wkv_b": P(ax.dp, ax.tp),
            "wo": P(ax.tp, ax.dp),
        }
        if cfg.q_lora_rank:
            p["wq_a"] = P(ax.dp, None)
            p["q_norm"] = P(None)
            p["wq_b"] = P(ax.dp, ax.tp)
        else:
            p["wq"] = P(ax.dp, ax.tp)
        return p
    if getattr(cfg, "attn_weight_shard", "d") == "f" and not cross:
        full = (*ax.dp, ax.tp)
        p = {
            "wq": P(None, full),
            "wk": P(None, full),
            "wv": P(None, full),
            "wo": P(full, None),
        }
    else:
        p = {
            "wq": P(ax.dp, ax.tp),
            "wk": P(ax.dp, ax.tp),
            "wv": P(ax.dp, ax.tp),
            "wo": P(ax.tp, ax.dp),
        }
    if cfg.qk_norm:
        p["q_scale"] = P(None)
        p["k_scale"] = P(None)
    if cross and cfg.cross_attn_gated:
        p["gate"] = P(None)
    return p


# ------------------------------------------------------------ full attention
def _sdpa(q, k, v, causal, window, q_offset=0):
    """q: [B,T,H,dh] k/v: [B,S,Hkv,dh] -> [B,T,H,dh] (fp32 softmax)."""
    b, t, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, t, hkv, g, dh).astype(jnp.float32) * (dh**-0.5)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k.astype(jnp.float32))
    qpos = jnp.arange(t)[:, None] + q_offset
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v.astype(jnp.float32))
    return out.reshape(b, t, h, v.shape[-1]).astype(q.dtype)  # v dim may != q dim (MLA)


def _chunked_sdpa(q, k, v, causal, window, chunk=1024):
    """Online-softmax over kv chunks (bounded memory for 32k+ prefill)."""
    b, t, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    assert s % chunk == 0, (s, chunk)
    qg = q.reshape(b, t, hkv, g, dh).astype(jnp.float32) * (dh**-0.5)
    kc = k.reshape(b, s // chunk, chunk, hkv, dh)
    vc = v.reshape(b, s // chunk, chunk, hkv, v.shape[-1])
    qpos = jnp.arange(t)[:, None]

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ci, k_blk, v_blk = inp
        scores = jnp.einsum(
            "bthgd,bchd->bhgtc", qg, k_blk.astype(jnp.float32)
        )
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        mask = jnp.ones((t, chunk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        m_cur = jnp.maximum(m_prev, scores.max(-1))
        alpha = jnp.exp(m_prev - m_cur)
        probs = jnp.exp(scores - m_cur[..., None])
        l_cur = l_prev * alpha + probs.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgtc,bchd->bhgtd", probs, v_blk.astype(jnp.float32)
        )
        return (m_cur, l_cur, acc), None

    vd = v.shape[-1]
    m0 = jnp.full((b, hkv, g, t), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, t), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, t, vd), jnp.float32)
    ks = jnp.moveaxis(kc, 1, 0)
    vs = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(s // chunk), ks, vs)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, -2, 1).reshape(b, t, h, vd)
    return out.astype(q.dtype)


def gqa_forward(x, p, cfg, window, kv_x=None, causal=None, seq_axes=None):
    """Full-sequence attention (train / prefill). kv_x: cross-attn source.
    seq_axes=(dp, tp): sequence-parallel mode - q keeps its seq dim sharded
    over tp while K/V are all-gathered (cheap: Hkv*dh << H*dh)."""
    b, t, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    q = (x @ p["wq"]).reshape(b, t, h, dh)
    k = (src @ p["wk"]).reshape(b, src.shape[1], hkv, dh)
    v = (src @ p["wv"]).reshape(b, src.shape[1], hkv, dh)
    if seq_axes is not None:
        dp_, tp_ = seq_axes
        q = jax.lax.with_sharding_constraint(q, P(dp_, tp_, None, None))
        k = jax.lax.with_sharding_constraint(k, P(dp_, None, None, None))
        v = jax.lax.with_sharding_constraint(v, P(dp_, None, None, None))
    if cfg.qk_norm:
        q = qk_head_norm(q, p["q_scale"])
        k = qk_head_norm(k, p["k_scale"])
    is_causal = cfg.causal if causal is None else causal
    if kv_x is None:  # self-attention gets RoPE
        pos = jnp.arange(t)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    s = src.shape[1]
    if s >= CHUNKED_THRESHOLD:
        out = _chunked_sdpa(q, k, v, is_causal and kv_x is None, window)
    else:
        out = _sdpa(q, k, v, is_causal and kv_x is None, window)
    if seq_axes is not None:
        out = jax.lax.with_sharding_constraint(
            out, P(seq_axes[0], seq_axes[1], None, None)
        )
    y = out.reshape(b, t, h * dh) @ p["wo"]
    if kv_x is not None and "gate" in p:
        y = jnp.tanh(p["gate"].astype(jnp.float32)).astype(y.dtype) * y
    return y, (k, v)


# ----------------------------------------------------------------- MLA paths
def mla_qkv(x, p, cfg):
    """Expanded-form MLA projections for train/prefill."""
    b, t, d = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    if cfg.q_lora_rank:
        qa = rms_norm(x @ p["wq_a"], {"scale": p["q_norm"]})
        q = (qa @ p["wq_b"]).reshape(b, t, h, nope + rope_d)
    else:
        q = (x @ p["wq"]).reshape(b, t, h, nope + rope_d)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    kv_a = x @ p["wkv_a"]  # [B,T,r+rope]
    c_kv = rms_norm(kv_a[..., :r], {"scale": p["kv_norm"]})
    k_pe = kv_a[..., r:]
    pos = jnp.arange(t)
    q_pe = apply_rope(q_pe, pos, cfg.rope_theta)
    k_pe = apply_rope(k_pe[:, :, None, :], pos, cfg.rope_theta)[:, :, 0]
    kv = (c_kv @ p["wkv_b"]).reshape(b, t, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None], (b, t, h, rope_d))], -1
    )
    qq = jnp.concatenate([q_nope, q_pe], -1)
    return qq, k, v, c_kv, k_pe


def mla_forward(x, p, cfg, window=None, seq_axes=None):
    b, t, d = x.shape
    q, k, v, c_kv, k_pe = mla_qkv(x, p, cfg)
    if seq_axes is not None:
        dp_, tp_ = seq_axes
        q = jax.lax.with_sharding_constraint(q, P(dp_, tp_, None, None))
        k = jax.lax.with_sharding_constraint(k, P(dp_, None, None, None))
        v = jax.lax.with_sharding_constraint(v, P(dp_, None, None, None))
    if t >= CHUNKED_THRESHOLD:
        out = _chunked_sdpa(q, k, v, cfg.causal, window)
    else:
        out = _sdpa(q, k, v, cfg.causal, window)
    y = out.reshape(b, t, cfg.n_heads * cfg.v_head_dim) @ p["wo"]
    return y, (c_kv, k_pe)


def _usable_dp(ax: Axes, mesh, batch: int) -> tuple[str, ...] | None:
    """dp axes if the batch divides them, else None (replicate batch -
    the long_500k batch=1 case)."""
    n = 1
    for a in ax.dp:
        n *= int(mesh.shape[a])
    return ax.dp if batch % n == 0 else None


# --------------------------------------------------- sharded flash decode
def gqa_flash_decode(q, k_cache, v_cache, pos, window, ax: Axes, mesh):
    """q: [B,H,dh]; caches: [B,S,Hkv,dh] with S sharded over ax.tp.
    Partial softmax per sequence stripe, pmax/psum merge. Heads stay whole,
    so GQA ratios never have to divide the mesh."""
    tp = ax.tp
    n_shards = int(mesh.shape[tp])
    s_total = k_cache.shape[1]
    stripe = s_total // n_shards

    def local_fn(q_loc, k_loc, v_loc, pos_arr):
        # q_loc: [Bl,H,dh] (replicated over tp); k/v_loc: [Bl,stripe,Hkv,dh]
        bl, h, dh = q_loc.shape
        hkv = k_loc.shape[2]
        g = h // hkv
        pos_s = pos_arr[0]
        shard = jax.lax.axis_index(tp)
        base = shard * stripe
        qg = q_loc.reshape(bl, hkv, g, dh).astype(jnp.float32) * (dh**-0.5)
        scores = jnp.einsum("bhgd,bshd->bhgs", qg, k_loc.astype(jnp.float32))
        kpos = base + jnp.arange(stripe)[None, None, None, :]
        mask = kpos <= pos_s
        if window is not None:
            mask &= kpos > pos_s - window
        scores = jnp.where(mask, scores, -1e30)
        m_loc = scores.max(-1)  # [bl,hkv,g]
        m_glob = jax.lax.pmax(m_loc, tp)
        probs = jnp.exp(scores - m_glob[..., None])
        l_loc = probs.sum(-1)
        o_loc = jnp.einsum("bhgs,bshd->bhgd", probs, v_loc.astype(jnp.float32))
        l_glob = jax.lax.psum(l_loc, tp)
        o_glob = jax.lax.psum(o_loc, tp)
        out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return out.reshape(bl, h, dh).astype(q_loc.dtype)

    dp = _usable_dp(ax, mesh, q.shape[0])
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(dp, None, None),
            P(dp, tp, None, None),
            P(dp, tp, None, None),
            P(None),
        ),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, pos[None])


def mla_flash_decode(q_lat, q_pe, ckv_cache, kpe_cache, pos, ax: Axes, mesh):
    """Absorbed-form MLA decode over a latent cache sharded on sequence.
    q_lat: [B,H,r], q_pe: [B,H,rope]; caches: [B,S,r], [B,S,rope].
    Returns ctx_lat: [B,H,r]."""
    tp = ax.tp
    n_shards = int(mesh.shape[tp])
    stripe = ckv_cache.shape[1] // n_shards

    def local_fn(ql, qp, ckv, kpe, pos_arr):
        bl, h, r = ql.shape
        pos_s = pos_arr[0]
        base = jax.lax.axis_index(tp) * stripe
        scores = jnp.einsum(
            "bhr,bsr->bhs", ql.astype(jnp.float32), ckv.astype(jnp.float32)
        ) + jnp.einsum(
            "bhe,bse->bhs", qp.astype(jnp.float32), kpe.astype(jnp.float32)
        )
        scores = scores * ((r + qp.shape[-1]) ** -0.5)
        kpos = base + jnp.arange(stripe)[None, None, :]
        scores = jnp.where(kpos <= pos_s, scores, -1e30)
        m_loc = scores.max(-1)
        m_glob = jax.lax.pmax(m_loc, tp)
        probs = jnp.exp(scores - m_glob[..., None])
        l_glob = jax.lax.psum(probs.sum(-1), tp)
        ctx = jax.lax.psum(
            jnp.einsum("bhs,bsr->bhr", probs, ckv.astype(jnp.float32)), tp
        )
        out = ctx / jnp.maximum(l_glob, 1e-30)[..., None]
        return out.astype(ql.dtype)

    dp = _usable_dp(ax, mesh, q_lat.shape[0])
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(dp, None, None),
            P(dp, None, None),
            P(dp, tp, None),
            P(dp, tp, None),
            P(None),
        ),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(q_lat, q_pe, ckv_cache, kpe_cache, pos[None])
