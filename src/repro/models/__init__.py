from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import Axes
from repro.models.model import Model

__all__ = ["LayerSpec", "ModelConfig", "Model", "Axes"]
