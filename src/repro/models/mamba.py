"""Mamba-1 block (falcon-mamba / jamba mixer).

Train/prefill runs a chunked selective scan (sequential lax.scan over chunks,
associative scan inside a chunk - bounds the [T, D, N] intermediates); the
Pallas kernel (:mod:`repro.kernels.mamba_scan`) is the TPU runtime path.
Decode is a single recurrence step on (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import Axes


def init_mamba(key, cfg, dtype) -> dict:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.ssm_state
    keys = jax.random.split(key, 7)
    s = d**-0.5
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": jax.random.normal(keys[0], (d, 2 * di), dtype) * s,
        "conv_w": jax.random.normal(keys[1], (cfg.d_conv, di), dtype) * 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(keys[2], (di, dt_rank + 2 * n), dtype) * (di**-0.5),
        "dt_proj": jax.random.normal(keys[3], (dt_rank, di), dtype) * (dt_rank**-0.5),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
        ),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(keys[4], (di, d), dtype) * (di**-0.5),
    }


def specs_mamba(ax: Axes) -> dict:
    return {
        "in_proj": P(ax.dp, ax.tp),
        "conv_w": P(None, ax.tp),
        "conv_b": P(ax.tp),
        "x_proj": P(ax.tp, None),
        "dt_proj": P(None, ax.tp),
        "dt_bias": P(ax.tp),
        "a_log": P(ax.tp, None),
        "d_skip": P(ax.tp),
        "out_proj": P(ax.tp, ax.dp),
    }


def _ssm_scan_chunked(x, dt, a, b, c, chunk: int = 512):
    """h_t = exp(dt_t a) h_{t-1} + (dt_t x_t) b_t ; y_t = h_t . c_t
    x/dt: [B,T,D]; a: [D,N]; b/c: [B,T,N] -> y [B,T,D] (fp32)."""
    bsz, t, d = x.shape
    n = a.shape[1]
    chunk = min(chunk, t)
    while t % chunk:
        chunk //= 2
    nc = t // chunk
    # reshape into chunks and scan sequentially across them
    xs = x.reshape(bsz, nc, chunk, d)
    dts = dt.reshape(bsz, nc, chunk, d)
    bs = b.reshape(bsz, nc, chunk, n)
    cs = c.reshape(bsz, nc, chunk, n)

    def chunk_step(h0, inp):
        xc, dtc, bc, cc = inp  # [B,chunk,D], ..., [B,chunk,N]
        dac = jnp.exp(dtc[..., None] * a)  # [B,chunk,D,N]
        u = (dtc * xc)[..., None] * bc[:, :, None, :]  # [B,chunk,D,N]

        def combine(e1, e2):
            a1, u1 = e1
            a2, u2 = e2
            return a1 * a2, u1 * a2 + u2

        da_cum, u_cum = jax.lax.associative_scan(combine, (dac, u), axis=1)
        h = da_cum * h0[:, None] + u_cum  # [B,chunk,D,N]
        y = jnp.einsum("bcdn,bcn->bcd", h, cc)
        return h[:, -1], y

    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    _, ys = jax.lax.scan(
        chunk_step,
        h0,
        (
            jnp.moveaxis(xs, 1, 0),
            jnp.moveaxis(dts, 1, 0),
            jnp.moveaxis(bs, 1, 0),
            jnp.moveaxis(cs, 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1).reshape(bsz, t, d)


def mamba_forward(x, p, cfg):
    """Full-sequence Mamba block. Returns (y, (conv_state, ssm_state))."""
    bsz, t, d = x.shape
    di = cfg.mamba_expand * d
    n = cfg.ssm_state
    dt_rank = max(d // 16, 1)
    xz = x @ p["in_proj"]  # [B,T,2*di]
    xi, z = jnp.split(xz, 2, axis=-1)
    # depthwise causal conv along T
    pad = cfg.d_conv - 1
    xi_pad = jnp.pad(xi, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(
        xi_pad[:, i : i + t] * p["conv_w"][i][None, None, :]
        for i in range(cfg.d_conv)
    ) + p["conv_b"]
    xc = jax.nn.silu(conv)
    proj = xc @ p["x_proj"]  # [B,T,dt_rank+2N]
    dt_in = proj[..., :dt_rank]
    b = proj[..., dt_rank : dt_rank + n].astype(jnp.float32)
    c = proj[..., dt_rank + n :].astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_in @ p["dt_proj"] + p["dt_bias"].astype(dt_in.dtype)
    ).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])  # [di, N]
    y = _ssm_scan_chunked(xc.astype(jnp.float32), dt, a, b, c)
    y = y + xc.astype(jnp.float32) * p["d_skip"][None, None, :]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    conv_state = xi_pad[:, t : t + pad] if pad else jnp.zeros((bsz, 0, di), x.dtype)
    # final ssm state is not tracked in full-seq mode (recomputed at serve
    # prefill); decode path maintains it incrementally.
    ssm_state = jnp.zeros((bsz, di, n), jnp.float32)
    return y, (conv_state, ssm_state)


def mamba_decode_step(x, p, cfg, conv_state, ssm_state):
    """One-token step. x: [B,1,D]; conv_state: [B,d_conv-1,di];
    ssm_state: [B,di,N]. Returns (y [B,1,D], new states)."""
    bsz, _, d = x.shape
    di = cfg.mamba_expand * d
    n = cfg.ssm_state
    dt_rank = max(d // 16, 1)
    xz = x[:, 0] @ p["in_proj"]  # [B, 2di]
    xi, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([conv_state, xi[:, None]], axis=1)  # [B,d_conv,di]
    conv = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(conv)  # [B, di]
    proj = xc @ p["x_proj"]
    dt_in = proj[..., :dt_rank]
    b = proj[..., dt_rank : dt_rank + n].astype(jnp.float32)
    c = proj[..., dt_rank + n :].astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_in @ p["dt_proj"] + p["dt_bias"].astype(dt_in.dtype)
    ).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[..., None] * a[None])  # [B,di,N]
    h = da * ssm_state + (dt * xc.astype(jnp.float32))[..., None] * b[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c) + xc.astype(jnp.float32) * p["d_skip"][None]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    new_conv = window[:, 1:]
    return y[:, None], (new_conv, h)
