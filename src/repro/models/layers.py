"""Shared layers: norms, RoPE, dense FFN, and shard_map expert-parallel MoE.

Sharding convention (2-D FSDP x TP, "pod" = extra pure-DP axis):
  * ``ax.tp``  - the tensor-parallel mesh axis ("model"),
  * ``ax.dp``  - tuple of data axes params are FSDP-sharded over
                 (("data",) single-pod, ("pod","data") multi-pod).
Every init_* has a matching specs_* mirroring the pytree with PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


@dataclasses.dataclass(frozen=True)
class Axes:
    dp: tuple[str, ...] = ("data",)
    tp: str = "model"

    @property
    def batch(self):
        return self.dp  # activation batch axes


# --------------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def specs_rmsnorm() -> dict:
    return {"scale": P(None)}


def rms_norm(x, p, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def qk_head_norm(x, scale, eps: float = 1e-6):
    """Per-head RMS norm over head_dim (qwen3/gemma3). x: [..., H, Dh]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- RoPE
def rope_freqs(dh: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x, positions, theta: float):
    """x: [..., T, H, Dh] (rotate pairs); positions: [..., T]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, Dh/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- dense FFN
def init_dense_ffn(key, d: int, f: int, activation: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d**-0.5
    s_out = f**-0.5
    p = {
        "w_in": jax.random.normal(k1, (d, f), dtype) * s_in,
        "w_out": jax.random.normal(k2, (f, d), dtype) * s_out,
    }
    if activation == "swiglu":
        p["w_gate"] = jax.random.normal(k3, (d, f), dtype) * s_in
    return p


def specs_dense_ffn(ax: Axes, activation: str, weight_shard: str = "d") -> dict:
    if weight_shard == "f":
        # weight-stationary decode: hidden dim sharded over every axis,
        # activations replicated in D, partial outputs psum'd by GSPMD
        full = (*ax.dp, ax.tp)
        p = {"w_in": P(None, full), "w_out": P(full, None)}
        if activation == "swiglu":
            p["w_gate"] = P(None, full)
        return p
    p = {"w_in": P(ax.dp, ax.tp), "w_out": P(ax.tp, ax.dp)}
    if activation == "swiglu":
        p["w_gate"] = P(ax.dp, ax.tp)
    return p


def _act(h, g, activation: str):
    if activation == "swiglu":
        return jax.nn.silu(g) * h
    if activation == "gelu":
        return jax.nn.gelu(h)
    if activation == "sq_relu":
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(activation)


def dense_ffn(x, p, activation: str):
    h = x @ p["w_in"]
    g = x @ p["w_gate"] if "w_gate" in p else None
    return _act(h, g, activation) @ p["w_out"]


# ------------------------------------------------------ expert-parallel MoE
def init_moe(key, d: int, f: int, n_experts: int, n_shared: int,
             activation: str, dtype) -> dict:
    keys = jax.random.split(key, 6)
    s_in = d**-0.5
    s_out = f**-0.5
    p = {
        "router": jax.random.normal(keys[0], (d, n_experts), jnp.float32) * s_in,
        "w_in": jax.random.normal(keys[1], (n_experts, d, f), dtype) * s_in,
        "w_out": jax.random.normal(keys[2], (n_experts, f, d), dtype) * s_out,
    }
    if activation == "swiglu":
        p["w_gate"] = jax.random.normal(keys[3], (n_experts, d, f), dtype) * s_in
    if n_shared:
        p["shared"] = init_dense_ffn(keys[4], d, n_shared * f, activation, dtype)
    return p


def specs_moe(ax: Axes, activation: str, n_shared: int) -> dict:
    p = {
        "router": P(None, None),
        "w_in": P(ax.tp, ax.dp, None),
        "w_out": P(ax.tp, None, ax.dp),
    }
    if activation == "swiglu":
        p["w_gate"] = P(ax.tp, ax.dp, None)
    if n_shared:
        p["shared"] = specs_dense_ffn(ax, activation)
    return p


def _positions_in_expert(e_flat, n_experts: int):
    """pos[i] = rank of entry i within its expert group (sort-based, no
    [T,E] cumsum materialisation)."""
    order = jnp.argsort(e_flat)
    sorted_e = e_flat[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(n_experts))
    pos_sorted = jnp.arange(e_flat.shape[0]) - start[sorted_e]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    return pos


def moe_ffn(x, p, cfg, ax: Axes, mesh):
    """Expert-parallel MoE under shard_map: explicit token all-to-all over the
    TP axis, per-device grouped GEMMs over its local experts, FSDP weight
    all-gather over the dp axes. Returns (y, aux_loss)."""
    e = cfg.n_experts
    top_k = cfg.top_k
    act = cfg.activation
    dp, tp = ax.dp, ax.tp
    has_gate = act == "swiglu"
    n_shards = int(mesh.shape[tp])
    assert e % n_shards == 0, (e, n_shards)
    el = e // n_shards

    def local_fn(x_loc, router, w_in, w_gate, w_out):
        # x_loc: [Bl, S, D]; w_in/w_gate: [El, Dl, F]; w_out: [El, F, Dl]
        bl, s, d = x_loc.shape
        tl = bl * s
        # floor 8 aligns training tiles; decode batches are tiny - adapt
        cap_floor = 8 if tl * top_k >= 8 * e else 1
        cap = int(max(cap_floor, (-(-tl * top_k // e)) * cfg.capacity_factor))
        tokens = x_loc.reshape(tl, d)
        logits = tokens.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        w_topk, idx = jax.lax.top_k(probs, top_k)  # [Tl, k]
        w_topk = w_topk / jnp.maximum(w_topk.sum(-1, keepdims=True), 1e-9)
        # aux load-balance loss (Switch-style)
        frac_routed = jnp.mean(
            jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0
        )
        aux = e * jnp.mean(frac_routed * jnp.mean(probs, axis=0))
        e_flat = idx.reshape(-1)  # [Tl*k]
        pos = _positions_in_expert(e_flat, e)
        keep = pos < cap
        dest = jnp.where(keep, e_flat * cap + pos, e * cap)  # overflow slot
        tok_rep = jnp.repeat(tokens, top_k, axis=0)  # [Tl*k, D]
        send = jnp.zeros((e * cap + 1, d), x_loc.dtype).at[dest].set(tok_rep)
        # tiled all-to-all: block q (= experts of tp-shard q) goes to shard q
        recv = jax.lax.all_to_all(
            send[: e * cap], tp, split_axis=0, concat_axis=0, tiled=True
        )
        # recv block j = tokens shard j routed to MY local experts
        grouped = (
            recv.reshape(n_shards, el, cap, d)
            .transpose(1, 0, 2, 3)
            .reshape(el, n_shards * cap, d)
        )
        # FSDP weight gather over dp axes: minor axis first so the chunk
        # order reconstructs the global D dimension
        w_in_full, w_out_full, w_gate_full = w_in, w_out, w_gate
        for a in reversed(dp):
            w_in_full = jax.lax.all_gather(w_in_full, a, axis=1, tiled=True)
            w_out_full = jax.lax.all_gather(w_out_full, a, axis=2, tiled=True)
            if has_gate:
                w_gate_full = jax.lax.all_gather(w_gate_full, a, axis=1, tiled=True)
        # fp32 accumulation end-to-end through the expert GEMMs: the grouped
        # shapes depend on the EP layout (el vs e experts, n_shards*cap rows),
        # so low-precision intermediates would round differently per mesh and
        # break the 1-device <-> EP parity contract
        h = jnp.einsum("ecd,edf->ecf", grouped, w_in_full,
                       preferred_element_type=jnp.float32)
        if has_gate:
            g = jnp.einsum("ecd,edf->ecf", grouped, w_gate_full,
                           preferred_element_type=jnp.float32)
            h = jax.nn.silu(g) * h
        elif act == "gelu":
            h = jax.nn.gelu(h)
        else:
            r = jax.nn.relu(h)
            h = r * r
        y = jnp.einsum("ecf,efd->ecd", h, w_out_full,
                       preferred_element_type=jnp.float32).astype(x_loc.dtype)
        back = (
            y.reshape(el, n_shards, cap, d)
            .transpose(1, 0, 2, 3)
            .reshape(e * cap, d)
        )
        ret = jax.lax.all_to_all(back, tp, split_axis=0, concat_axis=0, tiled=True)
        ret_flat = jnp.concatenate([ret, jnp.zeros((1, d), x_loc.dtype)], axis=0)
        vals = ret_flat[dest].astype(jnp.float32) * (
            keep * w_topk.reshape(-1)
        )[:, None]
        out = vals.reshape(tl, top_k, d).sum(axis=1).astype(x_loc.dtype)
        return out.reshape(bl, s, d), aux[None]

    n_dp = 1
    for a in dp:
        n_dp *= int(mesh.shape[a])
    dp_x = dp if x.shape[0] % n_dp == 0 else None  # batch=1 decode: replicate
    spec_x = P(dp_x, None, None)
    gate_spec = P(tp, dp, None) if has_gate else P(None)
    gate_arg = p.get("w_gate", jnp.zeros((1,), x.dtype))
    y, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(spec_x, P(None, None), P(tp, dp, None), gate_spec, P(tp, None, dp)),
        out_specs=(spec_x, P(dp_x)),
        check_vma=False,
    )(x, p["router"], p["w_in"], gate_arg, p["w_out"])
    y = jax.ad_checkpoint.checkpoint_name(y, "moe_out")
    aux_loss = aux.mean()
    if "shared" in p:
        y = y + dense_ffn(x, p["shared"], act)
    return y, aux_loss


def specs_moe_fshard(ax: Axes, activation: str, n_shared: int) -> dict:
    """Decode-mode expert weights: hidden dim sharded over dp, weights never
    gathered (they dwarf decode activations)."""
    p = {
        "router": P(None, None),
        "w_in": P(ax.tp, None, ax.dp),
        "w_out": P(ax.tp, ax.dp, None),
    }
    if activation == "swiglu":
        p["w_gate"] = P(ax.tp, None, ax.dp)
    if n_shared:
        p["shared"] = specs_dense_ffn(ax, activation)
    return p


def moe_ffn_fshard(x, p, cfg, ax: Axes, mesh):
    """Weight-stationary expert-parallel MoE for decode: activations are tiny
    (B tokens) so we all-gather tokens over dp, a2a over tp as usual, compute
    each dp shard's F-slice of every expert GEMM, and psum the partial
    outputs over dp. Zero weight movement. Returns (y, aux)."""
    e, top_k, act = cfg.n_experts, cfg.top_k, cfg.activation
    dp, tp = ax.dp, ax.tp
    has_gate = act == "swiglu"
    n_tp = int(mesh.shape[tp])
    el = e // n_tp
    n_dp = 1
    for a in dp:
        n_dp *= int(mesh.shape[a])
    bdiv = x.shape[0] % n_dp == 0
    dp_x = dp if bdiv else None

    def local_fn(x_loc, router, w_in, w_gate, w_out):
        # x_loc [Bl,S,D]; w_in/w_gate [El, D, Fl]; w_out [El, Fl, D]
        bl, s, d = x_loc.shape
        xg = x_loc
        if bdiv:
            for a in reversed(dp):
                xg = jax.lax.all_gather(xg, a, axis=0, tiled=True)
        tl = xg.shape[0] * s
        tokens = xg.reshape(tl, d)
        # floor 8 aligns training tiles; decode batches are tiny - adapt
        cap_floor = 8 if tl * top_k >= 8 * e else 1
        cap = int(max(cap_floor, (-(-tl * top_k // e)) * cfg.capacity_factor))
        logits = tokens.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        w_topk, idx = jax.lax.top_k(probs, top_k)
        w_topk = w_topk / jnp.maximum(w_topk.sum(-1, keepdims=True), 1e-9)
        frac = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
        aux = e * jnp.mean(frac * jnp.mean(probs, axis=0))
        e_flat = idx.reshape(-1)
        pos = _positions_in_expert(e_flat, e)
        keep = pos < cap
        dest = jnp.where(keep, e_flat * cap + pos, e * cap)
        tok_rep = jnp.repeat(tokens, top_k, axis=0)
        send = jnp.zeros((e * cap + 1, d), x_loc.dtype).at[dest].set(tok_rep)
        recv = jax.lax.all_to_all(
            send[: e * cap], tp, split_axis=0, concat_axis=0, tiled=True
        )
        grouped = (
            recv.reshape(n_tp, el, cap, d).transpose(1, 0, 2, 3)
            .reshape(el, n_tp * cap, d)
        )
        # fp32 accumulation for the same parity reason as the train path
        h = jnp.einsum("ecd,edf->ecf", grouped, w_in,
                       preferred_element_type=jnp.float32)  # F-slice only
        if has_gate:
            g = jnp.einsum("ecd,edf->ecf", grouped, w_gate,
                           preferred_element_type=jnp.float32)
            h = jax.nn.silu(g) * h
        elif act == "gelu":
            h = jax.nn.gelu(h)
        else:
            r = jax.nn.relu(h)
            h = r * r
        y = jnp.einsum("ecf,efd->ecd", h, w_out,
                       preferred_element_type=jnp.float32)  # partial over F
        for a in dp:
            y = jax.lax.psum(y, a)  # full expert outputs, weights unmoved
        y = y.astype(x_loc.dtype)
        back = (
            y.reshape(el, n_tp, cap, d).transpose(1, 0, 2, 3).reshape(e * cap, d)
        )
        ret = jax.lax.all_to_all(back, tp, split_axis=0, concat_axis=0, tiled=True)
        ret_flat = jnp.concatenate([ret, jnp.zeros((1, d), x_loc.dtype)], axis=0)
        vals = ret_flat[dest].astype(jnp.float32) * (
            keep * w_topk.reshape(-1)
        )[:, None]
        out_all = (
            vals.reshape(tl, top_k, d).sum(axis=1).astype(x_loc.dtype)
            .reshape(xg.shape)
        )
        if bdiv:  # take back this shard's batch rows
            row = jax.lax.axis_index(dp[-1])
            for a in dp[:-1]:
                row = row + jax.lax.axis_index(a) * int(mesh.shape[dp[-1]])
            out = jax.lax.dynamic_slice_in_dim(out_all, row * bl, bl, axis=0)
        else:
            out = out_all
        return out, aux[None]

    spec_x = P(dp_x, None, None)
    gate_spec = P(tp, None, dp) if has_gate else P(None)
    gate_arg = p.get("w_gate", jnp.zeros((1,), x.dtype))
    y, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(spec_x, P(None, None), P(tp, None, dp), gate_spec,
                  P(tp, dp, None)),
        out_specs=(spec_x, P(dp_x)),
        check_vma=False,
    )(x, p["router"], p["w_in"], gate_arg, p["w_out"])
    aux_loss = aux.mean()
    if "shared" in p:
        y = y + dense_ffn(x, p["shared"], act)
    return y, aux_loss
