"""Model assembly: embed -> prefix layers -> scan(super-blocks) -> head.

The same apply code serves all ten assigned architectures; heterogeneity
lives in ``cfg.prefix``/``cfg.block`` LayerSpecs. Layer stacks are repeated
with ``lax.scan`` over parameter pytrees stacked on a leading ``n_blocks``
axis, keeping HLO size ~O(len(block)) regardless of depth (60-100-layer
models compile in seconds on the CPU dry-run host).

Decode carries a cache pytree mirroring the block structure; attention
caches shard their sequence dim over the TP axis and use the shard_map
flash-decode (see attention.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import (
    gqa_flash_decode,
    gqa_forward,
    init_attention,
    mla_flash_decode,
    mla_forward,
    specs_attention,
)
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import (
    Axes,
    apply_rope,
    dense_ffn,
    init_dense_ffn,
    init_moe,
    init_rmsnorm,
    moe_ffn,
    qk_head_norm,
    rms_norm,
    specs_dense_ffn,
    specs_moe,
    specs_rmsnorm,
)
from repro.models.mamba import (
    init_mamba,
    mamba_decode_step,
    mamba_forward,
    specs_mamba,
)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------- layer p/s
def init_layer(key, spec: LayerSpec, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 4)
    p: dict = {"norm1": init_rmsnorm(cfg.d_model, dt)}
    if spec.mixer == "attn":
        p["attn"] = init_attention(keys[0], cfg, dt)
    elif spec.mixer == "cross_attn":
        p["attn"] = init_attention(keys[0], cfg, dt, cross=True)
    elif spec.mixer == "mamba":
        p["mamba"] = init_mamba(keys[0], cfg, dt)
    if spec.ffn != "none":
        p["norm2"] = init_rmsnorm(cfg.d_model, dt)
    if spec.ffn in ("dense", "moe_dense"):
        p["ffn"] = init_dense_ffn(keys[1], cfg.d_model, cfg.d_ff, cfg.activation, dt)
    if spec.ffn in ("moe", "moe_dense"):
        p["moe"] = init_moe(
            keys[2], cfg.d_model, cfg.d_ff_expert or cfg.d_ff,
            cfg.n_experts, cfg.n_shared_experts, cfg.activation, dt,
        )
    return p


def specs_layer(spec: LayerSpec, cfg: ModelConfig, ax: Axes) -> dict:
    p: dict = {"norm1": specs_rmsnorm()}
    if spec.mixer in ("attn", "cross_attn"):
        p["attn"] = specs_attention(cfg, ax, cross=(spec.mixer == "cross_attn"))
    elif spec.mixer == "mamba":
        p["mamba"] = specs_mamba(ax)
    if spec.ffn != "none":
        p["norm2"] = specs_rmsnorm()
    if spec.ffn in ("dense", "moe_dense"):
        p["ffn"] = specs_dense_ffn(ax, cfg.activation, cfg.dense_weight_shard)
    if spec.ffn in ("moe", "moe_dense"):
        if cfg.moe_weight_shard == "f":
            from repro.models.layers import specs_moe_fshard

            p["moe"] = specs_moe_fshard(ax, cfg.activation, cfg.n_shared_experts)
        else:
            p["moe"] = specs_moe(ax, cfg.activation, cfg.n_shared_experts)
    return p


def _wsc(x, spec):
    """with_sharding_constraint under whatever mesh is ambient."""
    return jax.lax.with_sharding_constraint(x, P(*spec))


def apply_layer(x, p, spec: LayerSpec, cfg: ModelConfig, ax: Axes, mesh,
                img_embeds=None):
    """Full-sequence layer application. Returns (x, aux_loss, cache_entry)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    seq_sp = cfg.activation_partitioning == "seq"
    if seq_sp:
        # sequence-parallel: activations carry [B(dp), T(tp), D]. Attention
        # q stays seq-sharded; K/V are all-gathered (small); this avoids
        # GSPMD's full-score all-reduce when head counts don't divide the
        # mesh (see EXPERIMENTS.md §Perf).
        x = _wsc(x, (ax.dp, ax.tp, None))
    h = rms_norm(x, p["norm1"])
    if spec.mixer == "attn":
        if seq_sp:
            h = _wsc(h, (ax.dp, ax.tp, None))
        if cfg.use_mla:
            y, kv = mla_forward(h, p["attn"], cfg, window=spec.window,
                                seq_axes=(ax.dp, ax.tp) if seq_sp else None)
        else:
            y, kv = gqa_forward(h, p["attn"], cfg, window=spec.window,
                                seq_axes=(ax.dp, ax.tp) if seq_sp else None)
        cache = kv
        x = x + y
    elif spec.mixer == "cross_attn":
        y, kv = gqa_forward(h, p["attn"], cfg, window=None, kv_x=img_embeds,
                            seq_axes=(ax.dp, ax.tp) if seq_sp else None)
        cache = kv
        x = x + y
    elif spec.mixer == "mamba":
        if seq_sp:  # the scan is sequential over T: gather the sequence
            h = _wsc(h, (ax.dp, None, None))
        y, states = mamba_forward(h, p["mamba"], cfg)
        cache = states
        x = x + y
    if spec.ffn != "none":
        if seq_sp:
            x = _wsc(x, (ax.dp, ax.tp, None))
        h2 = rms_norm(x, p["norm2"])
        out = jnp.zeros_like(x)
        if spec.ffn in ("dense", "moe_dense"):
            out = out + dense_ffn(h2, p["ffn"], cfg.activation)
        if spec.ffn in ("moe", "moe_dense"):
            if seq_sp:  # EP shard_map expects batch-sharded tokens
                h2 = _wsc(h2, (ax.dp, None, None))
            moe_impl = moe_ffn
            if cfg.moe_weight_shard == "f":
                from repro.models.layers import moe_ffn_fshard as moe_impl
            mo, a = moe_impl(h2, p["moe"], cfg, ax, mesh)
            if seq_sp:
                mo = _wsc(mo, (ax.dp, ax.tp, None))
            out = out + mo
            aux = aux + a
        x = x + out
    return x, aux, cache


# --------------------------------------------------------------------- model
class Model:
    def __init__(self, cfg: ModelConfig, ax: Axes | None = None, mesh=None):
        self.cfg = cfg
        self.ax = ax or Axes()
        self.mesh = mesh

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(key, 4 + len(cfg.prefix))
        params: dict = {}
        if cfg.frontend == "frames":
            pass  # frame embeddings arrive precomputed at d_model width
        else:
            params["embed"] = (
                jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model), dt)
                * 0.02
            )
        params["prefix"] = tuple(
            init_layer(keys[4 + i], s, cfg) for i, s in enumerate(cfg.prefix)
        )
        def one_block(k):
            bkeys = jax.random.split(k, len(cfg.block))
            return tuple(
                init_layer(bk, s, cfg) for bk, s in zip(bkeys, cfg.block)
            )
        block_keys = jax.random.split(keys[1], cfg.n_blocks)
        blocks = [one_block(k) for k in block_keys]
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
        params["final_norm"] = init_rmsnorm(cfg.d_model, dt)
        if not cfg.tie_embeddings:
            params["unembed"] = (
                jax.random.normal(keys[2], (cfg.d_model, cfg.vocab_size), dt)
                * (cfg.d_model**-0.5)
            )
        return params

    def init_shapes(self) -> dict:
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    # ----------------------------------------------------------------- specs
    def param_specs(self) -> dict:
        cfg, ax = self.cfg, self.ax
        specs: dict = {}
        if cfg.frontend != "frames":
            specs["embed"] = P(ax.tp, ax.dp)  # vocab x d_model
        specs["prefix"] = tuple(specs_layer(s, cfg, ax) for s in cfg.prefix)
        specs["blocks"] = jax.tree.map(
            lambda spec: P(None, *spec),
            tuple(specs_layer(s, cfg, ax) for s in cfg.block),
            is_leaf=lambda x: isinstance(x, P),
        )
        specs["final_norm"] = specs_rmsnorm()
        if not cfg.tie_embeddings:
            specs["unembed"] = P(ax.dp, ax.tp)
        return specs

    # --------------------------------------------------------------- forward
    def forward(self, params, inputs) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Full-sequence forward. inputs: dict with "tokens" [B,S] (or
        "frames" [B,S,D]) and optionally "image_embeds" [B,N,D].
        Returns (logits [B,S,V], aux_loss)."""
        cfg, ax, mesh = self.cfg, self.ax, self.mesh
        if cfg.frontend == "frames":
            x = inputs["frames"].astype(_dtype(cfg))
        else:
            x = params["embed"][inputs["tokens"]]
            if cfg.embed_scale:
                x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        img = inputs.get("image_embeds")
        aux_total = jnp.zeros((), jnp.float32)
        for spec, p in zip(cfg.prefix, params["prefix"]):
            x, aux, _ = apply_layer(x, p, spec, cfg, ax, mesh, img)
            aux_total = aux_total + aux

        def block_fn(carry, block_params):
            x, aux_acc = carry
            for i, spec in enumerate(cfg.block):
                x, aux, _ = apply_layer(
                    x, block_params[i], spec, cfg, ax, mesh, img
                )
                aux_acc = aux_acc + aux
            return (x, aux_acc), None

        body = block_fn
        if cfg.remat:
            policies = {
                "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                "nothing": jax.checkpoint_policies.nothing_saveable,
                "save_moe": jax.checkpoint_policies.save_only_these_names(
                    "moe_out"
                ),
            }
            body = jax.checkpoint(block_fn, policy=policies[cfg.remat_policy])
        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total), params["blocks"]
        )
        x = rms_norm(x, params["final_norm"])
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["unembed"]
        return logits, aux_total

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, seq: int, dtype=None) -> dict:
        """Decode cache pytree mirroring prefix/block structure.

        Sliding-window layers get a *ring* cache of length ``window`` (slot =
        pos % window, entries rope'd at insert) - this is what keeps e.g.
        gemma3's 40 local layers from carrying 500k-long caches."""
        cfg = self.cfg
        dt = dtype or _dtype(cfg)
        di = cfg.mamba_expand * cfg.d_model

        def layer_cache(spec: LayerSpec):
            if spec.mixer == "attn":
                length = seq if spec.window is None else min(seq, spec.window)
                if cfg.use_mla:
                    return {
                        "ckv": jnp.zeros((batch, length, cfg.kv_lora_rank), dt),
                        "kpe": jnp.zeros((batch, length, cfg.qk_rope_dim), dt),
                    }
                dh = cfg.head_dim
                return {
                    "k": jnp.zeros((batch, length, cfg.n_kv_heads, dh), dt),
                    "v": jnp.zeros((batch, length, cfg.n_kv_heads, dh), dt),
                }
            if spec.mixer == "cross_attn":
                dh = cfg.head_dim
                return {
                    "k_img": jnp.zeros((batch, cfg.n_img_tokens, cfg.n_kv_heads, dh), dt),
                    "v_img": jnp.zeros((batch, cfg.n_img_tokens, cfg.n_kv_heads, dh), dt),
                }
            if spec.mixer == "mamba":
                return {
                    "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dt),
                    "ssm": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
                }
            return {}

        prefix = tuple(layer_cache(s) for s in cfg.prefix)
        one = tuple(layer_cache(s) for s in cfg.block)
        blocks = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_blocks,) + x.shape), one
        )
        return {"prefix": prefix, "blocks": blocks}

    def cache_specs(self) -> dict:
        cfg, ax = self.cfg, self.ax

        def layer_spec(spec: LayerSpec):
            if spec.mixer == "attn":
                if spec.window is not None:
                    # ring caches are small -> replicate over tp
                    if cfg.use_mla:
                        return {"ckv": P(ax.dp, None, None), "kpe": P(ax.dp, None, None)}
                    return {
                        "k": P(ax.dp, None, None, None),
                        "v": P(ax.dp, None, None, None),
                    }
                if cfg.use_mla:
                    return {
                        "ckv": P(ax.dp, ax.tp, None),
                        "kpe": P(ax.dp, ax.tp, None),
                    }
                return {
                    "k": P(ax.dp, ax.tp, None, None),
                    "v": P(ax.dp, ax.tp, None, None),
                }
            if spec.mixer == "cross_attn":
                return {
                    "k_img": P(ax.dp, None, None, None),
                    "v_img": P(ax.dp, None, None, None),
                }
            if spec.mixer == "mamba":
                return {"conv": P(ax.dp, None, ax.tp), "ssm": P(ax.dp, ax.tp, None)}
            return {}

        prefix = tuple(layer_spec(s) for s in cfg.prefix)
        one = tuple(layer_spec(s) for s in cfg.block)
        blocks = jax.tree.map(
            lambda s: P(None, *s), one, is_leaf=lambda x: isinstance(x, P)
        )
        return {"prefix": prefix, "blocks": blocks}

    # ---------------------------------------------------------------- decode
    def _decode_layer(self, x, p, spec: LayerSpec, cache: dict, pos):
        """One-token step for one layer. x: [B,1,D]."""
        cfg, ax, mesh = self.cfg, self.ax, self.mesh
        b = x.shape[0]
        h = rms_norm(x, p["norm1"])
        if spec.mixer == "attn":
            if cfg.use_mla:
                x, cache = self._decode_mla(x, h, p["attn"], cache, pos, spec)
            else:
                x, cache = self._decode_gqa(x, h, p["attn"], cache, pos, spec)
        elif spec.mixer == "cross_attn":
            hq = h
            hcur = cache["k_img"].shape[1]
            y = _plain_cross_decode(hq, p["attn"], cfg, cache)
            x = x + y
        elif spec.mixer == "mamba":
            y, (conv, ssm) = mamba_decode_step(
                h, p["mamba"], cfg, cache["conv"], cache["ssm"]
            )
            cache = {"conv": conv, "ssm": ssm}
            x = x + y
        if spec.ffn != "none":
            h2 = rms_norm(x, p["norm2"])
            out = jnp.zeros_like(x)
            if spec.ffn in ("dense", "moe_dense"):
                out = out + dense_ffn(h2, p["ffn"], cfg.activation)
            if spec.ffn in ("moe", "moe_dense"):
                moe_impl = moe_ffn
                if cfg.moe_weight_shard == "f":
                    from repro.models.layers import moe_ffn_fshard as moe_impl
                mo, _ = moe_impl(h2, p["moe"], cfg, ax, mesh)
                out = out + mo
            x = x + out
        return x, cache

    def _decode_gqa(self, x, h, p, cache, pos, spec: LayerSpec):
        cfg, ax, mesh = self.cfg, self.ax, self.mesh
        b = x.shape[0]
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = (h @ p["wq"]).reshape(b, 1, hq, dh)
        k = (h @ p["wk"]).reshape(b, 1, hkv, dh)
        v = (h @ p["wv"]).reshape(b, 1, hkv, dh)
        if cfg.qk_norm:
            q = qk_head_norm(q, p["q_scale"])
            k = qk_head_norm(k, p["k_scale"])
        posv = jnp.full((b, 1), pos)
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
        length = cache["k"].shape[1]
        is_ring = spec.window is not None and length == spec.window
        slot = jax.lax.rem(pos, length) if is_ring else pos
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0)
        )
        if is_ring:
            # ring entries are rope'd at insert; all slots hold the last
            # `window` positions once warm. Mask unwritten slots while cold.
            g = hq // hkv
            qg = q[:, 0].reshape(b, hkv, g, dh).astype(jnp.float32) * (dh**-0.5)
            scores = jnp.einsum(
                "bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32)
            )
            slots = jnp.arange(length)
            valid = (slots <= pos) | (pos >= length)
            scores = jnp.where(valid[None, None, None], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum(
                "bhgs,bshd->bhgd", probs, v_cache.astype(jnp.float32)
            ).reshape(b, hq, dh).astype(x.dtype)
        else:
            out = gqa_flash_decode(
                q[:, 0], k_cache, v_cache, pos, spec.window, ax, mesh
            )  # [B,H,dh]
        y = out.reshape(b, 1, hq * dh) @ p["wo"]
        return x + y, {"k": k_cache, "v": v_cache}

    def _decode_mla(self, x, h, p, cache, pos, spec: LayerSpec):
        cfg, ax, mesh = self.cfg, self.ax, self.mesh
        b = x.shape[0]
        nh = cfg.n_heads
        nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        r = cfg.kv_lora_rank
        if cfg.q_lora_rank:
            qa = rms_norm(h @ p["wq_a"], {"scale": p["q_norm"]})
            q = (qa @ p["wq_b"]).reshape(b, 1, nh, nope + rope_d)
        else:
            q = (h @ p["wq"]).reshape(b, 1, nh, nope + rope_d)
        q_nope, q_pe = q[..., :nope], q[..., nope:]
        posv = jnp.full((b, 1), pos)
        q_pe = apply_rope(q_pe, posv, cfg.rope_theta)
        kv_a = h @ p["wkv_a"]  # [B,1,r+rope]
        c_kv = rms_norm(kv_a[..., :r], {"scale": p["kv_norm"]})
        k_pe = apply_rope(kv_a[..., None, r:], posv, cfg.rope_theta)[:, :, 0]
        ckv_cache = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, pos, 0)
        )
        kpe_cache = jax.lax.dynamic_update_slice(
            cache["kpe"], k_pe.astype(cache["kpe"].dtype), (0, pos, 0)
        )
        # absorbed projections
        w_uk = p["wkv_b"][:, : nh * nope].reshape(r, nh, nope)
        w_uv = p["wkv_b"][:, nh * nope :].reshape(r, nh, vd)
        q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)
        ctx_lat = mla_flash_decode(
            q_lat, q_pe[:, 0], ckv_cache, kpe_cache, pos, ax, mesh
        )  # [B,H,r]
        out = jnp.einsum("bhr,rhv->bhv", ctx_lat, w_uv)
        y = out.reshape(b, 1, nh * vd) @ p["wo"]
        return x + y, {"ckv": ckv_cache, "kpe": kpe_cache}

    def decode_step(self, params, cache, tokens, pos):
        """One decode step. tokens: [B,1] int32; pos: scalar int32 (position
        being written). Returns (logits [B,1,V], new_cache)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        new_prefix = []
        for spec, p, c in zip(cfg.prefix, params["prefix"], cache["prefix"]):
            x, c2 = self._decode_layer(x, p, spec, c, pos)
            new_prefix.append(c2)

        def block_fn(x, scanned):
            block_params, block_cache = scanned
            new_cache = []
            for i, spec in enumerate(cfg.block):
                x, c2 = self._decode_layer(x, block_params[i], spec, block_cache[i], pos)
                new_cache.append(c2)
            return x, tuple(new_cache)

        x, new_blocks = jax.lax.scan(
            block_fn, x, (params["blocks"], cache["blocks"])
        )
        x = rms_norm(x, params["final_norm"])
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["unembed"]
        return logits, {"prefix": tuple(new_prefix), "blocks": new_blocks}


def _plain_cross_decode(h, p, cfg, cache):
    """Cross-attention decode against the (small) cached image K/V."""
    b = h.shape[0]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (h @ p["wq"]).reshape(b, 1, hq, dh)
    if cfg.qk_norm:
        q = qk_head_norm(q, p["q_scale"])
    k, v = cache["k_img"], cache["v_img"]
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, dh).astype(jnp.float32) * (dh**-0.5)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k.astype(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v.astype(jnp.float32))
    y = out.reshape(b, 1, hq * dh).astype(h.dtype) @ p["wo"]
    if "gate" in p:
        y = jnp.tanh(p["gate"].astype(jnp.float32)).astype(y.dtype) * y
    return y
