"""Model configuration: one composable stack covers all ten assigned archs.

A model is ``prefix`` layers (unrolled) followed by ``n_blocks`` repeats of a
``block`` super-pattern (repeated with ``lax.scan`` so HLO size and compile
time are independent of depth). Heterogeneous stacks (local:global attention,
mamba:attn interleave, cross-attn injection, alternating MoE) are expressed
inside the super-block pattern.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str  # "attn" | "mamba" | "cross_attn"
    ffn: str  # "dense" | "moe" | "moe_dense" (arctic parallel residual) | "none"
    window: int | None = None  # sliding-window size for this layer's attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    vocab_size: int
    # ---- stack structure
    prefix: tuple[LayerSpec, ...] = ()
    block: tuple[LayerSpec, ...] = (LayerSpec("attn", "dense"),)
    n_blocks: int = 1
    # ---- attention
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int | None = None
    qk_norm: bool = False
    rope_theta: float = 1e4
    causal: bool = True  # False => encoder-only (no decode shapes)
    # ---- MLA (deepseek-v2)
    use_mla: bool = False
    q_lora_rank: int | None = None
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # ---- FFN
    d_ff: int = 0
    activation: str = "swiglu"  # swiglu | gelu | sq_relu
    # ---- MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # ---- mamba
    ssm_state: int = 16
    d_conv: int = 4
    mamba_expand: int = 2
    # ---- frontends (stubs per assignment)
    frontend: str = "tokens"  # tokens | frames (audio stub) | tokens+image (vlm)
    n_img_tokens: int = 0
    cross_attn_gated: bool = True
    # ---- misc
    embed_scale: bool = False  # gemma-style sqrt(d) embedding multiplier
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # training-policy hints consumed by launch/train
    opt_state_dtype: str = "float32"  # "bfloat16" for the giant MoEs
    remat: bool = True
    # ---- beyond-paper perf knobs (§Perf hillclimb; default = baseline)
    # "batch": activations shard batch over dp only (naive GSPMD baseline).
    # "seq":   sequence-parallel - activations also shard seq over the TP
    #          axis; attention all-gathers the (small) KV instead of letting
    #          GSPMD all-reduce full score tensors when head counts don't
    #          divide the mesh.
    activation_partitioning: str = "batch"
    # MoE expert-weight sharding: "d" = FSDP on d_model (weights gathered per
    # layer - right for training where tokens >> weights); "f" = shard the
    # hidden dim over dp and psum small partial outputs (right for decode
    # where weights >> tokens; weights never move).
    moe_weight_shard: str = "d"
    # Dense-FFN weights: "d" = FSDP on d_model (gathered per layer; train),
    # "f" = hidden dim sharded over (dp x tp) jointly, outputs psum'd -
    # weight-stationary decode (GSPMD infers the collective from the spec).
    dense_weight_shard: str = "d"
    # Attention projection weights: same "d"/"f" convention (GQA path only).
    attn_weight_shard: str = "d"
    # remat policy: "dots" (default), "nothing", or "save_moe" (keep MoE
    # outputs across the backward pass so the token all-to-all is not
    # re-played by rematerialisation - trades ~tokens x d_model x L bytes).
    remat_policy: str = "dots"

    # ------------------------------------------------------------ derived
    @property
    def head_dim(self) -> int:
        if self.use_mla:
            return self.qk_nope_dim + self.qk_rope_dim
        if self.d_head is not None:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def num_layers(self) -> int:
        return len(self.prefix) + self.n_blocks * len(self.block)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def supports_long_context(self) -> bool:
        """True when no layer needs O(T) full-attention KV at decode beyond
        a bounded window - i.e. SSM / hybrid / sliding-window families may
        run long_500k; pure full-attention archs skip it (see DESIGN.md)."""
        specs = self.layers()  # expanded stack, not the block pattern
        full_attn = [
            s for s in specs if s.mixer == "attn" and s.window is None
        ]
        # hybrids/window archs: a *minority* of full-attn layers is allowed
        # (they use sharded-KV flash-decode); pure full-attn archs are not.
        return len(full_attn) <= max(1, len(specs) // 4)

    def layers(self) -> list[LayerSpec]:
        return list(self.prefix) + list(self.block) * self.n_blocks

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d = self.d_model
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
        dh = self.head_dim
        for spec in self.layers():
            if spec.mixer == "attn" or spec.mixer == "cross_attn":
                if self.use_mla:
                    qin = self.q_lora_rank or d
                    if self.q_lora_rank:
                        total += d * self.q_lora_rank
                    total += qin * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    total += d * (self.kv_lora_rank + self.qk_rope_dim)
                    total += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_dim + self.v_head_dim
                    )
                    total += self.n_heads * self.v_head_dim * d
                else:
                    total += d * self.n_heads * dh
                    total += 2 * d * self.n_kv_heads * dh
                    total += self.n_heads * dh * d
            elif spec.mixer == "mamba":
                di = self.mamba_expand * d
                total += d * 2 * di  # in_proj
                total += di * self.d_conv  # conv
                total += di * (self.ssm_state * 2 + 2)  # B,C,dt proj-ish + A
                total += di * d  # out_proj
            if spec.ffn == "dense" or spec.ffn == "moe_dense":
                mult = 3 if self.activation == "swiglu" else 2
                total += mult * d * self.d_ff
            if spec.ffn in ("moe", "moe_dense"):
                fe = self.d_ff_expert or self.d_ff
                total += d * self.n_experts  # router
                total += self.n_experts * 3 * d * fe
                total += self.n_shared_experts * 3 * d * fe
            total += 2 * d  # norms
        total += d  # final norm
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared only)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        fe = self.d_ff_expert or self.d_ff
        inactive = 0
        for spec in self.layers():
            if spec.ffn in ("moe", "moe_dense"):
                inactive += (
                    (self.n_experts - self.top_k) * 3 * d * fe
                )
        return int(self.param_count() - inactive)
