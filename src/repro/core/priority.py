"""Pluggable buffer-priority (eviction) strategies for buffered streaming.

CUTTANA's Algorithm 1 keeps a bounded priority buffer and, on overflow,
evicts (places) the *best-scored* vertex. The paper hard-wires the buffer
score to Eq. 6; BuffCut ("Prioritized Buffered Streaming Graph
Partitioning") shows the eviction priority is a quality lever of its own.
This module factors that decision out of :class:`~repro.core.buffer.
PriorityBuffer` into strategy objects so the buffered policies
(:class:`~repro.core.engine.BufferedPolicy`,
:class:`~repro.core.engine.ShardedBufferedPolicy`) can swap priorities
per :class:`~repro.api.spec.PartitionSpec` without forking the engine:

* ``eq6`` (:class:`Eq6Priority`) - the paper's Eq. 6,
  ``deg/D_max + theta * assigned/deg``. This is the default and is
  **bit-identical** to the pre-strategy-layer buffer: the scalar and
  vectorised scoring expressions are kept literally the same IEEE-double
  computations (pinned in ``tests/test_priority.py``).
* ``completeness`` (:class:`CompletenessPriority`) - BuffCut-style
  neighbourhood-completeness priority: eviction is driven by the *fraction*
  of the neighbourhood already assigned (place vertices whose placement
  information is most complete), with only a small degree term -
  low-information vertices are delayed regardless of degree.
* ``gain`` (:class:`GainPriority`) - gain-aware delayed eviction: the
  buffer tracks, per buffered vertex, how its assigned neighbours split
  across partitions, and prioritizes vertices whose neighbourhood points
  *decisively* at one partition (large margin between the best and
  runner-up partitions). Ambiguous vertices are delayed until more of
  their neighbourhood commits - the delayed-decision heuristic.

Strategies are bounded-memory by construction: ``gain`` keeps a per-vertex
partition-count dict only for vertices *currently buffered* (<= the
buffer capacity), dropped on eviction.

Both buffered policies also share the eviction bookkeeping
(:class:`BufferStats`) that used to be copy-pasted between them.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "BUFFER_STRATEGIES",
    "BufferPriority",
    "Eq6Priority",
    "CompletenessPriority",
    "GainPriority",
    "make_priority",
    "BufferStats",
]

# canonical strategy names; repro.api.spec validates against the same tuple
# (duplicated there to keep the registry import-cycle-free - pinned equal in
# tests/test_priority.py)
BUFFER_STRATEGIES = ("eq6", "completeness", "gain")


class BufferPriority:
    """Eviction-priority strategy: higher score => evicted (placed) earlier.

    The buffer calls :meth:`score_counts` (scalar, at push time) and
    :meth:`score_counts_many` (vectorised, for a whole notified
    neighbourhood) with its flat ``(deg, assigned)`` bookkeeping.
    Strategies that need more signal than those two counters set
    ``tracks_parts`` and receive the partition ids of assigned neighbours
    through the ``on_push`` / ``on_notify`` / ``on_remove`` hooks.

    ``d_max`` doubles as the degree-bypass threshold (Thm. 1): the policies
    consult ``priority.d_max`` so admission and scoring stay one coherent
    strategy object.
    """

    name: str = "base"
    tracks_parts: bool = False

    def __init__(self, d_max: int, theta: float = 1.0):
        self.d_max = max(int(d_max), 1)
        self.theta = float(theta)

    # ------------------------------------------------------------- scoring
    def score_counts(self, v: int, deg: int, assigned: int) -> float:
        raise NotImplementedError

    def score_counts_many(
        self, vs: np.ndarray, deg: np.ndarray, assigned: np.ndarray
    ) -> np.ndarray:
        raise NotImplementedError

    # ------------------------------------- partition tracking (tracks_parts)
    def on_push(self, v: int, nbr_parts: np.ndarray | None) -> None:
        """``v`` entered the buffer; ``nbr_parts`` is ``part_of`` over its
        neighbourhood (may contain -1 for unassigned) or None when the
        caller has no partition info (standalone buffers)."""

    def on_notify(self, vs: np.ndarray, parts) -> None:
        """Buffered occurrences ``vs`` each gained one assigned neighbour;
        ``parts`` is that neighbour's partition - a scalar (one placed
        vertex's whole neighbourhood) or an array aligned with ``vs``."""

    def on_remove(self, v: int) -> None:
        """``v`` left the buffer (evicted or cascaded)."""


class Eq6Priority(BufferPriority):
    """CUTTANA Eq. 6: ``deg/D_max + theta * assigned/deg``.

    The expressions below are kept *literally* the ones the pre-refactor
    buffer used (same operation order on the same int/float operands), so
    the default strategy is bit-identical to the seed behaviour.
    """

    name = "eq6"

    def score_counts(self, v: int, deg: int, assigned: int) -> float:
        return deg / self.d_max + self.theta * assigned / max(deg, 1)

    def score_counts_many(self, vs, deg, assigned) -> np.ndarray:
        return deg / self.d_max + (self.theta * assigned) / np.maximum(deg, 1)


class CompletenessPriority(BufferPriority):
    """BuffCut-style neighbourhood-completeness priority.

    ``theta * assigned/deg + W_deg * deg/D_max``: the completeness fraction
    dominates, so a vertex is evicted when most of its neighbourhood is
    known - degree only breaks ties (``W_deg`` is deliberately small).
    Compared to Eq. 6 this *delays* high-degree vertices with unknown
    neighbourhoods instead of rushing them out.
    """

    name = "completeness"
    degree_weight = 0.25

    def score_counts(self, v: int, deg: int, assigned: int) -> float:
        return (
            self.theta * assigned / max(deg, 1)
            + self.degree_weight * deg / self.d_max
        )

    def score_counts_many(self, vs, deg, assigned) -> np.ndarray:
        return (self.theta * assigned) / np.maximum(deg, 1) + (
            self.degree_weight / self.d_max
        ) * deg


class GainPriority(BufferPriority):
    """Gain-aware delayed eviction.

    Tracks, per *buffered* vertex, the per-partition counts of its assigned
    neighbours and scores by the **margin** between the best and runner-up
    partitions: ``deg/D_max + theta * (best - runner_up)/deg``. A vertex
    whose known neighbours agree on one partition can be placed now with
    little regret; a vertex with a split neighbourhood is delayed until
    more neighbours commit (the delayed-decision heuristic). With no
    partition info (standalone buffers, ``on_push(v, None)``) the margin
    falls back to the assigned count, i.e. Eq. 6.

    Memory is bounded by the buffer capacity: counts exist only while the
    vertex is buffered.
    """

    name = "gain"
    tracks_parts = True

    def __init__(self, d_max: int, theta: float = 1.0):
        super().__init__(d_max, theta)
        self._pc: dict[int, dict[int, int]] = {}

    # ------------------------------------------------------------- tracking
    def on_push(self, v: int, nbr_parts: np.ndarray | None) -> None:
        if nbr_parts is None:
            return
        assigned = np.asarray(nbr_parts)
        assigned = assigned[assigned >= 0]
        counts: dict[int, int] = {}
        if assigned.size:
            ps, cs = np.unique(assigned, return_counts=True)
            counts = dict(zip(ps.tolist(), cs.tolist()))
        self._pc[int(v)] = counts

    def on_notify(self, vs: np.ndarray, parts) -> None:
        pc = self._pc
        if np.isscalar(parts) or getattr(parts, "ndim", 1) == 0:
            p = int(parts)
            for v in vs.tolist():
                counts = pc.get(v)
                if counts is not None:
                    counts[p] = counts.get(p, 0) + 1
        else:
            for v, p in zip(vs.tolist(), np.asarray(parts).tolist()):
                counts = pc.get(v)
                if counts is not None:
                    counts[p] = counts.get(p, 0) + 1

    def on_remove(self, v: int) -> None:
        self._pc.pop(int(v), None)

    # ------------------------------------------------------------- scoring
    def _margin(self, v: int, assigned: int) -> float:
        counts = self._pc.get(int(v))
        if counts is None:
            return float(assigned)  # untracked push: Eq. 6 fallback
        if not counts:
            return 0.0
        best = 0
        second = 0
        for c in counts.values():
            if c > best:
                best, second = c, best
            elif c > second:
                second = c
        return float(best - second)

    def score_counts(self, v: int, deg: int, assigned: int) -> float:
        return (
            deg / self.d_max
            + self.theta * self._margin(v, assigned) / max(deg, 1)
        )

    def score_counts_many(self, vs, deg, assigned) -> np.ndarray:
        margins = np.fromiter(
            (self._margin(v, a) for v, a in zip(vs.tolist(), assigned.tolist())),
            dtype=np.float64,
            count=len(vs),
        )
        return deg / self.d_max + (self.theta * margins) / np.maximum(deg, 1)


_STRATEGIES = {
    "eq6": Eq6Priority,
    "completeness": CompletenessPriority,
    "gain": GainPriority,
}
assert tuple(_STRATEGIES) == BUFFER_STRATEGIES


def make_priority(name: str, d_max: int, theta: float = 1.0) -> BufferPriority:
    """Resolve a strategy name to a fresh strategy instance (strategies are
    stateful - one per buffer, never shared across shards)."""
    cls = _STRATEGIES.get(name)
    if cls is None:
        raise ValueError(
            f"unknown buffer strategy {name!r}; "
            f"expected one of {BUFFER_STRATEGIES}"
        )
    return cls(d_max, theta)


@dataclasses.dataclass
class BufferStats:
    """Eviction bookkeeping shared by the sequential and sharded buffered
    policies (previously copy-pasted counters in each)."""

    evictions: int = 0
    drained: int = 0
    bypass: int = 0
    peak: int = 0

    def observe_len(self, n: int) -> None:
        if n > self.peak:
            self.peak = n

    def to_telemetry(self, strategy: str) -> dict:
        return {
            "buffer_evictions": self.evictions,
            "buffer_drained": self.drained,
            "buffer_peak": self.peak,
            "degree_bypass": self.bypass,
            "buffer_strategy": strategy,
        }
