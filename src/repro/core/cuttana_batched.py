"""Chunk-parallel streaming phase (beyond-paper, §III-C TPU adaptation).

The paper hides buffering/refinement cost behind a thread pipeline. A TPU has
no host threads to spare but has a very wide VPU, so we instead *batch* the
scoring loop: the stream is consumed in chunks of C vertices; one fused
kernel call (:mod:`repro.kernels.partition_score`) computes all C x K
neighbour histograms + penalties, then a cheap host loop applies assignments
in stream order (partition sizes are corrected per assignment; neighbour
histograms are allowed to be one-chunk stale - the usual bulk-synchronous
relaxation, quality impact measured in benchmarks/latency.py).

High-degree vertices (> ``sample_cap`` neighbours) are scored on a uniform
neighbour sample with the histogram rescaled - Thm. 1 says exact counts
matter least exactly for them.

This is now a thin configuration of :class:`repro.core.engine.StreamEngine`
(``ImmediatePolicy`` with ``exact=False``); the seed loop is kept in
:mod:`repro.core.legacy` and parity-tested against this wrapper.

Phase 2 (refinement) is unchanged - it is already graph-size independent.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.base import FennelParams, PartitionState, finalize
from repro.core.cuttana import _phase2_refine
from repro.core.engine import EngineConfig, FennelScorer, ImmediatePolicy, StreamEngine
from repro.core.subpartition import SubPartitioner
from repro.graph.csr import CSRGraph


def partition_batched(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    chunk: int = 512,
    sample_cap: int = 512,
    use_refinement: bool = True,
    subparts_per_partition: int | None = None,
    thresh: float = 0.0,
    order: str = "natural",
    seed: int = 0,
    use_pallas: bool | None = None,
    interpret: bool = False,
    telemetry: dict | None = None,
) -> np.ndarray:
    n = graph.num_vertices
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    if subparts_per_partition is None:
        subparts_per_partition = int(max(8, min(4096, n // (8 * k))))
    subp = SubPartitioner(
        graph, k, subparts_per_partition,
        epsilon=max(epsilon, 0.10), balance_mode=balance_mode, seed=seed,
    )
    params = FennelParams(hybrid=(balance_mode == "edge"))
    t0 = time.perf_counter()
    engine = StreamEngine(
        graph,
        state,
        FennelScorer(graph, k, params, balance_mode),
        ImmediatePolicy(),
        subpartitioner=subp,
        order=order,
        seed=seed,
        config=EngineConfig(
            chunk=chunk,
            sample_cap=sample_cap,
            exact=False,
            use_pallas=use_pallas,
            interpret=interpret,
        ),
    )
    engine.run()
    stream_s = time.perf_counter() - t0

    part = finalize(state)
    moves, improvement = 0, 0.0
    t1 = time.perf_counter()
    if use_refinement and k > 1:
        part, _, moves, improvement = _phase2_refine(
            graph, subp, k, epsilon, balance_mode, thresh
        )
    if telemetry is not None:
        telemetry.update(engine.telemetry)
        telemetry.update(
            stream_seconds=stream_s,
            refine_seconds=time.perf_counter() - t1,
            refine_moves=moves,
            refine_improvement=improvement,
            subpartitions=int(subp.kp),
        )
    return part
