"""Chunk-parallel streaming phase (beyond-paper, §III-C TPU adaptation).

The paper hides buffering/refinement cost behind a thread pipeline. A TPU has
no host threads to spare but has a very wide VPU, so we instead *batch* the
scoring loop: the stream is consumed in chunks of C vertices; one fused
kernel call (:mod:`repro.kernels.partition_score`) computes all C x K
neighbour histograms + penalties, then a cheap host loop applies assignments
in stream order (partition sizes are corrected per assignment; neighbour
histograms are allowed to be one-chunk stale - the usual bulk-synchronous
relaxation, quality impact measured in benchmarks/latency.py).

High-degree vertices (> ``sample_cap`` neighbours) are scored on a uniform
neighbour sample with the histogram rescaled - Thm. 1 says exact counts
matter least exactly for them.

Phase 2 (refinement) is unchanged - it is already graph-size independent.
"""
from __future__ import annotations

import numpy as np

from repro.core.base import FennelParams, PartitionState, finalize
from repro.core.refinement import Refiner, build_subpartition_graph
from repro.core.subpartition import SubPartitioner
from repro.graph.csr import CSRGraph
from repro.graph.stream import stream_order
from repro.kernels.partition_score.ops import fennel_scores


def partition_batched(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    chunk: int = 512,
    sample_cap: int = 512,
    use_refinement: bool = True,
    subparts_per_partition: int | None = None,
    thresh: float = 0.0,
    order: str = "natural",
    seed: int = 0,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> np.ndarray:
    n = graph.num_vertices
    m = max(graph.num_edges, 1)
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    if subparts_per_partition is None:
        subparts_per_partition = int(max(8, min(4096, n // (8 * k))))
    subp = SubPartitioner(
        graph, k, subparts_per_partition,
        epsilon=max(epsilon, 0.10), balance_mode=balance_mode, seed=seed,
    )
    params = FennelParams(hybrid=(balance_mode == "edge"))
    alpha = params.alpha_scale * np.sqrt(k) * m / (max(n, 1) ** 1.5)
    gamma = params.gamma
    mu = n / max(graph.indices.shape[0], 1)
    rng = np.random.default_rng(seed)
    indptr, indices = graph.indptr, graph.indices
    ids = stream_order(graph, order, seed)

    for start in range(0, n, chunk):
        batch = ids[start : start + chunk]
        c = len(batch)
        degs = (indptr[batch + 1] - indptr[batch]).astype(np.int64)
        width = int(min(max(degs.max(), 1), sample_cap))
        nbr_parts = np.full((c, width), -1, dtype=np.int32)
        scale = np.ones(c, dtype=np.float64)
        nbr_cache: list[np.ndarray] = []
        for i, v in enumerate(batch):
            nb = indices[indptr[v] : indptr[v + 1]]
            nbr_cache.append(nb)
            if nb.size > width:  # degree-capped sampling (Thm. 1 regime)
                sel = rng.choice(nb.size, size=width, replace=False)
                nbp = state.part_of[nb[sel]]
                scale[i] = nb.size / width
            else:
                nbp = state.part_of[nb]
            nbr_parts[i, : nbp.size] = nbp
        # one fused kernel call scores the whole chunk (histogram part)
        sizes = np.zeros(k, np.float32)  # penalty applied on host (fresh)
        hist = np.asarray(
            fennel_scores(
                nbr_parts, sizes, 0.0, gamma,
                use_pallas=use_pallas, interpret=interpret,
            ),
            dtype=np.float64,
        ) * scale[:, None]
        # host loop: fresh penalty + capacity, stale-by-chunk histograms
        for i, v in enumerate(batch):
            if params.hybrid:
                size = 0.5 * (state.v_counts + mu * state.e_counts)
            else:
                size = state.v_counts
            scores = hist[i] - alpha * gamma * np.power(
                np.maximum(size, 0.0), gamma - 1.0
            )
            allowed = ~state.would_overflow(int(degs[i]))
            p = state.argmax_tiebreak(scores, allowed)
            state.assign(int(v), p, int(degs[i]))
            subp.assign(int(v), p, nbr_cache[i], int(degs[i]))

    part = finalize(state)
    if use_refinement and k > 1:
        w = build_subpartition_graph(graph, subp.sub_of, subp.kp)
        sub_part = np.repeat(np.arange(k, dtype=np.int64), subp.s)
        if balance_mode == "edge":
            size, total = subp.sub_e_counts, float(graph.indices.shape[0])
        else:
            size, total = subp.sub_v_counts, float(n)
        r = Refiner(w, sub_part, size, k, epsilon, total_mass=total)
        r.refine(thresh=thresh)
        part = r.sub_part[subp.sub_of].astype(np.int32)
    return part
