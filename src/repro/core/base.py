"""Shared machinery for streaming vertex partitioners (paper §II, Eq. 5/7).

Every partitioner exposes ``partition(graph, k, ...) -> np.ndarray[|V|]``.
Balance modes:
  * ``"vertex"``  - Eq. 1: |V_i| <= (1+eps) |V|/K
  * ``"edge"``    - Eq. 2: Σ_{v∈V_i} |N(v)| <= (1+eps) 2|E|/K
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.graph.csr import CSRGraph

UNASSIGNED = -1


@dataclasses.dataclass
class PartitionState:
    """Mutable running state shared by all streaming partitioners."""

    k: int
    num_vertices: int
    total_degree: int  # == 2|E|
    epsilon: float
    balance_mode: str  # "vertex" | "edge"
    part_of: np.ndarray  # int32[|V|], UNASSIGNED until placed
    v_counts: np.ndarray  # float64[k]  vertices per partition
    e_counts: np.ndarray  # float64[k]  degree mass per partition
    rng: np.random.Generator

    @staticmethod
    def create(
        graph: CSRGraph,
        k: int,
        epsilon: float,
        balance_mode: str,
        seed: int = 0,
    ) -> "PartitionState":
        if balance_mode not in ("vertex", "edge"):
            raise ValueError(f"unknown balance mode {balance_mode}")
        return PartitionState(
            k=k,
            num_vertices=graph.num_vertices,
            total_degree=int(graph.indices.shape[0]),
            epsilon=epsilon,
            balance_mode=balance_mode,
            part_of=np.full(graph.num_vertices, UNASSIGNED, dtype=np.int32),
            v_counts=np.zeros(k, dtype=np.float64),
            e_counts=np.zeros(k, dtype=np.float64),
            rng=np.random.default_rng(seed),
        )

    # -------------------------------------------------------------- capacity
    @property
    def vertex_capacity(self) -> float:
        return (1.0 + self.epsilon) * self.num_vertices / self.k

    @property
    def edge_capacity(self) -> float:
        return (1.0 + self.epsilon) * self.total_degree / self.k

    def at_capacity(self) -> np.ndarray:
        """bool[k]: partitions that cannot accept more (by active balance mode)."""
        if self.balance_mode == "vertex":
            return self.v_counts >= self.vertex_capacity
        return self.e_counts >= self.edge_capacity

    def would_overflow(self, deg: int) -> np.ndarray:
        """bool[k]: placing a degree-``deg`` vertex would break the condition."""
        if self.balance_mode == "vertex":
            return self.v_counts + 1 > self.vertex_capacity
        return self.e_counts + deg > self.edge_capacity

    # ------------------------------------------------------------- mutation
    def assign(self, v: int, p: int, deg: int) -> None:
        self.part_of[v] = p
        self.v_counts[p] += 1
        self.e_counts[p] += deg

    # ------------------------------------------------------------- helpers
    def neighbor_histogram(self, nbrs: np.ndarray) -> np.ndarray:
        """float64[k]: count of already-assigned neighbours per partition."""
        assigned = self.part_of[nbrs]
        assigned = assigned[assigned != UNASSIGNED]
        if assigned.size == 0:
            return np.zeros(self.k, dtype=np.float64)
        return np.bincount(assigned, minlength=self.k).astype(np.float64)

    def argmax_tiebreak(self, scores: np.ndarray, allowed: np.ndarray) -> int:
        """argmax over allowed partitions with seeded random tie-breaking."""
        masked = np.where(allowed, scores, -np.inf)
        best = masked.max()
        if not np.isfinite(best):
            # every partition is at capacity - fall back to least loaded
            loads = self.v_counts if self.balance_mode == "vertex" else self.e_counts
            return int(loads.argmin())
        ties = np.flatnonzero(masked >= best - 1e-12)
        if ties.size == 1:
            return int(ties[0])
        return int(ties[self.rng.integers(ties.size)])


@dataclasses.dataclass(frozen=True)
class FennelParams:
    """FENNEL scoring (paper Eq. 7). gamma/alpha per Tsourakakis et al."""

    gamma: float = 1.5
    alpha_scale: float = 1.0  # multiplier on the canonical alpha
    hybrid: bool = True  # PowerLyra-style edge term in the penalty (Eq. 7)


def make_fennel_score(
    graph: CSRGraph, k: int, params: FennelParams, balance_mode: str
) -> Callable[[PartitionState, np.ndarray], np.ndarray]:
    """Returns score(state, hist) -> float64[k] implementing Eq. 7.

    score_i = hist_i - alpha*gamma * size_i^(gamma-1)
    where size_i = |V_i|                      (vertex mode, classic FENNEL)
          size_i = (|V_i| + mu * E_i) / 2     (edge mode, PowerLyra hybrid;
                                               mu = |V| / 2|E| so that the
                                               total hybrid mass is |V|)
    """
    n = max(graph.num_vertices, 1)
    m = max(graph.num_edges, 1)
    alpha = params.alpha_scale * np.sqrt(k) * m / (n**1.5)
    mu = n / max(graph.indices.shape[0], 1)  # |V| / 2|E|
    gamma = params.gamma
    use_hybrid = params.hybrid and balance_mode == "edge"

    def score(state: PartitionState, hist: np.ndarray) -> np.ndarray:
        if use_hybrid:
            size = 0.5 * (state.v_counts + mu * state.e_counts)
        else:
            size = state.v_counts
        return hist - alpha * gamma * np.power(np.maximum(size, 0.0), gamma - 1.0)

    return score


def finalize(state: PartitionState) -> np.ndarray:
    """All vertices must be assigned; returns int32[|V|]."""
    assert (state.part_of != UNASSIGNED).all(), "unassigned vertices remain"
    return state.part_of.copy()
