"""CUTTANA Phase 2: coarsened refinement (paper §III-B).

The sub-partition graph (Def. 3) is coarse enough to hold in memory for any
input graph, so refinement cost is independent of |V|, |E| (paper's headline
theoretical property). We maintain, exactly as the paper:

  * ``W``    - K'xK' weighted sub-partition adjacency (diag zeroed),
  * ``M``    - K'xK matrix, M[i,p] = sum_j W[i,j] * [P'(j) = p]
               (so ECP[i,p] = total_w[i] - M[i,p], Eq. 8),
  * ``DEC``  - DEC[i, dst] = ECP[i, src] - ECP[i, dst] = M[i,dst] - M[i,src]
               (Eq. 9),
  * ``MS``   - for every (src, dst) partition pair, a max-segment-tree over
               the DEC values of sub-partitions currently in ``src``
               (find-best O(1) at the root, update O(log(K'/K)), Lemma 1).

After a trade we update exactly the O(K') entries of Theorem 2. Feasibility
(the balance condition) is enforced at query time with a pruned descent of the
segment tree, so capacity-blocked trades are skipped without being lost.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph

NEG_INF = -np.inf


def build_subpartition_graph(
    graph: CSRGraph, sub_of: np.ndarray, kp: int
) -> np.ndarray:
    """Dense K'xK' weighted sub-partition adjacency; W[i,j] = #edges between
    members of S_i and S_j (diagonal zeroed; symmetric counts halved once by
    construction since CSR stores both directions)."""
    src = np.repeat(np.arange(graph.num_vertices, dtype=np.int64), graph.degrees)
    si = sub_of[src].astype(np.int64)
    sj = sub_of[graph.indices].astype(np.int64)
    key = si * kp + sj
    counts = np.bincount(key, minlength=kp * kp).astype(np.float64)
    w = counts.reshape(kp, kp)
    w = 0.5 * (w + w.T)  # symmetric storage counted each edge twice -> halve
    np.fill_diagonal(w, 0.0)
    return w


@dataclasses.dataclass
class RefineStats:
    moves: int = 0
    cut_improvement: float = 0.0
    stopped_reason: str = ""


class Refiner:
    def __init__(
        self,
        w: np.ndarray,
        sub_part: np.ndarray,  # int[K'] -> current partition of each sub-part
        size: np.ndarray,  # float[K'] balance mass of each sub-part
        k: int,
        epsilon: float,
        total_mass: float | None = None,
    ):
        self.kp = w.shape[0]
        self.k = k
        self.w = w
        self.sub_part = sub_part.astype(np.int64).copy()
        self.size = size.astype(np.float64)
        total = float(self.size.sum()) if total_mass is None else total_mass
        self.cap = (1.0 + epsilon) * total / k
        self.part_load = np.bincount(
            self.sub_part, weights=self.size, minlength=k
        ).astype(np.float64)
        self.total_w = w.sum(axis=1)
        onehot = np.zeros((self.kp, k), dtype=np.float64)
        onehot[np.arange(self.kp), self.sub_part] = 1.0
        self.m = w @ onehot  # M[i, p]
        # ------------------------------------------------------ segment trees
        # Balance is by MASS, not count: many near-empty sub-partitions can
        # legally crowd into one partition, so slot capacity must be the
        # worst case K' (Lemma 1 bounds the EXPECTED count, not the max).
        self.cap2 = 1 << int(np.ceil(np.log2(max(self.kp, 2))))
        self.tree = np.full((k, k, 2 * self.cap2), NEG_INF, dtype=np.float64)
        self.owner = np.full((k, self.cap2), -1, dtype=np.int64)
        self.slot_of = np.full(self.kp, -1, dtype=np.int64)
        self._free: list[list[int]] = [list(range(self.cap2 - 1, -1, -1)) for _ in range(k)]
        for i in range(self.kp):
            self._alloc_slot(i, int(self.sub_part[i]))
        for q in range(k):
            members = np.flatnonzero(self.sub_part == q)
            if members.size:
                self._write_entries_group(members, q)

    # ------------------------------------------------------------- slot mgmt
    def _alloc_slot(self, i: int, p: int) -> None:
        slot = self._free[p].pop()
        self.slot_of[i] = slot
        self.owner[p, slot] = i

    def _release_slot(self, i: int, p: int) -> None:
        slot = int(self.slot_of[i])
        self.owner[p, slot] = -1
        self._free[p].append(slot)
        # clear this slot's leaf across every (p, dst) tree, one repair pass
        self.tree[p, :, self.cap2 + slot] = NEG_INF
        self._repair_levels(p, slice(None), self.slot_of[i : i + 1])

    # ------------------------------------------------------------- tree ops
    def _repair_levels(self, src: int, dst_idx, slots: np.ndarray) -> None:
        """Recompute the internal max nodes above ``slots`` in the
        ``(src, dst)`` trees selected by ``dst_idx`` (a slice for "all
        destinations" or an index array) - ONE level-by-level pass repairs
        any number of dirty leaves, each level a single K-wide ``maximum``
        instead of the per-(dst, slot) scalar climbs this replaced."""
        t = self.tree[src]
        nodes = np.unique((np.asarray(slots, dtype=np.int64) + self.cap2) >> 1)
        while True:
            if isinstance(dst_idx, slice):
                t[dst_idx, nodes] = np.maximum(
                    t[dst_idx, 2 * nodes], t[dst_idx, 2 * nodes + 1]
                )
            else:
                t[np.ix_(dst_idx, nodes)] = np.maximum(
                    t[np.ix_(dst_idx, 2 * nodes)], t[np.ix_(dst_idx, 2 * nodes + 1)]
                )
            if nodes[0] == 1:  # perfect tree: every leaf reaches the root together
                return
            nodes = np.unique(nodes >> 1)

    def _write_entries(self, i: int) -> None:
        """(Re)write DEC entries of sub-partition ``i`` for all destinations."""
        p = int(self.sub_part[i])
        slot = int(self.slot_of[i])
        col = self.m[i] - self.m[i, p]
        col[p] = NEG_INF  # own partition is never a trade destination
        self.tree[p, :, self.cap2 + slot] = col
        self._repair_levels(p, slice(None), self.slot_of[i : i + 1])

    def _write_entries_group(self, members: np.ndarray, q: int) -> None:
        """Batched :meth:`_write_entries` for sub-partitions all living in
        ``q``: one [K, n] leaf write + one repair pass (the Theorem 2 path
        for neighbours in the move's src/dst partitions, whose DEC base
        changed for every destination)."""
        slots = self.slot_of[members]
        vals = self.m[members] - self.m[members, q][:, None]  # [n, K]
        vals[:, q] = NEG_INF
        self.tree[q][:, self.cap2 + slots] = vals.T
        self._repair_levels(q, slice(None), slots)

    def _write_pair_group(self, members: np.ndarray, q: int, src: int, dst: int) -> None:
        """Batched Theorem 2 update for neighbours whose home partition ``q``
        is uninvolved in the move: only their (q, src) and (q, dst) entries
        changed, so two leaf-row writes + one two-row repair pass."""
        slots = self.slot_of[members]
        base = self.m[members, q]
        t = self.tree[q]
        t[src, self.cap2 + slots] = self.m[members, src] - base
        t[dst, self.cap2 + slots] = self.m[members, dst] - base
        self._repair_levels(q, np.asarray([src, dst]), slots)

    def _best_feasible(self, src: int, dst: int, floor: float) -> tuple[int, float] | None:
        """Best DEC > floor among feasible moves src->dst (pruned descent)."""
        t = self.tree[src, dst]
        if t[1] <= floor:
            return None
        room = self.cap - self.part_load[dst]
        best_slot, best_val = -1, floor
        stack = [1]
        while stack:
            node = stack.pop()
            if t[node] <= best_val:
                continue
            if node >= self.cap2:  # leaf
                slot = node - self.cap2
                i = self.owner[src, slot]
                if i >= 0 and self.size[i] <= room + 1e-9:
                    best_slot, best_val = slot, t[node]
            else:
                # visit the larger child first for tighter pruning
                l, r = 2 * node, 2 * node + 1
                if t[l] >= t[r]:
                    stack.extend((r, l))
                else:
                    stack.extend((l, r))
        return None if best_slot < 0 else (best_slot, best_val)

    # ------------------------------------------------------------- main API
    def best_move(self, thresh: float = 0.0) -> tuple[int, int, float] | None:
        """Globally best feasible trade: (sub_part_id, dst, dec) or None."""
        best: tuple[int, int, float] | None = None
        floor = thresh
        for src in range(self.k):
            for dst in range(self.k):
                if src == dst:
                    continue
                got = self._best_feasible(src, dst, floor)
                if got is not None:
                    slot, val = got
                    best = (int(self.owner[src, slot]), dst, float(val))
                    floor = val
        return best

    def apply_move(self, i: int, dst: int) -> float:
        """Apply trade <S_i, dst>; returns the edge-cut decrease."""
        src = int(self.sub_part[i])
        assert src != dst
        dec = float(self.m[i, dst] - self.m[i, src])
        nbrs = np.flatnonzero(self.w[i])
        wvals = self.w[i, nbrs]
        # --- M updates for neighbours (Eq. 10 in M-form)
        self.m[nbrs, src] -= wvals
        self.m[nbrs, dst] += wvals
        # --- move i itself
        self._release_slot(i, src)
        self.sub_part[i] = dst
        self.part_load[src] -= self.size[i]
        self.part_load[dst] += self.size[i]
        self._alloc_slot(i, dst)
        self._write_entries(i)
        # --- Theorem 2 updates for neighbours, batched per home partition
        if nbrs.size:
            qs = self.sub_part[nbrs]
            for q in np.unique(qs).tolist():
                members = nbrs[qs == q]
                if q == src or q == dst:
                    # base m[j, q] changed: every destination entry is dirty
                    self._write_entries_group(members, int(q))
                else:
                    self._write_pair_group(members, int(q), src, dst)
        return dec

    def refine(
        self, thresh: float = 0.0, max_moves: int | None = None
    ) -> RefineStats:
        stats = RefineStats()
        while True:
            if max_moves is not None and stats.moves >= max_moves:
                stats.stopped_reason = "max_moves"
                return stats
            mv = self.best_move(thresh)
            if mv is None:
                stats.stopped_reason = "maximal" if thresh <= 0 else "thresh"
                return stats
            i, dst, dec = mv
            got = self.apply_move(i, dst)
            assert abs(got - dec) < 1e-6
            stats.moves += 1
            stats.cut_improvement += got

    # ------------------------------------------------------------- debugging
    def current_cut(self) -> float:
        """Edge-cut of the coarsened graph (Prop. 1)."""
        same = self.sub_part[:, None] == self.sub_part[None, :]
        return float(self.w[~same].sum() / 2.0)

    def check_invariants(self) -> None:
        onehot = np.zeros((self.kp, self.k))
        onehot[np.arange(self.kp), self.sub_part] = 1.0
        np.testing.assert_allclose(self.m, self.w @ onehot, atol=1e-6)
        loads = np.bincount(self.sub_part, weights=self.size, minlength=self.k)
        np.testing.assert_allclose(self.part_load, loads, atol=1e-6)
        for src in range(self.k):
            for dst in range(self.k):
                if src == dst:
                    continue
                t = self.tree[src, dst]
                for slot in range(self.cap2):
                    i = self.owner[src, slot]
                    expect = (
                        self.m[i, dst] - self.m[i, src] if i >= 0 else NEG_INF
                    )
                    got = t[self.cap2 + slot]
                    if i >= 0:
                        assert abs(got - expect) < 1e-6, (src, dst, slot, got, expect)
                    else:
                        assert got == NEG_INF


# --------------------------------------------------------------------- swaps
def best_swap(r: "Refiner") -> tuple[int, int, float] | None:
    """Paper §VI future work: when single trades are balance-blocked, a
    *pairwise swap* <S_i in V_a, S_j in V_b> can still improve quality while
    keeping both partitions within capacity. Returns the best (i, j, gain)
    with gain = DEC_i(a->b) + DEC_j(b->a) - 2*W(S_i,S_j), or None.

    O(K'^2) scan over cross-partition neighbour pairs - run only when
    ``refine`` stalls (the greedy single-trade loop is the common path)."""
    best: tuple[int, int, float] | None = None
    kp = r.kp
    for i in range(kp):
        a = int(r.sub_part[i])
        nbrs = np.flatnonzero(r.w[i])
        for j in nbrs:
            j = int(j)
            if j <= i:
                continue
            b = int(r.sub_part[j])
            if a == b:
                continue
            gain = (
                (r.m[i, b] - r.m[i, a])
                + (r.m[j, a] - r.m[j, b])
                - 2.0 * r.w[i, j]  # they stop being cut towards each other... twice-counted
            )
            if gain <= 1e-9:
                continue
            # balance: dest gains size[x] - size[y]
            if r.part_load[b] + r.size[i] - r.size[j] > r.cap + 1e-9:
                continue
            if r.part_load[a] + r.size[j] - r.size[i] > r.cap + 1e-9:
                continue
            if best is None or gain > best[2]:
                best = (i, j, float(gain))
    return best


def refine_with_swaps(r: "Refiner", thresh: float = 0.0,
                      max_rounds: int = 50) -> dict:
    """Alternate greedy single trades with pairwise swaps until neither
    improves (a strictly larger move class than the paper's maximality)."""
    moves = swaps = 0
    improvement = 0.0
    for _ in range(max_rounds):
        stats = r.refine(thresh=thresh)
        moves += stats.moves
        improvement += stats.cut_improvement
        sw = best_swap(r)
        if sw is None:
            break
        i, j, gain = sw
        a, b = int(r.sub_part[i]), int(r.sub_part[j])
        got = r.apply_move(i, b) + r.apply_move(j, a)
        improvement += got
        swaps += 1
    return {"moves": moves, "swaps": swaps, "improvement": improvement}
