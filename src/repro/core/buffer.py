"""CUTTANA's prioritized vertex buffer (paper §III-A, Algorithm 1).

A bounded max-priority queue keyed by the *buffer score* (Eq. 6):

    score(v) = |N(v)| / D_max  +  theta * assigned(v) / |N(v)|

Higher score => evicted (placed) earlier. Score updates (a neighbour got
assigned) are handled with the classic lazy-heap trick: push a fresh entry and
invalidate the old one by sequence comparison on pop.
"""
from __future__ import annotations

import heapq

import numpy as np


class PriorityBuffer:
    def __init__(self, capacity: int, d_max: int, theta: float = 1.0):
        self.capacity = int(capacity)
        self.d_max = max(int(d_max), 1)
        self.theta = float(theta)
        self._heap: list[tuple[float, int, int]] = []  # (-score, v, version)
        self._version: dict[int, int] = {}  # v -> latest version
        self._nbrs: dict[int, np.ndarray] = {}
        self._assigned: dict[int, int] = {}
        self._size = 0

    def __len__(self) -> int:
        return self._size

    @property
    def full(self) -> bool:
        return self._size >= self.capacity

    def score(self, v: int) -> float:
        deg = self._nbrs[v].shape[0]
        return deg / self.d_max + self.theta * self._assigned[v] / max(deg, 1)

    # ------------------------------------------------------------------ ops
    def push(self, v: int, nbrs: np.ndarray, assigned_count: int) -> None:
        assert v not in self._nbrs
        self._nbrs[v] = nbrs
        self._assigned[v] = int(assigned_count)
        self._version[v] = 0
        heapq.heappush(self._heap, (-self.score(v), v, 0))
        self._size += 1

    def contains(self, v: int) -> bool:
        return v in self._nbrs

    def notify_assigned(self, v: int) -> bool:
        """A neighbour of buffered ``v`` was placed. Returns True if ``v`` is
        now *complete* (all neighbours assigned) and should be evicted now."""
        self._assigned[v] += 1
        if self._assigned[v] >= self._nbrs[v].shape[0]:
            return True
        ver = self._version[v] + 1
        self._version[v] = ver
        heapq.heappush(self._heap, (-self.score(v), v, ver))
        return False

    def remove(self, v: int) -> np.ndarray:
        """Remove ``v`` (used for complete-eviction); stale heap entries are
        skipped lazily on pop."""
        nbrs = self._nbrs.pop(v)
        del self._assigned[v]
        del self._version[v]
        self._size -= 1
        return nbrs

    def pop_best(self) -> tuple[int, np.ndarray]:
        """Pop the vertex with the highest buffer score."""
        while self._heap:
            neg, v, ver = heapq.heappop(self._heap)
            if v in self._nbrs and self._version[v] == ver:
                return v, self.remove(v)
        raise IndexError("pop from empty buffer")
