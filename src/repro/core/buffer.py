"""CUTTANA's prioritized vertex buffer (paper §III-A, Algorithm 1).

A bounded max-priority queue keyed by the *buffer score* (Eq. 6):

    score(v) = |N(v)| / D_max  +  theta * assigned(v) / |N(v)|

Higher score => evicted (placed) earlier. Score updates (a neighbour got
assigned) are handled with the classic lazy-heap trick: push a fresh entry and
invalidate the old one by version comparison on pop.

Bookkeeping is array-backed: degree / assigned-count / version / membership
live in flat numpy arrays indexed by vertex id, so a whole neighbourhood can
be notified in one vectorised call (:meth:`PriorityBuffer.notify_many`) -
this is what lets the buffered placement policy in
:mod:`repro.core.engine` batch its score maintenance. When constructed with
``graph=``, neighbour lists come straight from the CSR arrays and nothing
per-vertex is stored outside the flat arrays; without a graph (standalone
use, e.g. property tests) the neighbour arrays passed to :meth:`push` are
kept in a side table.

The *scoring* is delegated to a pluggable :class:`~repro.core.priority.
BufferPriority` strategy (``priority=``). The default is
:class:`~repro.core.priority.Eq6Priority`, which computes exactly the
expressions above - the legacy ``PriorityBuffer(capacity, d_max, theta)``
constructor is preserved and bit-identical. Strategies with
``tracks_parts`` additionally receive partition ids through
``push(..., nbr_parts=...)`` / ``notify_many(..., parts=...)``.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.priority import BufferPriority, Eq6Priority


class PriorityBuffer:
    def __init__(
        self,
        capacity: int,
        d_max: int | None = None,
        theta: float = 1.0,
        graph=None,
        priority: BufferPriority | None = None,
    ):
        if priority is None:
            priority = Eq6Priority(1 if d_max is None else d_max, theta)
        self.capacity = int(capacity)
        self.priority = priority
        # legacy attribute surface (tests and telemetry read these)
        self.d_max = priority.d_max
        self.theta = priority.theta
        self._heap: list[tuple[float, int, int]] = []  # (-score, v, version)
        self._size = 0
        if graph is not None:
            self._indptr = graph.indptr
            self._indices = graph.indices
            self._nbrs = None
            n = graph.num_vertices
            self._deg = np.asarray(graph.degrees, dtype=np.int64)
        else:
            self._indptr = None
            self._indices = None
            self._nbrs: dict[int, np.ndarray] = {}
            n = 0
            self._deg = np.zeros(0, dtype=np.int64)
        self._assigned = np.zeros(n, dtype=np.int64)
        self._version = np.zeros(n, dtype=np.int64)
        self._in = np.zeros(n, dtype=bool)

    def __len__(self) -> int:
        return self._size

    @property
    def full(self) -> bool:
        return self._size >= self.capacity

    # ------------------------------------------------------------- internals
    def _grow(self, hi: int) -> None:
        cur = self._in.shape[0]
        if hi <= cur:
            return
        new = max(hi, 2 * cur, 64)
        for name in ("_deg", "_assigned", "_version"):
            old = getattr(self, name)
            arr = np.zeros(new, dtype=old.dtype)
            arr[:cur] = old
            setattr(self, name, arr)
        arr = np.zeros(new, dtype=bool)
        arr[:cur] = self._in
        self._in = arr

    def _neighbors(self, v: int) -> np.ndarray:
        if self._indptr is not None:
            return self._indices[self._indptr[v] : self._indptr[v + 1]]
        return self._nbrs[v]

    def score(self, v: int) -> float:
        deg = int(self._deg[v])
        return self.priority.score_counts(v, deg, int(self._assigned[v]))

    # ------------------------------------------------------------------ ops
    def push(
        self,
        v: int,
        nbrs: np.ndarray | None = None,
        assigned_count: int = 0,
        nbr_parts: np.ndarray | None = None,
    ) -> None:
        v = int(v)
        assert not self.contains(v)
        self._grow(v + 1)
        if self._indptr is None:
            assert nbrs is not None, "standalone buffer needs explicit nbrs"
            self._nbrs[v] = nbrs
            self._deg[v] = nbrs.shape[0]
        self._in[v] = True
        self._assigned[v] = int(assigned_count)
        if self.priority.tracks_parts:
            self.priority.on_push(v, nbr_parts)
        heapq.heappush(self._heap, (-self.score(v), v, int(self._version[v])))
        self._size += 1

    def contains(self, v: int) -> bool:
        return v < self._in.shape[0] and bool(self._in[v])

    def notify_assigned(self, v: int) -> bool:
        """A neighbour of buffered ``v`` was placed. Returns True if ``v`` is
        now *complete* (all neighbours assigned) and should be evicted now."""
        self._assigned[v] += 1
        if self._assigned[v] >= self._deg[v]:
            return True
        self._version[v] += 1
        heapq.heappush(self._heap, (-self.score(v), v, int(self._version[v])))
        return False

    def notify_many(self, vs: np.ndarray, parts=None) -> list[int]:
        """Vectorised :meth:`notify_assigned` over a placed vertex's whole
        neighbourhood. Bumps every buffered vertex in ``vs`` once per
        occurrence (duplicate entries are possible with ``dedupe=False``
        graphs); returns the now-complete ones in first-occurrence ``vs``
        order WITHOUT removing them (the caller cascades). ``parts`` - the
        partition of the newly assigned neighbour, scalar or aligned with
        ``vs`` - feeds partition-tracking strategies and is otherwise
        ignored."""
        if self._size == 0 or vs.size == 0 or self._in.shape[0] == 0:
            return []
        track = parts is not None and self.priority.tracks_parts
        parts_arr = None
        if track and not (np.isscalar(parts) or getattr(parts, "ndim", 1) == 0):
            parts_arr = np.asarray(parts)
        keep = vs < self._in.shape[0]
        vs = vs[keep]
        if parts_arr is not None:
            parts_arr = parts_arr[keep]
        inmask = self._in[vs]
        buffered = vs[inmask]
        if buffered.size == 0:
            return []
        np.add.at(self._assigned, buffered, 1)
        if track:
            self.priority.on_notify(
                buffered, parts if parts_arr is None else parts_arr[inmask]
            )
        if buffered.size > 1:
            buffered = buffered[np.sort(np.unique(buffered, return_index=True)[1])]
        deg = self._deg[buffered]
        asg = self._assigned[buffered]
        complete = asg >= deg
        live = buffered[~complete]
        if live.size:
            self._version[live] += 1
            sc = self.priority.score_counts_many(
                live, deg[~complete], asg[~complete]
            )
            heap = self._heap
            for s, w, ver in zip(
                (-sc).tolist(), live.tolist(), self._version[live].tolist()
            ):
                heapq.heappush(heap, (s, w, ver))
        return buffered[complete].tolist()

    def remove(self, v: int) -> np.ndarray:
        """Remove ``v`` (used for complete-eviction); outstanding heap entries
        are invalidated by the version bump and skipped lazily on pop."""
        v = int(v)
        assert self.contains(v)
        nbrs = self._neighbors(v)
        if self._indptr is None:
            del self._nbrs[v]
        self._in[v] = False
        self._version[v] += 1
        self._size -= 1
        if self.priority.tracks_parts:
            self.priority.on_remove(v)
        return nbrs

    def pop_best(self) -> tuple[int, np.ndarray]:
        """Pop the vertex with the highest buffer score."""
        while self._heap:
            neg, v, ver = heapq.heappop(self._heap)
            if self._in[v] and self._version[v] == ver:
                return v, self.remove(v)
        raise IndexError("pop from empty buffer")
