"""Incremental (re)partitioning under churn.

CUTTANA's buffered streaming design makes premature assignments revisable;
this module applies that primitive to *dynamic* graphs. Edge-arrival batches
(a :class:`~repro.graph.churn.ChurnStream`) are ingested one at a time:

1. newly seen vertices are placed by the streaming scorer (FENNEL Eq. 7
   against the hybrid mass) scored against the **live** partition loads -
   the balance capacities grow with the graph, so early arrivals are not
   crammed into capacities sized for the final graph;
2. edge-cut drift lambda = cut/m is tracked per batch against a reference
   set at the last (re)stream;
3. when drift exceeds ``drift_threshold``, a *windowed local re-stream* runs:
   the most recently touched boundary vertices (capped at ``window_frac`` of
   the seen graph) are re-streamed with full information through the PR 4
   reassign machinery (``ShardedImmediatePolicy(reassign=True)``), exactly a
   restreaming pass (Nishimura & Ugander) restricted to a window.

The whole-stream work is a fraction of re-partitioning from scratch at every
batch: each arriving vertex is placed once, plus the re-stream windows -
:class:`~repro.core.priority.BufferStats` tracks the window bookkeeping
(``bypass`` = immediate placements, ``drained`` = window re-streams,
``evictions`` = vertices actually moved).

Registered as ``cuttana-incremental`` (:mod:`repro.api.registry`); the
spec-facing :func:`partition_incremental` replays a static graph as a churn
stream (parity: one batch == the one-shot partitioner), while :func:`update`
warm-starts from a prior :class:`~repro.api.result.PartitionResult` and
returns a new one - the CLI ``update`` subcommand's engine.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import autotune
from repro.core.base import UNASSIGNED, FennelParams, PartitionState, finalize
from repro.core.engine import (
    EngineConfig,
    FennelScorer,
    ShardedImmediatePolicy,
    StreamEngine,
    _check_num_shards,
)
from repro.core.priority import BufferStats
from repro.graph.churn import ChurnStream, churn_from_graph
from repro.graph.csr import CSRGraph
from repro.graph.stream import stream_order

__all__ = ["IncrementalPartitioner", "partition_incremental", "update"]


class _GraphView:
    """The read surface :class:`FennelScorer` needs (``num_vertices``,
    ``num_edges``, ``indices.shape``) for the *currently seen* graph, without
    materializing it - alpha and mu track the live vertex/edge counts."""

    def __init__(self, num_vertices: int, num_edges: int):
        self.num_vertices = int(num_vertices)
        self.num_edges = int(num_edges)
        # O(1)-memory stand-in with the right shape (2|E| half-edges)
        self.indices = np.broadcast_to(
            np.int32(0), (max(2 * int(num_edges), 0),)
        )


class IncrementalPartitioner:
    """Stateful incremental partitioner over ``num_vertices`` vertex ids.

    ``ingest`` one edge batch at a time, then ``finalize`` to obtain the
    assignment (vertices never seen in any edge are placed onto the least
    loaded partition). ``num_shards`` >= 2 runs both new-vertex placement and
    re-stream windows through the bulk-synchronous superstep engine;
    ``max_workers`` changes wall-clock only, never assignments.
    """

    def __init__(
        self,
        num_vertices: int,
        k: int,
        *,
        epsilon: float = 0.05,
        balance_mode: str = "edge",
        seed: int = 0,
        drift_threshold: float = 0.10,
        window_frac: float = 0.25,
        num_shards: int = 1,
        max_workers: int = 0,
        chunk: int = 512,
    ):
        if balance_mode not in ("vertex", "edge"):
            raise ValueError(f"unknown balance mode {balance_mode}")
        if drift_threshold < 0:
            raise ValueError(
                f"drift_threshold must be >= 0, got {drift_threshold}"
            )
        if not (0 < window_frac <= 1):
            raise ValueError(
                f"window_frac must be in (0, 1], got {window_frac}"
            )
        self.n = int(num_vertices)
        self.k = int(k)
        self.seed = int(seed)
        self.drift_threshold = float(drift_threshold)
        self.window_frac = float(window_frac)
        self.num_shards = _check_num_shards(num_shards)
        self.max_workers = int(max_workers)
        self.chunk = int(chunk)
        self.params = FennelParams(hybrid=(balance_mode == "edge"))
        # live state: num_vertices/total_degree start at 0 and grow with the
        # stream, so the (1+eps)X/k capacities always reflect the seen graph
        self.state = PartitionState(
            k=self.k,
            num_vertices=0,
            total_degree=0,
            epsilon=float(epsilon),
            balance_mode=balance_mode,
            part_of=np.full(self.n, UNASSIGNED, dtype=np.int32),
            v_counts=np.zeros(self.k, dtype=np.float64),
            e_counts=np.zeros(self.k, dtype=np.float64),
            rng=np.random.default_rng(seed),
        )
        self.seen = 0  # vertices with at least one ingested edge
        self.m = 0  # unique undirected edges ingested so far
        self.cut = 0  # exact cut-edge count under the current assignment
        self.deg = np.zeros(self.n, dtype=np.int64)
        self.last_touch = np.full(self.n, -1, dtype=np.int64)
        self._lo_blocks: list[np.ndarray] = []
        self._hi_blocks: list[np.ndarray] = []
        self._keys = np.empty(0, dtype=np.int64)  # sorted canonical edge keys
        self._ref: float | None = None  # lambda at the last (re)stream point
        self.stats = BufferStats()
        self.batches = 0
        self.restream_windows = 0
        self.moved_vertices = 0
        self.new_vertices = 0
        self.stream_work = 0  # total vertex placements (new + re-streamed)
        self.kernel_calls = 0
        self.drift_before: list[float] = []
        self.drift_after: list[float] = []

    # ------------------------------------------------------------- warm start
    @classmethod
    def from_partition(
        cls,
        graph: CSRGraph,
        assignment: np.ndarray,
        k: int,
        *,
        num_vertices: int | None = None,
        **kwargs,
    ) -> "IncrementalPartitioner":
        """Warm-start from a prior snapshot + assignment: the prior edges
        count as already streamed (zero additional work), loads/cut/drift
        reference are seeded from the assignment. ``num_vertices`` may exceed
        the prior graph to leave room for vertices the churn will add."""
        n = graph.num_vertices if num_vertices is None else int(num_vertices)
        if n < graph.num_vertices:
            raise ValueError(
                f"num_vertices={n} smaller than the prior graph "
                f"({graph.num_vertices})"
            )
        assignment = np.asarray(assignment)
        if assignment.shape != (graph.num_vertices,):
            raise ValueError(
                f"assignment shape {assignment.shape} != "
                f"({graph.num_vertices},)"
            )
        inc = cls(n, k, **kwargs)
        deg = graph.degrees.astype(np.int64)
        inc.state.part_of[: graph.num_vertices] = assignment
        inc.state.v_counts[:] = np.bincount(assignment, minlength=k)
        inc.state.e_counts[:] = np.bincount(
            assignment, weights=deg.astype(np.float64), minlength=k
        )
        inc.deg[: graph.num_vertices] = deg
        inc.seen = graph.num_vertices  # isolated prior vertices are assigned
        inc.m = graph.num_edges
        inc.state.num_vertices = inc.seen
        inc.state.total_degree = 2 * inc.m
        edges = graph.edges_array()
        lo, hi = edges[:, 0], edges[:, 1]
        inc._lo_blocks.append(lo)
        inc._hi_blocks.append(hi)
        inc._keys = np.sort(lo * np.int64(inc.n) + hi)
        inc.cut = int((assignment[lo] != assignment[hi]).sum())
        inc._ref = inc.cut / max(inc.m, 1)
        inc.last_touch[: graph.num_vertices] = 0
        return inc

    # --------------------------------------------------------------- ingest
    def ingest(
        self, edges: np.ndarray, order_key: np.ndarray | None = None
    ) -> dict:
        """Ingest one edge-arrival batch; returns per-batch bookkeeping.

        Self loops and edges already ingested (in any earlier batch) are
        dropped. Newly seen vertices are placed in ascending id order, or by
        ``order_key[v]`` when given (how :func:`partition_incremental` honours
        the spec's stream order).
        """
        self.batches += 1
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        edges = edges[edges[:, 0] != edges[:, 1]]
        if edges.size and int(edges.max()) >= self.n:
            raise ValueError(
                f"edge endpoint {int(edges.max())} out of range for "
                f"num_vertices={self.n}"
            )
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        if lo.size:
            key = lo * np.int64(self.n) + hi
            _, first = np.unique(key, return_index=True)
            first.sort()
            lo, hi, key = lo[first], hi[first], key[first]
            if self._keys.size:
                pos = np.searchsorted(self._keys, key)
                pos_c = np.minimum(pos, self._keys.size - 1)
                fresh = (pos == self._keys.size) | (self._keys[pos_c] != key)
                lo, hi, key = lo[fresh], hi[fresh], key[fresh]
        if not lo.size:
            lam = self.cut / max(self.m, 1)
            return {"new_vertices": 0, "moved": 0, "edge_cut": lam}

        state = self.state
        ends = np.concatenate([lo, hi])
        new = np.unique(ends[state.part_of[ends] == UNASSIGNED])
        if order_key is not None and new.size:
            new = new[np.argsort(order_key[new], kind="stable")]
        # degree mass of edges landing on already-placed endpoints moves the
        # live loads *before* scoring; new endpoints add theirs on placement
        old_ends = ends[state.part_of[ends] != UNASSIGNED]
        if old_ends.size:
            np.add.at(
                state.e_counts,
                state.part_of[old_ends].astype(np.int64),
                1.0,
            )
        np.add.at(self.deg, lo, 1)
        np.add.at(self.deg, hi, 1)
        self.m += int(lo.size)
        self.seen += int(new.size)
        state.num_vertices = self.seen
        state.total_degree = 2 * self.m
        self._lo_blocks.append(lo)
        self._hi_blocks.append(hi)
        self._keys = np.sort(np.concatenate([self._keys, key]))

        if new.size:
            # a new vertex's batch row IS its whole adjacency so far, so the
            # batch-view CSR gives the scorer exact histograms for `new`
            batch_graph = CSRGraph.from_edges(
                np.stack([lo, hi], axis=1),
                num_vertices=self.n,
                dedupe=False,
            )
            self._run_engine(batch_graph, new.astype(np.int64), reassign=False)
            self.new_vertices += int(new.size)
            self.stream_work += int(new.size)
            self.stats.bypass += int(new.size)

        self.cut += int((state.part_of[lo] != state.part_of[hi]).sum())
        lam = self.cut / max(self.m, 1)
        moved = 0
        if self._ref is None:
            self._ref = lam
        elif lam > self._ref * (1.0 + self.drift_threshold):
            moved = self._restream(lam)
        else:
            self._ref = min(self._ref, lam)
        self.last_touch[np.unique(ends)] = self.batches
        return {
            "new_vertices": int(new.size),
            "moved": moved,
            "edge_cut": self.cut / max(self.m, 1),
        }

    # ------------------------------------------------------------- internals
    def _run_engine(
        self, graph: CSRGraph, ids: np.ndarray, reassign: bool
    ) -> None:
        engine = StreamEngine(
            graph,
            self.state,
            FennelScorer(
                _GraphView(self.seen, self.m),
                self.k,
                self.params,
                self.state.balance_mode,
            ),
            ShardedImmediatePolicy(self.num_shards, reassign=reassign),
            ids=ids,
            seed=self.seed,
            config=EngineConfig(chunk=self.chunk, max_workers=self.max_workers),
        )
        engine.run()
        self.kernel_calls += engine.telemetry["kernel_calls"]

    def _all_edges(self) -> tuple[np.ndarray, np.ndarray]:
        lo = (
            np.concatenate(self._lo_blocks)
            if self._lo_blocks
            else np.empty(0, dtype=np.int64)
        )
        hi = (
            np.concatenate(self._hi_blocks)
            if self._hi_blocks
            else np.empty(0, dtype=np.int64)
        )
        return lo, hi

    def _restream(self, lam: float) -> int:
        """Windowed local re-stream: re-place the most recently touched
        boundary vertices with full information. Returns vertices moved."""
        self.restream_windows += 1
        self.drift_before.append(float(lam))
        state = self.state
        lo, hi = self._all_edges()
        cut_mask = state.part_of[lo] != state.part_of[hi]
        cand = np.unique(np.concatenate([lo[cut_mask], hi[cut_mask]]))
        cap = max(1, int(np.ceil(self.window_frac * self.seen)))
        if cand.size > cap:
            # most recently touched first (drift lives where churn landed),
            # ties by ascending id; the selected window streams in id order
            recency = np.lexsort((cand, -self.last_touch[cand]))
            cand = np.sort(cand[recency][:cap])
        window = cand.astype(np.int64)
        if window.size:
            snapshot = CSRGraph.from_edges(
                np.stack([lo, hi], axis=1), num_vertices=self.n, dedupe=False
            )
            before = state.part_of[window].copy()
            self._run_engine(snapshot, window, reassign=True)
            moved = int((state.part_of[window] != before).sum())
        else:
            moved = 0
        self.moved_vertices += moved
        self.stream_work += int(window.size)
        self.stats.drained += int(window.size)
        self.stats.evictions += moved
        self.stats.observe_len(int(window.size))
        self.cut = int((state.part_of[lo] != state.part_of[hi]).sum())
        lam_after = self.cut / max(self.m, 1)
        self._ref = lam_after
        self.drift_after.append(float(lam_after))
        return moved

    # -------------------------------------------------------------- finalize
    def finalize(self) -> np.ndarray:
        """Assign any never-seen (isolated) vertices to the least loaded
        partition and return the full int32 assignment."""
        state = self.state
        isolated = np.flatnonzero(state.part_of == UNASSIGNED)
        for v in isolated:
            state.assign(int(v), int(state.v_counts.argmin()), 0)
        self.stream_work += int(isolated.size)
        self.seen = self.n
        state.num_vertices = self.n
        return finalize(state)

    def snapshot_graph(self) -> CSRGraph:
        """The static CSR graph of everything ingested so far."""
        lo, hi = self._all_edges()
        return CSRGraph.from_edges(
            np.stack([lo, hi], axis=1), num_vertices=self.n, dedupe=False
        )

    def telemetry(self) -> dict:
        out = {
            "batches": self.batches,
            "restream_windows": self.restream_windows,
            "moved_vertices": self.moved_vertices,
            "new_vertices": self.new_vertices,
            "stream_work": self.stream_work,
            "kernel_calls": self.kernel_calls,
            "edge_cut_live": self.cut / max(self.m, 1),
            "drift_before": [round(x, 6) for x in self.drift_before],
            "drift_after": [round(x, 6) for x in self.drift_after],
            "num_shards": self.num_shards,
        }
        out.update(self.stats.to_telemetry("incremental-window"))
        return out


def _resolve_shards(num_shards: int, chunk: int, num_vertices: int) -> int:
    if int(num_shards) == 0:
        num_shards = autotune.resolve(
            0, chunk, algo="restream", num_vertices=num_vertices
        ).num_shards
    return _check_num_shards(num_shards)


def partition_incremental(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    order: str = "natural",
    seed: int = 0,
    num_batches: int = 16,
    drift_threshold: float = 0.10,
    window_frac: float = 0.25,
    num_shards: int = 1,
    max_workers: int = 0,
    chunk: int = 512,
    telemetry: dict | None = None,
) -> np.ndarray:
    """``cuttana-incremental``: replay ``graph`` as a churn stream.

    The static graph is converted to an arrival stream via
    :func:`~repro.graph.churn.churn_from_graph` under the spec's
    ``order``/``seed`` and ingested in ``num_batches`` batches. With
    ``num_batches=1`` (and no isolated vertices) this is *exactly* the
    one-shot FENNEL streaming run - the parity pin - while larger batch
    counts exercise the live-load placement + drift-triggered re-stream path
    the ``update`` API uses on real churn.
    """
    if num_batches < 1:
        raise ValueError(f"num_batches must be >= 1, got {num_batches}")
    num_shards = _resolve_shards(num_shards, chunk, graph.num_vertices)
    t0 = time.perf_counter()
    stream = churn_from_graph(graph, order=order, seed=seed)
    pos = np.empty(graph.num_vertices, dtype=np.int64)
    pos[stream_order(graph, order, seed)] = np.arange(
        graph.num_vertices, dtype=np.int64
    )
    inc = IncrementalPartitioner(
        graph.num_vertices,
        k,
        epsilon=epsilon,
        balance_mode=balance_mode,
        seed=seed,
        drift_threshold=drift_threshold,
        window_frac=window_frac,
        num_shards=num_shards,
        max_workers=max_workers,
        chunk=chunk,
    )
    for batch in stream.batches(num_batches):
        inc.ingest(batch, order_key=pos)
    part = inc.finalize()
    if telemetry is not None:
        telemetry.update(inc.telemetry())
        telemetry["stream_seconds"] = time.perf_counter() - t0
    return part


def update(
    prior,
    batches,
    *,
    k: int | None = None,
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    seed: int = 0,
    num_batches: int = 16,
    drift_threshold: float = 0.10,
    window_frac: float = 0.25,
    num_shards: int = 1,
    max_workers: int = 0,
    chunk: int = 512,
):
    """Incrementally update a partition with new edge arrivals.

    ``prior`` is a :class:`~repro.api.result.PartitionResult` (its spec
    supplies k/epsilon/balance_mode/seed defaults), a ``(graph, assignment)``
    pair, or ``None`` for a cold start. ``batches`` is a
    :class:`~repro.graph.churn.ChurnStream` (replayed in ``num_batches``
    arrival batches) or an iterable of ``(m_i, 2)`` edge arrays.

    Returns a new :class:`~repro.api.result.PartitionResult` over the
    post-churn snapshot graph, with the incremental telemetry
    (``batches``/``restream_windows``/``moved_vertices``/``drift_*``) and
    ``timings["stream_seconds"]`` covering only the update work.
    """
    from repro.api.result import PartitionResult
    from repro.api.spec import PartitionSpec

    prior_graph, prior_assignment = None, None
    if prior is not None:
        if hasattr(prior, "assignment") and hasattr(prior, "spec"):
            prior_graph, prior_assignment = prior.graph, prior.assignment
            spec = prior.spec
            k = spec.k if k is None else k
            epsilon, balance_mode, seed = (
                spec.epsilon, spec.balance_mode, spec.seed,
            )
        else:
            prior_graph, prior_assignment = prior
    if k is None:
        raise ValueError("update() needs k (from the prior result or k=...)")

    if isinstance(batches, ChurnStream):
        batch_list = batches.batches(num_batches)
        churn_n = batches.num_vertices
    else:
        batch_list = [
            np.asarray(b, dtype=np.int64).reshape(-1, 2) for b in batches
        ]
        churn_n = max(
            (int(b.max()) + 1 for b in batch_list if b.size), default=0
        )
    n = max(churn_n, prior_graph.num_vertices if prior_graph is not None else 0)
    num_shards = _resolve_shards(num_shards, chunk, n)
    knobs = dict(
        epsilon=epsilon,
        balance_mode=balance_mode,
        seed=seed,
        drift_threshold=drift_threshold,
        window_frac=window_frac,
        num_shards=num_shards,
        max_workers=max_workers,
        chunk=chunk,
    )
    t0 = time.perf_counter()
    if prior_graph is not None:
        inc = IncrementalPartitioner.from_partition(
            prior_graph, prior_assignment, k, num_vertices=n, **knobs
        )
    else:
        inc = IncrementalPartitioner(n, k, **knobs)
    for batch in batch_list:
        inc.ingest(batch)
    part = inc.finalize()
    stream_s = time.perf_counter() - t0
    snapshot = inc.snapshot_graph()
    spec = PartitionSpec(
        algo="cuttana-incremental",
        k=k,
        epsilon=epsilon,
        balance_mode=balance_mode,
        seed=seed,
        params={
            "num_batches": max(len(batch_list), 1),
            "drift_threshold": drift_threshold,
            "window_frac": window_frac,
            "num_shards": num_shards,
            "max_workers": max_workers,
            "chunk": chunk,
        },
    )
    telemetry = inc.telemetry()
    telemetry.update(
        graph_backing="resident",
        peak_graph_bytes=int(snapshot.indptr.nbytes + snapshot.indices.nbytes),
        mapped_graph_bytes=0,
        compressed_graph_bytes=0,
        warm_start=prior_graph is not None,
    )
    return PartitionResult(
        spec=spec,
        graph=snapshot,
        assignment=part,
        timings={"total_s": stream_s, "stream_seconds": stream_s},
        telemetry=telemetry,
    )
