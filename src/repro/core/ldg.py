"""Linear Deterministic Greedy (Stanton & Kliot, KDD'12).

score_i = |V_i ∩ N(v)| * (1 - size_i / C)   with capacity C per balance mode.

Phase-1 runs through :class:`repro.core.engine.StreamEngine` (chunked
kernel-backed scoring, bit-identical to the seed per-vertex loop kept in
:mod:`repro.core.legacy`).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.base import PartitionState, finalize
from repro.core.engine import EngineConfig, ImmediatePolicy, LDGScorer, StreamEngine
from repro.graph.csr import CSRGraph


def partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "vertex",
    order: str = "natural",
    seed: int = 0,
    chunk: int = 512,
    use_pallas: bool | None = None,
    interpret: bool = False,
    telemetry: dict | None = None,
) -> np.ndarray:
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    t0 = time.perf_counter()
    engine = StreamEngine(
        graph,
        state,
        LDGScorer(graph, k, balance_mode),
        ImmediatePolicy(),
        order=order,
        seed=seed,
        config=EngineConfig(chunk=chunk, use_pallas=use_pallas, interpret=interpret),
    )
    engine.run()
    if telemetry is not None:
        telemetry.update(engine.telemetry)
        telemetry["stream_seconds"] = time.perf_counter() - t0
    return finalize(state)
