"""Linear Deterministic Greedy (Stanton & Kliot, KDD'12).

score_i = |V_i ∩ N(v)| * (1 - size_i / C)   with capacity C per balance mode.
"""
from __future__ import annotations

import numpy as np

from repro.core.base import PartitionState, finalize
from repro.graph.csr import CSRGraph
from repro.graph.stream import stream_order


def partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "vertex",
    order: str = "natural",
    seed: int = 0,
) -> np.ndarray:
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    indptr, indices = graph.indptr, graph.indices
    for v in stream_order(graph, order, seed):
        nbrs = indices[indptr[v] : indptr[v + 1]]
        hist = state.neighbor_histogram(nbrs)
        if balance_mode == "vertex":
            frac = state.v_counts / state.vertex_capacity
        else:
            frac = state.e_counts / state.edge_capacity
        scores = hist * np.maximum(1.0 - frac, 0.0)
        # LDG ties (incl. the all-zero-hist case) go to the least-loaded bin:
        # express that as a tiny negative load term.
        loads = state.v_counts if balance_mode == "vertex" else state.e_counts
        scores = scores - 1e-9 * loads
        allowed = ~state.would_overflow(nbrs.size)
        p = state.argmax_tiebreak(scores, allowed)
        state.assign(int(v), p, nbrs.size)
    return finalize(state)
