"""CUTTANA: prioritized buffered streaming + coarsened refinement (paper §III).

Phase 1 (Algorithm 1): stream vertices; vertices with degree >= D_max are
placed immediately (their premature-assignment risk is low, Thm. 1); the rest
enter a bounded priority buffer ordered by buffer score (Eq. 6). On overflow
the best-scored vertex is evicted and placed with the FENNEL/PowerLyra hybrid
score (Eq. 7). Placement of a vertex bumps the buffer score of its buffered
neighbours; a buffered vertex whose neighbourhood is fully assigned is evicted
immediately. Every placement also picks a *sub-partition* (Def. 2).

Phase 1 runs through :class:`repro.core.engine.StreamEngine`:
``use_buffer=True`` selects :class:`~repro.core.engine.BufferedPolicy`
(Algorithm 1 over the array-backed buffer), ``use_buffer=False`` the chunked
kernel-backed :class:`~repro.core.engine.ImmediatePolicy`. Both are
bit-identical to the seed loop kept in :mod:`repro.core.legacy`.

Phase 2: greedy trades on the coarsened sub-partition graph until maximal
(or early-stopped by ``thresh``), then vertices inherit their sub-partition's
final partition.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.base import FennelParams, PartitionState, finalize
from repro.core.engine import (
    BufferedPolicy,
    EngineConfig,
    FennelScorer,
    ImmediatePolicy,
    StreamEngine,
)
from repro.core.refinement import Refiner, build_subpartition_graph
from repro.core.subpartition import SubPartitioner
from repro.graph.csr import CSRGraph


def _phase2_refine(
    graph: CSRGraph,
    subp: SubPartitioner,
    k: int,
    epsilon: float,
    balance_mode: str,
    thresh: float,
    max_moves: int | None = None,
):
    """Merge + coarsen + refine (paper §III-B): build the sub-partition
    graph from phase-1's sub-assignments and run greedy trades. Shared by
    ``cuttana``, ``cuttana-batched``, ``cuttana-parallel`` (where it is the
    pass that reconciles shard-boundary vertices), and :func:`refine_any`.

    Returns ``(part, sub_part, moves, cut_improvement)``.
    """
    w = build_subpartition_graph(graph, subp.sub_of, subp.kp)
    sub_part = np.repeat(np.arange(k, dtype=np.int64), subp.s)
    if balance_mode == "edge":
        size = subp.sub_e_counts.copy()
        total = float(graph.indices.shape[0])
    else:
        size = subp.sub_v_counts.copy()
        total = float(graph.num_vertices)
    refiner = Refiner(w, sub_part, size, k, epsilon, total_mass=total)
    stats = refiner.refine(thresh=thresh, max_moves=max_moves)
    sub_part = refiner.sub_part.copy()
    part = sub_part[subp.sub_of].astype(np.int32)
    return part, sub_part, stats.moves, stats.cut_improvement


@dataclasses.dataclass
class CuttanaResult:
    """Compat container for ``return_detail=True`` callers.

    Deprecated: the canonical surface is :func:`repro.api.partition`, which
    folds these fields into ``PartitionResult.telemetry`` / ``.timings`` so
    every algorithm returns one uniform type.
    """

    part: np.ndarray
    sub_of: np.ndarray
    sub_part: np.ndarray  # final partition of each sub-partition
    refine_moves: int
    refine_improvement: float
    phase1_seconds: float
    phase2_seconds: float


def partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    d_max: int = 1000,
    max_qsize: int | None = None,
    theta: float = 1.0,
    subparts_per_partition: int | None = None,
    use_buffer: bool = True,
    use_refinement: bool = True,
    thresh: float = 0.0,
    max_moves: int | None = None,
    fennel_params: FennelParams | None = None,
    order: str = "natural",
    seed: int = 0,
    return_detail: bool = False,
    chunk: int = 512,
    use_pallas: bool | None = None,
    interpret: bool = False,
    prefetch: str = "auto",
    strategy: str = "eq6",
    telemetry: dict | None = None,
):
    """Full CUTTANA partitioner. Ablations: ``use_buffer=False`` /
    ``use_refinement=False`` reproduce the paper's Table III rows
    (both off == plain FENNEL with Eq. 7 scoring).

    ``strategy`` selects the buffer-eviction priority
    (:mod:`repro.core.priority`); the default ``"eq6"`` is the paper's
    Eq. 6 and bit-identical to the pre-strategy-layer engine.

    ``telemetry`` (if given) receives engine counters, phase wall times, and
    refinement stats; ``return_detail=True`` is the compat flag that instead
    returns the legacy :class:`CuttanaResult`."""
    n = graph.num_vertices
    if max_qsize is None:
        max_qsize = max(1024, n // 10)  # paper: 1e6 for 10^7..10^8-vertex graphs
    if subparts_per_partition is None:
        # paper: K'/K = 4096 for big graphs; scale down for small ones so that
        # sub-partitions still hold >= ~8 vertices on average.
        subparts_per_partition = int(max(8, min(4096, n // (8 * k))))

    params = fennel_params or FennelParams(hybrid=(balance_mode == "edge"))
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    subp = SubPartitioner(
        graph,
        k,
        subparts_per_partition,
        epsilon=max(epsilon, 0.10),
        balance_mode=balance_mode,
        seed=seed,
    )
    policy = (
        BufferedPolicy(max_qsize, d_max, theta, strategy=strategy)
        if use_buffer
        else ImmediatePolicy()
    )
    # t0 before engine construction: StreamEngine computes stream_order there,
    # which the seed loop counted inside phase 1
    t0 = time.perf_counter()
    engine = StreamEngine(
        graph,
        state,
        FennelScorer(graph, k, params, balance_mode),
        policy,
        subpartitioner=subp,
        order=order,
        seed=seed,
        config=EngineConfig(
            chunk=chunk, use_pallas=use_pallas, interpret=interpret,
            prefetch=prefetch,
        ),
    )
    engine.run()
    phase1_s = time.perf_counter() - t0

    part = finalize(state)
    sub_of = subp.sub_of.copy()
    kp = subp.kp
    sub_part = np.repeat(np.arange(k, dtype=np.int64), subp.s)

    t1 = time.perf_counter()
    moves, improvement = 0, 0.0
    if use_refinement and k > 1:
        part, sub_part, moves, improvement = _phase2_refine(
            graph, subp, k, epsilon, balance_mode, thresh, max_moves
        )
    phase2_s = time.perf_counter() - t1

    if telemetry is not None:
        telemetry.update(engine.telemetry)
        telemetry.update(
            phase1_seconds=phase1_s,
            phase2_seconds=phase2_s,
            refine_moves=moves,
            refine_improvement=improvement,
            subpartitions=int(kp),
        )
    if return_detail:
        return CuttanaResult(
            part=part,
            sub_of=sub_of,
            sub_part=sub_part,
            refine_moves=moves,
            refine_improvement=improvement,
            phase1_seconds=phase1_s,
            phase2_seconds=phase2_s,
        )
    return part


def partition_buffcut(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    d_max: int = 1000,
    strategy: str = "gain",
    max_qsize: int | None = None,
    theta: float = 1.0,
    subparts_per_partition: int | None = None,
    use_refinement: bool = True,
    thresh: float = 0.0,
    max_moves: int | None = None,
    order: str = "natural",
    seed: int = 0,
    chunk: int = 512,
    prefetch: str = "auto",
    telemetry: dict | None = None,
) -> np.ndarray:
    """``cuttana-buffcut``: CUTTANA's engine with a prioritized (non-Eq.-6)
    buffer-eviction strategy - ``"gain"`` (default) or ``"completeness"``.
    The registry/spec layer rejects ``strategy="eq6"`` here (that spec
    spells ``algo="cuttana"``); this entry point exists so the variant's
    own defaults are the callable's defaults."""
    return partition(
        graph, k, epsilon=epsilon, balance_mode=balance_mode, d_max=d_max,
        max_qsize=max_qsize, theta=theta,
        subparts_per_partition=subparts_per_partition,
        use_refinement=use_refinement, thresh=thresh, max_moves=max_moves,
        order=order, seed=seed, chunk=chunk, prefetch=prefetch,
        strategy=strategy, telemetry=telemetry,
    )


def refine_any(
    graph: CSRGraph,
    part: np.ndarray,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    subparts_per_partition: int | None = None,
    thresh: float = 0.0,
    seed: int = 0,
) -> np.ndarray:
    """Paper §III-B: refinement applies to *any* partitioner's output.

    Builds sub-partitions by re-streaming vertices inside their fixed
    partition assignment, then runs phase-2 trades.
    """
    n = graph.num_vertices
    if subparts_per_partition is None:
        subparts_per_partition = int(max(8, min(4096, n // (8 * k))))
    subp = SubPartitioner(
        graph, k, subparts_per_partition, balance_mode=balance_mode, seed=seed
    )
    indptr, indices = graph.indptr, graph.indices
    for v in range(n):
        nbrs = indices[indptr[v] : indptr[v + 1]]
        subp.assign(v, int(part[v]), nbrs, nbrs.size)
    refined, _, _, _ = _phase2_refine(graph, subp, k, epsilon, balance_mode, thresh)
    return refined
