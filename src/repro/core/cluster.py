"""Streaming-clustering coarsening prepass (``cluster+<algo>``).

"Clustering-based Partitioning for Large Web Graphs" (and the Hollocou
streaming-clustering line it builds on) shows that contracting community
structure *before* streaming lifts every downstream streaming partitioner:
a single bounded-memory pass groups tightly-connected low-degree vertices
into supervertices, the much smaller coarse graph is partitioned by an
ordinary streaming engine (which now sees whole communities as single
stream items), and the assignment is projected back to the original
vertices.

Pipeline stages (see ``src/repro/core/README.md``):

1. **Cluster** (:func:`streaming_cluster`) - one pass over the stream
   order. Each vertex joins the neighbouring cluster it shares the most
   edges with, subject to a volume cap (sum of member degrees) and a
   member-count cap so no cluster can exceed a fraction of one
   partition's capacity; vertices with degree >= ``hub_degree`` stay
   singletons (hubs belong to many communities - merging them destroys
   the frontier). Memory is O(|V|): the cluster id per vertex plus one
   volume/size counter per cluster.
2. **Contract** (:func:`build_coarse_graph`) - cross-cluster edges become
   the coarse edge list with multiplicity preserved (``dedupe=False``),
   so the streaming scorer's neighbour histograms count original edges,
   not merely coarse adjacency.
3. **Partition** - any registered engine partitioner (``cuttana``,
   ``fennel``) streams the coarse graph with the same epsilon / balance
   mode / order / seed.
4. **Project + repair** - ``part[v] = coarse_part[cluster_of[v]]``; a
   deterministic greedy pass then moves lowest-degree vertices out of
   over-capacity partitions (coarse-level balance is on coarse masses, so
   projection can overshoot the fine-grained condition slightly).
5. **Refine** - the standard phase-2 merge + coarsen + refine pass from
   :mod:`repro.core.cuttana` / :mod:`repro.core.refinement`.

Telemetry: ``clusters_found``, ``coarsening_ratio``, ``coarse_edges``,
``repair_moves``, ``prepass_seconds`` plus the inner partitioner's own
counters.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import cuttana as _cuttana
from repro.core import fennel as _fennel
from repro.core.cuttana import _phase2_refine
from repro.core.subpartition import SubPartitioner
from repro.graph.csr import CSRGraph

__all__ = [
    "streaming_cluster",
    "build_coarse_graph",
    "partition_cluster",
    "partition_cluster_cuttana",
    "partition_cluster_fennel",
]

_BASES = {"cuttana": None, "fennel": None}  # names validated up front


def streaming_cluster(
    graph,
    ids: np.ndarray,
    volume_cap: float,
    count_cap: int,
    hub_degree: int,
) -> tuple[np.ndarray, int, np.ndarray]:
    """Single-pass bounded-memory clustering in stream order.

    Returns ``(cluster_of, num_clusters, volumes)``. Deterministic: the
    candidate clusters are ranked by shared-edge count with ties to the
    smaller cluster id.
    """
    indptr, indices = graph.indptr, graph.indices
    n = graph.num_vertices
    cluster_of = np.full(n, -1, dtype=np.int64)
    vols: list[float] = []
    sizes: list[int] = []
    open_: list[bool] = []  # hub/isolated clusters are closed to joins
    nxt = 0
    for v in ids.tolist():
        lo, hi = int(indptr[v]), int(indptr[v + 1])
        deg = hi - lo
        if deg == 0 or deg >= hub_degree:
            cluster_of[v] = nxt
            vols.append(float(deg))
            sizes.append(1)
            open_.append(False)
            nxt += 1
            continue
        nc = cluster_of[indices[lo:hi]]
        nc = nc[nc >= 0]
        best = -1
        if nc.size:
            cids, counts = np.unique(nc, return_counts=True)
            # descending shared-edge count; np.unique returns ascending ids,
            # so a stable sort breaks count ties toward the smaller id
            for j in np.argsort(-counts, kind="stable").tolist():
                c = int(cids[j])
                if (
                    open_[c]
                    and vols[c] + deg <= volume_cap
                    and sizes[c] < count_cap
                ):
                    best = c
                    break
        if best < 0:
            best = nxt
            vols.append(0.0)
            sizes.append(0)
            open_.append(True)
            nxt += 1
        cluster_of[v] = best
        vols[best] += deg
        sizes[best] += 1
    return cluster_of, nxt, np.asarray(vols, dtype=np.float64)


def build_coarse_graph(
    graph, cluster_of: np.ndarray, num_clusters: int
) -> CSRGraph:
    """Contract clusters into supervertices, keeping cross-cluster edge
    multiplicity (``dedupe=False``) so coarse neighbour histograms weigh
    original edges."""
    src = np.repeat(
        np.arange(graph.num_vertices, dtype=np.int64),
        np.asarray(graph.degrees, dtype=np.int64),
    )
    cs = cluster_of[src]
    cd = cluster_of[graph.indices]
    keep = cs < cd  # each undirected cross-cluster edge once; intra dropped
    edges = np.stack([cs[keep], cd[keep]], axis=1)
    return CSRGraph.from_edges(edges, num_vertices=num_clusters, dedupe=False)


def _repair_balance(
    graph, part: np.ndarray, k: int, epsilon: float, balance_mode: str
) -> int:
    """Deterministic greedy repair of the fine-grained balance condition
    after projection: shed lowest-degree vertices from over-capacity
    partitions into the neighbour-richest partition with headroom.
    Mutates ``part`` in place; returns the number of moves."""
    degrees = np.asarray(graph.degrees, dtype=np.int64)
    n = graph.num_vertices
    if balance_mode == "vertex":
        mass = np.ones(n, dtype=np.float64)
        cap = (1.0 + epsilon) * n / k
    else:
        mass = degrees.astype(np.float64)
        cap = (1.0 + epsilon) * graph.indices.shape[0] / k
    loads = np.bincount(part, weights=mass, minlength=k)
    moves = 0
    for _ in range(5):  # ping-pong guard; one pass suffices in practice
        over = np.flatnonzero(loads > cap + 1e-9)
        if over.size == 0:
            break
        for p in over.tolist():
            members = np.flatnonzero(part == p)
            for v in members[np.argsort(degrees[members], kind="stable")].tolist():
                if loads[p] <= cap + 1e-9:
                    break
                m_v = mass[v]
                fits = loads + m_v <= cap + 1e-9
                fits[p] = False
                nbrs = graph.neighbors(v)
                hist = np.bincount(part[nbrs], minlength=k)
                if fits.any():
                    q = int(np.where(fits, hist, -1).argmax())
                else:
                    # a vertex too heavy for any headroom: least-loaded wins
                    masked = loads.copy()
                    masked[p] = np.inf
                    q = int(masked.argmin())
                part[v] = q
                loads[p] -= m_v
                loads[q] += m_v
                moves += 1
    return moves


def partition_cluster(
    graph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    base: str = "cuttana",
    hub_degree: int = 1000,
    cluster_cap_frac: float = 0.1,
    use_refinement: bool = True,
    thresh: float = 0.0,
    subparts_per_partition: int | None = None,
    order: str = "natural",
    seed: int = 0,
    chunk: int = 512,
    telemetry: dict | None = None,
) -> np.ndarray:
    """Coarsen-stream-project-refine around any engine base partitioner.

    ``cluster_cap_frac`` bounds each cluster to that fraction of one
    partition's mass (degree volume AND vertex count), so the coarse
    instance always has enough movable units to balance; ``hub_degree``
    keeps high-degree vertices as singletons.
    """
    if base not in _BASES:
        raise ValueError(
            f"unknown cluster base {base!r}; expected one of {tuple(_BASES)}"
        )
    if not (0.0 < cluster_cap_frac <= 1.0):
        raise ValueError(
            f"cluster_cap_frac must be in (0, 1], got {cluster_cap_frac!r}"
        )
    n = graph.num_vertices
    t0 = time.perf_counter()
    from repro.graph.stream import stream_order

    ids = stream_order(graph, order, seed)
    volume_cap = max(cluster_cap_frac * graph.indices.shape[0] / k, 1.0)
    count_cap = max(int(cluster_cap_frac * n / k), 1)
    cluster_of, num_clusters, _ = streaming_cluster(
        graph, ids, volume_cap, count_cap, hub_degree
    )
    coarse = build_coarse_graph(graph, cluster_of, num_clusters)
    prepass_s = time.perf_counter() - t0

    inner_tel: dict = {}
    if base == "cuttana":
        coarse_part = _cuttana.partition(
            coarse, k, epsilon=epsilon, balance_mode=balance_mode,
            use_refinement=True, order=order, seed=seed, chunk=chunk,
            telemetry=inner_tel,
        )
    else:
        coarse_part = _fennel.partition(
            coarse, k, epsilon=epsilon, balance_mode=balance_mode,
            order=order, seed=seed, chunk=chunk, telemetry=inner_tel,
        )

    part = coarse_part[cluster_of].astype(np.int64)
    t1 = time.perf_counter()
    repair_moves = _repair_balance(graph, part, k, epsilon, balance_mode)

    moves, improvement = 0, 0.0
    if use_refinement and k > 1:
        if subparts_per_partition is None:
            subparts_per_partition = int(max(8, min(4096, n // (8 * k))))
        subp = SubPartitioner(
            graph, k, subparts_per_partition, balance_mode=balance_mode,
            seed=seed,
        )
        indptr, indices = graph.indptr, graph.indices
        for v in range(n):
            nbrs = indices[indptr[v] : indptr[v + 1]]
            subp.assign(v, int(part[v]), nbrs, nbrs.size)
        part, _, moves, improvement = _phase2_refine(
            graph, subp, k, epsilon, balance_mode, thresh
        )
    project_s = time.perf_counter() - t1

    if telemetry is not None:
        telemetry.update(inner_tel)
        telemetry.update(
            clusters_found=int(num_clusters),
            coarsening_ratio=float(num_clusters) / max(n, 1),
            coarse_edges=int(coarse.indices.shape[0] // 2),
            repair_moves=int(repair_moves),
            refine_moves=int(moves),
            refine_improvement=float(improvement),
            prepass_seconds=prepass_s,
            project_seconds=project_s,
            cluster_base=base,
        )
    return np.asarray(part, dtype=np.int32)


def partition_cluster_cuttana(graph, k: int, **kwargs) -> np.ndarray:
    """``cluster+cuttana``: coarsening prepass around CUTTANA."""
    return partition_cluster(graph, k, base="cuttana", **kwargs)


def partition_cluster_fennel(graph, k: int, **kwargs) -> np.ndarray:
    """``cluster+fennel``: coarsening prepass around FENNEL."""
    return partition_cluster(graph, k, base="fennel", **kwargs)
