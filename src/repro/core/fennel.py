"""FENNEL streaming vertex partitioner (Tsourakakis et al., WSDM'14).

This is the paper's primary baseline *and* the scoring core CUTTANA builds on
(paper Eq. 7). ``hybrid=True`` + ``balance_mode="edge"`` reproduces the
edge-balanced variant the paper added to FENNEL for its RQ2 study.

Phase-1 runs through :class:`repro.core.engine.StreamEngine` (chunked
kernel-backed scoring, bit-identical to the seed per-vertex loop kept in
:mod:`repro.core.legacy`).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.base import FennelParams, PartitionState, finalize
from repro.core.engine import EngineConfig, FennelScorer, ImmediatePolicy, StreamEngine
from repro.graph.csr import CSRGraph


def partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "vertex",
    params: FennelParams | None = None,
    order: str = "natural",
    seed: int = 0,
    chunk: int = 512,
    use_pallas: bool | None = None,
    interpret: bool = False,
    prefetch: str = "auto",
    telemetry: dict | None = None,
) -> np.ndarray:
    params = params or FennelParams()
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    t0 = time.perf_counter()
    engine = StreamEngine(
        graph,
        state,
        FennelScorer(graph, k, params, balance_mode),
        ImmediatePolicy(),
        order=order,
        seed=seed,
        config=EngineConfig(
            chunk=chunk, use_pallas=use_pallas, interpret=interpret,
            prefetch=prefetch,
        ),
    )
    engine.run()
    if telemetry is not None:
        telemetry.update(engine.telemetry)
        telemetry["stream_seconds"] = time.perf_counter() - t0
    return finalize(state)
