"""FENNEL streaming vertex partitioner (Tsourakakis et al., WSDM'14).

This is the paper's primary baseline *and* the scoring core CUTTANA builds on
(paper Eq. 7). ``hybrid=True`` + ``balance_mode="edge"`` reproduces the
edge-balanced variant the paper added to FENNEL for its RQ2 study.
"""
from __future__ import annotations

import numpy as np

from repro.core.base import FennelParams, PartitionState, finalize, make_fennel_score
from repro.graph.csr import CSRGraph
from repro.graph.stream import stream_order


def partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "vertex",
    params: FennelParams | None = None,
    order: str = "natural",
    seed: int = 0,
) -> np.ndarray:
    params = params or FennelParams()
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    score_fn = make_fennel_score(graph, k, params, balance_mode)
    indptr, indices = graph.indptr, graph.indices
    for v in stream_order(graph, order, seed):
        nbrs = indices[indptr[v] : indptr[v + 1]]
        hist = state.neighbor_histogram(nbrs)
        scores = score_fn(state, hist)
        allowed = ~state.would_overflow(nbrs.size)
        p = state.argmax_tiebreak(scores, allowed)
        state.assign(int(v), p, nbrs.size)
    return finalize(state)
