"""The paper's contribution: CUTTANA and the partitioner zoo.

``get_partitioner(name)`` returns a callable
``fn(graph, k, epsilon=..., balance_mode=..., order=..., seed=...) -> part``.
Every streaming partitioner routes its streaming phase through the unified
:class:`repro.core.engine.StreamEngine`; the seed per-vertex loops survive
under ``*-legacy`` names (from :mod:`repro.core.legacy`) as parity baselines
and benchmark reference points. Edge partitioners (vertex-cut) live in
:mod:`repro.core.hdrf` and return an :class:`EdgePartition` via
``get_edge_partitioner``.
"""
from __future__ import annotations

from repro.core import cuttana, fennel, heistream_like, ldg, legacy
from repro.core.base import FennelParams
from repro.core.cuttana import CuttanaResult, refine_any
from repro.core.cuttana_batched import partition_batched
from repro.core.engine import (
    BufferedPolicy,
    EngineConfig,
    FennelScorer,
    ImmediatePolicy,
    LDGScorer,
    PlacementPolicy,
    Scorer,
    StreamEngine,
)
from repro.core.hdrf import EdgePartition, partition_ginger, partition_hdrf
from repro.core.random_hash import partition_chunked, partition_hash, partition_random

def _restream(graph, k, **kw):
    from repro.core.restream import partition_restream

    kw.setdefault("base", "cuttana")
    return partition_restream(graph, k, **kw)


PARTITIONERS = {
    # engine-backed (canonical)
    "cuttana": cuttana.partition,
    "cuttana-batched": partition_batched,
    "cuttana-restream": _restream,
    "fennel": fennel.partition,
    "ldg": ldg.partition,
    "heistream": heistream_like.partition,
    "random": partition_random,
    "hash": partition_hash,
    "chunked": partition_chunked,
    # seed per-vertex reference loops (parity baselines / benchmarks)
    "cuttana-legacy": legacy.cuttana_partition,
    "cuttana-batched-legacy": legacy.cuttana_batched_partition,
    "fennel-legacy": legacy.fennel_partition,
    "ldg-legacy": legacy.ldg_partition,
    "heistream-legacy": legacy.heistream_partition,
}

EDGE_PARTITIONERS = {
    "hdrf": partition_hdrf,
    "ginger": partition_ginger,
}


def get_partitioner(name: str):
    return PARTITIONERS[name]


def get_edge_partitioner(name: str):
    return EDGE_PARTITIONERS[name]


__all__ = [
    "PARTITIONERS",
    "EDGE_PARTITIONERS",
    "get_partitioner",
    "get_edge_partitioner",
    "FennelParams",
    "CuttanaResult",
    "EdgePartition",
    "refine_any",
    "StreamEngine",
    "EngineConfig",
    "Scorer",
    "FennelScorer",
    "LDGScorer",
    "PlacementPolicy",
    "ImmediatePolicy",
    "BufferedPolicy",
]
