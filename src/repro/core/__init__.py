"""The paper's contribution: CUTTANA and the partitioner zoo.

The canonical entry point is :mod:`repro.api` - build a
:class:`~repro.api.PartitionSpec` and call :func:`repro.api.partition` to get
a uniform :class:`~repro.api.PartitionResult` for any registered algorithm.
The declarative registry (:mod:`repro.api.registry`) is the single source of
truth for the zoo; ``PARTITIONERS`` / ``EDGE_PARTITIONERS`` and
``get_partitioner`` / ``get_edge_partitioner`` below are thin deprecated
shims kept for existing callers and parity tests.

Every streaming partitioner routes its streaming phase through the unified
:class:`repro.core.engine.StreamEngine`; the seed per-vertex loops survive
under ``*-legacy`` names (from :mod:`repro.core.legacy`) as parity baselines
and benchmark reference points. Edge partitioners (vertex-cut) live in
:mod:`repro.core.hdrf` and return an :class:`EdgePartition`.
"""
from __future__ import annotations

from repro.api.registry import REGISTRY, get_info
from repro.core import cuttana, fennel, heistream_like, ldg, legacy
from repro.core.base import FennelParams
from repro.core.cuttana import CuttanaResult, refine_any
from repro.core.cuttana_batched import partition_batched
from repro.core.engine import (
    BufferedPolicy,
    EngineConfig,
    FennelScorer,
    ImmediatePolicy,
    LDGScorer,
    PlacementPolicy,
    Scorer,
    ShardedBufferedPolicy,
    ShardedImmediatePolicy,
    StreamEngine,
)
from repro.core.incremental import (
    IncrementalPartitioner,
    partition_incremental,
    update,
)
from repro.core.parallel import fennel_parallel, partition_parallel
from repro.core.hdrf import EdgePartition, partition_ginger, partition_hdrf
from repro.core.random_hash import partition_chunked, partition_hash, partition_random

# Legacy name -> callable views of the declarative registry (deprecated;
# prefer repro.api). Resolved eagerly so iteration keeps working.
PARTITIONERS = {
    name: info.resolve()
    for name, info in REGISTRY.items()
    if info.kind == "edge-cut"
}

EDGE_PARTITIONERS = {
    name: info.resolve()
    for name, info in REGISTRY.items()
    if info.kind == "vertex-cut"
}


def get_partitioner(name: str):
    """Deprecated shim over :func:`repro.api.get_info`: returns the bare
    callable for an edge-cut (vertex) partitioner. Unknown names raise a
    ``ValueError`` listing registered algorithms and the nearest match."""
    return get_info(name, kind="edge-cut").resolve()


def get_edge_partitioner(name: str):
    """Deprecated shim: bare callable for a vertex-cut (edge) partitioner."""
    return get_info(name, kind="vertex-cut").resolve()


__all__ = [
    "PARTITIONERS",
    "EDGE_PARTITIONERS",
    "get_partitioner",
    "get_edge_partitioner",
    "FennelParams",
    "CuttanaResult",
    "EdgePartition",
    "refine_any",
    "StreamEngine",
    "EngineConfig",
    "Scorer",
    "FennelScorer",
    "LDGScorer",
    "PlacementPolicy",
    "ImmediatePolicy",
    "BufferedPolicy",
    "ShardedImmediatePolicy",
    "ShardedBufferedPolicy",
    "partition_parallel",
    "fennel_parallel",
    "IncrementalPartitioner",
    "partition_incremental",
    "update",
]
