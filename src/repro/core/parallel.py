"""Parallel CUTTANA: shard-parallel buffered streaming (paper §V).

The paper's headline systems claim is "a parallel version for CUTTANA that
offers nearly the same partitioning latency as existing streaming
partitioners". This module wires the sharded bulk-synchronous policies of
:mod:`repro.core.engine` into full partitioners:

* :func:`partition_parallel` (``cuttana-parallel``) - S shard-local priority
  buffers around one shared :class:`~repro.core.base.PartitionState`; every
  superstep scores all shards' candidates in ONE packed
  :func:`~repro.kernels.partition_score.fennel_scores_sharded` kernel call,
  exchanges assignments/loads at the boundary, and the usual merge ->
  coarsen -> refine phase 2 reconciles shard-boundary vertices afterwards.
* :func:`fennel_parallel` (``fennel-parallel``) - the same superstep core
  with immediate placement, i.e. a bulk-synchronous parallel FENNEL.

``num_shards=1`` is *defined* as the sequential engine (both wrappers build
the exact objects :mod:`repro.core.cuttana` / :mod:`repro.core.fennel`
build), so assignments are bit-identical to ``cuttana`` / ``fennel`` and all
sequential parity guarantees carry over; ``tests/test_parallel.py`` pins
this for every stream order. For S >= 2 the relaxed consistency (histograms
one superstep stale across shards) trades a bounded quality delta for the
batched streaming latency - measured by the ``scaling`` benchmark suite.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import autotune
from repro.core.base import FennelParams, PartitionState, finalize
from repro.core.cuttana import _phase2_refine
from repro.core.engine import (
    EngineConfig,
    FennelScorer,
    ShardedBufferedPolicy,
    ShardedImmediatePolicy,
    StreamEngine,
)
from repro.core.subpartition import SubPartitioner
from repro.graph.csr import CSRGraph

__all__ = ["partition_parallel", "fennel_parallel"]


def _resolve_knobs(
    num_shards, chunk, *, algo: str, graph: CSRGraph, telemetry: dict | None
) -> tuple[int, int]:
    """Resolve ``num_shards=0``/"auto" and ``chunk=0`` through the tuning
    artifact (see :mod:`repro.core.autotune`); record the source."""
    tuning = autotune.resolve(
        num_shards, chunk, algo=algo, num_vertices=graph.num_vertices
    )
    if telemetry is not None and tuning.source != "explicit":
        telemetry["autotune"] = {
            "num_shards": tuning.num_shards,
            "chunk": tuning.chunk,
            "source": tuning.source,
        }
    return tuning.num_shards, tuning.chunk


def partition_parallel(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    num_shards: int = 4,
    d_max: int = 1000,
    max_qsize: int | None = None,
    theta: float = 1.0,
    subparts_per_partition: int | None = None,
    use_refinement: bool = True,
    thresh: float = 0.0,
    max_moves: int | None = None,
    fennel_params: FennelParams | None = None,
    order: str = "natural",
    seed: int = 0,
    chunk: int = 512,
    max_workers: int = 0,
    use_pallas: bool | None = None,
    interpret: bool = False,
    prefetch: str = "auto",
    strategy: str = "eq6",
    telemetry: dict | None = None,
) -> np.ndarray:
    """Shard-parallel CUTTANA: Algorithm 1 over ``num_shards`` interleaved
    shard cursors with bulk-synchronous supersteps, then phase-2 refinement.
    ``strategy`` selects the shard buffers' eviction priority
    (:mod:`repro.core.priority`; default Eq. 6, bit-identical to before the
    strategy layer existed).

    ``num_shards=1`` is bit-identical to :func:`repro.core.cuttana.partition`
    under the same knobs; ``num_shards=0`` resolves through the auto-tuner
    (:mod:`repro.core.autotune`), as does ``chunk=0``. ``max_workers``
    threads run the per-shard superstep tasks (0 = auto,
    ``min(num_shards, cpu_count)``); assignments are bit-identical for every
    worker count. ``telemetry`` additionally receives the parallel counters
    (``supersteps``, ``sync_rounds``, ``boundary_conflicts``,
    ``num_shards``, ``max_workers``) and the per-superstep ``profile``.
    """
    num_shards, chunk = _resolve_knobs(
        num_shards, chunk, algo="cuttana-parallel", graph=graph,
        telemetry=telemetry,
    )
    n = graph.num_vertices
    if max_qsize is None:
        max_qsize = max(1024, n // 10)
    if subparts_per_partition is None:
        subparts_per_partition = int(max(8, min(4096, n // (8 * k))))

    params = fennel_params or FennelParams(hybrid=(balance_mode == "edge"))
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    subp = SubPartitioner(
        graph,
        k,
        subparts_per_partition,
        epsilon=max(epsilon, 0.10),
        balance_mode=balance_mode,
        seed=seed,
    )
    t0 = time.perf_counter()
    engine = StreamEngine(
        graph,
        state,
        FennelScorer(graph, k, params, balance_mode),
        ShardedBufferedPolicy(num_shards, max_qsize, d_max, theta, strategy=strategy),
        subpartitioner=subp,
        order=order,
        seed=seed,
        config=EngineConfig(
            chunk=chunk, use_pallas=use_pallas, interpret=interpret,
            max_workers=max_workers, prefetch=prefetch,
        ),
    )
    engine.run()
    phase1_s = time.perf_counter() - t0

    part = finalize(state)
    kp = subp.kp

    t1 = time.perf_counter()
    moves, improvement = 0, 0.0
    if use_refinement and k > 1:
        # merge + coarsen + refine: the trade pass that reconciles the
        # shard-boundary vertices the relaxed supersteps mis-scored
        part, _, moves, improvement = _phase2_refine(
            graph, subp, k, epsilon, balance_mode, thresh, max_moves
        )
    phase2_s = time.perf_counter() - t1

    if telemetry is not None:
        telemetry.update(engine.telemetry)
        telemetry.update(
            phase1_seconds=phase1_s,
            phase2_seconds=phase2_s,
            refine_moves=moves,
            refine_improvement=improvement,
            subpartitions=int(kp),
        )
    return part


def fennel_parallel(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "vertex",
    num_shards: int = 4,
    params: FennelParams | None = None,
    order: str = "natural",
    seed: int = 0,
    chunk: int = 512,
    max_workers: int = 0,
    use_pallas: bool | None = None,
    interpret: bool = False,
    prefetch: str = "auto",
    telemetry: dict | None = None,
) -> np.ndarray:
    """Bulk-synchronous parallel FENNEL over ``num_shards`` shard cursors.

    ``num_shards=1`` is bit-identical to :func:`repro.core.fennel.partition`;
    ``num_shards=0`` / ``chunk=0`` resolve through the auto-tuner, and
    ``max_workers`` (0 = auto) sets the shard-task thread count without
    affecting assignments.
    """
    num_shards, chunk = _resolve_knobs(
        num_shards, chunk, algo="fennel-parallel", graph=graph,
        telemetry=telemetry,
    )
    params = params or FennelParams()
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    t0 = time.perf_counter()
    engine = StreamEngine(
        graph,
        state,
        FennelScorer(graph, k, params, balance_mode),
        ShardedImmediatePolicy(num_shards),
        order=order,
        seed=seed,
        config=EngineConfig(
            chunk=chunk, use_pallas=use_pallas, interpret=interpret,
            max_workers=max_workers, prefetch=prefetch,
        ),
    )
    engine.run()
    if telemetry is not None:
        telemetry.update(engine.telemetry)
        telemetry["stream_seconds"] = time.perf_counter() - t0
    return finalize(state)
