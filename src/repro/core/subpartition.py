"""Sub-partitioning (paper §III-B, Def. 2): assigning each vertex to one of
``S = K'/K`` sub-partitions *inside* its chosen partition, during phase 1.

Global sub-partition id of (partition p, local slot s) is ``p * S + s``.
The same FENNEL-style score (Eq. 7) is used with sub-partition-level
hyper-parameters; sizes are kept near-equal (the refinement algorithm's
Lemma 1 relies on equal-sized sub-partitions).
"""
from __future__ import annotations

import numpy as np

from repro.core.base import UNASSIGNED
from repro.graph.csr import CSRGraph


class SubPartitioner:
    def __init__(
        self,
        graph: CSRGraph,
        k: int,
        subparts_per_partition: int,
        epsilon: float = 0.10,
        balance_mode: str = "edge",
        gamma: float = 1.5,
        seed: int = 0,
    ):
        self.k = k
        self.s = int(subparts_per_partition)
        self.kp = k * self.s  # K'
        self.balance_mode = balance_mode
        self.epsilon = epsilon
        n = max(graph.num_vertices, 1)
        self.sub_of = np.full(graph.num_vertices, UNASSIGNED, dtype=np.int32)
        self.sub_v_counts = np.zeros(self.kp, dtype=np.float64)
        self.sub_e_counts = np.zeros(self.kp, dtype=np.float64)
        # Paper: "Equation 7 ... but with different hyperparameters". At K'
        # granularity the canonical FENNEL alpha dwarfs the affinity term and
        # produces incoherent (load-balance-only) sub-partitions, which makes
        # phase-2 trades useless. We instead use greedy affinity with a weak
        # linear size penalty plus a HARD capacity (sub-partitions must stay
        # near-equal-sized for Lemma 1), which maximises internal edges.
        self.gamma = gamma
        self.mu = n / max(graph.indices.shape[0], 1)
        self.v_cap = (1.0 + epsilon) * n / self.kp
        self.e_cap = (1.0 + epsilon) * graph.indices.shape[0] / self.kp
        self.rng = np.random.default_rng(seed + 7)

    def assign(self, v: int, p: int, nbrs: np.ndarray, deg: int) -> int:
        """Choose a sub-partition for ``v`` inside partition ``p``."""
        lo, hi = p * self.s, (p + 1) * self.s
        sub_assigned = self.sub_of[nbrs]
        sub_assigned = sub_assigned[(sub_assigned >= lo) & (sub_assigned < hi)]
        hist = np.bincount(sub_assigned - lo, minlength=self.s).astype(np.float64)
        if self.balance_mode == "edge":
            size = 0.5 * (
                self.sub_v_counts[lo:hi] + self.mu * self.sub_e_counts[lo:hi]
            )
            cap = 0.5 * (self.v_cap + self.mu * self.e_cap)
            over = self.sub_e_counts[lo:hi] + deg > self.e_cap
        else:
            size = self.sub_v_counts[lo:hi]
            cap = self.v_cap
            over = self.sub_v_counts[lo:hi] + 1 > self.v_cap
        # greedy affinity; weak linear penalty only breaks ties toward the
        # least-loaded sub-partition, the hard cap guarantees near-equal sizes
        scores = hist - 0.125 * (size / max(cap, 1e-9))
        masked = np.where(over, -np.inf, scores)
        best = masked.max()
        if not np.isfinite(best):
            local = int(self.sub_e_counts[lo:hi].argmin())
        else:
            ties = np.flatnonzero(masked >= best - 1e-12)
            local = int(ties[0] if ties.size == 1 else ties[self.rng.integers(ties.size)])
        sp = lo + local
        self.sub_of[v] = sp
        self.sub_v_counts[sp] += 1
        self.sub_e_counts[sp] += deg
        return sp

    def assign_superstep(
        self,
        vs: np.ndarray,  # int64[total] vertices placed this superstep
        ps: np.ndarray,  # int64[total] their committed partitions
        degs: np.ndarray,  # int64[total]
        rows: np.ndarray,  # int64[nnz] flat expansion, sorted ascending
        cols: np.ndarray,  # int64[nnz] neighbour ids
        wave: int = 128,
    ) -> None:
        """Vectorised sub-placement for one committed superstep of the
        parallel engine (the per-vertex :meth:`assign` numpy dispatch was
        the dominant phase-1 cost there).

        ``wave`` vertices are scored at a time: each wave's neighbour ->
        sub-partition histograms are built from the LIVE ``sub_of`` (so
        earlier waves of the same superstep are visible exactly - no
        correction pass needed), sizes are frozen within the wave and a
        bincount projection catches would-be capacity overshoots, which are
        replayed per vertex. Ties break to the lowest sub-slot: like the
        shard placement waves, deterministic without rng, so the parallel
        engine's output is independent of worker count. Runs as a chained
        pool task - it must not read partition state beyond its arguments.
        """
        total = int(vs.shape[0])
        if total == 0:
            return
        s = self.s
        edge_mode = self.balance_mode == "edge"
        cap = (
            0.5 * (self.v_cap + self.mu * self.e_cap) if edge_mode else self.v_cap
        )
        cap = max(cap, 1e-9)
        sub_v, sub_e = self.sub_v_counts, self.sub_e_counts
        V2 = sub_v.reshape(self.k, s)
        E2 = sub_e.reshape(self.k, s)
        degf = degs.astype(np.float64)
        ps = np.asarray(ps, dtype=np.int64)
        for g0 in range(0, total, int(wave)):
            g1 = min(g0 + int(wave), total)
            g = g1 - g0
            a, b = np.searchsorted(rows, (g0, g1))
            r = rows[a:b] - g0
            sub_nb = self.sub_of[cols[a:b]].astype(np.int64)
            p_r = ps[rows[a:b]]
            same = (sub_nb >= p_r * s) & (sub_nb < (p_r + 1) * s)
            hist = (
                np.bincount(
                    r[same] * s + (sub_nb[same] - p_r[same] * s), minlength=g * s
                )
                .astype(np.float64)
                .reshape(g, s)
            )
            pw = ps[g0:g1]
            dw = degf[g0:g1]
            bv = V2[pw]
            be = E2[pw]
            if edge_mode:
                size = 0.5 * (bv + self.mu * be)
                over = be + dw[:, None] > self.e_cap
            else:
                size = bv
                over = bv + 1.0 > self.v_cap
            masked = np.where(over, -np.inf, hist - 0.125 * (size / cap))
            local = masked.argmax(axis=1).astype(np.int64)
            best = masked[np.arange(g), local]
            fb = ~(best > -np.inf)
            if fb.any():
                local[fb] = be[fb].argmin(axis=1)
            sp = pw * s + local
            addv = np.bincount(sp, minlength=self.kp).astype(np.float64)
            adde = np.bincount(sp, weights=dw, minlength=self.kp)
            over_p = (
                sub_e + adde > self.e_cap if edge_mode else sub_v + addv > self.v_cap
            )
            nf = np.flatnonzero(~fb)
            if nf.size and over_p[sp[nf]].any():
                # rare: the wave would overshoot a sub-partition's hard cap -
                # replay per vertex against live counts (frozen affinities)
                for i in range(g):
                    p = int(pw[i])
                    lo = p * s
                    ve = sub_v[lo : lo + s]
                    ee = sub_e[lo : lo + s]
                    if edge_mode:
                        size_i = 0.5 * (ve + self.mu * ee)
                        over_i = ee + dw[i] > self.e_cap
                    else:
                        size_i = ve
                        over_i = ve + 1.0 > self.v_cap
                    m = np.where(over_i, -np.inf, hist[i] - 0.125 * (size_i / cap))
                    b_ = m.max()
                    li = int(m.argmax()) if b_ > -np.inf else int(ee.argmin())
                    spi = lo + li
                    sp[i] = spi
                    sub_v[spi] += 1.0
                    sub_e[spi] += dw[i]
            else:
                sub_v += addv
                sub_e += adde
            self.sub_of[vs[g0:g1]] = sp
