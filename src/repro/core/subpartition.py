"""Sub-partitioning (paper §III-B, Def. 2): assigning each vertex to one of
``S = K'/K`` sub-partitions *inside* its chosen partition, during phase 1.

Global sub-partition id of (partition p, local slot s) is ``p * S + s``.
The same FENNEL-style score (Eq. 7) is used with sub-partition-level
hyper-parameters; sizes are kept near-equal (the refinement algorithm's
Lemma 1 relies on equal-sized sub-partitions).
"""
from __future__ import annotations

import numpy as np

from repro.core.base import UNASSIGNED
from repro.graph.csr import CSRGraph


class SubPartitioner:
    def __init__(
        self,
        graph: CSRGraph,
        k: int,
        subparts_per_partition: int,
        epsilon: float = 0.10,
        balance_mode: str = "edge",
        gamma: float = 1.5,
        seed: int = 0,
    ):
        self.k = k
        self.s = int(subparts_per_partition)
        self.kp = k * self.s  # K'
        self.balance_mode = balance_mode
        self.epsilon = epsilon
        n = max(graph.num_vertices, 1)
        self.sub_of = np.full(graph.num_vertices, UNASSIGNED, dtype=np.int32)
        self.sub_v_counts = np.zeros(self.kp, dtype=np.float64)
        self.sub_e_counts = np.zeros(self.kp, dtype=np.float64)
        # Paper: "Equation 7 ... but with different hyperparameters". At K'
        # granularity the canonical FENNEL alpha dwarfs the affinity term and
        # produces incoherent (load-balance-only) sub-partitions, which makes
        # phase-2 trades useless. We instead use greedy affinity with a weak
        # linear size penalty plus a HARD capacity (sub-partitions must stay
        # near-equal-sized for Lemma 1), which maximises internal edges.
        self.gamma = gamma
        self.mu = n / max(graph.indices.shape[0], 1)
        self.v_cap = (1.0 + epsilon) * n / self.kp
        self.e_cap = (1.0 + epsilon) * graph.indices.shape[0] / self.kp
        self.rng = np.random.default_rng(seed + 7)

    def assign(self, v: int, p: int, nbrs: np.ndarray, deg: int) -> int:
        """Choose a sub-partition for ``v`` inside partition ``p``."""
        lo, hi = p * self.s, (p + 1) * self.s
        sub_assigned = self.sub_of[nbrs]
        sub_assigned = sub_assigned[(sub_assigned >= lo) & (sub_assigned < hi)]
        hist = np.bincount(sub_assigned - lo, minlength=self.s).astype(np.float64)
        if self.balance_mode == "edge":
            size = 0.5 * (
                self.sub_v_counts[lo:hi] + self.mu * self.sub_e_counts[lo:hi]
            )
            cap = 0.5 * (self.v_cap + self.mu * self.e_cap)
            over = self.sub_e_counts[lo:hi] + deg > self.e_cap
        else:
            size = self.sub_v_counts[lo:hi]
            cap = self.v_cap
            over = self.sub_v_counts[lo:hi] + 1 > self.v_cap
        # greedy affinity; weak linear penalty only breaks ties toward the
        # least-loaded sub-partition, the hard cap guarantees near-equal sizes
        scores = hist - 0.125 * (size / max(cap, 1e-9))
        masked = np.where(over, -np.inf, scores)
        best = masked.max()
        if not np.isfinite(best):
            local = int(self.sub_e_counts[lo:hi].argmin())
        else:
            ties = np.flatnonzero(masked >= best - 1e-12)
            local = int(ties[0] if ties.size == 1 else ties[self.rng.integers(ties.size)])
        sp = lo + local
        self.sub_of[v] = sp
        self.sub_v_counts[sp] += 1
        self.sub_e_counts[sp] += deg
        return sp
