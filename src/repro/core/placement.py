"""CUTTANA-based MoE expert placement (beyond-paper integration).

Expert-parallel MoE pays one all-to-all per layer: every token travels to the
devices owning its top-k experts. When co-routed experts (experts that often
fire for the SAME token) live on the same device, a token's k probes collapse
into fewer distinct destinations, shrinking hierarchical A2A payload and
DCN hops in multi-pod meshes.

Expert co-activation is a weighted graph: vertices = experts, edge weight
W[e1,e2] = #tokens routing to both. Placing experts on D devices minimizing
cross-device co-activation under a per-device capacity IS balanced graph
partitioning - so we feed it to CUTTANA's refinement engine (the coarse
graph is small: E vertices), exactly the paper's "refinement improves any
partitioner" claim applied to a new domain.

``evaluate_placement`` scores a placement by expected distinct-device fanout
per token (the hierarchical-A2A message count).
"""
from __future__ import annotations

import numpy as np

from repro.core.refinement import Refiner


def coactivation_graph(routing_trace: np.ndarray, n_experts: int) -> np.ndarray:
    """routing_trace: int[T, k] expert ids per token. Returns W[E, E]."""
    w = np.zeros((n_experts, n_experts), dtype=np.float64)
    k = routing_trace.shape[1]
    for a in range(k):
        for b in range(a + 1, k):
            np.add.at(w, (routing_trace[:, a], routing_trace[:, b]), 1.0)
    w = w + w.T
    np.fill_diagonal(w, 0.0)
    return w


def place_experts(
    routing_trace: np.ndarray,
    n_experts: int,
    n_devices: int,
    epsilon: float = 0.0,
    seed: int = 0,
) -> np.ndarray:
    """Returns device_of[E]. Capacity is exact (E/D experts per device) when
    epsilon=0 - expert-parallel kernels need equal expert counts."""
    assert n_experts % n_devices == 0
    w = coactivation_graph(routing_trace, n_experts)
    # load = tokens per expert (balance the routing load too)
    load = np.bincount(routing_trace.reshape(-1), minlength=n_experts).astype(
        np.float64
    )
    per_dev = n_experts // n_devices
    init = np.repeat(np.arange(n_devices), per_dev)  # contiguous baseline
    # epsilon=0 would freeze the refiner (no slack to move into); use expert
    # COUNT as the balance mass with one-expert slack, then repair to exact.
    size = np.ones(n_experts)
    r = Refiner(w, init, size, n_devices, epsilon=max(epsilon, 1.0 / per_dev))
    r.refine()
    placement = r.sub_part.copy()
    # repair: enforce exactly per_dev experts per device (move smallest-loss)
    counts = np.bincount(placement, minlength=n_devices)
    while counts.max() > per_dev:
        src = int(counts.argmax())
        dst = int(counts.argmin())
        members = np.flatnonzero(placement == src)
        # move the member with least affinity to src
        internal = w[members][:, members].sum(axis=1)
        victim = members[int(internal.argmin())]
        placement[victim] = dst
        counts[src] -= 1
        counts[dst] += 1
    return placement.astype(np.int32)


def evaluate_placement(
    routing_trace: np.ndarray, placement: np.ndarray
) -> dict:
    """Expected distinct destination devices per token (A2A fanout) and
    device load balance."""
    dev = placement[routing_trace]  # [T, k]
    fanout = np.array([len(np.unique(row)) for row in dev])
    load = np.bincount(dev.reshape(-1), minlength=placement.max() + 1)
    return {
        "mean_fanout": float(fanout.mean()),
        "max_fanout": float(fanout.max()),
        "device_load_imbalance": float(load.max() / max(load.mean(), 1e-12)),
    }


def synthetic_routing_trace(
    n_tokens: int,
    n_experts: int,
    top_k: int,
    n_clusters: int | None = None,
    skew: float = 0.7,
    seed: int = 0,
) -> np.ndarray:
    """Synthetic-but-realistic trace: experts form co-activation clusters
    (domain/language specialisation observed in MoE routing studies); a
    token draws its cluster, then top-k experts mostly within it."""
    rng = np.random.default_rng(seed)
    if n_clusters is None:
        n_clusters = max(2, n_experts // 8)
    cluster_of = rng.permutation(np.arange(n_experts) % n_clusters)
    members = [np.flatnonzero(cluster_of == c) for c in range(n_clusters)]
    trace = np.zeros((n_tokens, top_k), dtype=np.int64)
    tok_cluster = rng.integers(0, n_clusters, n_tokens)
    for t in range(n_tokens):
        m = members[tok_cluster[t]]
        picks = []
        for _ in range(top_k):
            if rng.random() < skew and m.size:
                picks.append(int(m[rng.integers(m.size)]))
            else:
                picks.append(int(rng.integers(n_experts)))
        trace[t] = picks
    return trace
