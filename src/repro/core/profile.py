"""Lightweight per-superstep profiling for the sharded engine.

The superstep core splits each round into phases and feeds their durations
here; the aggregate lands in ``telemetry["profile"]`` (and from there in
:class:`repro.api.result.PartitionResult`). Phases:

* ``prep``    - frontier expansion (CSR slicing + in-shard correction
  pairs). Prefetched one superstep ahead, so with >= 2 workers this mostly
  measures *wait* on an already-running task - small prep_s is the overlap
  working, not the expansion being free.
* ``score``   - assigned-neighbour histogramming (host bincount inside the
  shard tasks, or the packed Pallas call on the main thread).
* ``place``   - wave-vectorised placement inside the shard tasks.
* ``exchange`` - the boundary exchange: committing assignments/loads to the
  shared state and counting cross-shard conflicts.
* ``merge``   - post-boundary merges: the chained sub-partition pass and the
  buffered policy's buffer notifications.

``score_s``/``place_s`` are summed across shard tasks, so with W workers
they may exceed wall time; ``parallel_wall_s`` is the actual start-to-join
wall of the concurrent section, and ``queue_wait_s`` the summed lag between
task submission and task start (pool saturation indicator).

Cost: a few float adds per superstep - safe to leave on unconditionally.
"""
from __future__ import annotations

PHASES = ("prep", "score", "place", "exchange", "merge")


class SuperstepProfiler:
    def __init__(self, workers: int, keep: int = 64):
        self.workers = int(workers)
        self.totals = {p: 0.0 for p in PHASES}
        self.parallel_wall_s = 0.0
        self.queue_wait_s = 0.0
        self.supersteps = 0
        self._keep = int(keep)
        self._rows: list[dict] = []

    def record(self, *, parallel_wall: float = 0.0, **phase_seconds) -> None:
        """Account one superstep. ``phase_seconds`` keys must be in
        :data:`PHASES`; omitted phases count as zero."""
        self.supersteps += 1
        for phase, dt in phase_seconds.items():
            self.totals[phase] += dt
        self.parallel_wall_s += parallel_wall
        if len(self._rows) < self._keep:
            row = {p: round(phase_seconds.get(p, 0.0), 6) for p in PHASES}
            row["parallel_wall"] = round(parallel_wall, 6)
            self._rows.append(row)

    def add(self, phase: str, seconds: float) -> None:
        """Accumulate time into a phase outside the per-superstep record
        (prefetch waits, ingest scans, chain flushes)."""
        self.totals[phase] += seconds

    def add_queue_wait(self, seconds: float) -> None:
        self.queue_wait_s += seconds

    def to_dict(self) -> dict:
        out = {
            "workers": self.workers,
            "supersteps": self.supersteps,
            "parallel_wall_s": round(self.parallel_wall_s, 6),
            "queue_wait_s": round(self.queue_wait_s, 6),
        }
        for p in PHASES:
            out[f"{p}_s"] = round(self.totals[p], 6)
        # first _keep supersteps verbatim: enough to see warmup + steady state
        # without unbounded growth on million-superstep runs
        out["per_superstep"] = list(self._rows)
        return out
