"""Auto-tuned sharding: resolve ``num_shards=0`` ("auto") and ``chunk=0``
from the scaling suite's latency-vs-boundary-conflicts curve.

The scaling benchmark (``benchmarks/scaling.py``) records, per algorithm and
shard count, the phase-1 stream latency and the boundary-conflict count.
More shards buy concurrency but raise cross-shard staleness (conflicts), so
the useful operating point is the *knee*: the smallest configuration whose
latency is within a slack of the best. ``benchmarks.scaling`` serialises
that curve plus the chosen knee per algorithm into ``TUNING_partition.json``;
at run time :func:`resolve` consumes the artifact when a caller asks for
``num_shards=0`` / ``"auto"`` (checked in ``$REPRO_TUNING_PATH``, the
working directory, then the repo root). Without an artifact a conservative
CPU-count heuristic applies, so auto mode never fails - it only gets better
when the suite has run.
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path

DEFAULT_FILENAME = "TUNING_partition.json"
ENV_PATH = "REPRO_TUNING_PATH"
_LATENCY_SLACK = 0.10

__all__ = [
    "Tuning",
    "choose_num_shards",
    "choose_chunk",
    "build_artifact",
    "load_artifact",
    "resolve",
]


@dataclasses.dataclass(frozen=True)
class Tuning:
    """Resolved parallel knobs plus where they came from (``explicit``,
    ``artifact:<path>`` or ``heuristic``) - recorded in telemetry so a run
    is attributable to its tuning source."""

    num_shards: int
    chunk: int
    source: str


def choose_num_shards(rows: list[dict], latency_slack: float = _LATENCY_SLACK) -> int | None:
    """Knee of the latency-vs-conflicts curve: among shard counts whose
    stream latency is within ``latency_slack`` of the fastest, pick the one
    with the fewest boundary conflicts (ties toward fewer shards)."""
    cand = [
        r
        for r in rows
        if isinstance(r.get("stream_seconds"), (int, float))
        and int(r.get("num_shards", 0)) >= 1
    ]
    if not cand:
        return None
    best = min(r["stream_seconds"] for r in cand)
    ok = [r for r in cand if r["stream_seconds"] <= best * (1.0 + latency_slack)]
    ok.sort(key=lambda r: (int(r.get("boundary_conflicts", 0)), int(r["num_shards"])))
    return int(ok[0]["num_shards"])


def choose_chunk(rows: list[dict]) -> int | None:
    """Fastest chunk size from a chunk-sweep (rows carrying a ``chunk``
    field); ties toward the smaller chunk (lower staleness)."""
    cand = [
        r
        for r in rows
        if isinstance(r.get("stream_seconds"), (int, float)) and int(r.get("chunk", 0)) >= 1
    ]
    if not cand:
        return None
    cand.sort(key=lambda r: (r["stream_seconds"], int(r["chunk"])))
    return int(cand[0]["chunk"])


def build_artifact(rows_by_algo: dict[str, list[dict]], chunk_rows: list[dict] | None = None) -> dict:
    """Serialisable tuning artifact from scaling-suite rows grouped by
    algorithm. ``chosen`` holds the per-algorithm knee plus a ``default``
    (worst-case knee across algorithms, so an unknown algorithm still gets a
    sane shard count)."""
    chosen: dict[str, dict] = {}
    curves: dict[str, list[dict]] = {}
    chunk = choose_chunk(chunk_rows or [])
    for algo, rows in sorted(rows_by_algo.items()):
        s = choose_num_shards(rows)
        if s is None:
            continue
        entry = {"num_shards": s}
        if chunk is not None:
            entry["chunk"] = chunk
        chosen[algo] = entry
        curves[algo] = [
            {
                "num_shards": int(r["num_shards"]),
                "stream_seconds": float(r["stream_seconds"]),
                "boundary_conflicts": int(r.get("boundary_conflicts", 0)),
            }
            for r in rows
            if isinstance(r.get("stream_seconds"), (int, float))
            and int(r.get("num_shards", 0)) >= 1
        ]
    if chosen:
        # default = the *smallest* knee across algorithms: under-sharding
        # costs latency, over-sharding costs quality (conflicts)
        entry = {"num_shards": int(min(e["num_shards"] for e in chosen.values()))}
        if chunk is not None:
            entry["chunk"] = chunk
        chosen["default"] = entry
    return {"version": 1, "latency_slack": _LATENCY_SLACK, "chosen": chosen, "curves": curves}


def _candidate_paths(path: str | os.PathLike | None) -> list[Path]:
    if path is not None:
        return [Path(path)]
    out = []
    env = os.environ.get(ENV_PATH)
    if env:
        out.append(Path(env))
    out.append(Path.cwd() / DEFAULT_FILENAME)
    # src/repro/core/autotune.py -> repo root is parents[3]
    out.append(Path(__file__).resolve().parents[3] / DEFAULT_FILENAME)
    return out


def load_artifact(path: str | os.PathLike | None = None) -> tuple[dict, Path] | None:
    """First readable tuning artifact along the search path, or None."""
    for p in _candidate_paths(path):
        try:
            with open(p) as fh:
                art = json.load(fh)
        except (OSError, ValueError):
            continue
        if isinstance(art, dict) and isinstance(art.get("chosen"), dict):
            return art, p
    return None


def _heuristic_num_shards(num_vertices: int | None) -> int:
    s = max(2, min(8, os.cpu_count() or 1))
    if num_vertices is not None:
        # a shard should see at least a few chunks' worth of stream, else
        # superstep overhead dominates; tiny graphs fall back to sequential
        s = max(1, min(s, int(num_vertices) // 2048))
    return s


def resolve(
    num_shards: int,
    chunk: int,
    *,
    algo: str,
    num_vertices: int | None = None,
    path: str | os.PathLike | None = None,
) -> Tuning:
    """Resolve possibly-auto (``0``) parallel knobs to concrete values.

    Explicit values pass through untouched (source ``explicit``). Auto
    values come from the tuning artifact's ``chosen[algo]`` (falling back to
    ``chosen["default"]``), else from the CPU-count heuristic.
    """
    num_shards = int(num_shards)
    chunk = int(chunk)
    if num_shards < 0:
        raise ValueError(f"num_shards must be >= 1, or 0/'auto', got {num_shards!r}")
    if chunk < 0:
        raise ValueError(f"chunk must be >= 1, or 0 for auto, got {chunk!r}")
    if num_shards >= 1 and chunk >= 1:
        return Tuning(num_shards, chunk, "explicit")
    loaded = load_artifact(path)
    entry = None
    source = "heuristic"
    if loaded is not None:
        art, p = loaded
        entry = art["chosen"].get(algo) or art["chosen"].get("default")
        if entry is not None:
            source = f"artifact:{p}"
    if num_shards == 0:
        if entry is not None:
            num_shards = int(entry["num_shards"])
        else:
            num_shards = _heuristic_num_shards(num_vertices)
    if chunk == 0:
        if entry is not None and int(entry.get("chunk", 0)) >= 1:
            chunk = int(entry["chunk"])
        else:
            chunk = 512
    return Tuning(num_shards, chunk, source)
