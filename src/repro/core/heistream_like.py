"""HeiStream-like buffered *batch* streaming partitioner (Faraj & Schulz).

The published HEISTREAM buffers a batch of vertices, builds the induced model
graph (batch vertices + one contracted node per partition), runs a multilevel
partition on it, and commits. We reproduce the behaviourally important parts:
batch-induced subgraph + greedy initial placement + FM-style local refinement
inside the batch against partition anchor nodes. Like the original, quality is
strongly order-sensitive (great when batches are neighbourhood-coherent, e.g.
road networks - exactly the paper's US-Roads observation).

The greedy placement phase is a :class:`repro.core.engine.StreamEngine` chunk
(one kernel call per batch); FM refinement runs as the engine's
``on_chunk_end`` hook. Bit-identical to the seed loop in
:mod:`repro.core.legacy`.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.base import FennelParams, PartitionState, finalize
from repro.core.engine import EngineConfig, FennelScorer, ImmediatePolicy, StreamEngine
from repro.graph.csr import CSRGraph


def partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "vertex",
    batch_size: int = 4096,
    fm_passes: int = 3,
    order: str = "natural",
    seed: int = 0,
    use_pallas: bool | None = None,
    interpret: bool = False,
    telemetry: dict | None = None,
) -> np.ndarray:
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    indptr, indices = graph.indptr, graph.indices
    rng = np.random.default_rng(seed)
    fm_moves = 0

    def fm_refine(eng: StreamEngine, batch: np.ndarray, nbr_views: list) -> None:
        # ---- FM-style refinement inside the batch
        nonlocal fm_moves
        for _ in range(fm_passes):
            moved = 0
            for v in rng.permutation(batch):
                v = int(v)
                nbrs = indices[indptr[v] : indptr[v + 1]]
                deg = nbrs.size
                cur = int(state.part_of[v])
                hist = state.neighbor_histogram(nbrs)
                gains = hist - hist[cur]  # edge-cut gain of moving v -> p
                if balance_mode == "vertex":
                    over = state.v_counts + 1 > state.vertex_capacity
                else:
                    over = state.e_counts + deg > state.edge_capacity
                over[cur] = False
                gains = np.where(over, -np.inf, gains)
                best = int(gains.argmax())
                if best != cur and gains[best] > 0:
                    state.part_of[v] = best
                    state.v_counts[cur] -= 1
                    state.v_counts[best] += 1
                    state.e_counts[cur] -= deg
                    state.e_counts[best] += deg
                    moved += 1
            fm_moves += moved
            if moved == 0:
                break
        # FM moved mass behind the scorer's back - refresh its penalty cache
        eng.scorer.begin(state)

    t0 = time.perf_counter()
    engine = StreamEngine(
        graph,
        state,
        FennelScorer(
            graph, k, FennelParams(hybrid=(balance_mode == "edge")), balance_mode
        ),
        ImmediatePolicy(),
        order=order,
        seed=seed,
        config=EngineConfig(
            chunk=batch_size, use_pallas=use_pallas, interpret=interpret
        ),
        on_chunk_end=fm_refine,
    )
    engine.run()
    if telemetry is not None:
        telemetry.update(engine.telemetry)
        telemetry.update(
            stream_seconds=time.perf_counter() - t0, fm_moves=fm_moves
        )
    return finalize(state)
