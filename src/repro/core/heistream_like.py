"""HeiStream-like buffered *batch* streaming partitioner (Faraj & Schulz).

The published HEISTREAM buffers a batch of vertices, builds the induced model
graph (batch vertices + one contracted node per partition), runs a multilevel
partition on it, and commits. We reproduce the behaviourally important parts:
batch-induced subgraph + greedy initial placement + FM-style local refinement
inside the batch against partition anchor nodes. Like the original, quality is
strongly order-sensitive (great when batches are neighbourhood-coherent, e.g.
road networks - exactly the paper's US-Roads observation).
"""
from __future__ import annotations

import numpy as np

from repro.core.base import FennelParams, PartitionState, finalize, make_fennel_score
from repro.graph.csr import CSRGraph
from repro.graph.stream import stream_order


def partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "vertex",
    batch_size: int = 4096,
    fm_passes: int = 3,
    order: str = "natural",
    seed: int = 0,
) -> np.ndarray:
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    score_fn = make_fennel_score(
        graph, k, FennelParams(hybrid=(balance_mode == "edge")), balance_mode
    )
    indptr, indices = graph.indptr, graph.indices
    rng = np.random.default_rng(seed)
    ids = stream_order(graph, order, seed)

    for start in range(0, len(ids), batch_size):
        batch = [int(v) for v in ids[start : start + batch_size]]
        nbrs_of = {v: indices[indptr[v] : indptr[v + 1]] for v in batch}
        # ---- initial greedy placement (assigns into global state)
        for v in batch:
            nbrs = nbrs_of[v]
            hist = state.neighbor_histogram(nbrs)  # includes batch-local
            scores = score_fn(state, hist)
            allowed = ~state.would_overflow(nbrs.size)
            p = state.argmax_tiebreak(scores, allowed)
            state.assign(v, p, nbrs.size)
        # ---- FM-style refinement inside the batch
        for _ in range(fm_passes):
            moved = 0
            for v in rng.permutation(batch):
                v = int(v)
                nbrs = nbrs_of[v]
                deg = nbrs.size
                cur = int(state.part_of[v])
                hist = state.neighbor_histogram(nbrs)
                gains = hist - hist[cur]  # edge-cut gain of moving v -> p
                if balance_mode == "vertex":
                    over = state.v_counts + 1 > state.vertex_capacity
                else:
                    over = state.e_counts + deg > state.edge_capacity
                over[cur] = False
                gains = np.where(over, -np.inf, gains)
                best = int(gains.argmax())
                if best != cur and gains[best] > 0:
                    state.part_of[v] = best
                    state.v_counts[cur] -= 1
                    state.v_counts[best] += 1
                    state.e_counts[cur] -= deg
                    state.e_counts[best] += deg
                    moved += 1
            if moved == 0:
                break
    return finalize(state)
