"""Streaming *edge* partitioners (vertex-cut): HDRF and a Ginger-like variant.

The paper compares against these in the analytics study (Table IV) because
edge partitioners give better edge balance at the cost of vertex replication.

HDRF (Petroni et al., CIKM'15): for edge (u,v) prefer partitions that already
replicate the endpoints, biased towards replicating the *higher*-degree
endpoint, plus a load-balance term.

GINGER here is the PowerLyra-inspired hybrid-cut heuristic: same replication
greedy but the degree bias follows the hybrid-cut rule (co-locate edges with
their low-degree endpoint) and the balance term is FENNEL-shaped. This is a
faithful-in-spirit simplification (documented in DESIGN.md).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class EdgePartition:
    edge_part: np.ndarray  # int32[|E|] over graph.edges_array() order
    replicas: np.ndarray  # bool[|V|, k]
    masters: np.ndarray  # int32[|V|] - partition owning the vertex master
    edge_counts: np.ndarray  # int64[k]

    @property
    def replication_factor(self) -> float:
        reps = self.replicas.sum(axis=1)
        return float(reps[reps > 0].mean()) if (reps > 0).any() else 0.0

    def edge_imbalance(self) -> float:
        return float(self.edge_counts.max() / max(self.edge_counts.mean(), 1e-12))


def _partition_edges(
    graph: CSRGraph,
    k: int,
    seed: int,
    mode: str,
    lam: float = 4.0,
    epsilon: float = 0.05,
) -> EdgePartition:
    edges = graph.edges_array()
    m = edges.shape[0]
    # hard edge capacity (PowerGraph-style ingress behaviour): the score's
    # balance term alone cannot beat the replication term on power-law
    # graphs, so production edge partitioners cap partitions outright.
    cap = (1.0 + epsilon) * m / k
    rng = np.random.default_rng(seed)
    order = rng.permutation(m) if mode == "_shuffled" else np.arange(m)
    replicas = np.zeros((graph.num_vertices, k), dtype=bool)
    sizes = np.zeros(k, dtype=np.float64)
    pdeg = np.zeros(graph.num_vertices, dtype=np.int64)  # partial degrees
    edge_part = np.zeros(m, dtype=np.int32)
    # per-vertex per-partition edge counts for master election
    vp_edges = np.zeros((graph.num_vertices, k), dtype=np.int32)
    eps = 1e-3
    # ginger's FENNEL-shaped balance term is stream-invariant - hoist it
    alpha = np.sqrt(k) * m / (max(graph.num_vertices, 1) ** 1.5)
    bal_div = max(m / k, 1)
    for idx in order:
        u, v = int(edges[idx, 0]), int(edges[idx, 1])
        pdeg[u] += 1
        pdeg[v] += 1
        du, dv = pdeg[u], pdeg[v]
        theta_u = du / (du + dv)
        theta_v = 1.0 - theta_u
        if mode == "hdrf":
            gu = np.where(replicas[u], 1.0 + (1.0 - theta_u), 0.0)
            gv = np.where(replicas[v], 1.0 + (1.0 - theta_v), 0.0)
            c_rep = gu + gv
            mx, mn = sizes.max(), sizes.min()
            c_bal = lam * (mx - sizes) / (eps + mx - mn)
            scores = c_rep + c_bal
        else:  # ginger-like hybrid cut
            # favour the partition(s) holding the LOW-degree endpoint
            low_u = du <= dv
            gu = np.where(replicas[u], 2.0 if low_u else 1.0, 0.0)
            gv = np.where(replicas[v], 2.0 if not low_u else 1.0, 0.0)
            scores = gu + gv - alpha * np.sqrt(np.maximum(sizes, 0.0)) / bal_div
        scores = np.where(sizes + 1 > cap, -np.inf, scores)
        p = int(scores.argmax())
        if not np.isfinite(scores[p]):
            # every partition at the hard cap (possible when cap < 1 for tiny
            # graphs): argmax would silently pick partition 0 and break the
            # balance it exists to enforce - fall back to least loaded
            p = int(sizes.argmin())
        edge_part[idx] = p
        replicas[u, p] = True
        replicas[v, p] = True
        sizes[p] += 1
        vp_edges[u, p] += 1
        vp_edges[v, p] += 1
    masters = vp_edges.argmax(axis=1).astype(np.int32)
    # isolated vertices: spread round-robin
    iso = np.flatnonzero(graph.degrees == 0)
    masters[iso] = (iso % k).astype(np.int32)
    return EdgePartition(
        edge_part=edge_part,
        replicas=replicas,
        masters=masters,
        edge_counts=sizes.astype(np.int64),
    )


def partition_hdrf(graph: CSRGraph, k: int, lam: float = 4.0, seed: int = 0, **_) -> EdgePartition:
    return _partition_edges(graph, k, seed, "hdrf", lam)


def partition_ginger(graph: CSRGraph, k: int, seed: int = 0, **_) -> EdgePartition:
    return _partition_edges(graph, k, seed, "ginger")
