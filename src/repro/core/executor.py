"""Thread-pool execution layer for the sharded superstep engine.

The superstep core hands each shard a task that touches only (a) immutable
snapshot arrays and (b) that shard's disjoint slice of the superstep output
buffer, so tasks commute: the merged result is independent of scheduling
order and of the worker count. :class:`ShardPool` wraps a
``ThreadPoolExecutor`` with

* deterministic degradation - one worker (or one CPU) executes submissions
  inline on the calling thread, no pool, no queue;
* queue-wait accounting - time between ``submit`` and task start feeds the
  profiler's ``queue_wait_s``;
* ``submit_after`` - FIFO-chained tasks (used for the overlapped
  sub-partition merge: superstep t's merge may run while t+1 scores, but
  merges must apply in superstep order).

``JITTER`` is a test hook: when set to a ``random.Random``, every pooled
task sleeps a few random milliseconds before running. The determinism tests
use it to prove bit-parity is structural (disjoint writes), not an accident
of benign scheduling.
"""
from __future__ import annotations

import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

JITTER = None  # test hook: random.Random -> pooled tasks sleep 0..3 ms


def resolve_workers(requested: int | None, num_shards: int) -> int:
    """Worker count for S shard tasks: ``0``/``None`` means auto
    (``min(S, cpu_count)``); explicit requests are clamped to ``[1, S]``
    since a superstep never has more than S concurrent tasks."""
    s = max(int(num_shards), 1)
    if requested is None or int(requested) == 0:
        return max(1, min(s, os.cpu_count() or 1))
    r = int(requested)
    if r < 0:
        raise ValueError(f"max_workers must be >= 0 (0 = auto), got {requested!r}")
    return min(r, s)


class _InlineFuture:
    """Future-shaped wrapper around an already-computed result."""

    __slots__ = ("_value", "_exc")

    def __init__(self, value=None, exc=None):
        self._value = value
        self._exc = exc

    def result(self):
        if self._exc is not None:
            raise self._exc
        return self._value

    def done(self) -> bool:
        return True


class ShardPool:
    """``min(max_workers, S)`` threads for per-shard superstep tasks.

    With one worker every ``submit`` runs inline on the calling thread and
    returns an :class:`_InlineFuture`; the pooled and inline paths execute
    the same task functions on the same inputs, so results are identical by
    construction.
    """

    def __init__(self, requested: int | None, num_shards: int):
        self.workers = resolve_workers(requested, num_shards)
        self.queue_wait_s = 0.0
        self._lock = threading.Lock()
        self._ex: ThreadPoolExecutor | None = (
            ThreadPoolExecutor(self.workers, thread_name_prefix="shard")
            if self.workers > 1
            else None
        )

    def submit(self, fn, *args) -> Future | _InlineFuture:
        if self._ex is None:
            try:
                return _InlineFuture(value=fn(*args))
            except BaseException as exc:  # re-raised at .result()
                return _InlineFuture(exc=exc)
        submitted = time.perf_counter()

        def task():
            wait = time.perf_counter() - submitted
            with self._lock:
                self.queue_wait_s += wait
            if JITTER is not None:
                time.sleep(JITTER.random() * 0.003)
            return fn(*args)

        return self._ex.submit(task)

    def submit_after(self, prev: Future | _InlineFuture | None, fn, *args):
        """Submit a task that runs after ``prev`` completes. The executor
        queue is FIFO, so ``prev`` (submitted earlier) always starts first
        and at worst holds its own worker - never a deadlock."""
        if prev is None:
            return self.submit(fn, *args)

        def chained():
            prev.result()
            return fn(*args)

        return self.submit(chained)

    def shutdown(self) -> None:
        if self._ex is not None:
            self._ex.shutdown(wait=True)
            self._ex = None
