"""Unified batched streaming engine: one scoring core for every partitioner.

Every streaming vertex partitioner in this repo is "a stream loop + a scoring
rule + a placement discipline" (paper §III-A; cf. Faraj & Schulz's buffered
streaming framing). :class:`StreamEngine` factors that shape into a single
hot path:

* the stream is consumed in chunks of ``C`` vertices; all ``C x K``
  assigned-neighbour histograms for a chunk come from ONE call to the fused
  :mod:`repro.kernels.partition_score` kernel (Pallas on TPU, jnp reference
  elsewhere) instead of a per-vertex ``bincount``;
* a light host loop applies assignments in stream order. In ``exact`` mode
  the chunk histograms are incrementally corrected as in-chunk neighbours
  get assigned, so results are *bit-identical* to the classic per-vertex
  loops preserved in :mod:`repro.core.legacy` (parity-tested in
  ``tests/test_engine.py``). With ``exact=False`` histograms are left
  one-chunk stale (bulk-synchronous relaxation) and vertices above
  ``sample_cap`` neighbours are scored on a uniform sample with the
  histogram rescaled - the ``cuttana-batched`` speed/quality trade;
* scoring rules are pluggable :class:`Scorer` objects (FENNEL vertex /
  FENNEL-PowerLyra hybrid Eq. 7, LDG) that keep their balance penalty
  incrementally updated instead of recomputing a K-wide ``power`` per
  vertex;
* placement disciplines are pluggable :class:`PlacementPolicy` objects:
  :class:`ImmediatePolicy` (FENNEL / LDG / HeiStream batches / restream
  reassignment) or :class:`BufferedPolicy` - CUTTANA Algorithm 1 with the
  D_max bypass and the complete-eviction cascade, backed by the array-based
  :class:`~repro.core.buffer.PriorityBuffer`;
* the *sharded* policies (:class:`ShardedImmediatePolicy`,
  :class:`ShardedBufferedPolicy`) run S interleaved shard frontiers per
  bulk-synchronous superstep - one packed
  :func:`~repro.kernels.partition_score.fennel_scores_sharded` kernel call
  scores every shard's candidates, shard-local buffers/load views keep the
  supersteps independent, and the shared :class:`PartitionState` is
  exchanged only at superstep boundaries (the paper's parallel CUTTANA,
  relaxed consistency surfaced as ``boundary_conflicts`` telemetry).
  ``num_shards=1`` delegates to the sequential policies, so it stays
  bit-identical to the classic engine.

Extension points: implement ``Scorer`` for a new scoring rule (e.g. a
weighted-affinity variant) or ``PlacementPolicy`` for a new placement
discipline and wire them into a thin ``partition()`` wrapper - see
``src/repro/core/README.md``.

Out-of-core contract: every graph access in this module goes through the CSR
read surface (``indptr``/``indices`` slicing and fancy indexing, ``degrees``,
``num_vertices``), never through whole-graph materialization - so a
memory-mapped :class:`~repro.graph.external.ExternalCSRGraph` streams through
every policy with assignments bit-identical to the resident path (pinned in
``tests/test_outofcore.py``). Keep it that way: a chunk may gather the pages
it touches, but nothing here may copy ``indices`` wholesale.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from repro.core.base import FennelParams, PartitionState
from repro.core.buffer import PriorityBuffer
from repro.core.executor import ShardPool
from repro.core.priority import BufferStats, make_priority
from repro.core.profile import SuperstepProfiler
from repro.core.subpartition import SubPartitioner
from repro.graph.csr import CSRGraph
from repro.graph.prefetch import BatchPrefetcher, PrefetchStats
from repro.graph.stream import ShardedStream, stream_order
from repro.kernels.partition_score.ops import (
    fennel_scores,
    fennel_scores_sharded,
    kernel_active,
    neighbor_histograms_host,
)

# widest dense neighbour axis a kernel call may use in exact mode; rows with
# higher degree are histogrammed exactly on host instead (Thm. 1 hubs are
# rare per chunk, so this bounds memory without sampling)
_EXACT_KERNEL_WIDTH = 1024

__all__ = [
    "Scorer",
    "FennelScorer",
    "LDGScorer",
    "PlacementPolicy",
    "ImmediatePolicy",
    "BufferedPolicy",
    "ShardedImmediatePolicy",
    "ShardedBufferedPolicy",
    "EngineConfig",
    "StreamEngine",
]


# ------------------------------------------------------------------ scorers
@runtime_checkable
class Scorer(Protocol):
    """Per-vertex scoring rule. ``scores`` is called once per placement with
    the vertex's assigned-neighbour histogram; implementations may cache the
    balance penalty and must keep it fresh through ``on_assign`` /
    ``on_unassign`` (every mass mutation the engine makes flows through
    these; if outside code mutates the state - e.g. an FM pass - call
    ``begin`` again)."""

    def begin(self, state: PartitionState) -> None: ...

    def scores(self, state: PartitionState, hist: np.ndarray) -> np.ndarray: ...

    def on_assign(self, state: PartitionState, p: int, deg: int) -> None: ...

    def on_unassign(self, state: PartitionState, p: int, deg: int) -> None: ...


class FennelScorer:
    """FENNEL Eq. 7: ``hist_i - alpha*gamma*size_i^(gamma-1)`` with
    ``size_i = |V_i|`` (vertex mode) or the PowerLyra hybrid mass
    ``(|V_i| + mu*E_i)/2`` (edge mode, ``params.hybrid``). Identical numbers
    to :func:`repro.core.base.make_fennel_score`, but the K-wide penalty is
    cached and only the assigned partition's entry is recomputed per
    placement."""

    def __init__(
        self,
        graph: CSRGraph,
        k: int,
        params: FennelParams | None = None,
        balance_mode: str = "vertex",
    ):
        params = params or FennelParams()
        n = max(graph.num_vertices, 1)
        m = max(graph.num_edges, 1)
        self.alpha = params.alpha_scale * np.sqrt(k) * m / (n**1.5)
        self.gamma = params.gamma
        self.mu = n / max(graph.indices.shape[0], 1)
        self.hybrid = params.hybrid and balance_mode == "edge"
        self._penalty: np.ndarray | None = None
        self._ag = float(self.alpha * self.gamma)
        self._gm1 = self.gamma - 1.0

    def begin(self, state: PartitionState) -> None:
        if self.hybrid:
            size = 0.5 * (state.v_counts + self.mu * state.e_counts)
        else:
            size = state.v_counts
        self._penalty = self.alpha * self.gamma * np.power(
            np.maximum(size, 0.0), self.gamma - 1.0
        )

    def scores(self, state: PartitionState, hist: np.ndarray) -> np.ndarray:
        return hist - self._penalty

    def _update(self, state: PartitionState, p: int) -> None:
        if self.hybrid:
            size = 0.5 * (state.v_counts[p] + self.mu * state.e_counts[p])
        else:
            size = state.v_counts[p]
        self._penalty[p] = self.alpha * self.gamma * np.power(
            np.maximum(size, 0.0), self.gamma - 1.0
        )

    def on_assign(self, state: PartitionState, p: int, deg: int) -> None:
        self._update(state, p)

    def on_unassign(self, state: PartitionState, p: int, deg: int) -> None:
        self._update(state, p)

    # ------------------------------------------------------ affine fast path
    def affine(self, state: PartitionState):
        """scores == hist * mul + add (mul None => 1). See ImmediatePolicy."""
        self.begin(state)
        return None, -self._penalty

    def affine_update(self, v_p: float, e_p: float):
        """New (mul_p, add_p) after partition p's counts became (v_p, e_p).
        Pure-python IEEE doubles: same values as the numpy path bit-for-bit
        (``x ** y`` and ``np.power`` both call libm ``pow``)."""
        if self.hybrid:
            size = 0.5 * (v_p + self.mu * e_p)
        else:
            size = v_p
        if size < 0.0:
            size = 0.0
        return None, -(self._ag * size**self._gm1)

    def affine_arrays(self, v_counts, e_counts):
        """Vectorised :meth:`affine_update`: ``(mul, add)`` for a whole load
        view at once (``mul`` None => 1). Elementwise over any shape, and the
        same libm ``pow`` as the scalar path. Stateless - safe to call from
        concurrent shard tasks."""
        if self.hybrid:
            size = 0.5 * (v_counts + self.mu * e_counts)
        else:
            size = np.asarray(v_counts, dtype=np.float64)
        return None, -(self._ag * np.power(np.maximum(size, 0.0), self._gm1))


class LDGScorer:
    """Linear Deterministic Greedy: ``hist_i * max(1 - size_i/C, 0)`` with a
    tiny negative load term for least-loaded tie-breaking (identical numbers
    to the seed :mod:`repro.core.ldg` loop)."""

    def __init__(self, graph: CSRGraph, k: int, balance_mode: str = "vertex"):
        self.balance_mode = balance_mode
        self._factor: np.ndarray | None = None
        self._cap = 0.0

    def _loads(self, state: PartitionState) -> np.ndarray:
        return state.v_counts if self.balance_mode == "vertex" else state.e_counts

    def begin(self, state: PartitionState) -> None:
        self._cap = (
            state.vertex_capacity
            if self.balance_mode == "vertex"
            else state.edge_capacity
        )
        self._factor = np.maximum(1.0 - self._loads(state) / self._cap, 0.0)

    def scores(self, state: PartitionState, hist: np.ndarray) -> np.ndarray:
        return hist * self._factor - 1e-9 * self._loads(state)

    def _update(self, state: PartitionState, p: int) -> None:
        self._factor[p] = np.maximum(1.0 - self._loads(state)[p] / self._cap, 0.0)

    def on_assign(self, state: PartitionState, p: int, deg: int) -> None:
        self._update(state, p)

    def on_unassign(self, state: PartitionState, p: int, deg: int) -> None:
        self._update(state, p)

    # ------------------------------------------------------ affine fast path
    def affine(self, state: PartitionState):
        self.begin(state)
        return self._factor, -(1e-9 * self._loads(state))

    def affine_update(self, v_p: float, e_p: float):
        lp = v_p if self.balance_mode == "vertex" else e_p
        if self._cap == 0.0:
            # edgeless graph in edge mode: numpy's 0/0 gives nan, which sinks
            # every score and triggers the least-loaded fallback; plain python
            # would raise instead, so reproduce the nan path explicitly
            return float("nan"), -(1e-9 * lp)
        f = 1.0 - lp / self._cap
        if f < 0.0:
            f = 0.0
        return f, -(1e-9 * lp)

    def affine_arrays(self, v_counts, e_counts):
        """Vectorised :meth:`affine_update` (see FennelScorer): stateless,
        elementwise, including the nan path for edgeless edge-mode graphs."""
        loads = np.asarray(
            v_counts if self.balance_mode == "vertex" else e_counts,
            dtype=np.float64,
        )
        if self._cap == 0.0:
            return np.full_like(loads, np.nan), -(1e-9 * loads)
        return np.maximum(1.0 - loads / self._cap, 0.0), -(1e-9 * loads)


# ------------------------------------------------------------------- config
@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Chunking/kernel knobs for the scoring core.

    ``exact=True``: in-chunk histogram corrections, no sampling - results
    match the sequential per-vertex loops bit-for-bit. ``exact=False``:
    histograms stale by one chunk, degree-capped sampling above
    ``sample_cap`` (only honoured in this mode).

    ``max_workers`` threads run the sharded policies' per-shard superstep
    tasks (``None``/``0`` = auto: ``min(num_shards, cpu_count)``); results
    are bit-identical for every worker count because shard tasks write
    disjoint buffers. ``wave`` is the vectorised placement width inside a
    shard task: candidates are scored ``wave`` at a time against a frozen
    penalty/histogram view, refreshed exactly between waves.

    ``prefetch`` controls the decode-ahead pipeline for out-of-core graphs:
    ``"auto"`` overlaps chunk/superstep decode with scoring only when the
    graph is memory-mapped, ``"on"`` forces it, ``"off"`` disables it AND the
    sharded ahead-of-time frontier expansion - the true synchronous baseline
    the out-of-core benchmarks compare against. The prefetcher consumes the
    identical fetch results in the identical order, so assignments are
    bit-identical across all three modes."""

    chunk: int = 512
    sample_cap: int = 512
    exact: bool = True
    use_pallas: bool | None = None
    interpret: bool = False
    max_workers: int | None = None
    wave: int = 128
    prefetch: str = "auto"


def _resolve_prefetch(mode: str, graph) -> tuple[bool, bool]:
    """``(decode_ahead, ahead_prep)`` for a prefetch mode: ``"on"`` forces
    the decode pipeline, ``"off"`` disables it and the sharded ahead-of-time
    frontier expansion, ``"auto"`` enables the pipeline only for mapped
    graphs (anything exposing ``backing == "mapped"``) and leaves ahead-prep
    on - resident runs keep their existing overlap."""
    if mode not in ("auto", "on", "off"):
        raise ValueError(f'prefetch must be "auto", "on" or "off", got {mode!r}')
    if mode == "on":
        return True, True
    if mode == "off":
        return False, False
    return getattr(graph, "backing", "resident") == "mapped", True


# ----------------------------------------------------------------- policies
@runtime_checkable
class PlacementPolicy(Protocol):
    def run(self, engine: "StreamEngine") -> None: ...


class ImmediatePolicy:
    """Place every stream vertex as soon as it arrives (FENNEL/LDG/HeiStream
    greedy phase). With ``reassign=True`` the stream *re-visits* already
    assigned vertices (restreaming): each vertex is pulled out of its current
    partition, rescored against the full assignment, and may move."""

    def __init__(self, reassign: bool = False):
        self.reassign = reassign

    def run(self, eng: "StreamEngine") -> None:
        if self.reassign and eng.subp is not None:
            # SubPartitioner has no unassign: re-adding an already-placed
            # vertex would double-count its sub-partition mass
            raise ValueError("reassign mode does not support a subpartitioner")
        if hasattr(eng.scorer, "affine"):
            self._run_affine(eng)
        else:
            self._run_generic(eng)

    # ------------------------------------------------- generic scorer path
    def _run_generic(self, eng: "StreamEngine") -> None:
        """Protocol-only path for custom scorers: per-vertex numpy scoring."""
        state = eng.state
        scorer = eng.scorer
        subp = eng.subp
        part_of = state.part_of
        v_counts, e_counts = state.v_counts, state.e_counts
        reassign = self.reassign
        for batch, degs, expanded in _iter_chunk_expansions(eng):
            nbr_views = _chunk_views(expanded[2], degs)
            hist, corr = eng.chunk_histograms(batch, degs, nbr_views, expanded)
            bl = batch.tolist()
            dl = degs.tolist()
            for i in range(len(bl)):
                v, deg = bl[i], dl[i]
                if reassign:
                    cur = int(part_of[v])
                    v_counts[cur] -= 1
                    e_counts[cur] -= deg
                    scorer.on_unassign(state, cur, deg)
                s = scorer.scores(state, hist[i])
                allowed = ~state.would_overflow(deg)
                if reassign:
                    allowed[cur] = True
                p = state.argmax_tiebreak(s, allowed)
                if reassign:
                    part_of[v] = p
                    v_counts[p] += 1
                    e_counts[p] += deg
                    scorer.on_assign(state, p, deg)
                    if corr is not None and p != cur:
                        dst, starts = corr
                        for j in dst[starts[i] : starts[i + 1]]:
                            hist[j, cur] -= 1.0
                            hist[j, p] += 1.0
                else:
                    state.assign(v, p, deg)
                    scorer.on_assign(state, p, deg)
                    if subp is not None:
                        subp.assign(v, p, nbr_views[i], deg)
                    if corr is not None:
                        dst, starts = corr
                        for j in dst[starts[i] : starts[i + 1]]:
                            hist[j, p] += 1.0
            if eng.on_chunk_end is not None:
                eng.on_chunk_end(eng, batch, nbr_views)

    # ------------------------------------------------- affine scorer path
    def _run_affine(self, eng: "StreamEngine") -> None:
        """Fast host loop for scorers exposing the affine contract
        ``scores == hist * mul + add``. The K-wide selection runs in plain
        Python over lists (for K <= a few hundred, numpy dispatch overhead
        dwarfs the arithmetic); canonical numpy state is written back once
        per chunk. Every operation is the same IEEE double computation as
        the generic path, so results stay bit-identical - parity-tested
        against :mod:`repro.core.legacy`."""
        state = eng.state
        scorer = eng.scorer
        subp = eng.subp
        part_of = state.part_of
        v_counts, e_counts = state.v_counts, state.e_counts
        reassign = self.reassign
        k = state.k
        krange = range(k)
        rng = state.rng
        vertex_mode = state.balance_mode == "vertex"
        cap = state.vertex_capacity if vertex_mode else state.edge_capacity
        neg_inf = float("-inf")
        sc = [neg_inf] * k  # per-vertex score buffer (neg_inf == disallowed)
        for batch, degs, expanded in _iter_chunk_expansions(eng):
            nbr_views = (
                _chunk_views(expanded[2], degs)
                if subp is not None or eng.on_chunk_end is not None
                else None
            )
            hist, corr = eng.chunk_histograms(batch, degs, nbr_views, expanded)
            H = hist.tolist()
            bl = batch.tolist()
            dl = degs.tolist()
            assigned = [0] * len(bl)
            # python mirrors of the balance state; canonical arrays are
            # flushed at chunk end (before any on_chunk_end hook), so hooks
            # may mutate state freely - affine() re-syncs next chunk
            mul_a, add_a = scorer.affine(state)
            mul = None if mul_a is None else mul_a.tolist()
            add = add_a.tolist()
            v_list = v_counts.tolist()
            e_list = e_counts.tolist()
            load = v_list if vertex_mode else e_list
            for i in range(len(bl)):
                v, deg = bl[i], dl[i]
                cur = -1
                if reassign:
                    cur = int(part_of[v])  # pre-pass value: writes deferred
                    v_list[cur] -= 1
                    e_list[cur] -= deg
                    u = scorer.affine_update(v_list[cur], e_list[cur])
                    if mul is not None:
                        mul[cur] = u[0]
                    add[cur] = u[1]
                row = H[i]
                inc = 1 if vertex_mode else deg
                best = neg_inf
                if mul is None:
                    for p in krange:
                        if load[p] + inc > cap and p != cur:
                            sc[p] = neg_inf
                            continue
                        s = row[p] + add[p]
                        sc[p] = s
                        if s > best:
                            best = s
                else:
                    for p in krange:
                        if load[p] + inc > cap and p != cur:
                            sc[p] = neg_inf
                            continue
                        s = row[p] * mul[p] + add[p]
                        sc[p] = s
                        if s > best:
                            best = s
                if best == neg_inf:
                    # every partition at capacity - least-loaded fallback,
                    # same rule as PartitionState.argmax_tiebreak
                    p = load.index(min(load))
                else:
                    thr = best - 1e-12
                    ties = [p for p in krange if sc[p] >= thr]
                    p = ties[0] if len(ties) == 1 else int(ties[rng.integers(len(ties))])
                assigned[i] = p
                v_list[p] += 1
                e_list[p] += deg
                u = scorer.affine_update(v_list[p], e_list[p])
                if mul is not None:
                    mul[p] = u[0]
                add[p] = u[1]
                if subp is not None:
                    subp.assign(v, p, nbr_views[i], deg)
                if corr is not None and p != cur:
                    dst, starts = corr
                    if reassign:
                        for j in dst[starts[i] : starts[i + 1]]:
                            rj = H[j]
                            rj[cur] -= 1.0
                            rj[p] += 1.0
                    else:
                        for j in dst[starts[i] : starts[i + 1]]:
                            H[j][p] += 1.0
            # flush deferred writes back into the canonical numpy state
            part_of[batch] = assigned
            v_counts[:] = v_list
            e_counts[:] = e_list
            if eng.on_chunk_end is not None:
                eng.on_chunk_end(eng, batch, nbr_views)


class BufferedPolicy:
    """CUTTANA Algorithm 1: vertices with degree >= D_max are placed
    immediately (Thm. 1); the rest enter the bounded priority buffer; on
    overflow the best-scored vertex is evicted and placed; placements bump
    buffered neighbours (vectorised through ``notify_many``) and fully-known
    vertices cascade out immediately.

    ``strategy`` selects the eviction priority (:mod:`repro.core.priority`):
    ``"eq6"`` (paper default, bit-identical to the pre-strategy engine),
    ``"completeness"``, or ``"gain"``."""

    def __init__(
        self,
        max_qsize: int,
        d_max: int,
        theta: float = 1.0,
        strategy: str = "eq6",
    ):
        self.max_qsize = int(max_qsize)
        self.priority_factory = lambda: make_priority(strategy, d_max, theta)
        prio = self.priority_factory()  # validates name eagerly
        self.strategy = prio.name
        self.d_max = prio.d_max
        self.theta = prio.theta
        self.buffer: PriorityBuffer | None = None

    def run(self, eng: "StreamEngine") -> None:
        state = eng.state
        prio = self.priority_factory()
        buf = PriorityBuffer(self.max_qsize, graph=eng.graph, priority=prio)
        self.buffer = buf
        part_of = state.part_of
        d_max = self.d_max
        track = prio.tracks_parts
        stats = BufferStats()

        def cascade(v: int, nbrs: np.ndarray) -> None:
            worklist = [(v, nbrs)]
            while worklist:
                u, un = worklist.pop()
                p = eng.place(u, un)
                for w in buf.notify_many(un, p if track else None):
                    worklist.append((w, buf.remove(w)))

        # admission reads neighbour rows a chunk at a time so the prefetcher
        # can decode chunk t+1 while chunk t's buffer churn runs; the
        # cascade/eviction rows stay data-dependent per-row reads
        for batch, degs, expanded in _iter_chunk_expansions(eng):
            views = _chunk_views(expanded[2], degs)
            for i, v in enumerate(batch.tolist()):
                if part_of[v] != -1:
                    continue  # already placed via complete-eviction cascade
                nbrs = views[i]
                if nbrs.size >= d_max:
                    stats.bypass += 1
                    cascade(v, nbrs)
                    continue
                nbr_parts = part_of[nbrs]
                assigned = int((nbr_parts != -1).sum())
                if assigned == nbrs.size and nbrs.size > 0:
                    cascade(v, nbrs)  # complete already
                    continue
                buf.push(v, nbrs, assigned, nbr_parts if track else None)
                stats.observe_len(len(buf))
                if buf.full:
                    u, un = buf.pop_best()
                    stats.evictions += 1
                    cascade(u, un)
        while len(buf):
            u, un = buf.pop_best()
            stats.drained += 1
            cascade(u, un)
        eng.telemetry.update(stats.to_telemetry(self.strategy))


# ------------------------------------------------------------------ helpers
def _expand_csr_batch(indptr, indices, batch, degs):
    """Flat neighbour expansion of a candidate batch: returns
    ``(rows, idx_in_row, cols)`` where flat position ``j`` is the
    ``idx_in_row[j]``-th neighbour (vertex id ``cols[j]``) of
    ``batch[rows[j]]``. Shared by the sequential chunk path, the superstep
    core, and the sharded buffer's admission scan."""
    rows = np.repeat(np.arange(batch.shape[0], dtype=np.int64), degs)
    offs = np.zeros(batch.shape[0], dtype=np.int64)
    np.cumsum(degs[:-1], out=offs[1:])
    idx_in_row = np.arange(rows.shape[0], dtype=np.int64) - offs[rows]
    cols = indices[np.repeat(indptr[batch], degs) + idx_in_row]
    return rows, idx_in_row, cols


def _chunk_views(cols, degs):
    """Per-row neighbour arrays from a flat chunk expansion - same values as
    slicing ``indices`` row by row, but without re-touching the graph."""
    if degs.shape[0] == 0:
        return []
    return np.split(cols, np.cumsum(degs[:-1]))


def _iter_chunk_expansions(eng: "StreamEngine"):
    """Yield ``(batch, degs, (rows, idx_in_row, cols))`` per stream chunk.

    The fetch touches only the immutable CSR read surface, so when the
    engine's prefetcher is enabled chunk t+1 is expanded (for a compressed
    mapped graph: varint-decoded) on the prefetch thread while chunk t is
    being scored. Inline and prefetched paths run the identical fetch in the
    identical order, so the consumed stream is bit-identical either way.
    """
    indptr, indices = eng.graph.indptr, eng.graph.indices
    ids = eng.ids
    chunk = eng.config.chunk

    def fetch(start):
        batch = ids[start : start + chunk]
        degs = (indptr[batch + 1] - indptr[batch]).astype(np.int64)
        return batch, degs, _expand_csr_batch(indptr, indices, batch, degs)

    starts = range(0, ids.shape[0], chunk)
    if not eng.prefetch_enabled:
        for s in starts:
            yield fetch(s)
        return
    pf = BatchPrefetcher(fetch, starts, stats=eng.prefetch_stats)
    try:
        yield from pf
    finally:
        pf.close()


# --------------------------------------------------------- sharded policies
def _check_num_shards(num_shards) -> int:
    s = int(num_shards)
    if s < 1 or s != num_shards:
        raise ValueError(f"num_shards must be a positive integer, got {num_shards!r}")
    return s


@dataclasses.dataclass
class _ShardPrep:
    """Frontier expansion for one shard's superstep batch.

    Everything here is derived from the immutable CSR plus the batch ids
    alone - no dependence on the evolving assignment - so preps can be (and
    are) computed on worker threads one superstep AHEAD of their use,
    overlapping superstep t's boundary exchange with t+1's expansion.
    """

    batch: np.ndarray  # int64[c] candidate ids (contiguous)
    degs: np.ndarray  # int64[c]
    rows: np.ndarray  # int64[nnz] local row index per neighbour slot
    idx_in_row: np.ndarray  # int64[nnz]
    cols: np.ndarray  # int64[nnz] neighbour ids
    corr_src: np.ndarray  # int64[nc] in-shard same-superstep pairs sorted by
    corr_dst: np.ndarray  # src; dst is placed later than src in shard order


def _prepare_shard(indptr, indices, batch) -> _ShardPrep:
    """Build one shard's :class:`_ShardPrep`. Stateless (the old shared
    scratch-array correction pass would race across threads) and touches the
    graph only through the CSR read surface."""
    batch = np.ascontiguousarray(batch, dtype=np.int64)
    degs = (indptr[batch + 1] - indptr[batch]).astype(np.int64)
    rows, idx_in_row, cols = _expand_csr_batch(indptr, indices, batch, degs)
    if cols.size:
        # in-shard same-superstep correction pairs via sorted membership
        # lookup: position of each neighbour id inside the batch, if any
        order = np.argsort(batch, kind="stable")
        sb = batch[order]
        loc = np.searchsorted(sb, cols)
        np.minimum(loc, sb.size - 1, out=loc)
        cpos = np.where(sb[loc] == cols, order[loc], -1)
        emask = (cpos >= 0) & (cpos < rows)
        src, dst = cpos[emask], rows[emask]
        o = np.argsort(src, kind="stable")
        src, dst = src[o], dst[o]
    else:
        src = dst = np.empty(0, dtype=np.int64)
    return _ShardPrep(batch, degs, rows, idx_in_row, cols, src, dst)


class _SuperstepRunner:
    """Bulk-synchronous superstep core shared by the sharded policies.

    Per superstep, every shard's candidate vertices are scored against the
    *superstep-start snapshot* of the shared :class:`PartitionState`, then
    each shard places its candidates against a local view (snapshot + its
    own deltas, with the remaining per-partition capacity split evenly
    across shards). Assignments and loads are exchanged only at the
    superstep boundary - the paper's relaxed-consistency parallel design.
    Same-shard same-superstep neighbours are corrected exactly between
    placement waves; cross-shard ones are not, and are counted as
    ``boundary_conflicts`` for the merge + coarsen + refine pass to
    reconcile.

    Concurrency model: each shard is one task on a :class:`ShardPool`. A
    task reads only snapshot arrays (``part_of``, the superstep-start load
    vectors) and its own :class:`_ShardPrep`, and writes only its disjoint
    slices of the superstep's assignment/histogram buffers - tasks commute,
    so assignments are bit-identical for every ``max_workers``. The merge
    back into shared state is a vectorised bincount reduction on the main
    thread; the sub-partition merge is a FIFO-chained pool task that may
    overlap the next superstep's scoring.
    """

    def __init__(
        self,
        eng: "StreamEngine",
        sharded: ShardedStream,
        reassign: bool = False,
        need_cols: bool = False,
        need_parts: bool = False,
    ):
        if not hasattr(eng.scorer, "affine_arrays"):
            raise ValueError(
                "sharded policies require a scorer with the affine contract "
                "(scores == hist * mul + add); got "
                f"{type(eng.scorer).__name__}"
            )
        if reassign and eng.subp is not None:
            # same contract as ImmediatePolicy: SubPartitioner has no unassign
            raise ValueError("reassign mode does not support a subpartitioner")
        self.eng = eng
        self.sharded = sharded
        self.reassign = reassign
        self.need_cols = need_cols
        self.need_parts = need_parts
        state = eng.state
        self.k = state.k
        self.shard_of = sharded.shard_of(eng.graph.num_vertices)
        self.step_mark = np.full(eng.graph.num_vertices, -1, dtype=np.int64)
        self.step = 0
        self.sync_rounds = 0
        self.boundary_conflicts = 0
        self.vertex_mode = state.balance_mode == "vertex"
        self.cap = (
            state.vertex_capacity if self.vertex_mode else state.edge_capacity
        )
        self.wave = max(int(eng.config.wave), 1)
        self.pool = ShardPool(eng.config.max_workers, sharded.num_shards)
        self.profile = SuperstepProfiler(workers=self.pool.workers)
        self.prefetch_ahead = eng.prefetch_ahead
        # with an inline (single-worker) pool, prepare_async would run on the
        # calling thread and the ahead-prep overlap would silently vanish; a
        # dedicated decode thread keeps the pipeline real on one core
        self._prefetch_ex: ThreadPoolExecutor | None = (
            ThreadPoolExecutor(1, thread_name_prefix="prefetch")
            if eng.prefetch_enabled and self.pool.workers == 1
            else None
        )
        self._subp_chain = None
        self._v0: np.ndarray | None = None
        self._e0: np.ndarray | None = None

    def close(self) -> None:
        """Flush the chained sub-partition merges and stop the pool. Must
        run before anything reads ``eng.subp`` state (phase 2)."""
        if self._subp_chain is not None:
            t0 = time.perf_counter()
            self._subp_chain.result()
            self.profile.add("merge", time.perf_counter() - t0)
            self._subp_chain = None
        if self._prefetch_ex is not None:
            self._prefetch_ex.shutdown(wait=True)
            self._prefetch_ex = None
        self.pool.shutdown()

    # ----------------------------------------------------------- prefetch
    def prepare_async(self, batches: list[np.ndarray]) -> list:
        """Submit per-shard frontier expansion; futures align with shards."""
        eng = self.eng
        indptr, indices = eng.graph.indptr, eng.graph.indices
        fn = _prepare_shard
        if eng.prefetch_enabled:
            stats = eng.prefetch_stats

            def fn(ip, ix, b):
                t0 = time.perf_counter()
                try:
                    return _prepare_shard(ip, ix, b)
                finally:
                    stats.record_decode(time.perf_counter() - t0)

        submit = (
            self._prefetch_ex.submit
            if self._prefetch_ex is not None
            else self.pool.submit
        )
        return [
            submit(fn, indptr, indices, b) if b.shape[0] else None
            for b in batches
        ]

    def wait_preps(
        self, futs: list | None, record: bool = False
    ) -> list[_ShardPrep | None] | None:
        if futs is None:
            return None
        hit = all(f is None or f.done() for f in futs)
        t0 = time.perf_counter()
        preps = [f.result() if f is not None else None for f in futs]
        wait = time.perf_counter() - t0
        self.profile.add("prep", wait)
        if record and self.eng.prefetch_enabled:
            self.eng.prefetch_stats.record_wait(wait, hit)
        return preps

    # -------------------------------------------------------- histogramming
    def _histograms_packed(self, preps, counts, total):
        """float64[total, K] histograms via ONE packed sharded kernel call
        (TPU / interpret path; the host path histograms inside shard tasks
        with :func:`neighbor_histograms_host` instead)."""
        eng = self.eng
        k = self.k
        part_of = eng.state.part_of
        indptr, indices = eng.graph.indptr, eng.graph.indices
        num_shards = len(counts)
        cmax = max(max(counts), 1)
        max_deg = max(
            (int(p.degs.max()) for p in preps if p is not None and p.degs.size),
            default=0,
        )
        kw = max(min(max_deg, _EXACT_KERNEL_WIDTH), 1)
        width = max(8, 1 << (kw - 1).bit_length())
        bounds = np.cumsum(np.asarray(counts, dtype=np.int64))
        starts = bounds - np.asarray(counts, dtype=np.int64)
        nbr3 = np.full((num_shards, cmax, width), -1, dtype=np.int32)
        over_rows: list[tuple[int, int]] = []
        for s, prep in enumerate(preps):
            if prep is None:
                continue
            over = np.flatnonzero(prep.degs > kw)
            if over.size:
                fmask = (prep.degs <= kw)[prep.rows]
                nbr3[s, prep.rows[fmask], prep.idx_in_row[fmask]] = (
                    part_of[prep.cols[fmask]]
                )
                over_rows.extend(
                    (int(starts[s] + i), int(prep.batch[i])) for i in over
                )
            else:
                nbr3[s, prep.rows, prep.idx_in_row] = part_of[prep.cols]
        out = np.asarray(
            fennel_scores_sharded(
                nbr3, np.zeros((num_shards, k), dtype=np.float32), 0.0, 1.5,
                use_pallas=eng.config.use_pallas, interpret=eng.config.interpret,
            ),
            dtype=np.float64,
        )
        hist = np.empty((total, k), dtype=np.float64)
        for s, c in enumerate(counts):
            if c:
                hist[starts[s] : bounds[s]] = out[s, :c]
        for gi, v in over_rows:
            # over-width hubs: exact host histogram (Thm. 1 regime)
            nbp = part_of[indices[indptr[v] : indptr[v + 1]]]
            hist[gi] = np.bincount(nbp[nbp >= 0], minlength=k)
        return hist

    # ------------------------------------------------------- per-shard task
    def _shard_task(self, prep: _ShardPrep, hist_rows, out, room):
        """One shard's superstep work: histogram (host path) + wave-
        vectorised placement. Reads only snapshot arrays and ``prep``;
        writes only this shard's ``hist_rows``/``out`` slices - safe and
        deterministic under any pool scheduling."""
        t0 = time.perf_counter()
        part_of = self.eng.state.part_of
        if hist_rows is None:
            hist_rows = neighbor_histograms_host(
                prep.rows, part_of[prep.cols], prep.batch.shape[0], self.k
            )
        old = part_of[prep.batch].astype(np.int64) if self.reassign else None
        t1 = time.perf_counter()
        self._place_shard(prep, hist_rows, out, room, old)
        t2 = time.perf_counter()
        return t1 - t0, t2 - t1, old

    def _place_shard(self, prep, hist, out, room, old):
        """Wave-vectorised placement of one shard's candidates.

        ``wave`` candidates are scored at a time against the superstep
        snapshot plus this shard's own running deltas: within a wave the
        balance penalty and in-shard neighbour histograms are frozen (the
        relaxation the supersteps already make across shards, one level
        down); between waves both are refreshed exactly. A wave whose picks
        would overshoot a partition's shard-local headroom is replayed per
        vertex against live loads (rare - caught by the bincount projection
        below), so the capacity rule is enforced exactly as sequentially.
        Ties break to the lowest partition index - deterministic without
        consuming shared rng state, which is what makes assignments
        independent of the worker count.
        """
        k = self.k
        scorer = self.eng.scorer
        c = prep.batch.shape[0]
        degf = prep.degs.astype(np.float64)
        inc = np.ones(c, dtype=np.float64) if self.vertex_mode else degf
        v_loc = self._v0.copy()
        e_loc = self._e0.copy()
        used = np.zeros(k, dtype=np.float64)
        wave = self.wave
        csrc, cdst = prep.corr_src, prep.corr_dst
        for g0 in range(0, c, wave):
            g1 = min(g0 + wave, c)
            g = g1 - g0
            rows_i = np.arange(g)
            hb = hist[g0:g1]
            mul, add = scorer.affine_arrays(v_loc, e_loc)
            sc = hb + add if mul is None else hb * mul + add
            incw = inc[g0:g1]
            fits = used + incw[:, None] <= room
            cur = None
            if old is not None:
                # pull each candidate out of its current partition in its
                # own row's view: staying put is always allowed, and cur's
                # penalty reflects the vertex's removal (sequential rule)
                cur = old[g0:g1]
                fits[rows_i, cur] = True
                smul, sadd = scorer.affine_arrays(
                    v_loc[cur] - 1.0, e_loc[cur] - degf[g0:g1]
                )
                own = hb[rows_i, cur]
                sc[rows_i, cur] = own + sadd if smul is None else own * smul + sadd
            masked = np.where(fits, sc, -np.inf)
            choice = masked.argmax(axis=1).astype(np.int64)
            best = masked[rows_i, choice]
            fallback = ~(best > -np.inf)  # -inf (or nan): headroom exhausted
            if fallback.any():
                loads_loc = v_loc if self.vertex_mode else e_loc
                choice[fallback] = int(loads_loc.argmin())
            add_w = np.bincount(choice, weights=incw, minlength=k)
            proj = used + add_w
            if cur is not None:
                proj = proj - np.bincount(cur, weights=incw, minlength=k)
            repaired = False
            if (proj > room).any():
                nf = np.flatnonzero(~fallback)
                # fallback-only overshoot mirrors the sequential fallback
                # (capacity is advisory there); real picks must not overshoot
                if nf.size and (proj > room)[choice[nf]].any():
                    repaired = True
                    self._repair_wave(
                        g0, g1, sc, incw, degf, room, used, v_loc, e_loc,
                        choice, cur,
                    )
            if not repaired:
                used += add_w
                v_loc += np.bincount(choice, minlength=k).astype(np.float64)
                e_loc += np.bincount(choice, weights=degf[g0:g1], minlength=k)
                if cur is not None:
                    used -= np.bincount(cur, weights=incw, minlength=k)
                    v_loc -= np.bincount(cur, minlength=k).astype(np.float64)
                    e_loc -= np.bincount(cur, weights=degf[g0:g1], minlength=k)
            out[g0:g1] = choice
            if csrc.size:
                lo = np.searchsorted(csrc, g0)
                hi = np.searchsorted(csrc, g1)
                if hi > lo:
                    d_ = cdst[lo:hi]
                    later = d_ >= g1
                    if later.any():
                        d_ = d_[later]
                        s_ = csrc[lo:hi][later] - g0
                        np.add.at(hist, (d_, choice[s_]), 1.0)
                        if cur is not None:
                            np.add.at(hist, (d_, cur[s_]), -1.0)

    def _repair_wave(
        self, g0, g1, sc, incw, degf, room, used, v_loc, e_loc, choice, cur
    ):
        """Scalar replay of one wave against live shard-local loads (frozen
        wave scores): only runs when the vectorised projection would
        overshoot, so the balance invariant is exactly the sequential one."""
        vertex_mode = self.vertex_mode
        for i in range(g1 - g0):
            inc_i = incw[i]
            f_i = used + inc_i <= room
            if cur is not None:
                f_i[cur[i]] = True
            m = np.where(f_i, sc[i], -np.inf)
            b = m.max()
            if b > -np.inf:
                p = int(m.argmax())
            else:
                p = int((v_loc if vertex_mode else e_loc).argmin())
            choice[i] = p
            d = degf[g0 + i]
            used[p] += inc_i
            v_loc[p] += 1.0
            e_loc[p] += d
            if cur is not None:
                q = cur[i]
                used[q] -= inc_i
                v_loc[q] -= 1.0
                e_loc[q] -= d

    # ----------------------------------------------------------- superstep
    def run_superstep(
        self,
        batches: list[np.ndarray],
        preps: list[_ShardPrep | None] | None = None,
    ) -> np.ndarray | None:
        """Score + place all shards' candidates concurrently, commit at the
        boundary via a vectorised reduction.

        Returns the flat neighbour-id array of everything placed (the
        buffered policy notifies every shard buffer with it; only built
        when ``need_cols``; with ``need_parts`` a ``(cols, parts)`` pair
        where ``parts[j]`` is the partition the owner of neighbour slot
        ``j`` was just placed in - partition-tracking buffer strategies
        feed it to ``notify_many``), or None when the superstep had no
        candidates.
        """
        eng = self.eng
        state = eng.state
        self.step += 1
        counts = [int(b.shape[0]) for b in batches]
        total = sum(counts)
        if total == 0:
            return None
        if preps is None:
            preps = self.wait_preps(self.prepare_async(batches))
        eng.telemetry["kernel_calls"] += 1
        k = self.k
        v_counts, e_counts = state.v_counts, state.e_counts
        loads0 = v_counts if self.vertex_mode else e_counts
        # remaining per-partition capacity split evenly across the shards
        # that actually place this superstep (empty batches - e.g. drained
        # cursors - must not starve the active ones): the merged superstep
        # cannot overshoot the balance condition any worse than the
        # sequential least-loaded fallback already can
        active = sum(1 for c in counts if c)
        room = np.maximum(self.cap - loads0, 0.0) / active
        self._v0 = v_counts.copy()
        self._e0 = e_counts.copy()
        bounds = np.cumsum(np.asarray(counts, dtype=np.int64))
        starts = bounds - np.asarray(counts, dtype=np.int64)
        assigned_flat = np.empty(total, dtype=np.int64)
        hist_all = None
        score_s = 0.0
        if eng._use_kernel:
            t_k = time.perf_counter()
            hist_all = self._histograms_packed(preps, counts, total)
            score_s += time.perf_counter() - t_k
        # fan out: one task per non-empty shard, each writing its disjoint
        # slice of assigned_flat (and mutating only its own hist rows)
        t_par = time.perf_counter()
        futs = []
        for s, prep in enumerate(preps):
            if prep is None:
                continue
            hist_rows = (
                hist_all[starts[s] : bounds[s]] if hist_all is not None else None
            )
            futs.append(
                self.pool.submit(
                    self._shard_task, prep, hist_rows,
                    assigned_flat[starts[s] : bounds[s]], room,
                )
            )
        place_s = 0.0
        olds = []
        for f in futs:
            h_s, p_s, old = f.result()
            score_s += h_s
            place_s += p_s
            if old is not None:
                olds.append(old)
        parallel_wall = time.perf_counter() - t_par
        # ------------------------------------------------ boundary exchange
        t_x = time.perf_counter()
        live = [p for p in preps if p is not None]
        big = np.concatenate([p.batch for p in live])
        degf = np.concatenate([p.degs for p in live]).astype(np.float64)
        if self.reassign:
            old_flat = np.concatenate(olds)
            v_counts -= np.bincount(old_flat, minlength=k).astype(np.float64)
            e_counts -= np.bincount(old_flat, weights=degf, minlength=k)
        state.part_of[big] = assigned_flat
        v_counts += np.bincount(assigned_flat, minlength=k).astype(np.float64)
        e_counts += np.bincount(assigned_flat, weights=degf, minlength=k)
        self.sync_rounds += 1
        self.step_mark[big] = self.step
        conflicts = 0
        for s, prep in enumerate(preps):
            if prep is None or prep.cols.size == 0:
                continue
            same_step = self.step_mark[prep.cols] == self.step
            conflicts += int((same_step & (self.shard_of[prep.cols] != s)).sum())
        # each conflicting edge appears once from either endpoint
        self.boundary_conflicts += conflicts // 2
        exchange_s = time.perf_counter() - t_x
        # ----------------------------------- overlapped sub-partition merge
        merge_s = 0.0
        if eng.subp is not None:
            t_m = time.perf_counter()
            rows_g = np.concatenate(
                [p.rows + starts[s] for s, p in enumerate(preps) if p is not None]
            )
            cols_g = np.concatenate([p.cols for p in live])
            degs_g = np.concatenate([p.degs for p in live])
            # FIFO-chained: superstep t's sub-placement may overlap t+1's
            # scoring (placement never reads sub-partition state), but
            # merges apply in superstep order and close() flushes the chain
            # before phase 2 reads it
            self._subp_chain = self.pool.submit_after(
                self._subp_chain, eng.subp.assign_superstep,
                big, assigned_flat, degs_g, rows_g, cols_g, self.wave,
            )
            merge_s = time.perf_counter() - t_m
        self.profile.record(
            score=score_s, place=place_s, exchange=exchange_s, merge=merge_s,
            parallel_wall=parallel_wall,
        )
        if self.need_cols:
            cols_all = np.concatenate([p.cols for p in live])
            if self.need_parts:
                # partition of the *placer*, aligned with its neighbour slots
                parts_all = np.concatenate(
                    [
                        assigned_flat[starts[s] : bounds[s]][p.rows]
                        for s, p in enumerate(preps)
                        if p is not None
                    ]
                )
                return cols_all, parts_all
            return cols_all
        return big

    def finalize_telemetry(self) -> None:
        self.profile.add_queue_wait(self.pool.queue_wait_s)
        self.eng.telemetry.update(
            supersteps=self.step,
            sync_rounds=self.sync_rounds,
            boundary_conflicts=self.boundary_conflicts,
            num_shards=self.sharded.num_shards,
            max_workers=self.pool.workers,
            profile=self.profile.to_dict(),
        )


class ShardedImmediatePolicy:
    """S interleaved shard frontiers placed per bulk-synchronous superstep.

    The FENNEL/LDG analogue of the paper's parallel CUTTANA: every superstep
    each shard advances its cursor by ``config.chunk`` vertices, all shards'
    chunks are scored in one packed kernel call, and the shared state is
    synchronized at the boundary. ``num_shards=1`` is *defined* as the
    sequential engine (delegates to :class:`ImmediatePolicy`), so every
    sequential parity guarantee carries over bit-for-bit.

    ``reassign=True`` is the restreaming mode (every vertex already holds an
    assignment; each superstep pulls its candidates out of their current
    partitions in the shard-local view and may move them) - the sharded
    counterpart of ``ImmediatePolicy(reassign=True)``.
    """

    def __init__(self, num_shards: int, reassign: bool = False):
        self.num_shards = _check_num_shards(num_shards)
        self.reassign = reassign

    def run(self, eng: "StreamEngine") -> None:
        if self.num_shards == 1:
            ImmediatePolicy(reassign=self.reassign).run(eng)
            eng.telemetry.update(
                supersteps=0, sync_rounds=0, boundary_conflicts=0, num_shards=1
            )
            return
        sharded = ShardedStream.from_ids(eng.ids, self.num_shards)
        runner = _SuperstepRunner(eng, sharded, reassign=self.reassign)
        try:
            steps = list(sharded.superstep_batches(eng.config.chunk))
            if not runner.prefetch_ahead:
                # prefetch="off": the true synchronous baseline - every
                # superstep expands its own frontier before scoring
                for batches in steps:
                    runner.run_superstep(batches)
            else:
                prefetched = runner.prepare_async(steps[0]) if steps else None
                for t, batches in enumerate(steps):
                    preps = runner.wait_preps(prefetched, record=True)
                    # overlap: expand superstep t+1's frontier while t scores,
                    # places and merges (expansion reads only the immutable CSR)
                    prefetched = (
                        runner.prepare_async(steps[t + 1])
                        if t + 1 < len(steps)
                        else None
                    )
                    runner.run_superstep(batches, preps)
        finally:
            runner.close()
        runner.finalize_telemetry()


class ShardedBufferedPolicy:
    """Parallel CUTTANA Algorithm 1: shard-local priority buffers around the
    bulk-synchronous superstep core.

    Each shard ingests ``config.chunk`` stream vertices per superstep into
    its own :class:`PriorityBuffer` (D_max bypasses and already-complete
    vertices become immediate candidates; overflow evicts the best-scored
    ones), all shards' candidates are placed through ONE packed kernel call,
    and at the boundary every shard's buffer is notified with the whole
    superstep's placements - cross-shard visibility arrives exactly one
    superstep late (relaxed consistency). Complete vertices surfacing at a
    boundary are placed in the next superstep; buffers drain chunk-at-a-time
    once their cursor is exhausted. ``num_shards=1`` delegates to the
    sequential :class:`BufferedPolicy` (bit-identical by construction).
    """

    def __init__(
        self,
        num_shards: int,
        max_qsize: int,
        d_max: int,
        theta: float = 1.0,
        strategy: str = "eq6",
    ):
        self.num_shards = _check_num_shards(num_shards)
        self.max_qsize = int(max_qsize)
        prio = make_priority(strategy, d_max, theta)  # validates name eagerly
        self.strategy = prio.name
        self.tracks_parts = prio.tracks_parts
        self.d_max = prio.d_max
        self.theta = prio.theta
        self.buffers: list[PriorityBuffer] | None = None

    def run(self, eng: "StreamEngine") -> None:
        if self.num_shards == 1:
            seq = BufferedPolicy(
                self.max_qsize, self.d_max, self.theta, strategy=self.strategy
            )
            seq.run(eng)
            self.buffers = [seq.buffer]
            eng.telemetry.update(
                supersteps=0, sync_rounds=0, boundary_conflicts=0, num_shards=1
            )
            return
        num_shards = self.num_shards
        graph = eng.graph
        indptr, indices = graph.indptr, graph.indices
        part_of = eng.state.part_of
        sharded = ShardedStream.from_ids(eng.ids, num_shards)
        track = self.tracks_parts
        runner = _SuperstepRunner(eng, sharded, need_cols=True, need_parts=track)
        chunk = max(int(eng.config.chunk), 1)
        bufs = [
            PriorityBuffer(
                self.max_qsize,
                graph=graph,
                priority=make_priority(self.strategy, self.d_max, self.theta),
            )
            for _ in range(num_shards)
        ]
        self.buffers = bufs
        pending: list[list[int]] = [[] for _ in range(num_shards)]
        cursors = [0] * num_shards
        d_max = self.d_max
        prefetch_on = eng.prefetch_enabled
        stats = eng.prefetch_stats
        # decode-ahead slots: shard -> (cursor snapshot, in-flight scan).
        # Each slot is written on the main thread between rounds and consumed
        # only by that shard's ingest task, so access stays disjoint.
        adm: dict[int, tuple[int, object]] = {}

        def scan(s: int, cursor: int):
            """Assignment-independent half of shard s's ingest: the stream
            slice and its (decoded) neighbour expansion. Reads only the
            immutable CSR, so it may overlap a superstep writing ``part_of``."""
            take = sharded.shards[s][cursor : cursor + chunk]
            if not take.shape[0]:
                return take, None, None
            tdegs = (indptr[take + 1] - indptr[take]).astype(np.int64)
            trows, _, tcols = _expand_csr_batch(indptr, indices, take, tdegs)
            return take, tdegs, (trows, tcols)

        def timed_scan(s: int, cursor: int):
            t0 = time.perf_counter()
            try:
                return scan(s, cursor)
            finally:
                stats.record_decode(time.perf_counter() - t0)

        def prefetch_scans():
            """Queue the next round's admission scans: once every ingest has
            returned, the round's cursors are final, so the next slices are
            known and can decode while the superstep scores and places."""
            ex = runner._prefetch_ex
            submit = ex.submit if ex is not None else runner.pool.submit
            for s in range(num_shards):
                if cursors[s] < sharded.shards[s].shape[0]:
                    adm[s] = (cursors[s], submit(timed_scan, s, cursors[s]))

        def ingest(s: int):
            """One shard's superstep ingest: admission scan + buffer churn.
            Touches only shard s's buffer/pending/cursor slots and reads the
            boundary-stable ``part_of``, so all S ingests run concurrently;
            per-shard counters come back for a deterministic main-thread sum.
            """
            cand = pending[s]
            pending[s] = []
            buf = bufs[s]
            pre = adm.pop(s, None)
            if pre is not None and pre[0] == cursors[s]:
                fut = pre[1]
                was_ready = fut.done()
                t0 = time.perf_counter()
                take, tdegs, texp = fut.result()
                stats.record_wait(time.perf_counter() - t0, was_ready)
            else:
                take, tdegs, texp = scan(s, cursors[s])
            cursors[s] += take.shape[0]
            evicted = drained_n = bypass_n = 0
            if take.shape[0]:
                trows, tcols = texp
                tparts = part_of[tcols]
                asg = np.bincount(
                    trows[tparts != -1], minlength=take.shape[0]
                )
                byp = tdegs >= d_max
                comp = (~byp) & (asg == tdegs) & (tdegs > 0)
                tl = take.tolist()
                al = asg.tolist()
                bypl = byp.tolist()
                compl = comp.tolist()
                if track:
                    toffs = np.zeros(take.shape[0] + 1, dtype=np.int64)
                    np.cumsum(tdegs, out=toffs[1:])
                for i in range(len(tl)):
                    if bypl[i]:
                        bypass_n += 1
                        cand.append(tl[i])
                    elif compl[i]:
                        cand.append(tl[i])
                    else:
                        buf.push(
                            tl[i],
                            None,
                            al[i],
                            tparts[toffs[i] : toffs[i + 1]] if track else None,
                        )
                while buf.full:
                    u, _ = buf.pop_best()
                    evicted += 1
                    cand.append(u)
            elif len(buf):
                # cursor exhausted: drain the buffer in score order,
                # chunk candidates per superstep
                for _ in range(max(chunk - len(cand), 0)):
                    if not len(buf):
                        break
                    u, _ = buf.pop_best()
                    drained_n += 1
                    cand.append(u)
            return (
                np.asarray(cand, dtype=np.int64),
                evicted, drained_n, bypass_n, len(buf),
            )

        def notify(s: int, placed_cols: np.ndarray, placed_parts=None):
            """Boundary: shard s's buffer learns about ALL placements.
            Mutates only shard s's buffer and pending slot."""
            buf = bufs[s]
            if not len(buf):
                return
            for w in buf.notify_many(placed_cols, placed_parts):
                buf.remove(w)
                pending[s].append(w)

        bstats = BufferStats()
        try:
            if prefetch_on:
                prefetch_scans()
            while True:
                t0 = time.perf_counter()
                results = [
                    f.result()
                    for f in [
                        runner.pool.submit(ingest, s) for s in range(num_shards)
                    ]
                ]
                runner.profile.add("prep", time.perf_counter() - t0)
                if prefetch_on:
                    prefetch_scans()
                batches = [r[0] for r in results]
                for _, ev, dr, by, blen in results:
                    bstats.evictions += ev
                    bstats.drained += dr
                    bstats.bypass += by
                    bstats.observe_len(blen)
                if all(b.shape[0] == 0 for b in batches):
                    exhausted = all(
                        cursors[s] >= sharded.shards[s].shape[0]
                        for s in range(num_shards)
                    )
                    if exhausted and not any(len(b) for b in bufs):
                        break
                    # everything ingested got buffered - still a superstep,
                    # no sync
                    runner.step += 1
                    continue
                res = runner.run_superstep(batches)
                cols, placed_parts = (
                    res if track and res is not None else (res, None)
                )
                if cols is not None and cols.size:
                    t1 = time.perf_counter()
                    for f in [
                        runner.pool.submit(notify, s, cols, placed_parts)
                        for s in range(num_shards)
                    ]:
                        f.result()
                    runner.profile.add("merge", time.perf_counter() - t1)
        finally:
            runner.close()
        eng.telemetry.update(bstats.to_telemetry(self.strategy))
        runner.finalize_telemetry()


# ------------------------------------------------------------------- engine
class StreamEngine:
    """Drives one streaming pass: ``scorer.begin`` then ``policy.run``.

    ``ids`` overrides the stream order (otherwise computed from
    ``order``/``seed``); ``subpartitioner`` hooks CUTTANA's Def. 2
    sub-placement into every commit; ``on_chunk_end(engine, batch,
    nbr_views)`` runs after each chunk in immediate mode (HeiStream's FM
    refinement uses it - mutate state there, then call
    ``engine.scorer.begin(engine.state)`` to refresh the penalty cache)."""

    def __init__(
        self,
        graph: CSRGraph,  # or any CSR read surface, e.g. ExternalCSRGraph
        state: PartitionState,
        scorer: Scorer,
        policy: PlacementPolicy,
        *,
        subpartitioner: SubPartitioner | None = None,
        order: str = "natural",
        seed: int = 0,
        ids: np.ndarray | None = None,
        config: EngineConfig | None = None,
        on_chunk_end: Callable[["StreamEngine", np.ndarray, list], None] | None = None,
    ):
        self.graph = graph
        self.state = state
        self.scorer = scorer
        self.policy = policy
        self.subp = subpartitioner
        self.config = config or EngineConfig()
        self.ids = stream_order(graph, order, seed) if ids is None else ids
        self.on_chunk_end = on_chunk_end
        # run counters consumed by repro.api's PartitionResult telemetry:
        # kernel_calls counts fused chunk-histogram calls, single_place_calls
        # the host-scored placements (buffered policy); policies add their own
        self.telemetry: dict = {"kernel_calls": 0, "single_place_calls": 0}
        self.prefetch_enabled, self.prefetch_ahead = _resolve_prefetch(
            self.config.prefetch, graph
        )
        self.prefetch_stats = PrefetchStats()
        self._sample_rng = np.random.default_rng(seed)
        self._pos = np.full(graph.num_vertices, -1, dtype=np.int64)
        self._zero_sizes = np.zeros(state.k, dtype=np.float32)
        self._use_kernel = kernel_active(self.config.use_pallas, self.config.interpret)

    def run(self) -> PartitionState:
        self.scorer.begin(self.state)
        self.policy.run(self)
        if self.prefetch_enabled:
            self.telemetry.update(self.prefetch_stats.to_telemetry())
        # a compressed indices proxy reports exact varint-decode wall time;
        # prefer it over the prefetcher's coarser fetch-wall aggregate
        decode_s = getattr(self.graph.indices, "decode_seconds", None)
        if decode_s is not None:
            self.telemetry["decode_wall_s"] = round(float(decode_s), 6)
        return self.state

    # ------------------------------------------------- per-vertex placement
    def place(self, v: int, nbrs: np.ndarray) -> int:
        """Score + place one vertex against the *fresh* state (used by the
        buffered policy, whose placement order is data-dependent)."""
        state = self.state
        self.telemetry["single_place_calls"] += 1
        hist = state.neighbor_histogram(nbrs)
        scores = self.scorer.scores(state, hist)
        allowed = ~state.would_overflow(nbrs.size)
        p = state.argmax_tiebreak(scores, allowed)
        state.assign(v, p, nbrs.size)
        self.scorer.on_assign(state, p, nbrs.size)
        if self.subp is not None:
            self.subp.assign(v, p, nbrs, nbrs.size)
        return p

    # --------------------------------------------------- chunked histograms
    def chunk_histograms(
        self,
        batch: np.ndarray,
        degs: np.ndarray,
        nbr_views: list[np.ndarray] | None = None,
        expanded: tuple | None = None,
    ):
        """All C x K assigned-neighbour histograms for a chunk via one fused
        kernel call.

        Returns ``(hist float64[C, K], corr)`` where ``corr`` is ``None`` in
        stale mode, else ``(dst, starts)``: for chunk position ``i``,
        ``dst[starts[i]:starts[i+1]]`` lists the later chunk positions that
        have ``batch[i]`` as a neighbour - the rows to bump when ``batch[i]``
        is assigned (the stale-histogram correction that makes exact mode
        bit-identical to the sequential loops). ``expanded`` is an optional
        precomputed :func:`_expand_csr_batch` result for the chunk - the
        prefetch pipeline passes it so a compressed graph is decoded once,
        not once per consumer."""
        cfg = self.config
        state = self.state
        c = batch.shape[0]
        if c == 0:
            return np.zeros((0, state.k), dtype=np.float64), None
        self.telemetry["kernel_calls"] += 1
        max_deg = int(degs.max())
        w = max(max_deg, 1)
        if not cfg.exact:
            w = min(w, cfg.sample_cap)
        indptr, indices = self.graph.indptr, self.graph.indices
        if expanded is None:
            expanded = _expand_csr_batch(indptr, indices, batch, degs)
        rows, idx_in_row, cols = expanded
        part_of = state.part_of
        scale = None
        sampled: list[tuple[int, np.ndarray]] = []
        if not cfg.exact and max_deg > w:
            scale = np.ones(c, dtype=np.float64)
            for i in np.flatnonzero(degs > w):
                # degree-capped sampling (Thm. 1 regime): exact counts matter
                # least for exactly these vertices
                if nbr_views is not None:
                    nb = nbr_views[i]
                else:
                    v = batch[i]
                    nb = indices[indptr[v] : indptr[v + 1]]
                sel = self._sample_rng.choice(nb.size, size=w, replace=False)
                sampled.append((int(i), part_of[nb[sel]]))
                scale[i] = nb.size / w
        if self._use_kernel:
            kw = w
            over: np.ndarray | None = None
            if cfg.exact and kw > _EXACT_KERNEL_WIDTH:
                # bound the dense [C, width] matrix: power-law hubs would
                # otherwise blow it up (one degree-500k vertex => ~1 GB).
                # The few over-width rows get exact host histograms below.
                kw = _EXACT_KERNEL_WIDTH
                over = np.flatnonzero(degs > kw)
            # pad the neighbour axis to a power of two >= 8 so kernel shapes
            # stay stable across chunks (padding is -1 and never counted)
            width = max(8, 1 << (kw - 1).bit_length())
            nbr_parts = np.full((c, width), -1, dtype=np.int32)
            if sampled or over is not None:
                fmask = (degs <= kw)[rows]
                nbr_parts[rows[fmask], idx_in_row[fmask]] = part_of[cols[fmask]]
                for i, nbp in sampled:
                    nbr_parts[i, :kw] = nbp
            else:
                nbr_parts[rows, idx_in_row] = part_of[cols]
            hist = np.asarray(
                fennel_scores(
                    nbr_parts, self._zero_sizes, 0.0, 1.5,
                    use_pallas=cfg.use_pallas, interpret=cfg.interpret,
                ),
                dtype=np.float64,
            )
            if over is not None:
                for i in over.tolist():
                    v = batch[i]
                    nbp = part_of[indices[indptr[v] : indptr[v + 1]]]
                    hist[i] = np.bincount(nbp[nbp >= 0], minlength=state.k)
        else:
            # CPU: flat bincount companion of the kernel, identical counts
            if sampled:
                fmask = (degs <= w)[rows]
                hist = neighbor_histograms_host(
                    rows[fmask], part_of[cols[fmask]], c, state.k
                )
                for i, nbp in sampled:
                    hist[i] = np.bincount(nbp[nbp >= 0], minlength=state.k)
            else:
                hist = neighbor_histograms_host(rows, part_of[cols], c, state.k)
        if scale is not None:
            hist *= scale[:, None]
        corr = self._inchunk_corr(batch, rows, cols) if cfg.exact else None
        return hist, corr

    def _inchunk_corr(self, batch: np.ndarray, rows: np.ndarray, cols: np.ndarray):
        """``(dst, starts)`` in-chunk correction lists for a candidate batch:
        for position ``i``, ``dst[starts[i]:starts[i+1]]`` are the later
        positions whose histograms must bump when ``batch[i]`` is assigned.
        ``rows``/``cols`` are the batch's flat (position, neighbour-id) pairs;
        shared by the sequential exact path and the per-shard superstep loop
        (where cross-shard pairs are deliberately absent - that staleness is
        the relaxed-consistency trade, surfaced as ``boundary_conflicts``)."""
        c = batch.shape[0]
        pos = self._pos
        pos[batch] = np.arange(c, dtype=np.int64)
        cpos = pos[cols]
        emask = (cpos >= 0) & (cpos < rows)
        pos[batch] = -1
        src = cpos[emask]
        dst = rows[emask]
        o = np.argsort(src, kind="stable")
        src, dst = src[o], dst[o]
        starts = np.searchsorted(src, np.arange(c + 1)).tolist()
        return (dst.tolist(), starts)
