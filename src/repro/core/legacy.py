"""Seed per-vertex reference loops, preserved for parity tests + benchmarks.

These are the pre-StreamEngine implementations of the streaming phase, kept
byte-for-byte in behaviour. The public modules (:mod:`repro.core.fennel`,
:mod:`repro.core.ldg`, :mod:`repro.core.cuttana`,
:mod:`repro.core.cuttana_batched`, :mod:`repro.core.heistream_like`,
:mod:`repro.core.restream`) now route through :mod:`repro.core.engine`;
``tests/test_engine.py`` asserts the engine reproduces these loops exactly,
and ``benchmarks/engine_compare.py`` measures the speedup against them.

Do not optimise this module - its whole value is being a fixed reference.
"""
from __future__ import annotations

import numpy as np

from repro.core.base import (
    FennelParams,
    PartitionState,
    finalize,
    make_fennel_score,
)
from repro.core.buffer import PriorityBuffer
from repro.core.refinement import Refiner, build_subpartition_graph
from repro.core.subpartition import SubPartitioner
from repro.graph.csr import CSRGraph
from repro.graph.stream import stream_order
from repro.kernels.partition_score.ops import fennel_scores


# ------------------------------------------------------------------- FENNEL
def fennel_partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "vertex",
    params: FennelParams | None = None,
    order: str = "natural",
    seed: int = 0,
) -> np.ndarray:
    params = params or FennelParams()
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    score_fn = make_fennel_score(graph, k, params, balance_mode)
    indptr, indices = graph.indptr, graph.indices
    for v in stream_order(graph, order, seed):
        nbrs = indices[indptr[v] : indptr[v + 1]]
        hist = state.neighbor_histogram(nbrs)
        scores = score_fn(state, hist)
        allowed = ~state.would_overflow(nbrs.size)
        p = state.argmax_tiebreak(scores, allowed)
        state.assign(int(v), p, nbrs.size)
    return finalize(state)


# ---------------------------------------------------------------------- LDG
def ldg_partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "vertex",
    order: str = "natural",
    seed: int = 0,
) -> np.ndarray:
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    indptr, indices = graph.indptr, graph.indices
    for v in stream_order(graph, order, seed):
        nbrs = indices[indptr[v] : indptr[v + 1]]
        hist = state.neighbor_histogram(nbrs)
        if balance_mode == "vertex":
            frac = state.v_counts / state.vertex_capacity
        else:
            frac = state.e_counts / state.edge_capacity
        scores = hist * np.maximum(1.0 - frac, 0.0)
        loads = state.v_counts if balance_mode == "vertex" else state.e_counts
        scores = scores - 1e-9 * loads
        allowed = ~state.would_overflow(nbrs.size)
        p = state.argmax_tiebreak(scores, allowed)
        state.assign(int(v), p, nbrs.size)
    return finalize(state)


# ------------------------------------------------------------------ CUTTANA
def cuttana_partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    d_max: int = 1000,
    max_qsize: int | None = None,
    theta: float = 1.0,
    subparts_per_partition: int | None = None,
    use_buffer: bool = True,
    use_refinement: bool = True,
    thresh: float = 0.0,
    max_moves: int | None = None,
    fennel_params: FennelParams | None = None,
    order: str = "natural",
    seed: int = 0,
) -> np.ndarray:
    """Seed CUTTANA (Algorithm 1 + phase-2), sequential per-vertex loop."""
    n = graph.num_vertices
    if max_qsize is None:
        max_qsize = max(1024, n // 10)
    if subparts_per_partition is None:
        subparts_per_partition = int(max(8, min(4096, n // (8 * k))))

    params = fennel_params or FennelParams(hybrid=(balance_mode == "edge"))
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    score_fn = make_fennel_score(graph, k, params, balance_mode)
    subp = SubPartitioner(
        graph,
        k,
        subparts_per_partition,
        epsilon=max(epsilon, 0.10),
        balance_mode=balance_mode,
        seed=seed,
    )
    indptr, indices = graph.indptr, graph.indices
    buf = PriorityBuffer(max_qsize, d_max, theta)

    def place(v: int, nbrs: np.ndarray) -> None:
        worklist = [(v, nbrs)]
        while worklist:
            u, un = worklist.pop()
            hist = state.neighbor_histogram(un)
            scores = score_fn(state, hist)
            allowed = ~state.would_overflow(un.size)
            p = state.argmax_tiebreak(scores, allowed)
            state.assign(u, p, un.size)
            subp.assign(u, p, un, un.size)
            for w in un:
                wi = int(w)
                if buf.contains(wi) and buf.notify_assigned(wi):
                    worklist.append((wi, buf.remove(wi)))

    if not use_buffer:
        for v in stream_order(graph, order, seed):
            place(int(v), indices[indptr[v] : indptr[v + 1]])
    else:
        for v in stream_order(graph, order, seed):
            v = int(v)
            if state.part_of[v] != -1:
                continue
            nbrs = indices[indptr[v] : indptr[v + 1]]
            if nbrs.size >= d_max:
                place(v, nbrs)
                continue
            assigned = int((state.part_of[nbrs] != -1).sum())
            if assigned == nbrs.size and nbrs.size > 0:
                place(v, nbrs)
                continue
            buf.push(v, nbrs, assigned)
            if buf.full:
                u, un = buf.pop_best()
                place(u, un)
        while len(buf):
            u, un = buf.pop_best()
            place(u, un)

    part = finalize(state)
    if use_refinement and k > 1:
        w = build_subpartition_graph(graph, subp.sub_of, subp.kp)
        sub_part = np.repeat(np.arange(k, dtype=np.int64), subp.s)
        if balance_mode == "edge":
            size, total = subp.sub_e_counts.copy(), float(graph.indices.shape[0])
        else:
            size, total = subp.sub_v_counts.copy(), float(n)
        refiner = Refiner(w, sub_part, size, k, epsilon, total_mass=total)
        refiner.refine(thresh=thresh, max_moves=max_moves)
        part = refiner.sub_part[subp.sub_of].astype(np.int32)
    return part


# ---------------------------------------------------------- CUTTANA batched
def cuttana_batched_partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    chunk: int = 512,
    sample_cap: int = 512,
    use_refinement: bool = True,
    subparts_per_partition: int | None = None,
    thresh: float = 0.0,
    order: str = "natural",
    seed: int = 0,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> np.ndarray:
    """Seed chunk-parallel variant: kernel histograms, stale by one chunk."""
    n = graph.num_vertices
    m = max(graph.num_edges, 1)
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    if subparts_per_partition is None:
        subparts_per_partition = int(max(8, min(4096, n // (8 * k))))
    subp = SubPartitioner(
        graph, k, subparts_per_partition,
        epsilon=max(epsilon, 0.10), balance_mode=balance_mode, seed=seed,
    )
    params = FennelParams(hybrid=(balance_mode == "edge"))
    alpha = params.alpha_scale * np.sqrt(k) * m / (max(n, 1) ** 1.5)
    gamma = params.gamma
    mu = n / max(graph.indices.shape[0], 1)
    rng = np.random.default_rng(seed)
    indptr, indices = graph.indptr, graph.indices
    ids = stream_order(graph, order, seed)

    for start in range(0, n, chunk):
        batch = ids[start : start + chunk]
        c = len(batch)
        degs = (indptr[batch + 1] - indptr[batch]).astype(np.int64)
        width = int(min(max(degs.max(), 1), sample_cap))
        nbr_parts = np.full((c, width), -1, dtype=np.int32)
        scale = np.ones(c, dtype=np.float64)
        nbr_cache: list[np.ndarray] = []
        for i, v in enumerate(batch):
            nb = indices[indptr[v] : indptr[v + 1]]
            nbr_cache.append(nb)
            if nb.size > width:
                sel = rng.choice(nb.size, size=width, replace=False)
                nbp = state.part_of[nb[sel]]
                scale[i] = nb.size / width
            else:
                nbp = state.part_of[nb]
            nbr_parts[i, : nbp.size] = nbp
        sizes = np.zeros(k, np.float32)
        hist = np.asarray(
            fennel_scores(
                nbr_parts, sizes, 0.0, gamma,
                use_pallas=use_pallas, interpret=interpret,
            ),
            dtype=np.float64,
        ) * scale[:, None]
        for i, v in enumerate(batch):
            if params.hybrid:
                size = 0.5 * (state.v_counts + mu * state.e_counts)
            else:
                size = state.v_counts
            scores = hist[i] - alpha * gamma * np.power(
                np.maximum(size, 0.0), gamma - 1.0
            )
            allowed = ~state.would_overflow(int(degs[i]))
            p = state.argmax_tiebreak(scores, allowed)
            state.assign(int(v), p, int(degs[i]))
            subp.assign(int(v), p, nbr_cache[i], int(degs[i]))

    part = finalize(state)
    if use_refinement and k > 1:
        w = build_subpartition_graph(graph, subp.sub_of, subp.kp)
        sub_part = np.repeat(np.arange(k, dtype=np.int64), subp.s)
        if balance_mode == "edge":
            size, total = subp.sub_e_counts, float(graph.indices.shape[0])
        else:
            size, total = subp.sub_v_counts, float(n)
        r = Refiner(w, sub_part, size, k, epsilon, total_mass=total)
        r.refine(thresh=thresh)
        part = r.sub_part[subp.sub_of].astype(np.int32)
    return part


# ---------------------------------------------------------------- HeiStream
def heistream_partition(
    graph: CSRGraph,
    k: int,
    epsilon: float = 0.05,
    balance_mode: str = "vertex",
    batch_size: int = 4096,
    fm_passes: int = 3,
    order: str = "natural",
    seed: int = 0,
) -> np.ndarray:
    state = PartitionState.create(graph, k, epsilon, balance_mode, seed)
    score_fn = make_fennel_score(
        graph, k, FennelParams(hybrid=(balance_mode == "edge")), balance_mode
    )
    indptr, indices = graph.indptr, graph.indices
    rng = np.random.default_rng(seed)
    ids = stream_order(graph, order, seed)

    for start in range(0, len(ids), batch_size):
        batch = [int(v) for v in ids[start : start + batch_size]]
        nbrs_of = {v: indices[indptr[v] : indptr[v + 1]] for v in batch}
        for v in batch:
            nbrs = nbrs_of[v]
            hist = state.neighbor_histogram(nbrs)
            scores = score_fn(state, hist)
            allowed = ~state.would_overflow(nbrs.size)
            p = state.argmax_tiebreak(scores, allowed)
            state.assign(v, p, nbrs.size)
        for _ in range(fm_passes):
            moved = 0
            for v in rng.permutation(batch):
                v = int(v)
                nbrs = nbrs_of[v]
                deg = nbrs.size
                cur = int(state.part_of[v])
                hist = state.neighbor_histogram(nbrs)
                gains = hist - hist[cur]
                if balance_mode == "vertex":
                    over = state.v_counts + 1 > state.vertex_capacity
                else:
                    over = state.e_counts + deg > state.edge_capacity
                over[cur] = False
                gains = np.where(over, -np.inf, gains)
                best = int(gains.argmax())
                if best != cur and gains[best] > 0:
                    state.part_of[v] = best
                    state.v_counts[cur] -= 1
                    state.v_counts[best] += 1
                    state.e_counts[cur] -= deg
                    state.e_counts[best] += deg
                    moved += 1
            if moved == 0:
                break
    return finalize(state)


# ---------------------------------------------------------------- restream
def restream_partition(
    graph: CSRGraph,
    k: int,
    passes: int = 3,
    base: str = "cuttana",
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    final_refine: bool = True,
    order: str = "random",
    seed: int = 0,
) -> np.ndarray:
    from repro.core import get_partitioner
    from repro.core.cuttana import refine_any

    part = get_partitioner(base)(
        graph, k, epsilon=epsilon, balance_mode=balance_mode,
        order=order, seed=seed,
    )
    indptr, indices = graph.indptr, graph.indices
    deg = graph.degrees
    params = FennelParams(hybrid=(balance_mode == "edge"))
    for p in range(1, passes):
        state = PartitionState.create(graph, k, epsilon, balance_mode, seed + p)
        state.part_of[:] = part
        state.v_counts[:] = np.bincount(part, minlength=k)
        state.e_counts[:] = np.bincount(
            part, weights=deg.astype(np.float64), minlength=k
        )
        score_fn = make_fennel_score(graph, k, params, balance_mode)
        for v in stream_order(graph, order, seed + p):
            v = int(v)
            d = int(deg[v])
            cur = int(state.part_of[v])
            state.v_counts[cur] -= 1
            state.e_counts[cur] -= d
            nbrs = indices[indptr[v] : indptr[v + 1]]
            hist = state.neighbor_histogram(nbrs)
            scores = score_fn(state, hist)
            allowed = ~state.would_overflow(d)
            allowed[cur] = True
            new = state.argmax_tiebreak(scores, allowed)
            state.part_of[v] = new
            state.v_counts[new] += 1
            state.e_counts[new] += d
        part = state.part_of.copy()
    if final_refine and k > 1:
        part = refine_any(
            graph, part, k, epsilon=epsilon, balance_mode=balance_mode,
            seed=seed,
        )
    return part
