"""Random / hash vertex partitioners (the trivial baselines)."""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def partition_random(graph: CSRGraph, k: int, seed: int = 0, **_) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, k, size=graph.num_vertices, dtype=np.int64).astype(np.int32)


def partition_hash(graph: CSRGraph, k: int, **_) -> np.ndarray:
    # splitmix-style integer hash for a deterministic spread
    v = np.arange(graph.num_vertices, dtype=np.uint64)
    v = (v ^ (v >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    v = (v ^ (v >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    v = v ^ (v >> np.uint64(31))
    return (v % np.uint64(k)).astype(np.int32)


def partition_chunked(graph: CSRGraph, k: int, **_) -> np.ndarray:
    """Contiguous id ranges - strong locality baseline (range partitioning)."""
    n = graph.num_vertices
    bounds = np.linspace(0, n, k + 1).astype(np.int64)
    part = np.zeros(n, dtype=np.int32)
    for i in range(k):
        part[bounds[i] : bounds[i + 1]] = i
    return part
