"""Restreaming (Nishimura & Ugander; Awadelkarim & Ugander) with CUTTANA as
the core partitioner - the paper's Related-Work positioning: "CUTTANA can be
used in restreaming as the core partitioner for faster convergence".

Pass 1 runs any registered partitioner; passes 2..n re-stream vertices with
the FULL previous assignment visible (no premature-assignment problem at
all), reassigning each vertex greedily under the balance condition; an
optional final refinement pass applies phase-2 trades.
"""
from __future__ import annotations

import numpy as np

from repro.core import get_partitioner
from repro.core.base import FennelParams, PartitionState, make_fennel_score
from repro.core.cuttana import refine_any
from repro.graph.csr import CSRGraph
from repro.graph.stream import stream_order


def partition_restream(
    graph: CSRGraph,
    k: int,
    passes: int = 3,
    base: str = "cuttana",
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    final_refine: bool = True,
    order: str = "random",
    seed: int = 0,
) -> np.ndarray:
    part = get_partitioner(base)(
        graph, k, epsilon=epsilon, balance_mode=balance_mode,
        order=order, seed=seed,
    )
    indptr, indices = graph.indptr, graph.indices
    deg = graph.degrees
    params = FennelParams(hybrid=(balance_mode == "edge"))
    for p in range(1, passes):
        state = PartitionState.create(graph, k, epsilon, balance_mode, seed + p)
        state.part_of[:] = part
        state.v_counts[:] = np.bincount(part, minlength=k)
        state.e_counts[:] = np.bincount(
            part, weights=deg.astype(np.float64), minlength=k
        )
        score_fn = make_fennel_score(graph, k, params, balance_mode)
        for v in stream_order(graph, order, seed + p):
            v = int(v)
            d = int(deg[v])
            cur = int(state.part_of[v])
            # remove v, score against the full assignment, reinsert
            state.v_counts[cur] -= 1
            state.e_counts[cur] -= d
            nbrs = indices[indptr[v] : indptr[v + 1]]
            hist = state.neighbor_histogram(nbrs)
            scores = score_fn(state, hist)
            allowed = ~state.would_overflow(d)
            allowed[cur] = True  # staying put never violates balance
            new = state.argmax_tiebreak(scores, allowed)
            state.part_of[v] = new
            state.v_counts[new] += 1
            state.e_counts[new] += d
        part = state.part_of.copy()
    if final_refine and k > 1:
        part = refine_any(
            graph, part, k, epsilon=epsilon, balance_mode=balance_mode,
            seed=seed,
        )
    return part
