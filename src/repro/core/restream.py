"""Restreaming (Nishimura & Ugander; Awadelkarim & Ugander) with CUTTANA as
the core partitioner - the paper's Related-Work positioning: "CUTTANA can be
used in restreaming as the core partitioner for faster convergence".

Pass 1 runs any registered partitioner; passes 2..n re-stream vertices with
the FULL previous assignment visible (no premature-assignment problem at
all), reassigning each vertex greedily under the balance condition; an
optional final refinement pass applies phase-2 trades.

Each re-pass is a :class:`repro.core.engine.StreamEngine` run with
``ShardedImmediatePolicy(reassign=True)``: ``num_shards=1`` (the default) is
*defined* as the sequential ``ImmediatePolicy(reassign=True)`` - chunked
kernel scoring with exact move corrections, bit-identical to the seed loop
in :mod:`repro.core.legacy` - while ``num_shards>=2`` gives restream passes
the same S-shard bulk-synchronous superstep speedup as ``cuttana-parallel``
(one packed kernel call scores every shard's frontier per superstep).
"""
from __future__ import annotations

import time

import numpy as np

from repro.api.registry import get_info
from repro.core import autotune
from repro.core.base import FennelParams, PartitionState
from repro.core.cuttana import refine_any
from repro.core.engine import (
    EngineConfig,
    FennelScorer,
    ShardedImmediatePolicy,
    StreamEngine,
    _check_num_shards,
)
from repro.graph.csr import CSRGraph


def partition_restream(
    graph: CSRGraph,
    k: int,
    passes: int = 3,
    base: str = "cuttana",
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    final_refine: bool = True,
    order: str = "random",
    seed: int = 0,
    chunk: int = 512,
    num_shards: int = 1,
    max_workers: int = 0,
    use_pallas: bool | None = None,
    interpret: bool = False,
    telemetry: dict | None = None,
) -> np.ndarray:
    # validate eagerly: with passes=1 no re-pass engine is ever built, and
    # with passes>=2 a late failure would waste the whole base partition.
    # num_shards=0 resolves through the auto-tuner like the parallel algos.
    if int(num_shards) == 0:
        num_shards = autotune.resolve(
            0, chunk, algo="restream", num_vertices=graph.num_vertices
        ).num_shards
    num_shards = _check_num_shards(num_shards)
    t0 = time.perf_counter()
    base_info = get_info(base, kind="edge-cut")
    base_telemetry: dict = {}
    base_kwargs = {"telemetry": base_telemetry} if base_info.telemetry else {}
    part = base_info.resolve()(
        graph, k, epsilon=epsilon, balance_mode=balance_mode,
        order=order, seed=seed, **base_kwargs,
    )
    base_s = time.perf_counter() - t0
    kernel_calls = base_telemetry.get("kernel_calls", 0)
    t0 = time.perf_counter()
    deg = graph.degrees
    params = FennelParams(hybrid=(balance_mode == "edge"))
    for p in range(1, passes):
        state = PartitionState.create(graph, k, epsilon, balance_mode, seed + p)
        state.part_of[:] = part
        state.v_counts[:] = np.bincount(part, minlength=k)
        state.e_counts[:] = np.bincount(
            part, weights=deg.astype(np.float64), minlength=k
        )
        engine = StreamEngine(
            graph,
            state,
            FennelScorer(graph, k, params, balance_mode),
            ShardedImmediatePolicy(num_shards, reassign=True),
            order=order,
            seed=seed + p,
            config=EngineConfig(
                chunk=chunk, use_pallas=use_pallas, interpret=interpret,
                max_workers=max_workers,
            ),
        )
        engine.run()
        kernel_calls += engine.telemetry["kernel_calls"]
        part = state.part_of.copy()
    stream_s = time.perf_counter() - t0
    t1 = time.perf_counter()
    if final_refine and k > 1:
        part = refine_any(
            graph, part, k, epsilon=epsilon, balance_mode=balance_mode,
            seed=seed,
        )
    if telemetry is not None:
        telemetry.update(
            passes=passes,
            base=base,
            num_shards=num_shards,
            kernel_calls=kernel_calls,
            base_seconds=base_s,
            stream_seconds=stream_s,
            refine_seconds=time.perf_counter() - t1,
        )
        if base_telemetry:
            # the base run's full counters (buffer evictions, refine moves,
            # ...) survive namespaced; kernel_calls above already sums them
            telemetry["base_telemetry"] = {
                key: val for key, val in base_telemetry.items()
                if not key.endswith("_seconds")
            }
    return part
