"""Restreaming (Nishimura & Ugander; Awadelkarim & Ugander) with CUTTANA as
the core partitioner - the paper's Related-Work positioning: "CUTTANA can be
used in restreaming as the core partitioner for faster convergence".

Pass 1 runs any registered partitioner; passes 2..n re-stream vertices with
the FULL previous assignment visible (no premature-assignment problem at
all), reassigning each vertex greedily under the balance condition; an
optional final refinement pass applies phase-2 trades.

Each re-pass is a :class:`repro.core.engine.StreamEngine` run with
``ImmediatePolicy(reassign=True)`` - chunked kernel scoring with exact
move corrections, bit-identical to the seed loop in
:mod:`repro.core.legacy`.
"""
from __future__ import annotations

import numpy as np

from repro.core import get_partitioner
from repro.core.base import FennelParams, PartitionState
from repro.core.cuttana import refine_any
from repro.core.engine import EngineConfig, FennelScorer, ImmediatePolicy, StreamEngine
from repro.graph.csr import CSRGraph


def partition_restream(
    graph: CSRGraph,
    k: int,
    passes: int = 3,
    base: str = "cuttana",
    epsilon: float = 0.05,
    balance_mode: str = "edge",
    final_refine: bool = True,
    order: str = "random",
    seed: int = 0,
    chunk: int = 512,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> np.ndarray:
    part = get_partitioner(base)(
        graph, k, epsilon=epsilon, balance_mode=balance_mode,
        order=order, seed=seed,
    )
    deg = graph.degrees
    params = FennelParams(hybrid=(balance_mode == "edge"))
    for p in range(1, passes):
        state = PartitionState.create(graph, k, epsilon, balance_mode, seed + p)
        state.part_of[:] = part
        state.v_counts[:] = np.bincount(part, minlength=k)
        state.e_counts[:] = np.bincount(
            part, weights=deg.astype(np.float64), minlength=k
        )
        engine = StreamEngine(
            graph,
            state,
            FennelScorer(graph, k, params, balance_mode),
            ImmediatePolicy(reassign=True),
            order=order,
            seed=seed + p,
            config=EngineConfig(
                chunk=chunk, use_pallas=use_pallas, interpret=interpret
            ),
        )
        engine.run()
        part = state.part_of.copy()
    if final_refine and k > 1:
        part = refine_any(
            graph, part, k, epsilon=epsilon, balance_mode=balance_mode,
            seed=seed,
        )
    return part
