"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch repro-100m --steps 200

Features exercised even at CPU smoke scale:
  * sharded params/optimizer via NamedSharding (any mesh),
  * jitted train_step with donated state,
  * async atomic checkpoints every --ckpt-every steps, keep-N,
  * crash-restart: --fail-at N raises mid-run; rerunning with the same
    --ckpt-dir resumes from the latest checkpoint (data pipeline included),
  * elastic re-mesh: checkpoints are host arrays, so a restart may use a
    different mesh/device count (see launch/elastic.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import use_mesh
from repro.configs import ALIASES, get_config, get_reduced_config
from repro.models import Axes, Model
from repro.models.config import LayerSpec, ModelConfig
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.train.data import TokenPipeline
from repro.train.optimizer import adamw_init, adamw_state_specs
from repro.train.step import make_train_step


def repro_100m() -> ModelConfig:
    """~100M-param llama-style model for the end-to-end example."""
    return ModelConfig(
        name="repro-100m",
        family="dense",
        d_model=640,
        vocab_size=32768,
        block=(LayerSpec("attn", "dense"),),
        n_blocks=10,
        n_heads=10,
        n_kv_heads=5,
        d_ff=1792,
        activation="swiglu",
        remat=False,
    )


def build_mesh(spec: str) -> Mesh:
    dims = [int(x) for x in spec.split("x")]
    n = int(np.prod(dims))
    devs = np.array(jax.devices()[:n]).reshape(dims)
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    return Mesh(devs, names)


def get_model_config(name: str) -> ModelConfig:
    if name == "repro-100m":
        return repro_100m()
    if name.startswith("reduced:"):
        return get_reduced_config(name.split(":", 1)[1])
    return get_config(name)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash after this step (fault-tolerance demo)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch)
    mesh = build_mesh(args.mesh)
    dp = ("data",) if "pod" not in mesh.axis_names else ("pod", "data")
    ax = Axes(dp=dp, tp="model")
    model = Model(cfg, ax, mesh)
    train_step = make_train_step(
        model, peak_lr=args.lr, warmup=min(100, args.steps // 10 + 1),
        total_steps=args.steps,
    )

    pspecs = model.param_specs()
    with use_mesh(mesh):
        params = jax.jit(
            model.init,
            out_shardings=jax.tree.map(
                lambda s: NamedSharding(mesh, s), pspecs,
                is_leaf=lambda x: isinstance(x, P),
            ),
        )(jax.random.key(0))
        opt_state = adamw_init(params, jnp.dtype(cfg.opt_state_dtype))

    start_step = 0
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params_h, opt_h), start_step = restore_checkpoint(
            args.ckpt_dir, (params, opt_state)
        )
        put = lambda tree, host: jax.tree.map(
            lambda x, h: jax.device_put(jnp.asarray(h), x.sharding), tree, host
        )
        params = put(params, params_h)
        opt_state = put(opt_state, opt_h)
        print(f"[restore] resumed from step {start_step}")

    pipe = TokenPipeline(
        cfg.vocab_size, args.seq_len, args.global_batch, seed=1234
    )
    pipe.skip_to(start_step)
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    t0 = time.time()
    tokens_done = 0
    with use_mesh(mesh):
        for step in range(start_step, args.steps):
            batch = next(pipe)
            batch = {
                k: jax.device_put(v, NamedSharding(mesh, P(dp, None)))
                for k, v in batch.items()
            }
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            tokens_done += args.global_batch * args.seq_len
            if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
                m = {k: float(v) for k, v in metrics.items()}
                tps = tokens_done / max(time.time() - t0, 1e-9)
                print(
                    f"step {step+1:5d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                    f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} tok/s={tps:,.0f}",
                    flush=True,
                )
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state))
            if args.fail_at is not None and step + 1 == args.fail_at:
                if ckpt:
                    ckpt.wait()
                raise RuntimeError(
                    f"[injected failure] node died at step {step+1}; "
                    f"rerun with the same --ckpt-dir to resume"
                )
    if ckpt:
        ckpt.save(args.steps, (params, opt_state))
        ckpt.wait()
    pipe.close()
    print("[done]")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
