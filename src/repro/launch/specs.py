"""ShapeDtypeStruct builders for the dry-run: weak-type-correct, shardable,
zero allocation. Includes divisibility sanitization (a dim that does not
divide its mesh axes falls back to replicated - e.g. hubert's vocab of 504
on a 16-way model axis) and the analytic MODEL_FLOPS used by the roofline.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def sanitize_spec(shape, spec: P, mesh: Mesh) -> P:
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = math.prod(int(mesh.shape[a]) for a in axes)
        if i < len(shape) and shape[i] % size == 0:
            out.append(entry)
        else:
            out.append(None)
    # pad missing dims with None
    while len(out) < len(shape):
        out.append(None)
    return P(*out)


def sds(shape, dtype, spec: P, mesh: Mesh) -> jax.ShapeDtypeStruct:
    spec = sanitize_spec(shape, spec, mesh)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def sds_tree(shapes_tree, specs_tree, mesh: Mesh):
    def one(s, p):
        return sds(s.shape, s.dtype, p, mesh)

    return jax.tree.map(
        one, shapes_tree, specs_tree, is_leaf=lambda x: isinstance(x, P)
    )


def batch_specs(cfg: ModelConfig, shape: dict, mesh: Mesh, dp, accum: int = 1):
    """Training/prefill batch ShapeDtypeStructs. ``shape``: SHAPES[name]."""
    b, s = shape["global_batch"], shape["seq_len"]
    out = {}
    lead = (accum, b // accum) if accum > 1 else (b,)
    lead_spec = (None, dp) if accum > 1 else (dp,)
    if cfg.frontend == "frames":
        out["frames"] = sds(
            (*lead, s, cfg.d_model), jnp.bfloat16, P(*lead_spec, None, None), mesh
        )
    else:
        out["tokens"] = sds((*lead, s), jnp.int32, P(*lead_spec, None), mesh)
    out["labels"] = sds((*lead, s), jnp.int32, P(*lead_spec, None), mesh)
    if cfg.n_img_tokens:
        out["image_embeds"] = sds(
            (*lead, cfg.n_img_tokens, cfg.d_model),
            jnp.bfloat16,
            P(*lead_spec, None, None),
            mesh,
        )
    return out


def pick_accum(cfg: ModelConfig, shape: dict, n_dp: int,
               target_bytes: float = 2.5e9, n_tp: int = 1) -> int:
    """Gradient-accumulation factor keeping the scan-carry activation
    footprint (microbatch x seq x d_model x 2B x n_blocks per device) under
    ``target_bytes``. Sequence-parallel activations divide the footprint by
    the TP size (pass n_tp)."""
    b, s = shape["global_batch"], shape["seq_len"]
    per_dev = max(b // n_dp, 1)
    accum = 1
    while accum < per_dev:
        mb = per_dev / accum
        footprint = mb * s * cfg.d_model * 2 * max(cfg.n_blocks, 1) / max(n_tp, 1)
        if footprint <= target_bytes:
            break
        accum *= 2
    # accum must divide the global batch and keep microbatch % n_dp == 0
    while accum > 1 and (b % accum or (b // accum) % n_dp):
        accum //= 2
    return accum


def analytic_model_flops(cfg: ModelConfig, shape: dict, kind: str) -> dict:
    """MODEL_FLOPS: 6*N*D (train) / 2*N*D (inference) with N = active params,
    plus attention-score FLOPs (which param counts miss)."""
    b, s = shape["global_batch"], shape["seq_len"]
    n_active = cfg.active_param_count()
    dh = cfg.head_dim
    att = 0.0
    for spec in cfg.layers():
        if spec.mixer == "attn":
            eff = min(spec.window, s) if spec.window else s
            if kind == "decode":
                # one token attends over the cache
                att += 2 * 2 * b * cfg.n_heads * dh * eff
            else:
                avg_ctx = eff / 2 if spec.window is None else eff
                att += 2 * 2 * b * s * cfg.n_heads * dh * avg_ctx
        elif spec.mixer == "cross_attn":
            tq = 1 if kind == "decode" else s
            att += 2 * 2 * b * tq * cfg.n_heads * dh * cfg.n_img_tokens
    if kind == "train":
        tokens = b * s
        flops = 6.0 * n_active * tokens + 3.0 * att
    elif kind == "prefill":
        tokens = b * s
        flops = 2.0 * n_active * tokens + att
    else:  # decode: one token per sequence
        tokens = b
        flops = 2.0 * n_active * tokens + att
    return {"model_flops": flops, "tokens": tokens, "active_params": n_active}
