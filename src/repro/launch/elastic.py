"""Elastic re-meshing demo: train on mesh A, crash, resume on mesh B.

Checkpoints store host arrays (mesh-agnostic), so resuming on a different
device count only changes the NamedShardings applied at device_put. This is
the recovery path when a pod (or slice) is lost: re-mesh to the surviving
slice, restore, continue.

    PYTHONPATH=src python -m repro.launch.elastic --ckpt-dir /tmp/elastic
"""
from __future__ import annotations

import argparse
import os
import tempfile

from repro.launch import train as train_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args(argv)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="elastic_")

    half = args.steps // 2
    print(f"[elastic] phase 1: mesh 1x1 for {half} steps")
    try:
        train_mod.main([
            "--arch", "repro-100m", "--steps", str(args.steps),
            "--global-batch", "8", "--seq-len", "128",
            "--mesh", "1x1", "--ckpt-dir", ckpt,
            "--ckpt-every", "10", "--fail-at", str(half),
        ])
    except RuntimeError as e:
        print(f"[elastic] caught: {e}")

    n = len(__import__("jax").devices())
    mesh2 = "1x2" if n >= 2 else "1x1"
    print(f"[elastic] phase 2: resume on mesh {mesh2} (survivors)")
    loss = train_mod.main([
        "--arch", "repro-100m", "--steps", str(args.steps),
        "--global-batch", "8", "--seq-len", "128",
        "--mesh", mesh2, "--ckpt-dir", ckpt, "--ckpt-every", "10",
    ])
    print(f"[elastic] recovered and finished; final loss {loss:.4f}")
    return loss


if __name__ == "__main__":
    main()
