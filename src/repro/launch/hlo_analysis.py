"""Compiled-HLO analysis for the roofline.

``compiled.cost_analysis()`` gives FLOPs/bytes but (a) no collective traffic
and (b) counts while-loop bodies ONCE regardless of trip count (verified
empirically) - fatal for scanned-layer models. So we parse the compiled HLO
text ourselves:

  * split into computations, build the call graph,
  * recover while trip counts from ``backend_config known_trip_count``
    (fallback: the condition's comparison constant),
  * propagate loop multipliers to transitively-called computations,
  * collective term: sum result bytes of every all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, x multiplier,
  * compute term: sum 2*prod(result_dims)*prod(contracting_dims) over every
    dot, x multiplier (a per-shard MXU FLOPs count).
"""
from __future__ import annotations

import json
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME = r"[\w\.\-~]+"
_DEF_RE = re.compile(rf"^\s*%?({_NAME})\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m or not m.group(2).strip():
        return []
    return [int(d) for d in m.group(2).split(",")]


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    size = _DTYPE_BYTES.get(dt, 4)
    for d in dims.split(","):
        if d:
            size *= int(d)
    return size


def _split_top(s: str) -> list[str]:
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            depth += ch in "({["
            depth -= ch in ")}]"
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _result_type(rhs: str) -> str:
    """Leading type expression of an instruction RHS."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                return rhs[: i + 1]
    return rhs.split(" ", 1)[0]


def _type_bytes(type_str: str) -> int:
    type_str = type_str.strip()
    if type_str.startswith("("):
        return sum(_shape_bytes(p) for p in _split_top(type_str[1:-1]))
    return _shape_bytes(type_str)


class HloModule:
    def __init__(self, hlo: str):
        self.comps: dict[str, list[str]] = {}
        self.defs: dict[str, dict[str, str]] = {}  # comp -> name -> type str
        cur = None
        for line in hlo.splitlines():
            stripped = line.strip()
            if stripped == "}":
                cur = None
                continue
            if (
                line.rstrip().endswith("{")
                and "(" in line
                and "=" not in line.split("(", 1)[0]
            ):
                m = re.match(rf"\s*(?:ENTRY\s+)?%?({_NAME})", line)
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    self.defs[cur] = {}
                    continue
            if cur is None:
                continue
            self.comps[cur].append(line)
            dm = _DEF_RE.match(line)
            if dm:
                self.defs[cur][dm.group(1)] = _result_type(dm.group(2))
        self.mult = self._multipliers()

    # ------------------------------------------------------------ call graph
    def _multipliers(self) -> dict[str, float]:
        calls: dict[str, list[tuple[str, float]]] = defaultdict(list)
        called_by: dict[str, set] = defaultdict(set)
        for name, lines in self.comps.items():
            for line in lines:
                if " while(" in line and "body=" in line:
                    body = re.search(rf"body=%?({_NAME})", line).group(1)
                    cond = re.search(rf"condition=%?({_NAME})", line).group(1)
                    tm = _TRIP_RE.search(line)
                    if tm:
                        tc = float(tm.group(1))
                    else:
                        tc = float(self._cond_trip(cond))
                    calls[name] += [(body, tc), (cond, tc)]
                    called_by[body].add(name)
                    called_by[cond].add(name)
                    continue
                for attr in ("to_apply=", "calls=", "called_computations={",
                             "body=", "condition="):
                    for m in re.finditer(re.escape(attr) + rf"%?({_NAME})", line):
                        calls[name].append((m.group(1), 1.0))
                        called_by[m.group(1)].add(name)
        roots = [n for n in self.comps if n not in called_by]
        mult: dict[str, float] = {}

        def visit(name: str, m: float):
            if name in mult and mult[name] >= m:
                return
            mult[name] = max(m, mult.get(name, 0.0))
            for child, k in calls.get(name, []):
                if child != name:
                    visit(child, m * k)

        for r in roots:
            visit(r, 1.0)
        return mult

    def _cond_trip(self, cond: str) -> int:
        best = 1
        for line in self.comps.get(cond, []):
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
        return best

    # ----------------------------------------------------------- collectives
    def collectives(self) -> dict:
        per_op: dict[str, float] = defaultdict(float)
        counts: dict[str, int] = defaultdict(int)
        for name, lines in self.comps.items():
            m = self.mult.get(name, 1.0)
            for line in lines:
                for op in COLLECTIVE_OPS:
                    if re.search(rf"\b{op}(?:-start)?\(", line):
                        dm = _DEF_RE.match(line)
                        if not dm:
                            continue
                        b = _type_bytes(_result_type(dm.group(2)))
                        per_op[op] += b * m
                        counts[op] += 1
                        break
        return {
            "collective_bytes": dict(per_op),
            "collective_counts": dict(counts),
            "total_collective_bytes": float(sum(per_op.values())),
        }

    # ------------------------------------------------------------------ dots
    def dot_flops(self) -> float:
        total = 0.0
        for name, lines in self.comps.items():
            m = self.mult.get(name, 1.0)
            table = self.defs.get(name, {})
            for line in lines:
                if " dot(" not in line:
                    continue
                dm = _DEF_RE.match(line)
                if not dm:
                    continue
                res_t = _result_type(dm.group(2))
                res_elems = math.prod(_dims(res_t)) if "[" in res_t else 0
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                if not cm:
                    continue
                lhs_dims = self._dot_lhs_dims(line, table)
                contract = [int(i) for i in cm.group(1).split(",") if i]
                try:
                    k_prod = math.prod(lhs_dims[i] for i in contract)
                except IndexError:
                    k_prod = 1
                total += 2.0 * res_elems * k_prod * m
        return total

    @staticmethod
    def _dot_lhs_dims(line: str, table: dict[str, str]) -> list[int]:
        """Shape dims of a dot's lhs operand.

        Newer HLO text annotates every operand with its type inline
        (``dot(f32[64,32]{1,0} %lhs, ...)``), which is authoritative;
        older text has bare operand names (``dot(%lhs, ...)``), which we
        resolve through the computation's definition table. The old regex
        grabbed the first token after ``dot(`` - in the new format that's
        the dtype, so the lhs lookup silently failed and every contracting
        dimension collapsed to 1.
        """
        start = line.find("dot(")
        if start < 0:
            return []
        args, depth = [], 0
        for i in range(start + len("dot("), len(line)):
            ch = line[i]
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    args = _split_top(line[start + len("dot(") : i])
                    break
                depth -= 1
        if not args:
            return []
        lhs = args[0].strip()
        sm = _SHAPE_RE.search(lhs)  # inline operand type wins
        if sm:
            return _dims(sm.group(0))
        nm = re.search(rf"%?({_NAME})\s*$", lhs)
        return _dims(table.get(nm.group(1), "")) if nm else []

    def max_trip_count(self) -> float:
        best = 1.0
        for name, lines in self.comps.items():
            for line in lines:
                tm = _TRIP_RE.search(line)
                if tm:
                    best = max(best, float(tm.group(1)))
        return best


def analyze(hlo: str) -> dict:
    mod = HloModule(hlo)
    out = mod.collectives()
    out["dot_flops_per_shard"] = mod.dot_flops()
    out["max_trip_count"] = mod.max_trip_count()
    out["num_computations"] = len(mod.comps)
    return out


# kept for backwards compatibility with earlier tests
def analyze_collectives(hlo: str) -> dict:
    return HloModule(hlo).collectives()


def analyze_dot_flops(hlo: str) -> dict:
    f = HloModule(hlo).dot_flops()
    return {"dot_flops": f}
