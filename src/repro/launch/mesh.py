"""Production meshes. A FUNCTION (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(multi_pod: bool):
    """(fsdp/data axes tuple, tp axis) for the production meshes."""
    return (("pod", "data") if multi_pod else ("data",)), "model"


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    import numpy as np

    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    from jax.sharding import Mesh

    return Mesh(devs, ("data", "model"))
