"""Batched serving driver: prefill + decode loop with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch reduced:qwen3-8b \
        --batch 4 --prompt-len 32 --gen 16

Serves a (reduced) model on the local mesh: runs a real prefill to populate
the KV/state caches, then a jitted decode loop with greedy sampling. This is
the end-to-end example for the inference side; the dry-run lowers the same
decode_step at production shapes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import use_mesh
from repro.launch.train import build_mesh, get_model_config
from repro.models import Axes, Model


def prefill_into_cache(model: Model, params, cache, tokens):
    """Sequential prefill via decode steps (correct for every mixer type;
    production prefill uses the chunked forward + cache write kernels)."""
    b, t = tokens.shape
    logits = None
    for pos in range(t):
        logits, cache = model.decode_step(
            params, cache, tokens[:, pos : pos + 1], jnp.int32(pos)
        )
    return logits, cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="reduced:qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit("encoder-only arch has no decode path")
    mesh = build_mesh(args.mesh)
    dp = ("data",) if "pod" not in mesh.axis_names else ("pod", "data")
    model = Model(cfg, Axes(dp=dp, tp="model"), mesh)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(2, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    max_len = args.prompt_len + args.gen

    with use_mesh(mesh):
        params = model.init(jax.random.key(0))
        cache = model.init_cache(args.batch, max_len)
        t0 = time.time()
        logits, cache = prefill_into_cache(model, params, cache, prompts)
        t_prefill = time.time() - t0

        decode = jax.jit(model.decode_step, donate_argnums=(1,))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out = [tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            pos = jnp.int32(args.prompt_len + i)
            logits, cache = decode(params, cache, tok, pos)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print("generated token ids:\n", gen)
    print(
        f"prefill {args.prompt_len} tok x{args.batch}: {t_prefill:.2f}s; "
        f"decode: {args.gen - 1} steps in {t_decode:.2f}s "
        f"({args.batch * (args.gen - 1) / max(t_decode, 1e-9):.1f} tok/s)"
    )
    return gen


if __name__ == "__main__":
    main()
