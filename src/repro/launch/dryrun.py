import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and dump the roofline
inputs (FLOPs / bytes / collective traffic) as JSON.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k [--multi-pod] [--out runs/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # full matrix
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.compat import use_mesh
from repro.configs import ALIASES, SHAPES, cells_for, get_config
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.launch.specs import (
    analytic_model_flops,
    batch_specs,
    pick_accum,
    sds,
    sds_tree,
)
from repro.models import Axes, Model
from repro.train.optimizer import adamw_init, adamw_state_specs
from repro.train.step import make_decode_step, make_prefill_step, make_train_step

# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
ICI_BW = 50e9  # per link


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    import dataclasses

    cfg = get_config(arch)
    force_accum = None
    if overrides:
        overrides = dict(overrides)
        force_accum = overrides.pop("accum", None)
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp, tp = mesh_axes(multi_pod)
    ax = Axes(dp=dp, tp=tp)
    model = Model(cfg, ax, mesh)
    shape = SHAPES[shape_name]
    kind = shape["kind"]
    n_dp = 1
    for a in dp:
        n_dp *= int(mesh.shape[a])

    param_sds = sds_tree(model.init_shapes(), model.param_specs(), mesh)
    meta = {"accum": 1}
    with use_mesh(mesh):  # with_sharding_constraint needs an ambient mesh
        if kind == "train":
            n_tp = int(mesh.shape[tp]) if cfg.activation_partitioning == "seq" else 1
            accum = int(force_accum) if force_accum else pick_accum(
                cfg, shape, n_dp, n_tp=n_tp
            )
            meta["accum"] = accum
            opt_shapes = jax.eval_shape(
                lambda p: adamw_init(p, jnp.dtype(cfg.opt_state_dtype)), param_sds
            )
            opt_sds = sds_tree(opt_shapes, adamw_state_specs(model.param_specs()), mesh)
            batch = batch_specs(cfg, shape, mesh, dp, accum=accum)
            step = make_train_step(model, accum=accum)
            lowered = jax.jit(step).lower(param_sds, opt_sds, batch)
        elif kind == "prefill":
            batch = batch_specs(cfg, shape, mesh, dp)
            step = make_prefill_step(model)
            lowered = jax.jit(step).lower(param_sds, batch)
        else:  # decode
            b, s = shape["global_batch"], shape["seq_len"]
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(b, s)
            )
            cache_sds = sds_tree(cache_shapes, model.cache_specs(), mesh)
            tokens = sds((b, 1), jnp.int32, jax.sharding.PartitionSpec(dp, None), mesh)
            pos = sds((), jnp.int32, jax.sharding.PartitionSpec(), mesh)
            step = make_decode_step(model)
            lowered = jax.jit(step).lower(param_sds, cache_sds, tokens, pos)
    return lowered, mesh, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    t0 = time.time()
    cfg = get_config(arch)
    cells = cells_for(arch, cfg)
    status = cells[shape_name]
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": status,
    }
    if status != "run":
        return result
    kind = SHAPES[shape_name]["kind"]
    if overrides:
        result["overrides"] = {k: str(v) for k, v in overrides.items()}
    try:
        lowered, mesh, meta = lower_cell(arch, shape_name, multi_pod, overrides)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
        chips = mesh.devices.size
        try:
            mem = compiled.memory_analysis()
            mem_info = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None
                ),
            }
        except Exception as e:  # pragma: no cover
            mem_info = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            cost = {
                "xla_flops_body_once": ca.get("flops"),
                "xla_bytes_body_once": ca.get("bytes accessed"),
            }
        except Exception as e:  # pragma: no cover
            cost = {"error": str(e)}
        hlo = compiled.as_text()
        h = analyze(hlo)
        model_fl = analytic_model_flops(cfg, SHAPES[shape_name], kind)
        # accumulate microbatching multiplies tokens back up via trip counts
        dot_total = h["dot_flops_per_shard"] * chips
        result.update(
            status="ok",
            chips=chips,
            accum=meta["accum"],
            lower_s=round(t_lower - t0, 1),
            compile_s=round(t_compile - t_lower, 1),
            memory=mem_info,
            cost=cost,
            dot_flops_per_shard=h["dot_flops_per_shard"],
            dot_flops_total=dot_total,
            collective_bytes_per_shard=h["collective_bytes"],
            collective_counts=h["collective_counts"],
            total_collective_bytes_per_shard=h["total_collective_bytes"],
            max_trip_count=h["max_trip_count"],
            **model_fl,
        )
        # --- roofline terms (seconds), single-chip denominators x chips
        compute_s = dot_total / (chips * PEAK_FLOPS)
        mem_bytes = cost.get("xla_bytes_body_once") or 0.0
        trip = max(h["max_trip_count"], 1.0)
        # bytes: body-once count is a lower bound; scale the dominant scan
        mem_s = mem_bytes * trip / (chips * HBM_BW) if mem_bytes else None
        coll_s = h["total_collective_bytes"] / ICI_BW
        result["roofline"] = {
            "compute_s": compute_s,
            "memory_s_upper": mem_s,
            "collective_s": coll_s,
            "model_flops_ratio": (
                model_fl["model_flops"] / dot_total if dot_total else None
            ),
        }
    except Exception as e:
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    result["wall_s"] = round(time.time() - t0, 1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--opt", default=None,
                    help="comma-separated cfg overrides, e.g. "
                         "activation_partitioning=seq,opt_state_dtype=bfloat16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells = []
    if args.all:
        for arch in ALIASES:
            for shape in SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape, args.multi_pod))

    overrides = None
    if args.opt:
        overrides = {}
        for kv in args.opt.split(","):
            k, v = kv.split("=")
            if v.isdigit():
                overrides[k] = int(v)
            else:
                try:
                    overrides[k] = float(v)
                except ValueError:
                    overrides[k] = v
    for arch, shape, mp in cells:
        tag = f"{ALIASES.get(arch, arch)}_{shape}_{'multi' if mp else 'single'}"
        if args.tag:
            tag += f"_{args.tag}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip cached] {tag}")
            continue
        print(f"[run] {tag}", flush=True)
        res = run_cell(arch, shape, mp, overrides)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(
            f"  -> {res['status']}"
            + (
                f" compile={res.get('compile_s')}s"
                f" dotTFLOP={res.get('dot_flops_total', 0)/1e12:.1f}"
                f" coll/shard={res.get('total_collective_bytes_per_shard', 0)/1e6:.0f}MB"
                if res["status"] == "ok"
                else f" {res.get('error', '')[:200]}"
            ),
            flush=True,
        )


if __name__ == "__main__":
    main()
