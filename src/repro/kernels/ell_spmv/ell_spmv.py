"""Pallas TPU kernel: ELL-format sparse gather/reduce.

The analytics engine's inner loop is ``out[r] = reduce_d x(cols[r, d])`` over
a row-padded (ELL) adjacency. The GPU way is scatter-add over a COO stream;
TPUs have no efficient scatter, so the hardware adaptation is: pack rows to a
fixed width, keep the *entire* source vector resident in VMEM (vertex states
are O(|V_local|) floats - a few MB per device shard, well within VMEM), and
let each grid step gather for a tile of rows. No atomics, no scatter; the
reduction happens along the minor axis in registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(x_ref, cols_ref, out_ref, *, reduce):
    x = x_ref[...]  # [1, Vp]   entire padded source vector
    cols = cols_ref[...]  # [BR, D]
    vals = x[0, cols.reshape(-1)].reshape(cols.shape)
    if reduce == "sum":
        out_ref[...] = vals.sum(axis=1, keepdims=True)
    else:
        out_ref[...] = vals.min(axis=1, keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("reduce", "block_r", "interpret")
)
def ell_spmv_pallas(
    x: jnp.ndarray,  # float32[Vp]  (padded; identity slot included)
    cols: jnp.ndarray,  # int32[R, D]  (R % block_r == 0)
    reduce: str = "sum",
    block_r: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    r, d = cols.shape
    assert r % block_r == 0
    kernel = functools.partial(_spmv_kernel, reduce=reduce)
    out = pl.pallas_call(
        kernel,
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((1, x.shape[0]), lambda i: (0, 0)),
            pl.BlockSpec((block_r, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), x.dtype),
        interpret=interpret,
    )(x[None, :], cols)
    return out[:, 0]
