"""Pure-jnp oracle for the ELL gather/reduce (analytics inner loop)."""
from __future__ import annotations

import jax.numpy as jnp


def ell_spmv_ref(
    x: jnp.ndarray,  # float32[V + 1]; x[V] is the identity pad slot
    cols: jnp.ndarray,  # int32[R, D]; pad entries point at slot V
    reduce: str = "sum",
) -> jnp.ndarray:
    """out[r] = reduce_d x[cols[r, d]] - one vertex-program gather step."""
    vals = x[cols]
    if reduce == "sum":
        return vals.sum(axis=1)
    return vals.min(axis=1)
