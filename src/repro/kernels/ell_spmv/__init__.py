from repro.kernels.ell_spmv.ops import ell_spmv

__all__ = ["ell_spmv"]
