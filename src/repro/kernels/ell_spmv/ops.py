"""Public wrapper for the ELL gather/reduce."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ell_spmv.ell_spmv import ell_spmv_pallas
from repro.kernels.ell_spmv.ref import ell_spmv_ref


def ell_spmv(
    x,  # float[V + 1] source states incl. identity pad slot at index V
    cols,  # int[R, D] ELL column indices (pad -> V)
    reduce: str = "sum",
    use_pallas: bool | None = None,
    interpret: bool = False,
):
    x = jnp.asarray(x)
    cols = jnp.asarray(cols, jnp.int32)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas and not interpret:
        return ell_spmv_ref(x, cols, reduce)
    r, d = cols.shape
    block_r = 128 if r >= 128 else 8
    rp = int(np.ceil(r / block_r)) * block_r
    pad_col = x.shape[0] - 1
    cols_p = jnp.full((rp, d), pad_col, jnp.int32).at[:r].set(cols)
    out = ell_spmv_pallas(
        x, cols_p, reduce=reduce, block_r=block_r, interpret=interpret
    )
    return out[:r]
