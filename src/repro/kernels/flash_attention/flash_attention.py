"""Pallas TPU kernel: FlashAttention-style online-softmax attention.

MXU-aligned tiling: the grid walks (batch*kv_head*q_group, q_block); each
step streams kv blocks through VMEM with fori_loop carrying the running
(max, denom, acc) statistics in fp32. Causal and sliding-window masks prune
whole kv blocks via the loop bounds (work skipped, not masked). Block sizes
default to 128x128 (MXU native); head_dim rides along the minor-most axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _attn_kernel(
    q_ref,  # [1, BQ, Dh]
    k_ref,  # [1, Tk, Dh]
    v_ref,  # [1, Tk, Dh]
    o_ref,  # [1, BQ, Dh]
    *,
    block_q: int,
    block_k: int,
    causal: bool,
    window: int | None,
    q_offset: int,
    sm_scale: float,
):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale  # [BQ, Dh]
    tk = k_ref.shape[1]
    q_start = qi * block_q + q_offset  # absolute position of first q row

    # kv block range this q block can see
    if causal:
        hi = jnp.minimum(
            pl.cdiv(q_start + block_q, block_k), pl.cdiv(tk, block_k)
        )
    else:
        hi = pl.cdiv(tk, block_k)
    if window is not None:
        lo = jnp.maximum((q_start - window + 1) // block_k, 0)
    else:
        lo = 0

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        # index the leading block axis with a length-1 Slice, not a Python
        # int: pallas' load discharge requires every non-Slice index to be a
        # shaped array, so a bare 0 breaks under interpret mode
        blk_idx = (pl.dslice(0, 1), pl.dslice(ki * block_k, block_k), slice(None))
        k_blk = pl.load(k_ref, blk_idx)[0].astype(jnp.float32)
        v_blk = pl.load(v_ref, blk_idx)[0].astype(jnp.float32)
        s = q @ k_blk.T  # [BQ, BK]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < tk
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v_blk
        return m_cur, l_cur, acc

    dh = q_ref.shape[-1]
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "block_q", "block_k", "interpret"
    ),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [BH, Tq, Dh]  (batch*heads flattened; Tq % block_q == 0)
    k: jnp.ndarray,  # [BH, Tk, Dh]
    v: jnp.ndarray,  # [BH, Tk, Dh]
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, tq, dh = q.shape
    tk = k.shape[1]
    assert tq % block_q == 0, (tq, block_q)
    sm_scale = dh**-0.5
    kernel = functools.partial(
        _attn_kernel,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        window=window,
        q_offset=q_offset,
        sm_scale=sm_scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, tq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, tk, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, tk, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
