"""Public attention wrapper: GQA folding, padding, kernel/ref dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(
    q,  # [B, Hq, Tq, Dh]
    k,  # [B, Hkv, Tk, Dh]
    v,  # [B, Hkv, Tk, Dh]
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_q: int = 128,
    block_k: int = 128,
):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas and not interpret:
        return attention_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)
    b, hq, tq, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    # fold GQA groups into the kv-head axis: each kv head serves g q-heads
    qf = q.reshape(b, hkv, g, tq, dh).reshape(b * hkv * g, tq, dh)
    kf = jnp.repeat(k.reshape(b * hkv, -1, dh), g, axis=0)
    vf = jnp.repeat(v.reshape(b * hkv, -1, dh), g, axis=0)
    bq = min(block_q, max(8, 1 << int(np.ceil(np.log2(max(tq, 1))))))
    tq_p = int(np.ceil(tq / bq)) * bq
    if tq_p != tq:
        qf = jnp.pad(qf, ((0, 0), (0, tq_p - tq), (0, 0)))
    out = flash_attention_pallas(
        qf, kf, vf,
        causal=causal, window=window, q_offset=q_offset,
        block_q=bq, block_k=min(block_k, kf.shape[1]),
        interpret=interpret,
    )
    out = out[:, :tq]
    return out.reshape(b, hq, tq, dh)
