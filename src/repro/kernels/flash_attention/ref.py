"""Pure-jnp oracle: exact softmax attention with causal / sliding-window
masking, fp32 accumulation."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # [B, Hq, Tq, Dh]
    k: jnp.ndarray,  # [B, Hkv, Tk, Dh]
    v: jnp.ndarray,  # [B, Hkv, Tk, Dh]
    causal: bool = True,
    window: int | None = None,  # sliding window size (None = full)
    q_offset: int = 0,  # absolute position of q[0] (decode: Tk - Tq)
) -> jnp.ndarray:
    b, hq, tq, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qf = q.astype(jnp.float32) * (dh**-0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, hkv, g, tq, dh)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)
    qpos = jnp.arange(tq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((tq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(b, hq, tq, dh).astype(q.dtype)
