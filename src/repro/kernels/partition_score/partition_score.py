"""Pallas TPU kernel: fused neighbour-partition histogram + FENNEL penalty.

The paper's streaming phase evaluates Eq. 7 for every vertex: count assigned
neighbours per partition, subtract the balance penalty, argmax. On CPU this is
the O(K|V| + |E|) inner loop; CUTTANA parallelises it with threads. The TPU
adaptation tiles a *batch* of vertices' padded neighbour-partition ids into
VMEM and builds the histogram with VPU compares against the lane-resident
partition ids - no scatter, MXU-free, fully vectorised.

Tiling:
  grid over vertex blocks (BB rows); neighbour axis D is looped inside the
  kernel in chunks of DC columns so the [BB, DC, K] compare cube stays within
  VMEM; K is padded to the 128-lane register width by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(nbr_ref, size_ref, out_ref, *, alpha, gamma, d_chunk):
    nbr = nbr_ref[...]  # [BB, D] int32
    sizes = size_ref[...]  # [1, K] float32
    bb, d = nbr.shape
    k = sizes.shape[-1]
    part_ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2)

    def body(c, hist):
        chunk = jax.lax.dynamic_slice(nbr, (0, c * d_chunk), (bb, d_chunk))
        eq = (chunk[:, :, None] == part_ids).astype(jnp.float32)
        return hist + eq.sum(axis=1)

    steps = d // d_chunk
    hist = jax.lax.fori_loop(
        0, steps, body, jnp.zeros((bb, k), jnp.float32)
    )
    penalty = alpha * gamma * jnp.power(jnp.maximum(sizes, 0.0), gamma - 1.0)
    out_ref[...] = hist - penalty


def _score_kernel_sharded(nbr_ref, size_ref, out_ref, *, alpha, gamma, d_chunk):
    """One (shard, vertex-block) grid cell: identical math to ``_score_kernel``
    but the size row is the *shard's* size view, so the fused penalty differs
    per shard (the parallel engine's bulk-synchronous local state)."""
    nbr = nbr_ref[0]  # [BB, D] int32 (leading shard dim is blocked to 1)
    sizes = size_ref[0]  # [1, K] float32
    bb, d = nbr.shape
    k = sizes.shape[-1]
    part_ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2)

    def body(c, hist):
        chunk = jax.lax.dynamic_slice(nbr, (0, c * d_chunk), (bb, d_chunk))
        eq = (chunk[:, :, None] == part_ids).astype(jnp.float32)
        return hist + eq.sum(axis=1)

    steps = d // d_chunk
    hist = jax.lax.fori_loop(0, steps, body, jnp.zeros((bb, k), jnp.float32))
    penalty = alpha * gamma * jnp.power(jnp.maximum(sizes, 0.0), gamma - 1.0)
    out_ref[0] = hist - penalty


@functools.partial(
    jax.jit, static_argnames=("alpha", "gamma", "block_b", "d_chunk", "interpret")
)
def fennel_scores_sharded_pallas(
    nbr_parts: jnp.ndarray,  # int32[S, C, D] (-1 pad; C % block_b == 0, D % d_chunk == 0)
    sizes: jnp.ndarray,  # float32[S, K]
    alpha: float,
    gamma: float,
    block_b: int = 128,
    d_chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """scores[S, C, K]: ONE kernel launch for all S shard frontiers.

    The grid is (shard, vertex-block); every cell loads its shard's candidate
    tile plus that shard's K-wide size row, so a whole superstep of the
    parallel engine is a single fused call instead of S sequential ones.
    """
    s, c, d = nbr_parts.shape
    k = sizes.shape[-1]
    assert c % block_b == 0 and d % d_chunk == 0
    kernel = functools.partial(
        _score_kernel_sharded, alpha=alpha, gamma=gamma, d_chunk=d_chunk
    )
    return pl.pallas_call(
        kernel,
        grid=(s, c // block_b),
        in_specs=[
            pl.BlockSpec((1, block_b, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, k), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, k), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((s, c, k), jnp.float32),
        interpret=interpret,
    )(nbr_parts, sizes[:, None, :])


@functools.partial(
    jax.jit, static_argnames=("alpha", "gamma", "block_b", "d_chunk", "interpret")
)
def fennel_scores_pallas(
    nbr_parts: jnp.ndarray,  # int32[B, D] (-1 pad; B % block_b == 0, D % d_chunk == 0)
    sizes: jnp.ndarray,  # float32[K]
    alpha: float,
    gamma: float,
    block_b: int = 128,
    d_chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, d = nbr_parts.shape
    k = sizes.shape[0]
    assert b % block_b == 0 and d % d_chunk == 0
    kernel = functools.partial(
        _score_kernel, alpha=alpha, gamma=gamma, d_chunk=d_chunk
    )
    return pl.pallas_call(
        kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(nbr_parts, sizes[None, :])
