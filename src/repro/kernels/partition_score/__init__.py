from repro.kernels.partition_score.ops import fennel_scores

__all__ = ["fennel_scores"]
