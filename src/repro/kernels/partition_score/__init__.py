from repro.kernels.partition_score.ops import fennel_scores, fennel_scores_sharded

__all__ = ["fennel_scores", "fennel_scores_sharded"]
