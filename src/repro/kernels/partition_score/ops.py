"""Public wrapper: pads to kernel-friendly shapes, dispatches kernel vs ref."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.partition_score.partition_score import (
    fennel_scores_pallas,
    fennel_scores_sharded_pallas,
)
from repro.kernels.partition_score.ref import (
    fennel_scores_ref,
    fennel_scores_sharded_ref,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kernel_active(use_pallas: bool | None, interpret: bool = False) -> bool:
    """Resolve the tri-state ``use_pallas`` flag the same way
    :func:`fennel_scores` does (None => kernel only on TPU)."""
    if interpret:
        return True
    return _on_tpu() if use_pallas is None else bool(use_pallas)


def neighbor_histograms_host(
    rows: np.ndarray,  # int[NNZ] batch-row index of each neighbour slot
    parts: np.ndarray,  # int[NNZ] neighbour partition ids, -1 = unassigned
    num_rows: int,
    k: int,
    out: np.ndarray | None = None,  # float64[num_rows, K] to fill in place
) -> np.ndarray:
    """hist[B, K] of assigned-neighbour counts from flat (row, part) pairs.

    The CPU companion of the Pallas histogram: one ``bincount`` over the
    chunk's edges instead of a per-vertex loop (and instead of the jnp
    reference's [B, D, K] one-hot cube, which is far too slow for the
    streaming hot path). ``out`` lets a shard worker fill its disjoint rows
    of a preallocated superstep histogram without a second allocation."""
    mask = parts >= 0
    idx = rows[mask] * np.int64(k) + parts[mask]
    hist = np.bincount(idx, minlength=num_rows * k).reshape(num_rows, k)
    if out is None:
        return hist.astype(np.float64)
    out[:] = hist
    return out


def fennel_scores(
    nbr_parts,
    sizes,
    alpha: float,
    gamma: float = 1.5,
    use_pallas: bool | None = None,
    interpret: bool = False,
):
    """scores[B, K] for a batch of vertices (Eq. 7 affinity + penalty).

    ``nbr_parts`` int[B, D] (-1 padding), ``sizes`` float[K].
    """
    nbr_parts = jnp.asarray(nbr_parts, jnp.int32)
    sizes = jnp.asarray(sizes, jnp.float32)
    if not kernel_active(use_pallas, interpret):
        return fennel_scores_ref(nbr_parts, sizes, alpha, gamma)
    b, d = nbr_parts.shape
    block_b = 128 if b >= 128 else 8
    d_chunk = 128 if d >= 128 else max(8, d)
    bp = int(np.ceil(b / block_b)) * block_b
    dp = int(np.ceil(d / d_chunk)) * d_chunk
    padded = jnp.full((bp, dp), -1, jnp.int32).at[:b, :d].set(nbr_parts)
    out = fennel_scores_pallas(
        padded, sizes, alpha, gamma,
        block_b=block_b, d_chunk=d_chunk, interpret=interpret,
    )
    return out[:b]


def fennel_scores_sharded(
    nbr_parts,
    sizes,
    alpha: float,
    gamma: float = 1.5,
    use_pallas: bool | None = None,
    interpret: bool = False,
):
    """scores[S, C, K] for S shard frontiers in one fused call.

    ``nbr_parts`` int[S, C, D] (-1 padding, both on the neighbour axis and
    for rows beyond a shard's candidate count), ``sizes`` float[S, K] - one
    size row per shard, so a caller *can* fuse shard-local penalties into
    the launch. The stream engine applies penalties incrementally on the
    host (they change per placement) and calls this with ``alpha=0`` / zero
    sizes - there the leading batch dimension packs all shards' padded
    frontiers into one (shard, block) grid launch per superstep.
    """
    nbr_parts = jnp.asarray(nbr_parts, jnp.int32)
    sizes = jnp.asarray(sizes, jnp.float32)
    if not kernel_active(use_pallas, interpret):
        return fennel_scores_sharded_ref(nbr_parts, sizes, alpha, gamma)
    s, c, d = nbr_parts.shape
    block_b = 128 if c >= 128 else 8
    d_chunk = 128 if d >= 128 else max(8, d)
    cp = int(np.ceil(max(c, 1) / block_b)) * block_b
    dp = int(np.ceil(max(d, 1) / d_chunk)) * d_chunk
    padded = jnp.full((s, cp, dp), -1, jnp.int32).at[:, :c, :d].set(nbr_parts)
    out = fennel_scores_sharded_pallas(
        padded, sizes, alpha, gamma,
        block_b=block_b, d_chunk=d_chunk, interpret=interpret,
    )
    return out[:, :c]
