"""Pure-jnp oracle for the fused FENNEL scoring kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fennel_scores_ref(
    nbr_parts: jnp.ndarray,  # int32[B, D] neighbour partition ids, -1 = pad
    sizes: jnp.ndarray,  # float32[K] partition sizes (active balance metric)
    alpha: float,
    gamma: float,
) -> jnp.ndarray:
    """scores[B, K] = |V_k ∩ N(v_b)| - alpha*gamma*sizes_k^(gamma-1)."""
    k = sizes.shape[0]
    onehot = nbr_parts[..., None] == jnp.arange(k, dtype=nbr_parts.dtype)
    hist = onehot.sum(axis=1).astype(jnp.float32)  # [B, K]
    penalty = alpha * gamma * jnp.power(jnp.maximum(sizes, 0.0), gamma - 1.0)
    return hist - penalty[None, :]
