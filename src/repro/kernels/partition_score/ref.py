"""Pure-jnp oracle for the fused FENNEL scoring kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fennel_scores_ref(
    nbr_parts: jnp.ndarray,  # int32[B, D] neighbour partition ids, -1 = pad
    sizes: jnp.ndarray,  # float32[K] partition sizes (active balance metric)
    alpha: float,
    gamma: float,
) -> jnp.ndarray:
    """scores[B, K] = |V_k ∩ N(v_b)| - alpha*gamma*sizes_k^(gamma-1)."""
    k = sizes.shape[0]
    onehot = nbr_parts[..., None] == jnp.arange(k, dtype=nbr_parts.dtype)
    hist = onehot.sum(axis=1).astype(jnp.float32)  # [B, K]
    penalty = alpha * gamma * jnp.power(jnp.maximum(sizes, 0.0), gamma - 1.0)
    return hist - penalty[None, :]


def fennel_scores_sharded_ref(
    nbr_parts: jnp.ndarray,  # int32[S, C, D] per-shard neighbour parts, -1 pad
    sizes: jnp.ndarray,  # float32[S, K] per-shard partition sizes
    alpha: float,
    gamma: float,
) -> jnp.ndarray:
    """scores[S, C, K]: the sharded (leading-batch-dimension) oracle.

    Shard ``s`` scores its candidates against *its own* size view - the
    bulk-synchronous parallel engine gives every shard the superstep-start
    snapshot plus its local deltas, so penalties differ per shard.
    """
    k = sizes.shape[-1]
    onehot = nbr_parts[..., None] == jnp.arange(k, dtype=nbr_parts.dtype)
    hist = onehot.sum(axis=2).astype(jnp.float32)  # [S, C, K]
    penalty = alpha * gamma * jnp.power(jnp.maximum(sizes, 0.0), gamma - 1.0)
    return hist - penalty[:, None, :]
