"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package ships three modules:
  * ``<name>.py`` - the pl.pallas_call with explicit BlockSpec VMEM tiling
                    (TPU is the target; validated with interpret=True on CPU),
  * ``ops.py``    - the jit'd public wrapper (falls back to the reference
                    implementation off-TPU),
  * ``ref.py``    - the pure-jnp oracle.

Kernels:
  * partition_score - CUTTANA/FENNEL scoring hot-spot (Eq. 7): fused
    neighbour-partition histogram + balance penalty over a vertex batch
    (the paper's O(K|V|+|E|) streaming inner loop, re-tiled for the VPU).
  * ell_spmv        - the analytics engine's gather/reduce over ELL-packed
    adjacency (PageRank/CC/SSSP inner loop).
  * flash_attention - online-softmax attention for LM prefill (causal /
    bidirectional / sliding-window).
  * mamba_scan      - fused selective-scan recurrence for Mamba blocks.
"""
