"""Public wrapper for the fused selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mamba_scan.mamba_scan import selective_scan_pallas
from repro.kernels.mamba_scan.ref import selective_scan_ref


def selective_scan(
    x, dt, a, b, c, d_skip,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_d: int = 512,
):
    """Returns (y[B,T,D], h_T[B,D,N])."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas and not interpret:
        return selective_scan_ref(x, dt, a, b, c, d_skip)
    d = x.shape[-1]
    bd = min(block_d, d)
    while d % bd:
        bd //= 2
    return selective_scan_pallas(
        x, dt, a, b, c, d_skip, block_d=max(bd, 1), interpret=interpret
    )
