"""Pallas TPU kernel: fused Mamba-1 selective scan.

GPU implementations (the official CUDA kernel) assign one thread block per
(batch, channel-chunk) and scan time sequentially in shared memory. The TPU
adaptation keeps the running state h[BD, N] resident in VMEM, the grid walks
(batch, channel blocks), and the kernel streams the time axis with a
fori_loop - recomputing the discretisation (exp(dt*A)) in-register so the
[T, D, N] tensors are never materialised in HBM (that is the fusion win).

y_t = ((exp(dt_t A) h_{t-1} + dt_t x_t B_t) C_t) + D x_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dskip_ref, y_ref, hT_ref):
    # blocks: x,dt: [1, T, BD]; a: [BD, N]; b,c: [1, T, N]; dskip: [1, BD]
    # out:   y: [1, T, BD]; hT: [1, BD, N]
    t = x_ref.shape[1]
    a = a_ref[...].astype(jnp.float32)  # [BD, N]
    dskip = dskip_ref[...].astype(jnp.float32)  # [1, BD]
    bd, n = a.shape
    h0 = jnp.zeros((bd, n), jnp.float32)

    def body(ti, h):
        xt = x_ref[0, ti, :].astype(jnp.float32)  # [BD]
        dtt = dt_ref[0, ti, :].astype(jnp.float32)  # [BD]
        bt = b_ref[0, ti, :].astype(jnp.float32)  # [N]
        ct = c_ref[0, ti, :].astype(jnp.float32)  # [N]
        da = jnp.exp(dtt[:, None] * a)  # [BD, N]
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y = (h * ct[None, :]).sum(axis=1) + dskip[0] * xt
        y_ref[0, ti, :] = y.astype(y_ref.dtype)
        return h

    hT = jax.lax.fori_loop(0, t, body, h0)
    hT_ref[0] = hT.astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def selective_scan_pallas(
    x: jnp.ndarray,  # [B, T, D]
    dt: jnp.ndarray,  # [B, T, D]
    a: jnp.ndarray,  # [D, N]
    b: jnp.ndarray,  # [B, T, N]
    c: jnp.ndarray,  # [B, T, N]
    d_skip: jnp.ndarray,  # [D]
    block_d: int = 512,
    interpret: bool = False,
):
    bsz, t, d = x.shape
    n = a.shape[1]
    assert d % block_d == 0, (d, block_d)
    grid = (bsz, d // block_d)
    y, h_t = pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, block_d), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, t, block_d), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((block_d, n), lambda bi, di: (di, 0)),
            pl.BlockSpec((1, t, n), lambda bi, di: (bi, 0, 0)),
            pl.BlockSpec((1, t, n), lambda bi, di: (bi, 0, 0)),
            pl.BlockSpec((1, block_d), lambda bi, di: (0, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, block_d), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, block_d, n), lambda bi, di: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, d), x.dtype),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, b, c, d_skip[None, :])
    return y, h_t
