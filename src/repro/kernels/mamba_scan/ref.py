"""Pure-jnp oracle for the Mamba-1 selective scan.

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t      h: [D, N]
    y_t = (h_t @ C_t) + D_skip * x_t                        y: [D]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(
    x: jnp.ndarray,  # [B, T, D]
    dt: jnp.ndarray,  # [B, T, D]   (already softplus'd)
    a: jnp.ndarray,  # [D, N]      (negative; state decay)
    b: jnp.ndarray,  # [B, T, N]
    c: jnp.ndarray,  # [B, T, N]
    d_skip: jnp.ndarray,  # [D]
    h0: jnp.ndarray | None = None,  # [B, D, N] initial state
):
    bsz, t, d = x.shape
    n = a.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    def scan_one(h, inp):
        xt, dtt, bt, ct = inp  # [D], [D], [N], [N]
        da = jnp.exp(dtt[:, None] * af)  # [D, N]
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y = (h * ct[None, :]).sum(-1)  # [D]
        return h, y

    def per_batch(xb, dtb, bb, cb, h0b):
        h, ys = jax.lax.scan(scan_one, h0b, (xb, dtb, bb, cb))
        return h, ys

    h0 = (
        jnp.zeros((bsz, d, n), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )
    hT, ys = jax.vmap(per_batch)(xf, dtf, bf, cf, h0)
    y = ys + xf * d_skip.astype(jnp.float32)[None, None, :]
    return y.astype(x.dtype), hT
