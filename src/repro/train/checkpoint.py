"""Fault-tolerant checkpointing: atomic, keep-N, mesh-agnostic.

Leaves are written as host numpy arrays (one .npy per leaf inside an .npz)
with a JSON manifest; the directory is renamed into place atomically so a
crash mid-write never corrupts the latest checkpoint. Because leaves are
stored unsharded, restore works under ANY mesh - this is what makes elastic
re-meshing (launch/elastic.py) trivial: save on 512 devices, restore on 256.

An optional background thread makes saves async (training continues while
the previous step's state is flushed).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:010d}"
    tmp = os.path.join(directory, f".tmp_{name}")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrays = {}
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtypes.append(str(arr.dtype))
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # numpy cannot round-trip ml_dtypes (bf16/fp8): store raw bits
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        arrays[f"leaf_{i}"] = arr
    np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": dtypes,
        "shapes": [list(np.shape(l)) for l in leaves],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    ckpts = sorted(
        d for d in os.listdir(directory) if d.startswith("step_")
    )
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    if not ckpts:
        return None
    return int(ckpts[-1].split("_")[1])


def restore_checkpoint(directory: str, tree_like, step: int | None = None):
    """Restore into the structure of ``tree_like`` (shapes/dtypes may be
    abstract); returns (tree, step). Device placement/sharding is applied by
    the caller (device_put with the current mesh's specs)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    data = np.load(os.path.join(path, "leaves.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten(tree_like)
    leaves = []
    for i in range(len(leaves_like)):
        arr = data[f"leaf_{i}"]
        want = manifest["dtypes"][i]
        if str(arr.dtype) != want:
            import ml_dtypes  # ships with jax

            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Serialises saves on a worker thread; ``wait()`` before exit."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pending: threading.Thread | None = None

    def save(self, step: int, tree) -> None:
        self.wait()
        # materialise on host NOW (so training can mutate device buffers)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._pending = threading.Thread(
            target=save_checkpoint,
            args=(self.directory, step, host_tree, self.keep),
            daemon=True,
        )
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
