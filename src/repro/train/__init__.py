from repro.train.optimizer import AdamWState, adamw_init, adamw_update
from repro.train.schedule import cosine_schedule
from repro.train.step import make_eval_step, make_train_step

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "make_train_step",
    "make_eval_step",
]
