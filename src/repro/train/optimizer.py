"""AdamW with configurable state dtype (fp32 default, bf16 for the giant
MoEs so optimizer state fits v5e HBM - see DESIGN.md §5) + global-norm clip.

Self-contained pytree implementation (no optax in the container). Optimizer
state inherits the parameter sharding specs, so m/v are FSDP-sharded exactly
like their parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_state_specs(param_specs) -> AdamWState:
    from jax.sharding import PartitionSpec as P

    return AdamWState(
        step=P(),
        m=jax.tree.map(lambda s: s, param_specs),
        v=jax.tree.map(lambda s: s, param_specs),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float | None = 1.0,
):
    step = state.step + 1
    if clip_norm is not None:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    else:
        gnorm = global_norm(grads)
    c1 = 1.0 - b1**step.astype(jnp.float32)
    c2 = 1.0 - b2**step.astype(jnp.float32)

    def upd_m(g, m):
        return (m.astype(jnp.float32) * b1 + g.astype(jnp.float32) * (1 - b1)).astype(m.dtype)

    def upd_v(g, v):
        gf = g.astype(jnp.float32)
        return (v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)).astype(v.dtype)

    new_m = jax.tree.map(upd_m, grads, state.m)
    new_v = jax.tree.map(upd_v, grads, state.v)

    def upd_p(p, m, v):
        mhat = m.astype(jnp.float32) / c1
        vhat = v.astype(jnp.float32) / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd_p, params, new_m, new_v)
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm
