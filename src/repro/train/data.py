"""Deterministic synthetic data pipeline (offline container - no corpora).

Produces packed next-token batches from a seeded Zipf-ish token source with
document boundaries, sharded per host and prefetched on a background thread.
The statistical content is irrelevant for systems work; determinism and the
host-sharding/prefetch machinery are what production runs exercise.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class TokenPipeline:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        host_index: int = 0,
        host_count: int = 1,
        seed: int = 0,
        prefetch: int = 2,
        doc_len_mean: int = 512,
    ):
        assert global_batch % host_count == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // host_count
        self.seed = seed
        self.host = host_index
        self.doc_len_mean = doc_len_mean
        self._step = 0
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.PCG64(hash((self.seed, self.host, step)) & 0x7FFFFFFF)
        )
        b, s = self.local_batch, self.seq
        toks = np.empty((b, s + 1), np.int32)
        for i in range(b):
            pos = 0
            while pos < s + 1:
                dl = int(rng.exponential(self.doc_len_mean)) + 8
                dl = min(dl, s + 1 - pos)
                doc = (rng.zipf(1.3, size=dl) % (self.vocab - 2)) + 2
                doc[0] = 1  # BOS
                toks[i, pos : pos + dl] = doc
                pos += dl
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            batch = self._batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self._step = step
        return batch

    def __iter__(self):
        return self

    def skip_to(self, step: int) -> None:
        """Resume support: drain until the pipeline is at ``step``."""
        while self._step + 1 < step:
            self.__next__()

    def close(self):
        self._stop.set()
