"""Gradient compression for the slow pod-interconnect axis (beyond-paper
distributed-optimization feature).

int8 quantize -> psum over the "pod" axis -> dequantize, with error-feedback
residuals (Seide et al. / 1-bit Adam lineage) so compression noise does not
bias convergence. Intra-pod reduction stays full precision (ICI is fast);
only the cross-pod hop - the DCN bottleneck at 2+ pods - is compressed 4x.

Implemented with shard_map so the compiled HLO shows the intended schedule:
fp32 psum over ("data",) then int8 psum over ("pod",).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def _quant(x, scale):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def compressed_psum_pod(grad, residual, mesh, pod_axis: str = "pod"):
    """grad: replicated-over-pod gradient block; returns (mean_grad, new_residual).

    Caller is responsible for grads already being reduced over the intra-pod
    data axes (jax.grad under GSPMD does that); this adds the cross-pod mean
    with int8 payload.
    """
    n_pods = int(mesh.shape[pod_axis])
    if n_pods == 1:
        return grad, residual

    def local(g, r):
        val = g.astype(jnp.float32) + r
        amax = jnp.max(jnp.abs(val))
        # share one scale so int8 sums are consistent
        scale = jax.lax.pmax(amax, pod_axis) / 127.0 + 1e-12
        q = _quant(val, scale)
        summed = jax.lax.psum(q.astype(jnp.int32), pod_axis)
        deq = summed.astype(jnp.float32) * scale / n_pods
        new_r = val - _quant(val, scale).astype(jnp.float32) * scale
        return deq.astype(g.dtype), new_r

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )(grad, residual)


def compress_grads(grads, residuals, mesh, pod_axis: str = "pod"):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        ng, nr = compressed_psum_pod(g, r, mesh, pod_axis)
        out_g.append(ng)
        out_r.append(nr)
    return jax.tree.unflatten(treedef, out_g), jax.tree.unflatten(treedef, out_r)


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
