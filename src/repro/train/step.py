"""train_step / eval_step builders (the functions the dry-run lowers).

Loss = token CE (fp32 logsumexp over the model-sharded vocab - GSPMD inserts
the psum) + router aux loss. One microbatch per step by default; gradient
accumulation wraps the grad fn in a lax.scan over microbatches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.optimizer import AdamWState, adamw_update
from repro.train.schedule import cosine_schedule


def token_ce(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32), axis=-1)
    return nll.mean()


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        ce = token_ce(logits, batch["labels"])
        loss = ce + model.cfg.router_aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(
    model: Model,
    peak_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    weight_decay: float = 0.1,
    accum: int = 1,
):
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state: AdamWState, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            # microbatch accumulation: batch leaves get a leading accum axis
            def micro(carry, mb):
                acc_grads, acc_loss = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc_grads = jax.tree.map(jnp.add, acc_grads, g)
                return (acc_grads, acc_loss + l), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = jax.lax.scan(
                micro, (zero, jnp.zeros((), jnp.float32)), batch
            )
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        lr = cosine_schedule(opt_state.step, peak_lr, warmup, total_steps)
        new_params, new_opt, gnorm = adamw_update(
            grads, opt_state, params, lr, weight_decay=weight_decay
        )
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(model: Model):
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return dict(metrics, loss=loss)

    return eval_step


def make_prefill_step(model: Model):
    """Serving prefill: forward only, returns logits of the last position."""

    def prefill(params, batch):
        logits, _ = model.forward(params, batch)
        return logits[:, -1:]

    return prefill


def make_decode_step(model: Model):
    def decode(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return decode
