"""``repro.api`` - the canonical typed entry point from stream to analytics.

The full paper pipeline is three chained calls:

    >>> from repro.api import PartitionSpec, partition
    >>> from repro.graph import rmat_graph
    >>> g = rmat_graph(20_000, avg_degree=16, seed=0)
    >>> result = partition(g, PartitionSpec(algo="cuttana", k=8))
    >>> result.quality()          # lazily computed + cached λ_EC, λ_CV, ...
    >>> result.analytics(program="pagerank", iters=30)   # paper Table IV
    >>> result.db(hops=2)                                # paper Table V

Specs are frozen and JSON-round-trippable (``PartitionSpec.from_json(
spec.to_json()) == spec``) and validate against the declarative registry at
construction. Run any spec headlessly with::

    python -m repro.api.cli partition --spec spec.json --out report.json
"""
from repro.api.registry import (
    REGISTRY,
    PartitionerInfo,
    get_info,
    list_algorithms,
    register,
)
from repro.api.result import PartitionResult
from repro.api.runner import partition
from repro.api.spec import STREAM_ORDERS, PartitionSpec

__all__ = [
    "PartitionSpec",
    "PartitionResult",
    "partition",
    "PartitionerInfo",
    "REGISTRY",
    "register",
    "get_info",
    "list_algorithms",
    "STREAM_ORDERS",
]
