"""The spec runner: ``partition(graph, spec) -> PartitionResult``.

Drives any registered algorithm from a :class:`PartitionSpec`. Keyword
arguments are built from the registry entry so a spec run calls the
underlying partitioner exactly as a hand-written call would - assignments are
bit-identical to the legacy callables under the same seed/order (pinned in
``tests/test_api.py``).
"""
from __future__ import annotations

import time

import numpy as np

from repro.api.registry import build_spec_kwargs, get_info
from repro.api.result import PartitionResult
from repro.api.spec import PartitionSpec
from repro.graph.csr import CSRGraph

__all__ = ["partition"]

# telemetry keys that are phase wall times, surfaced into result.timings
_TIMING_KEYS = (
    "phase1_seconds",
    "phase2_seconds",
    "base_seconds",
    "stream_seconds",
    "refine_seconds",
)


def partition(
    graph: CSRGraph | None,
    spec: PartitionSpec | dict | str | None = None,
    /,
    **overrides,
):
    """Run ``spec`` on ``graph`` and wrap the outcome in a PartitionResult.

    ``spec`` may be a :class:`PartitionSpec`, a dict of its fields, or just an
    algorithm name; ``overrides`` are applied on top (e.g.
    ``partition(g, "cuttana", k=8, balance_mode="edge")``).

    ``graph`` may be any object with the CSR read surface - a resident
    :class:`CSRGraph` or a memory-mapped
    :class:`~repro.graph.external.ExternalCSRGraph` - or ``None``, in which
    case the graph is resolved from ``spec.source`` (``rmat:*``,
    ``dataset:*``, or an on-disk graph path). A spec with a source can also
    be passed alone: ``partition(spec)``.

    Parallel algorithms additionally surface ``telemetry["profile"]`` (the
    per-superstep phase timings, see ``PartitionResult.profile``) and, when
    ``num_shards=0``/``"auto"`` or ``chunk=0`` was requested,
    ``telemetry["autotune"]`` recording the resolved knobs and their source
    (tuning artifact vs heuristic).
    """
    if spec is None and isinstance(graph, (PartitionSpec, dict, str)):
        # partition(spec_with_source) convenience form
        graph, spec = None, graph
    if spec is None:
        raise ValueError(
            "partition() needs a spec: a PartitionSpec, a dict of its "
            "fields, or an algorithm name"
        )
    if isinstance(spec, str):
        spec = PartitionSpec(algo=spec, **overrides)
    elif isinstance(spec, dict):
        spec = PartitionSpec.from_dict({**spec, **overrides})
    elif overrides:
        spec = spec.replace(**overrides)
    if graph is None:
        if spec.source is None:
            raise ValueError(
                "partition() needs a graph: pass one explicitly or set "
                "spec.source (rmat:<n>, dataset:<name>, or a graph path)"
            )
        from repro.graph.external import load_graph_source

        graph = load_graph_source(spec.source, seed=spec.seed)
    info = get_info(spec.algo)
    fn = info.resolve()
    kwargs = build_spec_kwargs(info, spec)
    telemetry: dict = {}
    if info.telemetry:
        kwargs["telemetry"] = telemetry
    t0 = time.perf_counter()
    out = fn(graph, spec.k, **kwargs)
    total_s = time.perf_counter() - t0

    edge_partition = None
    if info.kind == "vertex-cut":
        edge_partition = out
        assignment = np.asarray(out.edge_part)
    else:
        assignment = np.asarray(out)

    timings = {"total_s": total_s}
    for key in _TIMING_KEYS:
        if key in telemetry:
            timings[key] = telemetry.pop(key)
    # graph-memory accounting: for a mapped (out-of-core) graph the resident
    # footprint is just its host-side caches; for an in-memory CSR it is the
    # whole structure. mapped_graph_bytes is the file-backed remainder.
    backing = getattr(graph, "backing", "resident")
    if backing == "mapped":
        peak_graph_bytes = int(graph.nbytes_resident)
        mapped_graph_bytes = int(graph.nbytes_mapped)
    else:
        peak_graph_bytes = int(graph.indptr.nbytes + graph.indices.nbytes)
        mapped_graph_bytes = 0
    telemetry.update(
        graph_backing=backing,
        peak_graph_bytes=peak_graph_bytes,
        mapped_graph_bytes=mapped_graph_bytes,
        # block-compressed (v2) on-disk payload: byte index + varint data;
        # 0 for raw v1 files and resident graphs
        compressed_graph_bytes=int(getattr(graph, "nbytes_compressed", 0) or 0),
    )
    return PartitionResult(
        spec=spec,
        graph=graph,
        assignment=assignment,
        timings=timings,
        telemetry=telemetry,
        edge_partition=edge_partition,
    )
