"""The spec runner: ``partition(graph, spec) -> PartitionResult``.

Drives any registered algorithm from a :class:`PartitionSpec`. Keyword
arguments are built from the registry entry so a spec run calls the
underlying partitioner exactly as a hand-written call would - assignments are
bit-identical to the legacy callables under the same seed/order (pinned in
``tests/test_api.py``).
"""
from __future__ import annotations

import time

import numpy as np

from repro.api.registry import build_spec_kwargs, get_info
from repro.api.result import PartitionResult
from repro.api.spec import PartitionSpec
from repro.graph.csr import CSRGraph

__all__ = ["partition"]

# telemetry keys that are phase wall times, surfaced into result.timings
_TIMING_KEYS = (
    "phase1_seconds",
    "phase2_seconds",
    "base_seconds",
    "stream_seconds",
    "refine_seconds",
)


def partition(graph: CSRGraph, spec: PartitionSpec | dict | str, /, **overrides):
    """Run ``spec`` on ``graph`` and wrap the outcome in a PartitionResult.

    ``spec`` may be a :class:`PartitionSpec`, a dict of its fields, or just an
    algorithm name; ``overrides`` are applied on top (e.g.
    ``partition(g, "cuttana", k=8, balance_mode="edge")``).
    """
    if isinstance(spec, str):
        spec = PartitionSpec(algo=spec, **overrides)
    elif isinstance(spec, dict):
        spec = PartitionSpec.from_dict({**spec, **overrides})
    elif overrides:
        spec = spec.replace(**overrides)
    info = get_info(spec.algo)
    fn = info.resolve()
    kwargs = build_spec_kwargs(info, spec)
    telemetry: dict = {}
    if info.telemetry:
        kwargs["telemetry"] = telemetry
    t0 = time.perf_counter()
    out = fn(graph, spec.k, **kwargs)
    total_s = time.perf_counter() - t0

    edge_partition = None
    if info.kind == "vertex-cut":
        edge_partition = out
        assignment = np.asarray(out.edge_part)
    else:
        assignment = np.asarray(out)

    timings = {"total_s": total_s}
    for key in _TIMING_KEYS:
        if key in telemetry:
            timings[key] = telemetry.pop(key)
    return PartitionResult(
        spec=spec,
        graph=graph,
        assignment=assignment,
        timings=timings,
        telemetry=telemetry,
        edge_partition=edge_partition,
    )
