"""``PartitionSpec``: a frozen, JSON-round-trippable partitioning request.

A spec fully determines a partitioning run (algorithm, K, balance condition,
stream order, seed, per-algorithm knobs) and is validated at construction
against the declarative registry, so an invalid request fails *before* any
graph is streamed. ``PartitionSpec.from_json(spec.to_json()) == spec`` holds
for every registered algorithm - specs are the serializable unit for sweeps,
restream chains, and the headless CLI.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.api.registry import PartitionerInfo, get_info

__all__ = ["PartitionSpec", "STREAM_ORDERS"]

STREAM_ORDERS = ("natural", "random", "bfs", "dfs")
_BALANCE_MODES = ("vertex", "edge")
# buffer-eviction strategies (mirrors repro.core.priority.BUFFER_STRATEGIES;
# duplicated literally so the registry layer stays import-cycle-free - the
# two tuples are pinned equal in tests/test_priority.py). cuttana-buffcut is
# *defined* as the prioritized variant (eq6 spells algo="cuttana"), and the
# preserved seed loop only implements Eq. 6.
_BUFFER_STRATEGIES = ("eq6", "completeness", "gain")
_STRATEGY_CHOICES = {
    "cuttana-buffcut": ("completeness", "gain"),
    "cuttana-legacy": ("eq6",),
}


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """Declarative request: ``partition(graph, spec) -> PartitionResult``.

    ``params`` may be given as the algorithm's typed params dataclass, a
    plain dict of its fields, or None (defaults); it is normalized to the
    typed block at construction so equality and JSON round-trips are exact.
    """

    algo: str
    k: int
    epsilon: float = 0.05
    balance_mode: str = "edge"
    order: str = "natural"
    seed: int = 0
    params: Any = None
    # where the graph comes from when the caller does not pass one:
    # "rmat:<n>[:<avg_degree>]", "dataset:<name>", or a path to an on-disk
    # graph (".bin" external CSR partitioned out-of-core, ".npz" CSRGraph
    # dump). None means the caller supplies the graph object.
    source: str | None = None
    # serving-layer knob (consumed by PartitionResult.serve(), applicable to
    # every algorithm): boundary-vertex replica budget - a value in (0, 1)
    # is a fraction of |V| (vertex, partition) replica pairs, >= 1 an
    # absolute pair count, 0 disables replication.
    replication_budget: float = 0.0

    def __post_init__(self) -> None:
        info = get_info(self.algo)
        if not isinstance(self.k, int) or isinstance(self.k, bool) or self.k < 1:
            raise ValueError(f"k must be a positive integer, got {self.k!r}")
        if (
            not isinstance(self.epsilon, (int, float))
            or isinstance(self.epsilon, bool)
            or self.epsilon < 0
        ):
            raise ValueError(f"epsilon must be a number >= 0, got {self.epsilon!r}")
        if not isinstance(self.seed, int) or isinstance(self.seed, bool):
            raise ValueError(f"seed must be an integer, got {self.seed!r}")
        if self.balance_mode not in _BALANCE_MODES:
            raise ValueError(
                f"unknown balance_mode {self.balance_mode!r}; "
                f"expected one of {_BALANCE_MODES}"
            )
        if info.balance_modes and self.balance_mode not in info.balance_modes:
            raise ValueError(
                f"{self.algo!r} supports balance modes {info.balance_modes}, "
                f"got {self.balance_mode!r}"
            )
        if self.order not in STREAM_ORDERS:
            raise ValueError(
                f"unknown stream order {self.order!r}; expected one of "
                f"{STREAM_ORDERS}"
            )
        # a knob the algorithm does not consume must stay at its default -
        # otherwise two different specs would silently produce the same run
        # (seed is exempt: "may not matter" is its understood contract)
        for name in ("epsilon", "balance_mode", "order"):
            applicable = name in info.common or (
                name == "balance_mode" and bool(info.balance_modes)
            )
            if not applicable:
                default = type(self).__dataclass_fields__[name].default
                if getattr(self, name) != default:
                    raise ValueError(
                        f"{self.algo!r} does not use {name!r} "
                        f"(accepted spec fields: {info.common or ('none',)}); "
                        f"leave it at its default {default!r}"
                    )
        if (
            not isinstance(self.replication_budget, (int, float))
            or isinstance(self.replication_budget, bool)
            or self.replication_budget < 0
        ):
            raise ValueError(
                f"replication_budget must be a number >= 0, "
                f"got {self.replication_budget!r}"
            )
        if self.source is not None:
            # syntax-only validation (no filesystem I/O): a malformed source
            # fails at construction, a missing file fails at load time
            from repro.graph.external import validate_source

            validate_source(self.source)
        object.__setattr__(self, "params", _normalize_params(info, self.params))

    # ------------------------------------------------------------ properties
    @property
    def info(self) -> PartitionerInfo:
        return get_info(self.algo)

    # --------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        d = {
            "algo": self.algo,
            "k": self.k,
            "epsilon": self.epsilon,
            "balance_mode": self.balance_mode,
            "order": self.order,
            "seed": self.seed,
        }
        if self.source is not None:
            d["source"] = self.source
        if self.replication_budget != 0:
            d["replication_budget"] = self.replication_budget
        if self.params is not None:
            d["params"] = dataclasses.asdict(self.params)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "PartitionSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown PartitionSpec fields {sorted(unknown)}; "
                f"expected a subset of {sorted(known)}"
            )
        if "algo" not in d or "k" not in d:
            raise ValueError("PartitionSpec requires at least 'algo' and 'k'")
        return cls(**d)

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "PartitionSpec":
        d = json.loads(s)
        if not isinstance(d, dict):
            raise ValueError("PartitionSpec JSON must be an object")
        return cls.from_dict(d)

    def replace(self, **changes) -> "PartitionSpec":
        return dataclasses.replace(self, **changes)


def _normalize_params(info: PartitionerInfo, params: Any):
    cls = info.params_cls
    if cls is None:
        if params is None or params == {}:
            return None
        raise ValueError(f"{info.name!r} takes no per-algorithm params")
    if params is None:
        return cls()
    if isinstance(params, cls):
        return _check_param_types(info, params)
    if isinstance(params, dict):
        valid = {f.name for f in dataclasses.fields(cls)}
        unknown = set(params) - valid
        if unknown:
            raise ValueError(
                f"unknown {info.name!r} params {sorted(unknown)}; "
                f"valid fields: {sorted(valid)}"
            )
        if params.get("num_shards") == "auto":
            # spec sugar for the auto-tuned shard count; 0 is the canonical
            # (JSON-round-trippable, type-checked) encoding
            params = {**params, "num_shards": 0}
        return _check_param_types(info, cls(**params))
    raise ValueError(
        f"params for {info.name!r} must be a dict or {cls.__name__}, "
        f"got {type(params).__name__}"
    )


# field annotations in the params blocks (all from-__future__ strings)
_FIELD_TYPES = {
    "int": int,
    "float": (int, float),
    "bool": bool,
    "str": str,
}


def _check_param_types(info: PartitionerInfo, block: Any):
    """Field-by-field value typing, so a bad spec (e.g. ``d_max: "big"`` in a
    hand-edited JSON) fails at construction, not mid-stream."""
    for field in dataclasses.fields(block):
        value = getattr(block, field.name)
        ann = field.type
        allow_none = "None" in ann
        if value is None:
            if allow_none:
                continue
            raise ValueError(
                f"{info.name!r} param {field.name!r} must be {ann}, got None"
            )
        expected = _FIELD_TYPES.get(ann.split(" |")[0].strip())
        if expected is None:  # unmapped annotation: leave it to the callee
            continue
        ok = isinstance(value, expected)
        if expected is not bool and isinstance(value, bool):
            ok = False  # bool passes isinstance(int) but is never a knob value
        if not ok:
            raise ValueError(
                f"{info.name!r} param {field.name!r} must be {ann}, "
                f"got {type(value).__name__} {value!r}"
            )
        if field.name == "num_shards" and value < 0:
            # 0 (spec sugar: "auto") resolves through the tuning artifact at
            # run time; anything negative is always a caller error - fail at
            # spec construction, not mid-stream
            raise ValueError(
                f"{info.name!r} param 'num_shards' must be >= 1, "
                f"or 0/'auto' for the tuned shard count, got {value!r}"
            )
        if field.name == "max_workers" and value < 0:
            raise ValueError(
                f"{info.name!r} param 'max_workers' must be >= 0 "
                f"(0 = one thread per shard up to cpu_count), got {value!r}"
            )
        if field.name == "chunk":
            auto_ok = info.name in ("cuttana-parallel", "fennel-parallel")
            if value < (0 if auto_ok else 1):
                hint = " or 0 for the tuned chunk size" if auto_ok else ""
                raise ValueError(
                    f"{info.name!r} param 'chunk' must be >= 1{hint}, "
                    f"got {value!r}"
                )
        if field.name == "prefetch" and value not in ("auto", "on", "off"):
            raise ValueError(
                f"{info.name!r} param 'prefetch' must be one of "
                f"'auto', 'on', 'off', got {value!r}"
            )
        if field.name == "strategy":
            allowed = _STRATEGY_CHOICES.get(info.name, _BUFFER_STRATEGIES)
            if value not in allowed:
                raise ValueError(
                    f"{info.name!r} param 'strategy' must be one of "
                    f"{allowed}, got {value!r}"
                )
        if field.name == "num_batches" and value < 1:
            raise ValueError(
                f"{info.name!r} param 'num_batches' must be >= 1, got {value!r}"
            )
        if field.name == "drift_threshold" and value < 0:
            raise ValueError(
                f"{info.name!r} param 'drift_threshold' must be >= 0, "
                f"got {value!r}"
            )
        if field.name == "window_frac" and not (0 < value <= 1):
            raise ValueError(
                f"{info.name!r} param 'window_frac' must be in (0, 1], "
                f"got {value!r}"
            )
        if field.name == "hub_degree" and value < 2:
            raise ValueError(
                f"{info.name!r} param 'hub_degree' must be >= 2, got {value!r}"
            )
        if field.name == "cluster_cap_frac" and not (0 < value <= 1):
            raise ValueError(
                f"{info.name!r} param 'cluster_cap_frac' must be in (0, 1], "
                f"got {value!r}"
            )
    return block
