"""Headless spec runner:

    python -m repro.api.cli partition --spec spec.json --out report.json \\
        [--dataset social-s | --rmat 20000 | --graph graph.bin] \\
        [--with-analytics] [--with-db]
    python -m repro.api.cli serve-bench --spec spec.json --rmat 20000 \\
        --queries 5000 --concurrency 1000 [--replication-budget 0.05]
    python -m repro.api.cli update --spec spec.json --churn stream.npz \\
        [--prior-graph g.npz --prior-assignment part.npy]
    python -m repro.api.cli list

``partition`` loads a :class:`~repro.api.spec.PartitionSpec` from JSON, runs
it on the requested graph (a named benchmark dataset, a seeded R-MAT, or an
on-disk graph file partitioned out-of-core via ``--graph`` - convert an edge
list with ``scripts/convert_graph.py`` first; the spec's own ``source`` field
is used when no graph flag is given), and
emits a structured report (spec, timings, telemetry, quality metrics, and
optionally the analytics cost model / DB workload numbers). ``serve-bench``
additionally stands up the partition-aware serving layer
(:mod:`repro.serve.graph`) and drives a concurrent mixed query load through
it, reporting throughput, p50/p95/p99 latency, and RPC/byte counts from the
router's real message flow. ``update`` replays a saved
:class:`~repro.graph.churn.ChurnStream` through the incremental partitioner
(:mod:`repro.core.incremental`), optionally warm-starting from a prior
snapshot + assignment, and reports the churn telemetry (batches, re-stream
windows, moved vertices, drift trajectory). ``list`` prints the declarative
registry.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api.cli", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("partition", help="run a PartitionSpec JSON headlessly")
    p.add_argument("--spec", required=True, help="path to a PartitionSpec JSON file")
    p.add_argument("--out", default=None,
                   help="write the JSON report here (default: stdout)")
    g = p.add_mutually_exclusive_group()
    g.add_argument("--dataset", default=None,
                   help="named benchmark dataset (e.g. social-s, ldbc-s)")
    g.add_argument("--rmat", type=int, default=None, metavar="N",
                   help="generate an N-vertex R-MAT graph instead")
    g.add_argument("--graph", default=None, metavar="PATH",
                   help="partition an on-disk graph file: a .bin external "
                        "CSR (memory-mapped, out-of-core) or a .npz "
                        "CSRGraph dump")
    p.add_argument("--avg-degree", type=float, default=16.0,
                   help="R-MAT average degree (with --rmat)")
    p.add_argument("--graph-seed", type=int, default=0,
                   help="generator seed for --dataset/--rmat")
    p.add_argument("--assignment-out", default=None,
                   help="also save the raw assignment as .npy")
    p.add_argument("--prefetch", choices=("auto", "on", "off"), default=None,
                   help="override the spec's out-of-core decode-ahead mode "
                        "(auto = only for memory-mapped graphs); never "
                        "changes assignments")
    p.add_argument("--skip-quality", action="store_true",
                   help="omit quality metrics from the report (they scan "
                        "the whole edge set - skip for graphs that "
                        "deliberately exceed RAM)")
    p.add_argument("--with-analytics", action="store_true",
                   help="include the analytics cost model in the report")
    p.add_argument("--analytics-iters", type=int, default=30)
    p.add_argument("--with-db", action="store_true",
                   help="include the DB workload study in the report")
    p.add_argument("--db-queries", type=int, default=256)

    s = sub.add_parser(
        "serve-bench",
        help="partition, stand up the serving layer, drive a query load",
    )
    s.add_argument("--spec", required=True, help="path to a PartitionSpec JSON file")
    s.add_argument("--out", default=None,
                   help="write the JSON report here (default: stdout)")
    g = s.add_mutually_exclusive_group()
    g.add_argument("--dataset", default=None,
                   help="named benchmark dataset (e.g. social-s, ldbc-s)")
    g.add_argument("--rmat", type=int, default=None, metavar="N",
                   help="generate an N-vertex R-MAT graph instead")
    g.add_argument("--graph", default=None, metavar="PATH",
                   help="serve an on-disk graph file (.bin external CSR or "
                        ".npz CSRGraph dump)")
    s.add_argument("--avg-degree", type=float, default=16.0,
                   help="R-MAT average degree (with --rmat)")
    s.add_argument("--graph-seed", type=int, default=0,
                   help="generator seed for --dataset/--rmat")
    s.add_argument("--queries", type=int, default=1000,
                   help="number of queries in the load run")
    s.add_argument("--concurrency", type=int, default=256,
                   help="closed-loop in-flight query slots")
    s.add_argument("--mix", default=None, metavar="SPEC",
                   help='query mix, e.g. "point=0.2,one_hop=0.4,two_hop=0.4"')
    s.add_argument("--mode", choices=("closed", "open"), default="closed",
                   help="arrival discipline of the load generator")
    s.add_argument("--rate", type=float, default=None, metavar="QPS",
                   help="open-loop arrival rate (with --mode open)")
    s.add_argument("--load-seed", type=int, default=0,
                   help="workload generator seed")
    s.add_argument("--replication-budget", type=float, default=None,
                   help="override the spec's boundary-replication budget")
    s.add_argument("--max-workers", type=int, default=0,
                   help="serving worker threads (0 = auto, one per "
                        "partition up to cpu_count)")

    u = sub.add_parser(
        "update",
        help="incrementally update a prior partition with edge-arrival "
             "batches (algo must be cuttana-incremental)",
    )
    u.add_argument("--spec", required=True,
                   help="path to a cuttana-incremental PartitionSpec JSON")
    u.add_argument("--churn", required=True, metavar="PATH",
                   help="ChurnStream .npz (repro.graph.churn) to replay")
    u.add_argument("--prior-graph", default=None, metavar="PATH",
                   help=".npz CSRGraph snapshot to warm-start from "
                        "(cold start when omitted)")
    u.add_argument("--prior-assignment", default=None, metavar="PATH",
                   help=".npy prior assignment (requires --prior-graph)")
    u.add_argument("--num-batches", type=int, default=None,
                   help="override the spec's replay batch count")
    u.add_argument("--out", default=None,
                   help="write the JSON report here (default: stdout)")
    u.add_argument("--assignment-out", default=None,
                   help="also save the updated assignment as .npy")

    sub.add_parser("list", help="list the partitioner registry")
    return ap


def _load_graph(args, spec):
    if args.graph is not None:
        # file-only, as the help text promises: generator sources belong in
        # the spec's own `source` field
        from repro.graph.external import load_graph_file

        return load_graph_file(args.graph), args.graph
    if args.rmat is not None:
        from repro.graph.generators import rmat_graph

        return rmat_graph(
            args.rmat, avg_degree=args.avg_degree, seed=args.graph_seed
        ), f"rmat:{args.rmat}"
    if args.dataset is None and spec.source is not None:
        # no graph flags: fall back to the spec's own source, resolved with
        # spec.seed exactly like repro.api.partition(spec) - the same spec
        # JSON must mean the same graph through either entry point
        from repro.graph.external import load_graph_source

        return load_graph_source(spec.source, seed=spec.seed), spec.source
    from repro.graph.generators import DATASETS, load_dataset

    name = args.dataset or "social-s"
    if name not in DATASETS:
        raise SystemExit(
            f"unknown dataset {name!r}; available: {', '.join(sorted(DATASETS))}"
        )
    return load_dataset(name, seed=args.graph_seed), name


def _cmd_partition(args) -> int:
    import dataclasses

    from repro.api import PartitionSpec, partition

    spec_text = Path(args.spec).read_text()
    spec = PartitionSpec.from_json(spec_text)
    if args.prefetch is not None:
        params = spec.params
        fields = (
            {f.name for f in dataclasses.fields(params)}
            if params is not None
            else set()
        )
        if "prefetch" not in fields:
            raise SystemExit(
                f"{spec.algo!r} does not accept a prefetch knob"
            )
        spec = spec.replace(
            params=dataclasses.replace(params, prefetch=args.prefetch)
        )
    graph, graph_name = _load_graph(args, spec)
    result = partition(graph, spec)
    report = result.to_report(include_quality=not args.skip_quality)
    report["graph"]["name"] = graph_name
    if args.with_analytics:
        report["analytics"] = result.analytics(
            iters=args.analytics_iters, mode="model"
        )
    if args.with_db:
        report["db"] = {
            "one_hop": result.db(hops=1, num_queries=args.db_queries),
            "two_hop": result.db(hops=2, num_queries=args.db_queries),
        }
    if args.assignment_out:
        import numpy as np

        # np.save appends .npy when missing; record the path it actually used
        path = args.assignment_out
        if not path.endswith(".npy"):
            path += ".npy"
        np.save(path, result.assignment)
        report["assignment_path"] = path
    text = json.dumps(report, indent=2, sort_keys=True)
    if args.out:
        Path(args.out).write_text(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    return 0


def _cmd_update(args) -> int:
    import dataclasses

    import numpy as np

    from repro.api import PartitionSpec
    from repro.core.incremental import update
    from repro.graph.churn import ChurnStream
    from repro.graph.csr import CSRGraph

    spec = PartitionSpec.from_json(Path(args.spec).read_text())
    if spec.algo != "cuttana-incremental":
        raise SystemExit(
            f"update needs a cuttana-incremental spec, got {spec.algo!r}"
        )
    stream = ChurnStream.load(args.churn)
    prior = None
    if args.prior_assignment is not None and args.prior_graph is None:
        raise SystemExit("--prior-assignment requires --prior-graph")
    if args.prior_graph is not None:
        if args.prior_assignment is None:
            raise SystemExit("--prior-graph requires --prior-assignment")
        prior = (
            CSRGraph.load(args.prior_graph),
            np.load(args.prior_assignment),
        )
    knobs = dataclasses.asdict(spec.params)
    if args.num_batches is not None:
        knobs["num_batches"] = args.num_batches
    result = update(
        prior,
        stream,
        k=spec.k,
        epsilon=spec.epsilon,
        balance_mode=spec.balance_mode,
        seed=spec.seed,
        **knobs,
    )
    report = result.to_report()
    report["graph"]["name"] = args.churn
    report["churn"] = {
        "num_edges": stream.num_edges,
        "num_vertices": stream.num_vertices,
        "warm_start": prior is not None,
    }
    if args.assignment_out:
        path = args.assignment_out
        if not path.endswith(".npy"):
            path += ".npy"
        np.save(path, result.assignment)
        report["assignment_path"] = path
    text = json.dumps(report, indent=2, sort_keys=True)
    if args.out:
        Path(args.out).write_text(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    return 0


def _cmd_list() -> int:
    from repro.api import REGISTRY

    header = f"{'name':<24}{'kind':<12}{'placement':<11}{'engine':<8}{'balance':<14}params"
    print(header)
    print("-" * len(header))
    for name in sorted(REGISTRY):
        info = REGISTRY[name]
        balance = ",".join(info.balance_modes) or "-"
        params = ",".join(info.param_names()) or "-"
        print(
            f"{name:<24}{info.kind:<12}{info.placement:<11}"
            f"{info.engine:<8}{balance:<14}{params}"
        )
    return 0


def _cmd_serve_bench(args) -> int:
    from repro.api import PartitionSpec, partition

    spec = PartitionSpec.from_json(Path(args.spec).read_text())
    graph, graph_name = _load_graph(args, spec)
    result = partition(graph, spec)
    report = {
        "spec": spec.to_dict(),
        "graph": {
            "name": graph_name,
            "num_vertices": int(graph.num_vertices),
            "num_edges": int(graph.num_edges),
        },
        "serving": result.serve_bench(
            num_queries=args.queries,
            concurrency=args.concurrency,
            mix=args.mix,
            seed=args.load_seed,
            mode=args.mode,
            rate_qps=args.rate,
            replication_budget=args.replication_budget,
            max_workers=args.max_workers,
        ),
    }
    text = json.dumps(report, indent=2, sort_keys=True)
    if args.out:
        Path(args.out).write_text(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    return 0


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    if args.cmd == "list":
        return _cmd_list()
    if args.cmd == "serve-bench":
        return _cmd_serve_bench(args)
    if args.cmd == "update":
        return _cmd_update(args)
    return _cmd_partition(args)


if __name__ == "__main__":
    raise SystemExit(main())
