"""Declarative partitioner registry: the single source of truth for the zoo.

Each algorithm is described by a :class:`PartitionerInfo` entry instead of a
bare ``name -> callable`` dict: what it cuts (``kind``), how it places
vertices (``placement``), whether it routes through the batched
:class:`~repro.core.engine.StreamEngine` or is a preserved seed loop
(``engine``), which balance conditions it honours, and a *typed* params block
(a frozen dataclass) holding its per-algorithm knobs. ``PartitionSpec``
validates against these entries at construction, and
:func:`repro.api.partition` uses them to drive any algorithm uniformly.

This module is intentionally dependency-free (callables are referenced as
``"module:attr"`` strings and resolved lazily) so it can be imported from
``repro.core`` without cycles.
"""
from __future__ import annotations

import dataclasses
import difflib
import importlib
from typing import Any, Callable

__all__ = [
    "PartitionerInfo",
    "REGISTRY",
    "register",
    "get_info",
    "list_algorithms",
    "unknown_algorithm_error",
    "FennelAlgoParams",
    "LDGAlgoParams",
    "CuttanaAlgoParams",
    "CuttanaBuffcutAlgoParams",
    "CuttanaParallelAlgoParams",
    "FennelParallelAlgoParams",
    "CuttanaBatchedAlgoParams",
    "HeiStreamAlgoParams",
    "RestreamAlgoParams",
    "IncrementalAlgoParams",
    "HDRFAlgoParams",
    "ClusterAlgoParams",
]

# common spec fields a partitioner accepts as keyword arguments
_STREAM_COMMON = ("epsilon", "balance_mode", "order", "seed")


# ------------------------------------------------------- typed params blocks
@dataclasses.dataclass(frozen=True)
class FennelAlgoParams:
    """FENNEL knobs (paper Eq. 7). ``hybrid`` only bites in edge mode.
    ``prefetch`` ("auto"/"on"/"off") controls the out-of-core decode-ahead
    pipeline; it never changes assignments."""

    gamma: float = 1.5
    alpha_scale: float = 1.0
    hybrid: bool = True
    chunk: int = 512
    prefetch: str = "auto"


@dataclasses.dataclass(frozen=True)
class LDGAlgoParams:
    chunk: int = 512


@dataclasses.dataclass(frozen=True)
class CuttanaAlgoParams:
    """CUTTANA Algorithm 1 + phase-2 knobs (paper §III). ``strategy``
    selects the buffer-eviction priority (:mod:`repro.core.priority`);
    ``"eq6"`` is the paper's Eq. 6."""

    d_max: int = 1000
    max_qsize: int | None = None
    theta: float = 1.0
    subparts_per_partition: int | None = None
    use_buffer: bool = True
    use_refinement: bool = True
    thresh: float = 0.0
    max_moves: int | None = None
    chunk: int = 512
    prefetch: str = "auto"
    strategy: str = "eq6"


@dataclasses.dataclass(frozen=True)
class CuttanaBuffcutAlgoParams:
    """BuffCut-style prioritized buffered streaming: CUTTANA's engine with a
    non-Eq.-6 eviction priority (``"gain"`` delayed-decision margin scoring
    or ``"completeness"`` neighbourhood-completeness; ``"eq6"`` is rejected -
    that spec spells ``algo="cuttana"``)."""

    d_max: int = 1000
    strategy: str = "gain"
    max_qsize: int | None = None
    theta: float = 1.0
    subparts_per_partition: int | None = None
    use_refinement: bool = True
    thresh: float = 0.0
    max_moves: int | None = None
    chunk: int = 512
    prefetch: str = "auto"


@dataclasses.dataclass(frozen=True)
class ClusterAlgoParams:
    """Streaming-clustering coarsening prepass (:mod:`repro.core.cluster`)
    around an engine base partitioner: ``hub_degree`` keeps hubs as
    singleton supervertices, ``cluster_cap_frac`` bounds each cluster to a
    fraction of one partition's mass."""

    hub_degree: int = 1000
    cluster_cap_frac: float = 0.1
    use_refinement: bool = True
    thresh: float = 0.0
    subparts_per_partition: int | None = None
    chunk: int = 512


@dataclasses.dataclass(frozen=True)
class CuttanaParallelAlgoParams:
    """Shard-parallel CUTTANA (paper §V): ``num_shards`` interleaved shard
    cursors with bulk-synchronous supersteps around the Algorithm 1 knobs.

    ``num_shards=0`` (or the spec string ``"auto"``) and ``chunk=0`` resolve
    through the auto-tuner (:mod:`repro.core.autotune`); ``max_workers`` is
    the shard-task thread count (0 = auto, ``min(num_shards, cpu_count)``) -
    it changes wall-clock only, never assignments."""

    num_shards: int = 4
    d_max: int = 1000
    max_qsize: int | None = None
    theta: float = 1.0
    subparts_per_partition: int | None = None
    use_refinement: bool = True
    thresh: float = 0.0
    max_moves: int | None = None
    chunk: int = 512
    max_workers: int = 0
    prefetch: str = "auto"
    strategy: str = "eq6"


@dataclasses.dataclass(frozen=True)
class FennelParallelAlgoParams:
    """Bulk-synchronous parallel FENNEL: ``num_shards`` shard frontiers.
    ``num_shards=0``/``"auto"`` and ``chunk=0`` auto-tune; ``max_workers=0``
    means auto."""

    num_shards: int = 4
    gamma: float = 1.5
    alpha_scale: float = 1.0
    hybrid: bool = True
    chunk: int = 512
    max_workers: int = 0
    prefetch: str = "auto"


@dataclasses.dataclass(frozen=True)
class CuttanaBatchedAlgoParams:
    """Chunk-parallel variant: stale histograms + degree-capped sampling."""

    chunk: int = 512
    sample_cap: int = 512
    use_refinement: bool = True
    subparts_per_partition: int | None = None
    thresh: float = 0.0


@dataclasses.dataclass(frozen=True)
class HeiStreamAlgoParams:
    batch_size: int = 4096
    fm_passes: int = 3


@dataclasses.dataclass(frozen=True)
class RestreamAlgoParams:
    """Restream knobs. ``num_shards=1`` is the sequential restream;
    ``num_shards>=2`` runs every re-pass through the S-shard superstep core
    (same parallel engine as ``cuttana-parallel``); ``num_shards=0`` auto-
    tunes and ``max_workers`` (0 = auto) sets the shard-task threads."""

    passes: int = 3
    base: str = "cuttana"
    final_refine: bool = True
    chunk: int = 512
    num_shards: int = 1
    max_workers: int = 0


@dataclasses.dataclass(frozen=True)
class IncrementalAlgoParams:
    """Incremental (churn) mode knobs. ``num_batches`` splits the replayed
    arrival stream; a batch whose edge-cut drifts past ``drift_threshold``
    (relative to the last re-stream point) triggers a windowed local
    re-stream over at most ``window_frac`` of the seen vertices.
    ``num_shards=0``/``"auto"`` auto-tunes; ``max_workers`` (0 = auto) never
    changes assignments."""

    num_batches: int = 16
    drift_threshold: float = 0.10
    window_frac: float = 0.25
    num_shards: int = 1
    max_workers: int = 0
    chunk: int = 512


@dataclasses.dataclass(frozen=True)
class HDRFAlgoParams:
    lam: float = 4.0


# ------------------------------------------------------------------- entries
@dataclasses.dataclass(frozen=True)
class PartitionerInfo:
    """One registry entry.

    ``kind``:       "edge-cut" (vertex partitioner) | "vertex-cut" (edge
                    partitioner returning an ``EdgePartition``).
    ``placement``:  "immediate" | "buffered" | "restream" | "static".
    ``engine``:     "engine" (StreamEngine-backed) | "legacy" (preserved seed
                    loop) | "none" (no streaming scoring core).
    ``balance_modes``: balance conditions the algorithm enforces; empty means
                    the spec's ``balance_mode`` is not applicable.
    ``common``:     which of (epsilon, balance_mode, order, seed) the
                    callable accepts.
    ``params_cls``: frozen dataclass of per-algorithm knobs, or None.
    ``forward_exclude``: params-block fields *not* forwarded to the callable
                    (legacy loops predate some engine knobs, e.g. ``chunk``).
    ``fennel_params_fields``: params-block fields packed into a
                    :class:`repro.core.base.FennelParams` passed as
                    ``params=`` (FENNEL's historical calling convention).
    """

    name: str
    entry: str  # "module:attr", resolved lazily
    kind: str
    placement: str
    engine: str
    balance_modes: tuple[str, ...] = ()
    common: tuple[str, ...] = ()
    params_cls: type | None = None
    forward_exclude: tuple[str, ...] = ()
    fennel_params_fields: tuple[str, ...] = ()
    telemetry: bool = False
    description: str = ""

    def resolve(self) -> Callable:
        mod, _, attr = self.entry.partition(":")
        return getattr(importlib.import_module(mod), attr)

    def param_names(self) -> tuple[str, ...]:
        if self.params_cls is None:
            return ()
        return tuple(f.name for f in dataclasses.fields(self.params_cls))


REGISTRY: dict[str, PartitionerInfo] = {}


def register(info: PartitionerInfo) -> PartitionerInfo:
    if info.name in REGISTRY:
        raise ValueError(f"partitioner {info.name!r} already registered")
    REGISTRY[info.name] = info
    return info


def list_algorithms(kind: str | None = None) -> list[str]:
    return sorted(n for n, i in REGISTRY.items() if kind is None or i.kind == kind)


def unknown_algorithm_error(name: str, kind: str | None = None) -> ValueError:
    names = list_algorithms(kind)
    msg = f"unknown partitioner {name!r}; registered: {', '.join(names)}"
    close = difflib.get_close_matches(name, names, n=1)
    if close:
        msg += f". Did you mean {close[0]!r}?"
    return ValueError(msg)


def get_info(name: str, kind: str | None = None) -> PartitionerInfo:
    info = REGISTRY.get(name)
    if info is None:
        raise unknown_algorithm_error(name, kind)
    if kind is not None and info.kind != kind:
        raise ValueError(
            f"partitioner {name!r} is {info.kind}, not {kind} "
            f"(registered {kind} algorithms: {', '.join(list_algorithms(kind))})"
        )
    return info


def _register_all() -> None:
    both = ("vertex", "edge")
    entries = [
        # ---- engine-backed canonical streaming partitioners (edge-cut)
        PartitionerInfo(
            "cuttana", "repro.core.cuttana:partition", "edge-cut", "buffered",
            "engine", both, _STREAM_COMMON, CuttanaAlgoParams, telemetry=True,
            description="CUTTANA: prioritized buffered streaming + coarsened refinement",
        ),
        PartitionerInfo(
            "cuttana-buffcut", "repro.core.cuttana:partition_buffcut", "edge-cut",
            "buffered", "engine", both, _STREAM_COMMON,
            CuttanaBuffcutAlgoParams, telemetry=True,
            description="BuffCut-style prioritized buffered streaming "
                        "(gain/completeness eviction priorities)",
        ),
        PartitionerInfo(
            "cluster+cuttana", "repro.core.cluster:partition_cluster_cuttana",
            "edge-cut", "buffered", "engine", both, _STREAM_COMMON,
            ClusterAlgoParams, telemetry=True,
            description="streaming-clustering coarsening prepass around CUTTANA",
        ),
        PartitionerInfo(
            "cluster+fennel", "repro.core.cluster:partition_cluster_fennel",
            "edge-cut", "immediate", "engine", both, _STREAM_COMMON,
            ClusterAlgoParams, telemetry=True,
            description="streaming-clustering coarsening prepass around FENNEL",
        ),
        PartitionerInfo(
            "cuttana-batched", "repro.core.cuttana_batched:partition_batched",
            "edge-cut", "immediate", "engine", both, _STREAM_COMMON,
            CuttanaBatchedAlgoParams, telemetry=True,
            description="chunk-parallel CUTTANA (stale histograms + sampling)",
        ),
        PartitionerInfo(
            "cuttana-parallel", "repro.core.parallel:partition_parallel",
            "edge-cut", "buffered", "engine", both, _STREAM_COMMON,
            CuttanaParallelAlgoParams, telemetry=True,
            description="shard-parallel CUTTANA (S buffered shard frontiers, "
                        "bulk-synchronous supersteps)",
        ),
        PartitionerInfo(
            "fennel-parallel", "repro.core.parallel:fennel_parallel",
            "edge-cut", "immediate", "engine", both, _STREAM_COMMON,
            FennelParallelAlgoParams,
            fennel_params_fields=("gamma", "alpha_scale", "hybrid"),
            telemetry=True,
            description="bulk-synchronous parallel FENNEL (S shard frontiers)",
        ),
        PartitionerInfo(
            "cuttana-restream", "repro.core.restream:partition_restream",
            "edge-cut", "restream", "engine", both, _STREAM_COMMON,
            RestreamAlgoParams, telemetry=True,
            description="restreaming with CUTTANA as the core partitioner",
        ),
        PartitionerInfo(
            "cuttana-incremental",
            "repro.core.incremental:partition_incremental",
            "edge-cut", "restream", "engine", both, _STREAM_COMMON,
            IncrementalAlgoParams, telemetry=True,
            description="incremental partitioning under churn: live-load "
                        "streaming placement + drift-triggered windowed "
                        "re-streams",
        ),
        PartitionerInfo(
            "fennel", "repro.core.fennel:partition", "edge-cut", "immediate",
            "engine", both, _STREAM_COMMON, FennelAlgoParams,
            fennel_params_fields=("gamma", "alpha_scale", "hybrid"),
            telemetry=True,
            description="FENNEL streaming partitioner (Eq. 7 baseline)",
        ),
        PartitionerInfo(
            "ldg", "repro.core.ldg:partition", "edge-cut", "immediate",
            "engine", both, _STREAM_COMMON, LDGAlgoParams, telemetry=True,
            description="Linear Deterministic Greedy",
        ),
        PartitionerInfo(
            "heistream", "repro.core.heistream_like:partition", "edge-cut",
            "buffered", "engine", both, _STREAM_COMMON, HeiStreamAlgoParams,
            telemetry=True,
            description="HeiStream-like buffered batch streaming + FM refinement",
        ),
        # ---- trivial baselines
        PartitionerInfo(
            "random", "repro.core.random_hash:partition_random", "edge-cut",
            "static", "none", (), ("seed",),
            description="uniform random assignment",
        ),
        PartitionerInfo(
            "hash", "repro.core.random_hash:partition_hash", "edge-cut",
            "static", "none",
            description="splitmix-style id hash",
        ),
        PartitionerInfo(
            "chunked", "repro.core.random_hash:partition_chunked", "edge-cut",
            "static", "none",
            description="contiguous id ranges (range partitioning)",
        ),
        # ---- preserved seed loops (parity baselines / benchmarks)
        PartitionerInfo(
            "cuttana-legacy", "repro.core.legacy:cuttana_partition", "edge-cut",
            "buffered", "legacy", both, _STREAM_COMMON, CuttanaAlgoParams,
            forward_exclude=("chunk", "prefetch", "strategy"),
            description="seed per-vertex CUTTANA loop",
        ),
        PartitionerInfo(
            "cuttana-batched-legacy", "repro.core.legacy:cuttana_batched_partition",
            "edge-cut", "immediate", "legacy", both, _STREAM_COMMON,
            CuttanaBatchedAlgoParams,
            description="seed chunk-parallel CUTTANA loop",
        ),
        PartitionerInfo(
            "fennel-legacy", "repro.core.legacy:fennel_partition", "edge-cut",
            "immediate", "legacy", both, _STREAM_COMMON, FennelAlgoParams,
            forward_exclude=("chunk", "prefetch"),
            fennel_params_fields=("gamma", "alpha_scale", "hybrid"),
            description="seed per-vertex FENNEL loop",
        ),
        PartitionerInfo(
            "ldg-legacy", "repro.core.legacy:ldg_partition", "edge-cut",
            "immediate", "legacy", both, _STREAM_COMMON,
            description="seed per-vertex LDG loop",
        ),
        PartitionerInfo(
            "heistream-legacy", "repro.core.legacy:heistream_partition",
            "edge-cut", "buffered", "legacy", both, _STREAM_COMMON,
            HeiStreamAlgoParams,
            description="seed HeiStream-like loop",
        ),
        # ---- streaming edge partitioners (vertex-cut)
        PartitionerInfo(
            "hdrf", "repro.core.hdrf:partition_hdrf", "vertex-cut",
            "immediate", "none", (), ("seed",), HDRFAlgoParams,
            description="HDRF vertex-cut edge partitioner",
        ),
        PartitionerInfo(
            "ginger", "repro.core.hdrf:partition_ginger", "vertex-cut",
            "immediate", "none", (), ("seed",),
            description="Ginger-like hybrid-cut edge partitioner",
        ),
    ]
    for e in entries:
        register(e)


_register_all()


def build_spec_kwargs(info: PartitionerInfo, spec: Any) -> dict:
    """Keyword arguments that reproduce ``spec`` through ``info.resolve()``.

    Values equal the callable's own defaults when the params block is
    default-constructed, so a spec run is bit-identical to a bare call.
    """
    kwargs = {name: getattr(spec, name) for name in info.common}
    if spec.params is not None:
        block = dataclasses.asdict(spec.params)
        for name in info.forward_exclude:
            block.pop(name, None)
        if info.fennel_params_fields:
            from repro.core.base import FennelParams

            fp = {f: block.pop(f) for f in info.fennel_params_fields}
            kwargs["params"] = FennelParams(**fp)
        kwargs.update(block)
    return kwargs
