"""``PartitionResult``: one uniform result for every algorithm in the zoo.

Carries the assignment, the spec that produced it, per-phase wall times, and
engine/refinement telemetry. Quality metrics are computed lazily and cached
(``result.quality()``), and the downstream paper pipeline hangs off the
result directly: ``result.analytics(...)`` wraps :mod:`repro.analytics`
(cost model or the real JAX engine) and ``result.db(...)`` wraps
:mod:`repro.db`, so partition -> analytics -> db is three chained calls.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.api.spec import PartitionSpec
from repro.graph.csr import CSRGraph

__all__ = ["PartitionResult", "jsonify"]


@dataclasses.dataclass(eq=False)  # ndarray fields make generated __eq__ raise
class PartitionResult:
    """Result of running a :class:`PartitionSpec` on a graph.

    ``assignment`` is the algorithm's native output: a vertex->partition
    array for edge-cut algorithms, the edge->partition array for vertex-cut
    (edge) partitioners - bit-identical to what the underlying callable
    returns. For vertex-cut results ``edge_partition`` holds the full
    :class:`repro.core.hdrf.EdgePartition` (replicas, masters).
    """

    spec: PartitionSpec
    graph: CSRGraph
    assignment: np.ndarray
    timings: dict = dataclasses.field(default_factory=dict)
    telemetry: dict = dataclasses.field(default_factory=dict)
    edge_partition: Any = None
    _quality: dict | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    # ------------------------------------------------------------ properties
    @property
    def k(self) -> int:
        return self.spec.k

    @property
    def is_vertex_cut(self) -> bool:
        return self.edge_partition is not None

    @property
    def profile(self) -> dict | None:
        """Per-superstep wall-clock profile from the parallel engine
        (``None`` for sequential algorithms): worker count, queue wait, and
        the prep/score/place/exchange/merge phase split, plus up to 64
        per-superstep rows. See :mod:`repro.core.profile`."""
        return self.telemetry.get("profile")

    def vertex_assignment(self) -> np.ndarray:
        """A vertex->partition view usable by analytics/db localization:
        the assignment itself for edge-cut results, replica *masters* for
        vertex-cut results."""
        if self.is_vertex_cut:
            return np.asarray(self.edge_partition.masters)
        return self.assignment

    # --------------------------------------------------------------- quality
    def quality(self) -> dict:
        """Lazily computed + cached quality metrics.

        Edge-cut results: the paper's λ_EC / λ_CV / imbalances
        (:func:`repro.graph.metrics.quality_report`). Vertex-cut results:
        replication factor + edge imbalance (their Table IV columns).
        """
        if self._quality is None:
            if self.is_vertex_cut:
                ep = self.edge_partition
                self._quality = {
                    "kind": "vertex-cut",
                    "k": self.k,
                    "replication_factor": float(ep.replication_factor),
                    "edge_imbalance": float(ep.edge_imbalance()),
                }
            else:
                from repro.graph.metrics import quality_report

                self._quality = {
                    "kind": "edge-cut",
                    **quality_report(self.graph, self.assignment, self.k),
                }
        return self._quality

    # ------------------------------------------------------------- analytics
    def analytics(
        self,
        program: str = "pagerank",
        iters: int = 30,
        mode: str = "model",
    ) -> dict:
        """Run the paper's analytics study on this partition.

        ``mode="model"``: the v5e-pod cost model (works for edge-cut and
        vertex-cut results alike). ``mode="simulated"``: actually run the
        JAX vertex-program engine in simulated-device mode and report
        measured halo traffic (edge-cut results only).
        """
        if mode == "model":
            from repro.analytics import workload_cost

            target = self.edge_partition if self.is_vertex_cut else self.assignment
            return {
                "mode": "model",
                "program": program,
                **workload_cost(self.graph, target, self.k, iters),
            }
        if mode != "simulated":
            raise ValueError(f"unknown analytics mode {mode!r}")
        if self.is_vertex_cut:
            raise ValueError(
                "simulated analytics needs a vertex partition; "
                "vertex-cut results only support mode='model'"
            )
        import time

        from repro.analytics import GraphEngine, PROGRAMS, localize

        if program not in PROGRAMS:
            raise ValueError(
                f"unknown program {program!r}; expected one of "
                f"{sorted(PROGRAMS)}"
            )
        lg = localize(self.graph, self.assignment, self.k)
        eng = GraphEngine(lg, PROGRAMS[program]())
        t0 = time.perf_counter()
        values = eng.run_simulated(iters)
        seconds = time.perf_counter() - t0
        st = eng.stats(iters)
        return {
            "mode": "simulated",
            "program": program,
            "iters": iters,
            "seconds": seconds,
            "values": values,
            "halo_messages_per_iter": st.true_halo_messages_per_iter,
            "padded_halo_elements_per_iter": st.padded_halo_elements_per_iter,
            "max_local_edges": st.max_local_edges,
            "mean_local_edges": st.mean_local_edges,
        }

    # -------------------------------------------------------------------- db
    def db(
        self,
        workload: str = "ldbc",
        hops: int = 2,
        num_queries: int = 256,
        seed: int = 0,
        degree_biased: bool = True,
        concurrency: int = 24,
        seeds: np.ndarray | None = None,
    ) -> dict:
        """Run the graph-DB workload study (paper Table V) on this partition.

        Pass precomputed query ``seeds`` to reuse one mix across several
        calls (e.g. hops=1 and hops=2 on the same result); otherwise a fresh
        degree-biased LDBC-like mix is drawn from ``seed``.
        """
        from repro.db import QueryEngine, ldbc_query_mix

        if workload != "ldbc":
            raise ValueError(f"unknown db workload {workload!r}; expected 'ldbc'")
        if hops not in (1, 2):
            raise ValueError(f"hops must be 1 or 2, got {hops!r}")
        part = self.vertex_assignment()
        engine = QueryEngine(self.graph, part, self.k)
        if seeds is None:
            seeds = ldbc_query_mix(
                self.graph, num_queries, seed=seed, degree_biased=degree_biased
            )
        else:
            num_queries = len(seeds)
        _, stats = engine.one_hop(seeds) if hops == 1 else engine.two_hop(seeds)
        return {
            "workload": workload,
            "hops": hops,
            "num_queries": num_queries,
            "qps": stats.throughput_qps(concurrency),
            "p99_latency_ms": stats.p99_latency_s() * 1e3,
            "mean_latency_ms": float(stats.latencies_s.mean()) * 1e3,
            "total_rpcs": stats.total_rpcs,
            "total_net_values": stats.total_net_values,
            "total_scanned_edges": stats.total_scanned_edges,
        }

    # ---------------------------------------------------------------- serving
    def serve(
        self,
        replication_budget: float | None = None,
        max_workers: int = 0,
        fanout_cap: int = 64,
        store_results: bool = True,
    ):
        """Stand up a partition-aware query service over this partition.

        Returns an (unstarted) :class:`repro.serve.graph.GraphService`; use
        it as a context manager or hand it to
        :func:`repro.serve.graph.run_load`, which starts/stops it around the
        load run. ``replication_budget`` defaults to the spec's own knob.
        """
        from repro.serve.graph import GraphService

        budget = (
            self.spec.replication_budget
            if replication_budget is None
            else replication_budget
        )
        return GraphService(
            self.graph,
            self.vertex_assignment(),
            self.k,
            replication_budget=budget,
            max_workers=max_workers,
            fanout_cap=fanout_cap,
            store_results=store_results,
        )

    def serve_bench(
        self,
        num_queries: int = 1000,
        concurrency: int = 256,
        mix=None,
        seed: int = 0,
        mode: str = "closed",
        rate_qps: float | None = None,
        replication_budget: float | None = None,
        max_workers: int = 0,
        store_results: bool = False,
    ) -> dict:
        """Partition -> serve -> load-gen in one call; returns the serving
        report as a JSON-ready dict (the CLI ``serve-bench`` payload)."""
        from repro.serve.graph import run_load

        report = run_load(
            self.serve(
                replication_budget=replication_budget,
                max_workers=max_workers,
                store_results=store_results,
            ),
            num_queries=num_queries,
            concurrency=concurrency,
            mix=mix,
            seed=seed,
            mode=mode,
            rate_qps=rate_qps,
        )
        return jsonify(report.to_dict())

    # ----------------------------------------------------------------- report
    def to_report(
        self, include_assignment: bool = False, include_quality: bool = True
    ) -> dict:
        """JSON-serializable structured report (the CLI's output row).

        ``include_quality=False`` skips the quality metrics, which scan the
        whole edge set and materialize O(|E|) scratch - the escape hatch for
        out-of-core runs where the graph deliberately exceeds RAM.
        """
        report = {
            "spec": self.spec.to_dict(),
            "graph": {
                "num_vertices": int(self.graph.num_vertices),
                "num_edges": int(self.graph.num_edges),
            },
            "timings": jsonify(self.timings),
            "telemetry": jsonify(self.telemetry),
        }
        if include_quality:
            report["quality"] = jsonify(self.quality())
        if include_assignment:
            report["assignment"] = self.assignment.tolist()
        return report


def jsonify(obj):
    """Recursively convert numpy scalars/arrays for ``json.dumps``.

    Shared by ``PartitionResult.to_report`` and ``benchmarks/run.py --json``.
    """
    if isinstance(obj, dict):
        return {str(key): jsonify(val) for key, val in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonify(val) for val in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    return obj
